use cusz::sz::{self, blocks::SlabSpec, lorenzo};
use cusz::testkit::fields::{make, Regime};
use std::time::Instant;
fn main() {
    let spec = SlabSpec::new("3d_128", &[128,128,128], &[8,8,8]);
    let data = make(Regime::Smooth, spec.len(), 3);
    let n = spec.len();
    let eb = 1e-3f32; let hie = 0.5/eb;
    let mut dq = vec![0i32; n];
    let t = Instant::now();
    for _ in 0..10 { for (o,d) in dq.iter_mut().zip(&data) { *o = sz::prequant(*d, hie); } }
    println!("prequant  {:>8.3} ms", t.elapsed().as_secs_f64()*100.0);
    let mut delta = vec![0i32; n];
    let t = Instant::now();
    for _ in 0..10 { lorenzo::delta_nd(&dq, &spec.shape, &spec.block, &mut delta); }
    println!("delta3d   {:>8.3} ms", t.elapsed().as_secs_f64()*100.0);
    let t = Instant::now();
    let mut hist = vec![0u32; 1024];
    for _ in 0..10 { hist.iter_mut().for_each(|h| *h=0); for &d in &delta { hist[sz::code_of_delta(d, 512) as usize] += 1; } }
    println!("hist      {:>8.3} ms", t.elapsed().as_secs_f64()*100.0);
    let mut codes = vec![0u16; n];
    let t = Instant::now();
    for _ in 0..10 { for (c,&d) in codes.iter_mut().zip(&delta) { *c = sz::code_of_delta(d, 512); } }
    println!("codes     {:>8.3} ms", t.elapsed().as_secs_f64()*100.0);
    let t = Instant::now();
    for _ in 0..10 { let mut acc = delta.clone(); lorenzo::reconstruct_nd(&mut acc, &spec.shape, &spec.block); std::hint::black_box(&acc); }
    println!("recon     {:>8.3} ms", t.elapsed().as_secs_f64()*100.0);
    println!("(per 8.39MB slab, avg of 10)");
}
