//! Quickstart: compress and decompress one field through the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Generates a Nyx-like baryon_density field, compresses it at valrel 1e-4
//! (the paper's default evaluation bound), verifies the error bound, and
//! prints the compression ratio and PSNR.

use anyhow::Result;
use cusz::config::{CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::metrics;

fn main() -> Result<()> {
    // 1. A scientific field (stand-in for loading one from disk).
    let field = datagen::generate(Dataset::Nyx, "baryon_density", 42);
    println!("field {}  dims {:?}  {:.1} MB", field.name, field.dims, field.size_bytes() as f64 / 1e6);

    // 2. Configure: value-range-relative bound of 1e-4, PJRT backend if
    //    artifacts are built, CPU mirror otherwise.
    let cfg = CuszConfig { eb: ErrorBound::ValRel(1e-4), ..Default::default() };
    let coord = Coordinator::new_with_fallback(cfg)?;
    println!("engine: {}", coord.engine_name());

    // 3. Compress.
    let (archive, stats) = coord.compress_with_stats(&field)?;
    println!("\ncompression:\n{}", stats.report());

    // 4. Decompress and verify.
    let restored = coord.decompress(&archive)?;
    let psnr = metrics::psnr(&field.data, &restored.data);
    println!("PSNR {psnr:.2} dB");
    match metrics::verify_error_bound(&field.data, &restored.data, archive.header.abs_eb) {
        None => println!("error bound respected: |d - d*| <= {:.3e}", archive.header.abs_eb),
        Some(i) => anyhow::bail!("bound violated at {i}"),
    }
    Ok(())
}
