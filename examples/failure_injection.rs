//! Failure injection: demonstrate that the framework degrades cleanly —
//! corrupt archives are rejected (CRC), truncated streams error instead of
//! returning silently-wrong data, pathological inputs (NaN/Inf/huge
//! values/constant fields) round-trip, and the CPU fallback engages when
//! artifacts are missing.
//!
//!     cargo run --release --example failure_injection

use anyhow::Result;
use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::field::Field;
use cusz::metrics;
use cusz::util::prng::Rng;

fn check(name: &str, ok: bool) {
    println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
    assert!(ok, "{name}");
}

fn main() -> Result<()> {
    let cfg = CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::Abs(1e-3),
        ..Default::default()
    };
    let coord = Coordinator::new(cfg)?;
    let mut rng = Rng::new(1);
    let mut data: Vec<f32> = (0..65536).map(|_| rng.normal()).collect();

    println!("pathological inputs:");
    // NaN / Inf / huge magnitudes
    data[7] = f32::NAN;
    data[100] = f32::INFINITY;
    data[200] = -3.4e38;
    let field = Field::new("pathological", vec![65536], data.clone())?;
    let archive = coord.compress(&field)?;
    let out = coord.decompress(&archive)?;
    check("NaN round-trips verbatim", out.data[7].is_nan());
    check("Inf round-trips verbatim", out.data[100] == f32::INFINITY);
    check("f32::MIN-scale values round-trip", out.data[200] == -3.4e38);
    check(
        "finite values still within bound",
        metrics::verify_error_bound(&field.data, &out.data, 1e-3).is_none(),
    );

    // constant field (zero range)
    let constant = Field::new("const", vec![4096], vec![2.5f32; 4096])?;
    let a = coord.compress(&constant)?;
    let out = coord.decompress(&a)?;
    check("constant field round-trips", out.data.iter().all(|&v| (v - 2.5).abs() <= 1e-3));
    // a 4096-element field pays 16x slab padding (fixed AOT shapes), yet
    // still shrinks: ~1 bit/symbol over the padded slab + codebook
    check("constant field still shrinks", a.compressed_bytes() < constant.size_bytes());

    println!("corruption detection:");
    let field = Field::new("f", vec![256, 256], (0..65536).map(|i| (i as f32).sin()).collect())?;
    let archive = coord.compress(&field)?;
    let mut bytes = archive.to_bytes();

    // bad magic
    let mut b2 = bytes.clone();
    b2[2] ^= 0xff;
    check("bad magic rejected", Archive::from_bytes(&b2).is_err());

    // bit flip in the body (CRC must catch it)
    let n = bytes.len();
    bytes[n - 10] ^= 0x40;
    check("bit flip detected by CRC", Archive::from_bytes(&bytes).is_err());

    // truncation
    let bytes = archive.to_bytes();
    check("truncated archive rejected", Archive::from_bytes(&bytes[..n / 3]).is_err());

    // corrupt Huffman stream *after* CRC (simulates decoder-level issues):
    // truncate one chunk's bit length so strict inflate notices
    let mut tampered = archive.clone();
    tampered.stream.chunks[0].bits = tampered.stream.chunks[0].bits.saturating_sub(64);
    check("tampered bitstream rejected", coord.decompress(&tampered).is_err());

    // wrong-variant archive (header says a variant that doesn't fit dims)
    let mut wrong = archive.clone();
    wrong.header.variant = "3d_64".into();
    check("variant mismatch rejected", coord.decompress(&wrong).is_err());

    println!("fallback:");
    let missing = CuszConfig {
        backend: BackendKind::Pjrt,
        artifacts_dir: "/nonexistent".into(),
        ..Default::default()
    };
    check("missing artifacts -> clean error", Coordinator::new(missing.clone()).is_err());
    let fb = Coordinator::new_with_fallback(missing)?;
    check("fallback engages CPU engine", fb.engine_name() == "cpu");

    println!("\nall failure-injection checks passed");
    Ok(())
}
