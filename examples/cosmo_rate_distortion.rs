//! Rate-distortion study — regenerates Figures 6, 7, 8 and Table 5:
//! cuSZ (fixed valrel, eb sweep) vs the ZFP-style fixed-rate baseline
//! (rate sweep) on the Hurricane and Nyx datasets.
//!
//!     cargo run --release --example cosmo_rate_distortion -- [--nyx]
//!         [--hurricane] [--overall] [--table5] [--backend cpu]
//!
//! With no selector flags, runs everything. Output is CSV-ish series
//! (bitrate, PSNR) per field — the same series the paper plots.

use anyhow::Result;
use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::metrics;
use cusz::zfp::Zfp;

const EBS: [f64; 6] = [1e-2, 1e-3, 1e-4, 1e-5, 1e-6, 1e-7];
const RATES: [f64; 6] = [2.0, 4.0, 6.0, 8.0, 12.0, 16.0];

#[derive(Clone, Copy)]
struct Point {
    bitrate: f64,
    psnr: f64,
}

fn cusz_curve(coord: &Coordinator, field: &Field) -> Result<Vec<Point>> {
    let mut out = Vec::new();
    for &eb in &EBS {
        let mut cfg = coord.cfg.clone();
        cfg.eb = ErrorBound::ValRel(eb);
        let c = Coordinator::new(cfg)?;
        let (archive, stats) = c.compress_with_stats(field)?;
        let restored = c.decompress(&archive)?;
        out.push(Point {
            bitrate: stats.bitrate(),
            psnr: metrics::psnr(&field.data, &restored.data),
        });
    }
    Ok(out)
}

fn zfp_curve(field: &Field) -> Result<Vec<Point>> {
    let kernel_dims = field.kernel_dims();
    let mut out = Vec::new();
    for &rate in &RATES {
        let z = Zfp::new(rate);
        let stream = z.compress(&field.data, &kernel_dims)?;
        let restored = z.decompress(&stream)?;
        out.push(Point {
            bitrate: 32.0 * stream.compressed_bytes() as f64 / field.size_bytes() as f64,
            psnr: metrics::psnr(&field.data, &restored),
        });
    }
    Ok(out)
}

fn print_curves(title: &str, fields: &[(&str, Vec<Point>, Vec<Point>)]) {
    println!("\n=== {title} ===");
    println!("{:<24} | cusz: (bitrate, PSNR)...  | zfp: (bitrate, PSNR)...", "field");
    for (name, cusz, zfp) in fields {
        let fmt = |pts: &[Point]| {
            pts.iter().map(|p| format!("({:.2},{:.1})", p.bitrate, p.psnr)).collect::<Vec<_>>().join(" ")
        };
        println!("{name:<24} | {} | {}", fmt(cusz), fmt(zfp));
    }
}

/// Bitrate needed to reach `target` PSNR: linear interpolation along the
/// rate-distortion curve (sorted by bitrate), min over crossing segments.
fn bitrate_at_psnr(points: &[Point], target: f64) -> Option<f64> {
    let mut pts: Vec<&Point> = points.iter().collect();
    pts.sort_by(|a, b| a.bitrate.partial_cmp(&b.bitrate).unwrap());
    let mut best: Option<f64> = None;
    for w in pts.windows(2) {
        let (a, b) = (w[0], w[1]);
        let (lo, hi) = if a.psnr <= b.psnr { (a, b) } else { (b, a) };
        if lo.psnr <= target && target <= hi.psnr {
            let t = (target - lo.psnr) / (hi.psnr - lo.psnr).max(1e-9);
            let br = lo.bitrate + t * (hi.bitrate - lo.bitrate);
            best = Some(best.map_or(br, |x: f64| x.min(br)));
        }
    }
    // curve entirely above target: cheapest point already qualifies
    if best.is_none() {
        for p in &pts {
            if p.psnr >= target {
                best = Some(best.map_or(p.bitrate, |x: f64| x.min(p.bitrate)));
            }
        }
    }
    best
}

fn dataset_fields(ds: Dataset, per_ds: usize) -> Vec<Field> {
    ds.field_names().iter().take(per_ds).map(|f| datagen::generate(ds, f, 42)).collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let has = |f: &str| args.iter().any(|a| a == f);
    let all = !(has("--nyx") || has("--hurricane") || has("--overall") || has("--table5"));
    let backend =
        if args.iter().any(|a| a == "cpu") { BackendKind::Cpu } else { BackendKind::Pjrt };
    let cfg = CuszConfig { backend, ..Default::default() };
    let coord = Coordinator::new_with_fallback(cfg)?;
    println!("engine: {}", coord.engine_name());

    let per_ds = 6; // fields per dataset for the per-field figures

    if all || has("--nyx") {
        // Figure 6: per-field curves on Nyx
        let fields = dataset_fields(Dataset::Nyx, per_ds);
        let rows: Vec<(&str, Vec<Point>, Vec<Point>)> = fields
            .iter()
            .map(|f| {
                let name: &str = Box::leak(f.name.clone().into_boxed_str());
                (name, cusz_curve(&coord, f).unwrap(), zfp_curve(f).unwrap())
            })
            .collect();
        print_curves("Figure 6: rate-distortion, Nyx", &rows);
    }

    if all || has("--hurricane") {
        // Figure 7: per-field curves on Hurricane
        let fields = dataset_fields(Dataset::Hurricane, per_ds);
        let rows: Vec<(&str, Vec<Point>, Vec<Point>)> = fields
            .iter()
            .map(|f| {
                let name: &str = Box::leak(f.name.clone().into_boxed_str());
                (name, cusz_curve(&coord, f).unwrap(), zfp_curve(f).unwrap())
            })
            .collect();
        print_curves("Figure 7: rate-distortion, Hurricane", &rows);
    }

    if all || has("--overall") || has("--table5") {
        // Figure 8 + Table 5: dataset-average curves and the bitrate each
        // codec needs for PSNR ~ 85 dB.
        println!("\n=== Figure 8 / Table 5: overall rate-distortion ===");
        println!(
            "{:<12} {:>14} {:>8} {:>10} | {:>14} {:>8} {:>10}",
            "dataset", "cusz bitrate", "CR", "PSNR", "zfp bitrate", "CR", "PSNR"
        );
        for ds in [Dataset::CesmAtm, Dataset::Hurricane, Dataset::Nyx, Dataset::Qmcpack] {
            let fields = dataset_fields(ds, 4);
            // average the curves pointwise across fields
            let mut cusz_avg = vec![Point { bitrate: 0.0, psnr: 0.0 }; EBS.len()];
            let mut zfp_avg = vec![Point { bitrate: 0.0, psnr: 0.0 }; RATES.len()];
            for f in &fields {
                for (a, p) in cusz_avg.iter_mut().zip(cusz_curve(&coord, f)?) {
                    a.bitrate += p.bitrate / fields.len() as f64;
                    a.psnr += p.psnr / fields.len() as f64;
                }
                for (a, p) in zfp_avg.iter_mut().zip(zfp_curve(f)?) {
                    a.bitrate += p.bitrate / fields.len() as f64;
                    a.psnr += p.psnr / fields.len() as f64;
                }
            }
            let target = 85.0;
            let c = bitrate_at_psnr(&cusz_avg, target);
            let z = bitrate_at_psnr(&zfp_avg, target);
            let fmt = |b: Option<f64>, _pts: &[Point]| match b {
                Some(b) => format!("{:>14.2} {:>8.1} {:>10.1}", b, 32.0 / b, target),
                None => format!("{:>14} {:>8} {:>10}", "-", "-", "-"),
            };
            println!("{:<12} {} | {}", ds.name(), fmt(c, &cusz_avg), fmt(z, &zfp_avg));
            if let (Some(c), Some(z)) = (c, z) {
                println!("{:<12}   -> cusz needs {:.2}x lower bitrate at ~85 dB", "", z / c);
            }
        }
    }
    Ok(())
}
