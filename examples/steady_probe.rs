//! Steady-state coordinator timing (3 reps, report last).
use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
fn main() -> anyhow::Result<()> {
    let backend = if std::env::args().any(|a| a == "pjrt") { BackendKind::Pjrt } else { BackendKind::Cpu };
    let coord = Coordinator::new_with_fallback(CuszConfig { backend, eb: ErrorBound::ValRel(1e-4), ..Default::default() })?;
    let field = datagen::generate(Dataset::Nyx, "baryon_density", 42);
    let mut last = None;
    for _ in 0..3 { last = Some(coord.compress_with_stats(&field)?); }
    let (archive, stats) = last.unwrap();
    println!("engine {} COMPRESS:\n{}", coord.engine_name(), stats.report());
    let mut last = None;
    for _ in 0..3 { last = Some(coord.decompress_with_stats(&archive)?); }
    let (_, d) = last.unwrap();
    println!("DECOMPRESS:\n{}", d.timer.report(d.original_bytes));
    Ok(())
}
