//! Batched streaming compression service, end to end:
//!
//! 1. build one shared `Coordinator` (narrow per-job threading);
//! 2. stream a simulated multi-field snapshot through `BatchCompressor`
//!    (bounded worker pipeline with backpressure) into a sharded
//!    `.cuszb` bundle;
//! 3. list the bundle, then random-access a single field — decompress and
//!    verify its error bound without touching sibling payloads.
//!
//! Run: `cargo run --release --example batch_service`

use std::sync::Arc;

use anyhow::Result;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::metrics;
use cusz::serve::{BatchCompressor, BatchConfig};
use cusz::store::Store;

fn main() -> Result<()> {
    // a snapshot: every Hurricane field plus the CESM fields
    let mut snapshot: Vec<Field> = Vec::new();
    for ds in [Dataset::Hurricane, Dataset::CesmAtm] {
        for name in ds.field_names() {
            snapshot.push(datagen::generate(ds, name, 42));
        }
    }
    let total_mb: f64 = snapshot.iter().map(|f| f.size_bytes() as f64).sum::<f64>() / 1e6;
    println!("snapshot: {} fields, {total_mb:.1} MB", snapshot.len());

    let coord = Arc::new(Coordinator::new_with_fallback(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::ValRel(1e-4),
        threads: 2, // per-job; the batch layer supplies job concurrency
        ..Default::default()
    })?);

    let dir = std::env::temp_dir().join(format!("batch-service-{}.cuszb", std::process::id()));
    let mut store = Store::create(&dir, 4)?;
    let batch = BatchCompressor::new(Arc::clone(&coord), BatchConfig::default());
    let verify_name = snapshot[3].name.clone();
    let original = snapshot[3].clone();

    let stats = batch.run_into_store(snapshot, &mut store)?;
    println!("\n--- service ---\n{}", stats.report());

    println!("\n--- bundle ---");
    for e in store.list() {
        println!(
            "  {:<28} shard {}  {:>9} bytes  CR {:>6.1}x",
            e.name,
            e.shard,
            e.len,
            e.compression_ratio()
        );
    }

    // random access: one seek + one read + one decompress
    println!("\n--- random access: {verify_name} ---");
    let archive = store.get(&verify_name)?;
    let restored = coord.decompress(&archive)?;
    let psnr = metrics::psnr(&original.data, &restored.data);
    match metrics::verify_error_bound(&original.data, &restored.data, archive.header.abs_eb) {
        None => println!(
            "  bound {:.3e} RESPECTED, PSNR {psnr:.2} dB, dims {:?}",
            archive.header.abs_eb, restored.dims
        ),
        Some(i) => anyhow::bail!("error bound violated at index {i}"),
    }

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
