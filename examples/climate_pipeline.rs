//! End-to-end driver (DESIGN.md §7): stream a full synthetic snapshot —
//! every field of every SDRBench-like dataset — through the coordinator's
//! bounded-queue pipeline, exactly the "compress data as the simulation
//! produces it" workload that motivates the paper (§1: HACC snapshots,
//! LCLS-II data rates).
//!
//!     cargo run --release --example climate_pipeline [-- --backend cpu]
//!
//! Reports per-field CR/PSNR and the headline aggregate: end-to-end
//! pipeline throughput and overall compression ratio; verifies the error
//! bound on every reconstructed field. Results are recorded in
//! EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::{pipeline, Coordinator};
use cusz::datagen::{self, Dataset};
use cusz::metrics;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = if args.iter().any(|a| a == "cpu") || args.windows(2).any(|w| w[0] == "--backend" && w[1] == "cpu") {
        BackendKind::Cpu
    } else {
        BackendKind::Pjrt
    };
    let cfg = CuszConfig {
        eb: ErrorBound::ValRel(1e-4),
        backend,
        queue_depth: 4,
        ..Default::default()
    };
    let coord = Coordinator::new_with_fallback(cfg)?;
    println!("engine: {}  (streaming snapshot compression)", coord.engine_name());

    // Producer: every field of every dataset, generated on its own thread
    // (standing in for simulation output / instrument acquisition).
    let producer = |push: &dyn Fn(cusz::Field) -> bool| {
        for ds in Dataset::ALL {
            for fname in ds.field_names() {
                let field = datagen::generate(ds, fname, 42);
                if !push(field) {
                    return;
                }
            }
        }
    };

    // Sink: hold archives for verification (a real deployment writes them
    // to the parallel filesystem here).
    let mut archives = Vec::new();
    let report = pipeline::run(&coord, producer, |name, archive| {
        archives.push((name.to_string(), archive));
        Ok(())
    })?;

    println!("\n{:<32} {:>9} {:>9} {:>8} {:>9}", "field", "MB", "CR", "b/v", "PSNR dB");
    let mut violations = 0;
    for (name, archive) in &archives {
        let (ds_name, f_name) = name.split_once('/').unwrap_or(("?", name));
        let ds = Dataset::parse(ds_name).unwrap_or(Dataset::Nyx);
        let original = datagen::generate(ds, f_name, 42);
        let restored = coord.decompress(archive)?;
        let psnr = metrics::psnr(&original.data, &restored.data);
        let cr = original.size_bytes() as f64 / archive.compressed_bytes() as f64;
        if metrics::verify_error_bound(&original.data, &restored.data, archive.header.abs_eb)
            .is_some()
        {
            violations += 1;
        }
        println!(
            "{:<32} {:>9.2} {:>9.2} {:>8.3} {:>9.2}",
            name,
            original.size_bytes() as f64 / 1e6,
            cr,
            32.0 / cr,
            psnr
        );
    }

    println!("\n=== aggregate (headline) ===");
    println!("fields compressed      {}", report.fields);
    println!("original               {:.2} MB", report.original_bytes as f64 / 1e6);
    println!("compressed             {:.2} MB", report.compressed_bytes as f64 / 1e6);
    println!("overall CR             {:.2}x", report.compression_ratio());
    println!("pipeline wall time     {:.2} s", report.wall_seconds);
    println!("end-to-end throughput  {:.3} GB/s", report.throughput_gbps());
    println!("error-bound violations {violations}");
    anyhow::ensure!(violations == 0, "error bound violated");
    Ok(())
}
