//! Perf probe: per-layer timing of the quant engines (used by the
//! EXPERIMENTS.md §Perf iteration log).
//!     cargo run --release --example perf_probe
use cusz::runtime::{ArtifactManifest, CpuEngine, QuantEngine};
use cusz::testkit::fields::{make, Regime};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = ArtifactManifest::load(&dir)?;
    let pjrt = cusz::runtime::pjrt::PjrtEngine::start(manifest.clone())?;
    let cpu = CpuEngine { dict_size: 1024 };
    println!("{:<10} {:>12} {:>14} {:>14} {:>14} {:>14}", "variant", "MB", "pjrt C GB/s", "cpu C GB/s", "pjrt D GB/s", "cpu D GB/s");
    for meta in manifest.executables.iter().filter(|e| e.op == "compress") {
        let spec = meta.slab_spec();
        let data = make(Regime::Smooth, spec.len(), 3);
        let bytes = spec.len() * 4;
        let eb = 1e-3f32;
        // warm (compile)
        let delta = pjrt.compress_slab(&spec, &data, eb)?;
        let reps = 5;
        let t = Instant::now();
        for _ in 0..reps { pjrt.compress_slab(&spec, &data, eb)?; }
        let pc = bytes as f64 * reps as f64 / t.elapsed().as_secs_f64() / 1e9;
        let t = Instant::now();
        for _ in 0..reps { cpu.compress_slab(&spec, &data, eb)?; }
        let cc = bytes as f64 * reps as f64 / t.elapsed().as_secs_f64() / 1e9;
        pjrt.decompress_slab(&spec, &delta, eb)?;
        let t = Instant::now();
        for _ in 0..reps { pjrt.decompress_slab(&spec, &delta, eb)?; }
        let pd = bytes as f64 * reps as f64 / t.elapsed().as_secs_f64() / 1e9;
        let t = Instant::now();
        for _ in 0..reps { cpu.decompress_slab(&spec, &delta, eb)?; }
        let cd = bytes as f64 * reps as f64 / t.elapsed().as_secs_f64() / 1e9;
        println!("{:<10} {:>12.2} {:>14.3} {:>14.3} {:>14.3} {:>14.3}", meta.variant, bytes as f64/1e6, pc, cc, pd, cd);
    }
    Ok(())
}
