//! Table 8: PSNR of cusz-rs vs SZ-1.4 (classic float-space cascade) on all
//! 20 Hurricane fields and all 6 Nyx fields at valrel = 1e-4.
//!
//! Paper shape to reproduce: on zero-dominated fields (CLOUDf48, Q*f48,
//! baryon_density) cuSZ scores notably HIGHER PSNR than SZ-1.4 because
//! PREQUANT represents exact zeros exactly, while SZ-1.4's float-space
//! reconstruction leaves ~uniform error everywhere; on smooth fields and
//! the .log10 variants both sit at the valrel-implied ~84.8 dB.

mod common;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::metrics;
use cusz::util::bench::print_table;

fn main() {
    let coord = Coordinator::new_with_fallback(CuszConfig {
        backend: BackendKind::Pjrt,
        eb: ErrorBound::ValRel(1e-4),
        ..Default::default()
    })
    .unwrap();
    println!("cusz engine: {}", coord.engine_name());

    let mut rows = Vec::new();
    let mut boosted = 0usize;
    let mut tied = 0usize;
    let mut run = |ds: Dataset, fname: &str| {
        let field = datagen::generate(ds, fname, 42);
        let (lo, hi) = field.value_range();
        let eb = (1e-4 * (hi - lo) as f64) as f32;

        // SZ-1.4: classic float-space cascade (global Lorenzo)
        let c = cusz::sz::classic::compress(&field.data, &field.kernel_dims(), eb, 1024);
        let sz14 = cusz::sz::classic::decompress(&c, eb, 1024);
        let psnr_sz = metrics::psnr(&field.data, &sz14);

        // cusz-rs
        let archive = coord.compress(&field).unwrap();
        let out = coord.decompress(&archive).unwrap();
        let psnr_cusz = metrics::psnr(&field.data, &out.data);

        if psnr_cusz > psnr_sz + 1.0 {
            boosted += 1;
        } else if (psnr_cusz - psnr_sz).abs() <= 1.0 {
            tied += 1;
        }
        rows.push(vec![
            field.name.clone(),
            format!("{psnr_sz:.2}"),
            format!("{psnr_cusz:.2}"),
            format!("{:+.2}", psnr_cusz - psnr_sz),
        ]);
    };

    for fname in Dataset::Hurricane.field_names() {
        run(Dataset::Hurricane, fname);
    }
    for fname in Dataset::Nyx.field_names() {
        run(Dataset::Nyx, fname);
    }

    print_table(
        "Table 8: PSNR (dB) cuSZ vs SZ-1.4 at valrel 1e-4",
        &["field", "SZ-1.4", "cusz-rs", "delta"],
        &rows,
    );
    println!(
        "\n{boosted} fields with cuSZ PSNR boost (> +1 dB), {tied} ties — the paper's \
         pattern: boosts on zero/min-dominated fields (CLOUDf48 84.99->94.18, \
         baryon_density 89.71->98.25), ties at ~84.79 on smooth/log fields."
    );
}
