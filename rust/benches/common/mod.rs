//! Shared bench helpers: workload construction mirroring the paper's
//! five datasets, plus environment knobs (CUSZ_BENCH_QUICK=1 shrinks
//! everything for smoke runs).

use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::huffman::{self, CanonicalCodebook};
use cusz::util::bench::Bench;

pub fn bench() -> Bench {
    if quick() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

pub fn quick() -> bool {
    std::env::var("CUSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// The representative field per dataset used by the throughput tables.
pub fn dataset_field(ds: Dataset) -> Field {
    let name = match ds {
        Dataset::Hacc => "vx",
        Dataset::CesmAtm => "CLDHGH",
        Dataset::Hurricane => "CLOUDf48",
        Dataset::Nyx => "baryon_density",
        Dataset::Qmcpack => "einspline",
    };
    datagen::generate(ds, name, 42)
}

/// Quant-code symbol stream + codebook for a field at valrel 1e-4 — the
/// common input of the Huffman benches (Tables 4 and 6).
pub fn symbols_and_book(field: &Field) -> (Vec<u16>, CanonicalCodebook) {
    use cusz::config::{BackendKind, CuszConfig, ErrorBound};
    use cusz::coordinator::Coordinator;
    let coord = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::ValRel(1e-4),
        ..Default::default()
    })
    .unwrap();
    let archive = coord.compress(field).unwrap();
    let lengths = archive.encoder_aux.clone();
    let rev_book = CanonicalCodebook::from_lengths(&lengths).unwrap();
    let rev = huffman::ReverseCodebook::from_lengths(&lengths).unwrap();
    let symbols = huffman::inflate_chunks(&archive.stream, &rev, 8);
    (symbols, rev_book)
}

pub fn gbps(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs.max(1e-12) / 1e9
}
