//! Table 3: breakdown time (ms) of constructing a codebook — building the
//! Huffman tree and deriving the canonical codebook — as the number of
//! quantization bins sweeps 128..8192, on a Hurricane-like histogram.
//!
//! Paper shape to reproduce: both costs grow roughly linearly-to-
//! O(k log k) in the bin count, and the cost is independent of data size
//! (it depends only on the histogram).

mod common;

use cusz::huffman::{self, CanonicalCodebook};
use cusz::util::bench::print_table;

fn hurricane_histogram(bins: usize) -> Vec<u64> {
    // Gaussian-ish code distribution centred on the zero-delta bin, the
    // shape dual-quant produces on Hurricane fields, plus sparse tails so
    // every bin participates (worst case for tree depth).
    let radius = bins as f64 / 2.0;
    (0..bins)
        .map(|i| {
            let z = (i as f64 - radius) / (radius / 40.0);
            1 + (2.0e7 * (-z * z / 2.0).exp()) as u64
        })
        .collect()
}

fn main() {
    let bench = common::bench();
    let mut rows = Vec::new();
    for bins in [128usize, 256, 512, 1024, 2048, 4096, 8192] {
        let freq = hurricane_histogram(bins);
        let mut lengths = Vec::new();
        let t_tree = bench.run(&format!("tree {bins}"), 0, || {
            lengths = huffman::build_lengths(&freq);
        });
        let mut book = None;
        let t_book = bench.run(&format!("codebook {bins}"), 0, || {
            book = Some(CanonicalCodebook::from_lengths(&lengths).unwrap());
        });
        // sanity: codebook really usable
        let book = book.unwrap();
        assert_eq!(book.len.len(), bins);
        rows.push(vec![
            bins.to_string(),
            format!("{:.3}", t_tree.mean.as_secs_f64() * 1e3),
            format!("{:.3}", t_book.mean.as_secs_f64() * 1e3),
            format!("{:.3}", (t_tree.mean + t_book.mean).as_secs_f64() * 1e3),
        ]);
    }
    print_table(
        "Table 3: codebook construction time (ms) vs quantization bins",
        &["#quant bins", "build tree", "get codebook", "total"],
        &rows,
    );
    let t1024: f64 = rows[3][3].parse().unwrap();
    println!(
        "\npaper reference (V100): total 0.68/2.16/4.16/4.81/13.55/27.10/50.71 ms; \
         shape check: monotone growth, 1024-bin total here = {t1024:.3} ms"
    );
}
