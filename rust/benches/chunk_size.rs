//! Table 6: deflate and inflate throughput vs chunk size (2^6..2^16
//! symbols per chunk) on every dataset.
//!
//! Paper shape to reproduce: a clear interior optimum — tiny chunks pay
//! per-chunk overhead (the paper's kernel-launch/thread-count analogue is
//! our task-dispatch overhead), huge chunks starve the workers; and
//! inflate must reuse the deflate-time chunk geometry.

mod common;

use cusz::datagen::Dataset;
use cusz::huffman::{self, ReverseCodebook};
use cusz::util::bench::print_table;

fn main() {
    let bench = common::bench();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let sizes: Vec<usize> = (6..=16).map(|p| 1usize << p).collect();

    for ds in Dataset::ALL {
        let field = common::dataset_field(ds);
        let (symbols, book) = common::symbols_and_book(&field);
        let lengths = book.len.clone();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        let bytes = field.size_bytes();

        let mut rows = Vec::new();
        let mut best = (0usize, 0.0f64, 0.0f64);
        for &cs in &sizes {
            if cs > symbols.len() {
                continue;
            }
            let mut stream = None;
            let rd = bench.run(&format!("{} deflate {cs}", ds.name()), bytes, || {
                stream = Some(huffman::deflate_chunks(&symbols, &book, cs, threads));
            });
            let stream = stream.unwrap();
            let ri = bench.run(&format!("{} inflate {cs}", ds.name()), bytes, || {
                let out = huffman::inflate_chunks(&stream, &rev, threads);
                std::hint::black_box(out.len());
            });
            let nchunks = symbols.len().div_ceil(cs);
            if rd.gbps() + ri.gbps() > best.1 + best.2 {
                best = (cs, rd.gbps(), ri.gbps());
            }
            rows.push(vec![
                format!("2^{}", cs.trailing_zeros()),
                format!("{:.1e}", nchunks as f64),
                format!("{:.3}", rd.gbps()),
                format!("{:.3}", ri.gbps()),
            ]);
        }
        print_table(
            &format!(
                "Table 6 [{}, {:.1} MB]: throughput (GB/s) vs deflate chunk size",
                ds.name(),
                bytes as f64 / 1e6
            ),
            &["chunk size", "#chunks", "deflate", "inflate"],
            &rows,
        );
        println!(
            "optimal chunk {} ({} concurrent tasks): deflate {:.3} GB/s inflate {:.3} GB/s",
            best.0,
            symbols.len().div_ceil(best.0.max(1)),
            best.1,
            best.2
        );
    }
    println!(
        "\npaper reference (V100): optimum at ~2e4 concurrent threads per field; \
         here the optimum tracks ~{threads} cores x task granularity."
    );
}
