//! Batched-pipeline vs sequential-loop throughput over a multi-field
//! snapshot — the serving-shaped face of the paper's Figure 5 tables:
//! does job-level fan-out (serve::BatchCompressor, narrow per-job
//! threading) beat one field at a time with full internal parallelism?
//!
//! CUSZ_BENCH_QUICK=1 shrinks the snapshot for smoke runs.

mod common;

use std::sync::Arc;
use std::time::Instant;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::serve::{BatchCompressor, BatchConfig};
use cusz::store::Store;
use cusz::testkit::tmp_dir;

fn snapshot(quick: bool) -> Vec<Field> {
    let mut fields = Vec::new();
    let specs: &[(Dataset, u64)] = if quick {
        &[(Dataset::CesmAtm, 1), (Dataset::Hurricane, 1)]
    } else {
        &[
            (Dataset::CesmAtm, 1),
            (Dataset::CesmAtm, 2),
            (Dataset::Hurricane, 1),
            (Dataset::Hurricane, 2),
            (Dataset::Nyx, 1),
        ]
    };
    for &(ds, seed) in specs {
        for name in ds.field_names() {
            let mut f = datagen::generate(ds, name, seed);
            f.name = format!("{}@{}", f.name, seed);
            fields.push(f);
        }
    }
    fields
}

fn coordinator(threads: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::ValRel(1e-4),
            threads,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn main() {
    let quick = common::quick();
    let fields = snapshot(quick);
    let total_bytes: usize = fields.iter().map(|f| f.size_bytes()).sum();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!(
        "batch throughput: {} fields, {:.1} MB total, {cores} cores",
        fields.len(),
        total_bytes as f64 / 1e6
    );

    // --- sequential loop: one field at a time, full internal threading --
    let seq_dir = tmp_dir("bench-seq");
    let seq_coord = coordinator(0); // all cores inside each job
    let mut seq_store = Store::create(&seq_dir, 4).unwrap();
    let t0 = Instant::now();
    for f in &fields {
        let archive = seq_coord.compress(f).expect("sequential compress");
        seq_store.add(&archive).expect("sequential store add");
    }
    let seq_secs = t0.elapsed().as_secs_f64();

    // --- batched pipeline: job-level fan-out, narrow per-job threading --
    let batch_dir = tmp_dir("bench-batch");
    let batch_coord = coordinator(1);
    let mut batch_store = Store::create(&batch_dir, 4).unwrap();
    let batch = BatchCompressor::new(
        Arc::clone(&batch_coord),
        BatchConfig { workers: cores, queue_depth: 4, ..Default::default() },
    );
    let stats = batch
        .run_into_store(fields.clone(), &mut batch_store)
        .expect("batched run");
    let batch_secs = stats.wall_seconds;

    assert_eq!(batch_store.len(), seq_store.len());
    let seq_gbps = common::gbps(total_bytes, seq_secs);
    let batch_gbps = common::gbps(total_bytes, batch_secs);
    println!(
        "{:<42} {:>10.3} s  {:>9.3} GB/s",
        "sequential loop (threads=all)", seq_secs, seq_gbps
    );
    println!(
        "{:<42} {:>10.3} s  {:>9.3} GB/s",
        format!("batched pipeline (workers={cores})"),
        batch_secs,
        batch_gbps
    );
    println!(
        "batched vs sequential: {:.2}x  (service CR {:.2}x)",
        batch_gbps / seq_gbps.max(1e-12),
        stats.compression_ratio()
    );

    std::fs::remove_dir_all(&seq_dir).ok();
    std::fs::remove_dir_all(&batch_dir).ok();
}
