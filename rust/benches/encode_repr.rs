//! Table 4: encoding + deflating throughput with the fixed-length
//! codeword representation held as u64 vs u32, per dataset.
//!
//! Paper shape to reproduce: u32 beats u64 by ~1.5x (380 vs 250 GB/s on
//! V100) because the fixed-length encoded array is the bandwidth hog;
//! absolute numbers here are CPU-memory-bandwidth scaled.

mod common;

use cusz::datagen::Dataset;
use cusz::huffman::{deflate, encode};
use cusz::util::bench::print_table;

fn main() {
    let bench = common::bench();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for ds in Dataset::ALL {
        let field = common::dataset_field(ds);
        let (symbols, book) = common::symbols_and_book(&field);
        let bytes = field.size_bytes();

        // u64 representation: encode to packed u64, then deflate from it.
        let r64 = bench.run(&format!("{} enc64", ds.name()), bytes, || {
            let enc = encode::encode_fixed_u64(&symbols, &book, threads);
            let s = deflate::deflate_fixed_u64(&enc, 4096, threads);
            std::hint::black_box(s.total_bits());
        });

        // u32 representation (adaptive selection picks this when max
        // bitwidth fits 24 bits, which holds on all five datasets).
        let can_u32 = book.repr_bits() == 32;
        let r32 = if can_u32 {
            Some(bench.run(&format!("{} enc32", ds.name()), bytes, || {
                let enc = encode::encode_fixed_u32(&symbols, &book, threads);
                let s = deflate::deflate_fixed_u32(&enc, 4096, threads);
                std::hint::black_box(s.total_bits());
            }))
        } else {
            None
        };

        let g64 = r64.gbps();
        let g32 = r32.as_ref().map(|r| r.gbps()).unwrap_or(f64::NAN);
        if can_u32 {
            ratios.push(g32 / g64);
        }
        rows.push(vec![
            ds.name().to_string(),
            format!("{:.1}", r64.mean.as_secs_f64() * 1e6),
            format!("{g64:.3}"),
            r32.as_ref()
                .map(|r| format!("{:.1}", r.mean.as_secs_f64() * 1e6))
                .unwrap_or("-".into()),
            format!("{g32:.3}"),
            format!("{:.2}x", g32 / g64),
        ]);
    }
    print_table(
        "Table 4: encode+deflate, u64 vs u32 codeword representation",
        &["dataset", "enc.64 us", "GB/s", "enc.32 us", "GB/s", "u32/u64"],
        &rows,
    );
    let avg = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    println!(
        "\npaper reference (V100): u32 ~380 GB/s vs u64 ~250 GB/s => 1.51x; \
         measured mean speedup here: {avg:.2}x"
    );
}
