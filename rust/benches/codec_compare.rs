//! Codec comparison: encode/decode throughput and bits/symbol for the two
//! encoder backends (huffman vs fle) across quant-code profiles that span
//! the smoothness spectrum — the measurement behind `--codec auto`'s
//! threshold (and FZ-GPU's throughput-vs-ratio trade, arXiv:2304.12557).
//!
//! Both stages get the histogram for free (the real pipeline computes it
//! during dual-quant either way); Huffman still pays tree + codebook
//! construction inside encode, FLE pays nothing up front. Throughput is
//! reported against original field bytes (4 B/symbol), the paper's
//! convention.

mod common;

use cusz::codec::{self, stage_for, EncodeContext, EncoderKind};
use cusz::config::CodewordRepr;
use cusz::util::bench::print_table;
use cusz::util::prng::Rng;

const DICT: usize = 1024;
const RADIUS: i32 = (DICT / 2) as i32;

struct Profile {
    name: &'static str,
    symbols: Vec<u16>,
}

fn clamp_code(c: i32) -> u16 {
    c.clamp(1, DICT as i32 - 1) as u16
}

fn profiles(n: usize) -> Vec<Profile> {
    let mut rng = Rng::new(2024);
    vec![
        // smooth fields: deltas hug the radius (skewed histogram)
        Profile {
            name: "smooth",
            symbols: (0..n)
                .map(|_| clamp_code(RADIUS + (rng.normal() * 3.0) as i32))
                .collect(),
        },
        // mildly noisy: deltas uniform over ±16 bins
        Profile {
            name: "noisy-mild",
            symbols: (0..n)
                .map(|_| clamp_code(RADIUS - 16 + rng.below(33) as i32))
                .collect(),
        },
        // wide noise: deltas uniform over ±128 bins (near-incompressible)
        Profile {
            name: "noisy-wide",
            symbols: (0..n)
                .map(|_| clamp_code(RADIUS - 128 + rng.below(257) as i32))
                .collect(),
        },
        // spiky noise under a tight bound: most slots are outlier markers
        Profile {
            name: "noisy-spiky",
            symbols: (0..n)
                .map(|_| {
                    if rng.f32() < 0.6 {
                        0
                    } else {
                        clamp_code(RADIUS - 64 + rng.below(129) as i32)
                    }
                })
                .collect(),
        },
    ]
}

fn main() {
    let bench = common::bench();
    let n = if common::quick() { 1 << 19 } else { 1 << 22 };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(8);
    let bytes = n * 4; // original field bytes per symbol (f32)

    let mut rows = Vec::new();
    let mut fle_wins_encode = Vec::new();
    for p in profiles(n) {
        let mut freq = vec![0u64; DICT];
        for &s in &p.symbols {
            freq[s as usize] += 1;
        }
        let ctx = EncodeContext {
            dict_size: DICT,
            chunk_symbols: 4096,
            threads,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        let entropy = codec::entropy_bits(&freq);
        let auto = codec::auto_select(&freq);

        let mut per_kind = Vec::new();
        for kind in EncoderKind::ALL {
            let stage = stage_for(kind);
            let enc = bench.run(&format!("{} {} enc", p.name, kind.name()), bytes, || {
                let out = stage.encode(&p.symbols, &ctx).unwrap();
                std::hint::black_box(out.stream.total_bits());
            });
            let encoded = stage.encode(&p.symbols, &ctx).unwrap();
            let bits_per_sym = encoded.stream.total_bits() as f64 / n as f64;
            let dec = bench.run(&format!("{} {} dec", p.name, kind.name()), bytes, || {
                let syms = stage
                    .decode(&encoded.aux, &encoded.stream, DICT, threads, n)
                    .unwrap();
                std::hint::black_box(syms.len());
            });
            per_kind.push((kind, enc.gbps(), dec.gbps(), bits_per_sym));
        }
        let (_, huff_enc, _, _) = per_kind[0];
        let (_, fle_enc, _, _) = per_kind[1];
        if fle_enc > huff_enc {
            fle_wins_encode.push(p.name);
        }
        for (kind, enc_gbps, dec_gbps, bps) in per_kind {
            rows.push(vec![
                p.name.to_string(),
                kind.name().to_string(),
                format!("{enc_gbps:.3}"),
                format!("{dec_gbps:.3}"),
                format!("{bps:.2}"),
                format!("{entropy:.2}"),
                if kind == auto { "<- auto".to_string() } else { String::new() },
            ]);
        }
    }

    print_table(
        "Codec comparison: encoder backends across quant-code profiles",
        &["profile", "encoder", "enc GB/s", "dec GB/s", "bits/sym", "entropy", "auto pick"],
        &rows,
    );
    println!(
        "\nFLE out-encodes Huffman on: {}",
        if fle_wins_encode.is_empty() {
            "(none this run)".to_string()
        } else {
            fle_wins_encode.join(", ")
        }
    );
    println!(
        "reference shape (FZ-GPU, arXiv:2304.12557): bitshuffle+FLE trades \
         ratio for throughput on noisy inputs; huffman keeps the ratio edge \
         on smooth ones"
    );
}
