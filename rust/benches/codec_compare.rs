//! Codec comparison: encode/decode throughput and bits/symbol for every
//! encoder backend (huffman / fle / rle) across quant-code profiles that
//! span the smoothness spectrum — the measurement behind `--codec auto`
//! (and FZ-GPU's throughput-vs-ratio trade, arXiv:2304.12557).
//!
//! Beyond the per-backend table this bench (a) runs the per-chunk
//! selection acceptance check — on a mixed-smoothness field, `auto` at
//! chunk granularity must land within 2% of the per-chunk oracle and at
//! or under the best uniform backend — and (b) emits the measured
//! cost-model constants (per-profile fitted bits factors and the
//! throughput equalizers) to stdout and to
//! `target/codec-cost-model.txt`, which CI archives as an artifact.
//!
//! Both stages get the histogram for free (the real pipeline computes it
//! during dual-quant either way); Huffman still pays tree + codebook
//! construction inside encode, FLE/RLE pay nothing up front. Throughput
//! is reported against original field bytes (4 B/symbol), the paper's
//! convention.

mod common;

use cusz::codec::{self, cost, stage_for, CostModel, EncodeContext, EncoderKind};
use cusz::config::CodewordRepr;
use cusz::huffman;
use cusz::util::bench::print_table;
use cusz::util::prng::Rng;

const DICT: usize = 1024;
const RADIUS: i32 = (DICT / 2) as i32;
const CHUNK: usize = 4096;

struct Profile {
    name: &'static str,
    symbols: Vec<u16>,
}

fn clamp_code(c: i32) -> u16 {
    c.clamp(1, DICT as i32 - 1) as u16
}

fn profiles(n: usize) -> Vec<Profile> {
    let mut rng = Rng::new(2024);
    vec![
        // smooth fields: deltas hug the radius (skewed histogram)
        Profile {
            name: "smooth",
            symbols: (0..n)
                .map(|_| clamp_code(RADIUS + (rng.normal() * 3.0) as i32))
                .collect(),
        },
        // zero-dominated: one constant bin with sparse excursions
        Profile {
            name: "zero-dom",
            symbols: (0..n)
                .map(|_| {
                    if rng.f32() < 0.97 {
                        RADIUS as u16
                    } else {
                        clamp_code(RADIUS - 20 + rng.below(41) as i32)
                    }
                })
                .collect(),
        },
        // mildly noisy: deltas uniform over ±16 bins
        Profile {
            name: "noisy-mild",
            symbols: (0..n)
                .map(|_| clamp_code(RADIUS - 16 + rng.below(33) as i32))
                .collect(),
        },
        // wide noise: deltas uniform over ±128 bins (near-incompressible)
        Profile {
            name: "noisy-wide",
            symbols: (0..n)
                .map(|_| clamp_code(RADIUS - 128 + rng.below(257) as i32))
                .collect(),
        },
        // spiky noise under a tight bound: most slots are outlier markers
        Profile {
            name: "noisy-spiky",
            symbols: (0..n)
                .map(|_| {
                    if rng.f32() < 0.6 {
                        0
                    } else {
                        clamp_code(RADIUS - 64 + rng.below(129) as i32)
                    }
                })
                .collect(),
        },
    ]
}

/// Mixed-smoothness stream: chunk-aligned stripes rotating through the
/// three pure regimes — the field shape where every uniform choice loses.
fn mixed_symbols(n: usize) -> Vec<u16> {
    let mut rng = Rng::new(7);
    (0..n)
        .map(|i| match (i / CHUNK) % 3 {
            0 => RADIUS as u16,
            1 => clamp_code(RADIUS + (rng.normal() * 3.0) as i32),
            _ => clamp_code(RADIUS - 128 + rng.below(257) as i32),
        })
        .collect()
}

fn histogram(symbols: &[u16]) -> Vec<u64> {
    let mut freq = vec![0u64; DICT];
    for &s in symbols {
        freq[s as usize] += 1;
    }
    freq
}

/// Serialized stream cost of an encoded result in bytes (words + sidecar),
/// the same convention the per-chunk cost model prices.
fn encoded_bytes(stream_payload: usize, aux: usize) -> usize {
    stream_payload + aux
}

fn main() {
    let bench = common::bench();
    let n = if common::quick() { 1 << 19 } else { 1 << 22 };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(8);
    let bytes = n * 4; // original field bytes per symbol (f32)
    let model = CostModel::MEASURED;

    let mut rows = Vec::new();
    let mut report = String::new();
    report.push_str("# measured cost-model constants (codec_compare)\n");
    report.push_str("# fitted_factor = actual encoded stream bits / probe-estimated bits\n");

    for p in profiles(n) {
        let freq = histogram(&p.symbols);
        let ctx = EncodeContext {
            dict_size: DICT,
            chunk_symbols: CHUNK,
            threads,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        let entropy = codec::entropy_bits(&freq);
        let auto = codec::auto_select(&freq);
        let lengths = huffman::build_lengths(&freq);

        // probe-estimated per-chunk bits, summed field-wide, per backend
        let mut est = [0u64; 3];
        for chunk in p.symbols.chunks(CHUNK) {
            let probe = cost::probe_chunk(chunk, &lengths, RADIUS);
            for (slot, (_, bits)) in est.iter_mut().zip(model.chunk_costs(&probe)) {
                *slot += bits;
            }
        }

        for kind in EncoderKind::ALL {
            let stage = stage_for(kind);
            let enc_res = bench.run(&format!("{} {} enc", p.name, kind.name()), bytes, || {
                let out = stage.encode(&p.symbols, &ctx).unwrap();
                std::hint::black_box(out.stream.total_bits());
            });
            let encoded = stage.encode(&p.symbols, &ctx).unwrap();
            let bits_per_sym = encoded.stream.total_bits() as f64 / n as f64;
            let dec_res = bench.run(&format!("{} {} dec", p.name, kind.name()), bytes, || {
                let syms = stage
                    .decode(&encoded.aux, &encoded.stream, DICT, threads, n)
                    .unwrap();
                std::hint::black_box(syms.len());
            });
            let actual_bits =
                (encoded.stream.payload_bytes() + encoded.aux.len()) as u64 * 8;
            let fitted = actual_bits as f64 / est[kind.to_tag() as usize].max(1) as f64;
            report.push_str(&format!(
                "{} {} fitted_factor {:.4} enc_gbps {:.3} dec_gbps {:.3} bits_per_sym {:.3}\n",
                p.name,
                kind.name(),
                fitted,
                enc_res.gbps(),
                dec_res.gbps(),
                bits_per_sym,
            ));
            rows.push(vec![
                p.name.to_string(),
                kind.name().to_string(),
                format!("{:.3}", enc_res.gbps()),
                format!("{:.3}", dec_res.gbps()),
                format!("{bits_per_sym:.2}"),
                format!("{entropy:.2}"),
                format!("{fitted:.3}"),
                if kind == auto { "<- auto".to_string() } else { String::new() },
            ]);
        }
    }

    print_table(
        "Codec comparison: encoder backends across quant-code profiles",
        &[
            "profile", "encoder", "enc GB/s", "dec GB/s", "bits/sym", "entropy", "fit", "auto pick",
        ],
        &rows,
    );

    // ---- per-chunk selection vs the oracle on a mixed field ------------
    let mixed = mixed_symbols(n);
    let freq = histogram(&mixed);
    let ctx = EncodeContext {
        dict_size: DICT,
        chunk_symbols: CHUNK,
        threads,
        codeword_repr: CodewordRepr::Adaptive,
        freq: &freq,
    };
    let mut uniform = Vec::new();
    for kind in EncoderKind::ALL {
        let enc = stage_for(kind).encode(&mixed, &ctx).unwrap();
        uniform.push((kind, encoded_bytes(enc.stream.payload_bytes(), enc.aux.len())));
    }
    let best_uniform = uniform.iter().map(|&(_, b)| b).min().unwrap();

    // oracle: per chunk, the smallest of the three actual encodings
    let lengths = huffman::build_lengths(&freq);
    let book = huffman::CanonicalCodebook::from_lengths(&lengths).unwrap();
    let mut oracle_bytes = lengths.len(); // shared codebook sidecar
    for chunk in mixed.chunks(CHUNK) {
        let h = huffman::deflate::deflate_one(chunk, &book);
        let f = stage_for(EncoderKind::Fle).encode(chunk, &ctx).unwrap();
        let r = stage_for(EncoderKind::Rle).encode(chunk, &ctx).unwrap();
        let hcost = h.words.len() * 8;
        let fcost = f.stream.payload_bytes() + f.aux.len();
        let rcost = r.stream.payload_bytes() + r.aux.len();
        oracle_bytes += hcost.min(fcost).min(rcost);
    }

    let mixed_src = codec::SymbolSource::from_slice(&mixed);
    let chunked = codec::chunked::encode_chunked(&mixed_src, &ctx, &model).unwrap();
    let chunked_bytes = chunked.stream.payload_bytes()
        + chunked.shared_aux.len()
        + chunked.chunk_aux.iter().map(|a| a.len()).sum::<usize>()
        + chunked.tags.len();
    let bench_chunked = bench.run("mixed per-chunk auto enc", bytes, || {
        let out = codec::chunked::encode_chunked(&mixed_src, &ctx, &model).unwrap();
        std::hint::black_box(out.stream.total_bits());
    });

    let mut mix_rows = Vec::new();
    for (kind, b) in &uniform {
        mix_rows.push(vec![
            format!("uniform {}", kind.name()),
            format!("{b}"),
            format!("{:.3}x", bytes as f64 / *b as f64),
            String::new(),
        ]);
    }
    mix_rows.push(vec![
        "per-chunk oracle".to_string(),
        format!("{oracle_bytes}"),
        format!("{:.3}x", bytes as f64 / oracle_bytes as f64),
        String::new(),
    ]);
    mix_rows.push(vec![
        "per-chunk auto".to_string(),
        format!("{chunked_bytes}"),
        format!("{:.3}x", bytes as f64 / chunked_bytes as f64),
        format!("{:.3} GB/s enc", bench_chunked.gbps()),
    ]);
    print_table(
        "Mixed-smoothness field: per-chunk auto vs uniform backends",
        &["encoder", "stream+sidecar bytes", "ratio", "note"],
        &mix_rows,
    );

    // acceptance: within 2% of the oracle, and never above the best
    // uniform backend (plus the tag table it additionally carries)
    let oracle_gap = chunked_bytes as f64 / oracle_bytes as f64;
    let counts = chunked.counts;
    println!(
        "\nper-chunk auto: {:.2}% of oracle (chunks huffman:{} fle:{} rle:{})",
        oracle_gap * 100.0,
        counts[0],
        counts[1],
        counts[2]
    );
    assert!(
        oracle_gap <= 1.02,
        "per-chunk auto {chunked_bytes} B strays >2% from oracle {oracle_bytes} B"
    );
    assert!(
        chunked_bytes <= best_uniform + chunked.tags.len() * 4 + chunked.shared_aux.len() + 128,
        "per-chunk auto {chunked_bytes} B worse than best uniform {best_uniform} B"
    );

    // ---- measured-throughput feedback: CostModel::from_registry --------
    // The per-backend loop above ran every encoder through the
    // instrumented stages, so the global telemetry registry now holds
    // real symbols/ns per backend. Close the loop: a model whose
    // throughput factors are derived from those recorded spans must hold
    // the same 2% oracle tolerance (chunk-level selection is priced on
    // exact bits + sidecar, so calibration adjusts throughput tiebreaks
    // without ever degrading selection — locked here, not assumed).
    let calibrated = CostModel::from_registry(cusz::obs::global());
    let chunked_cal = codec::chunked::encode_chunked(&mixed_src, &ctx, &calibrated).unwrap();
    let cal_bytes = chunked_cal.stream.payload_bytes()
        + chunked_cal.shared_aux.len()
        + chunked_cal.chunk_aux.iter().map(|a| a.len()).sum::<usize>()
        + chunked_cal.tags.len();
    let cal_gap = cal_bytes as f64 / oracle_bytes as f64;
    println!(
        "registry-calibrated model: {:.2}% of oracle \
         (huffman_factor {:.3}, rle_factor {:.3})",
        cal_gap * 100.0,
        calibrated.huffman_throughput_factor,
        calibrated.rle_throughput_factor,
    );
    assert!(
        cal_gap <= 1.02,
        "registry-calibrated model {cal_bytes} B strays >2% from oracle {oracle_bytes} B"
    );

    report.push_str(&format!(
        "calibrated huffman_throughput_factor {:.4} rle_throughput_factor {:.4} \
         calibrated_oracle_gap {cal_gap:.4}\n",
        calibrated.huffman_throughput_factor, calibrated.rle_throughput_factor,
    ));
    report.push_str(&format!(
        "mixed per_chunk_auto_bytes {chunked_bytes} oracle_bytes {oracle_bytes} \
         best_uniform_bytes {best_uniform} oracle_gap {oracle_gap:.4}\n"
    ));
    report.push_str(&format!(
        "model huffman_throughput_factor {} rle_throughput_factor {} \
         fle_sidecar_bits {} rle_sidecar_bits {}\n",
        model.huffman_throughput_factor,
        model.rle_throughput_factor,
        model.fle_sidecar_bits,
        model.rle_sidecar_bits,
    ));

    let out_path = std::path::Path::new("target").join("codec-cost-model.txt");
    if std::fs::create_dir_all("target").is_ok() && std::fs::write(&out_path, &report).is_ok() {
        println!("cost-model constants written to {}", out_path.display());
    }
    println!(
        "\nreference shape (FZ-GPU, arXiv:2304.12557): bitshuffle+FLE trades \
         ratio for throughput on noisy inputs; huffman keeps the ratio edge \
         on smooth ones; RLE collapses zero/constant-dominated streams"
    );
}
