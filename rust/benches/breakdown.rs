//! Table 7: per-kernel breakdown of compression and decompression across
//! CPU-SZ (classic Algorithm 1), cusz-rs (this system), and the ZFP-style
//! fixed-rate baseline, on all five datasets.
//!
//! Columns mirror the paper: predict-quant, histogram, codebook (ms),
//! encode+deflate, kernel-total compression, Huffman decode, reversed
//! predict-quant, kernel-total decompression. All throughputs are GB/s of
//! *original* data (paper footnote 4).
//!
//! Paper shape to reproduce: dual-quant >> classic predict-quant (the RAW
//! cascade is the bottleneck); Huffman decode is the decompression
//! bottleneck; zfp kernels are faster but compress far worse (Table 5
//! covers the ratio side).

mod common;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::Dataset;
use cusz::util::bench::print_table;
use cusz::zfp::Zfp;

fn main() {
    let bench = common::bench();
    let use_pjrt = std::env::var("CUSZ_BENCH_BACKEND").map(|b| b == "pjrt").unwrap_or(true);
    let coord = Coordinator::new_with_fallback(CuszConfig {
        backend: if use_pjrt { BackendKind::Pjrt } else { BackendKind::Cpu },
        eb: ErrorBound::ValRel(1e-4),
        ..Default::default()
    })
    .unwrap();
    println!("cusz engine: {}", coord.engine_name());

    let mut rows = Vec::new();
    for ds in Dataset::ALL {
        let field = common::dataset_field(ds);
        let bytes = field.size_bytes();
        let mb = bytes as f64 / 1e6;

        // ---- cusz-rs -----------------------------------------------------
        // stage timings come from the instrumented coordinator; bench reps
        // give a stable mean
        let mut cstats = None;
        let mut archive = None;
        bench.run(&format!("{} cusz compress", ds.name()), bytes, || {
            let (a, s) = coord.compress_with_stats(&field).unwrap();
            archive = Some(a);
            cstats = Some(s);
        });
        let cstats = cstats.unwrap();
        let archive = archive.unwrap();
        let mut dstats = None;
        bench.run(&format!("{} cusz decompress", ds.name()), bytes, || {
            let (_, s) = coord.decompress_with_stats(&archive).unwrap();
            dstats = Some(s);
        });
        let dstats = dstats.unwrap();
        let g = |t: std::time::Duration| bytes as f64 / t.as_secs_f64().max(1e-12) / 1e9;

        rows.push(vec![
            format!("cusz {}", ds.name()),
            format!("{mb:.0}"),
            format!("{:.2}", g(cstats.timer.total("1.predict-quant"))),
            format!("{:.2}", g(cstats.timer.total("2.histogram"))),
            format!("{:.2}", cstats.timer.total("3.codebook").as_secs_f64() * 1e3),
            format!("{:.2}", g(cstats.timer.total("5.encode-deflate"))),
            format!("{:.2}", g(cstats.timer.total("total"))),
            format!("{:.2}", g(dstats.timer.total("1.decode"))),
            // the fused pass folds patch + inverse-Lorenzo + scatter +
            // verbatim into one slab-parallel stage
            format!("{:.2}", g(dstats.timer.total("2.patch-reverse-scatter"))),
            format!("{:.2}", g(dstats.timer.total("total"))),
        ]);

        // ---- CPU-SZ (classic, single thread) -------------------------------
        if !common::quick() {
            let eb = cstats.abs_eb;
            let kernel_dims = field.kernel_dims();
            let mut classic = None;
            let rc = bench.run(&format!("{} classic compress", ds.name()), bytes, || {
                classic = Some(cusz::sz::classic::compress(&field.data, &kernel_dims, eb, 1024));
            });
            let classic = classic.unwrap();
            let rd = bench.run(&format!("{} classic decompress", ds.name()), bytes, || {
                let out = cusz::sz::classic::decompress(&classic, eb, 1024);
                std::hint::black_box(out.len());
            });
            rows.push(vec![
                format!("cpu-sz {}", ds.name()),
                format!("{mb:.0}"),
                format!("{:.3}", rc.gbps()),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("{:.3}", rc.gbps()),
                "-".into(),
                format!("{:.3}", rd.gbps()),
                format!("{:.3}", rd.gbps()),
            ]);
        }

        // ---- zfp fixed-rate -------------------------------------------------
        let kernel_dims = field.kernel_dims();
        let z = Zfp::new(8.0);
        let mut stream = None;
        let rzc = bench.run(&format!("{} zfp compress", ds.name()), bytes, || {
            stream = Some(z.compress(&field.data, &kernel_dims).unwrap());
        });
        let stream = stream.unwrap();
        let rzd = bench.run(&format!("{} zfp decompress", ds.name()), bytes, || {
            let out = z.decompress(&stream).unwrap();
            std::hint::black_box(out.len());
        });
        rows.push(vec![
            format!("zfp-8 {}", ds.name()),
            format!("{mb:.0}"),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{:.3}", rzc.gbps()),
            "-".into(),
            "-".into(),
            format!("{:.3}", rzd.gbps()),
        ]);
    }

    print_table(
        "Table 7: kernel breakdown (GB/s except codebook in ms)",
        &[
            "system/dataset",
            "MB",
            "P+Q",
            "hist",
            "codebook ms",
            "enc+defl",
            "compress",
            "sym-dec",
            "rev P+Q",
            "decompress",
        ],
        &rows,
    );
    println!(
        "\npaper shape checks: (1) cusz P+Q >> cpu-sz P+Q (dual-quant removes the RAW \
         cascade); (2) decompression slower than compression (decode-bound); \
         (3) zfp kernel faster but—see Table 5—at far lower compression ratio."
    );
}
