//! Figure 5: overall compression / decompression throughput of cusz-rs vs
//! the serial classic CPU-SZ and the chunked-parallel "OpenMP-SZ" baseline
//! (all cores), per dataset.
//!
//! Paper shape to reproduce: cusz >> serial SZ (paper: 242.9-370.1x on
//! V100 vs 1 core) and cusz > OpenMP-SZ (paper: 11.0-13.1x vs 32 cores);
//! on this CPU-only testbed the parallel structure is the same but both
//! sides share the same silicon, so expect the *ordering* and a
//! multi-x gap driven by dual-quant + parallel Huffman vs the cascade.
//! OpenMP-SZ supports only 3D datasets in the paper; we mark the others
//! n/a identically.

mod common;

use cusz::config::{BackendKind, CuszConfig, ErrorBound};
use cusz::coordinator::Coordinator;
use cusz::datagen::Dataset;
use cusz::util::bench::print_table;

fn main() {
    let bench = common::bench();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let coord = Coordinator::new_with_fallback(CuszConfig {
        backend: BackendKind::Pjrt,
        eb: ErrorBound::ValRel(1e-4),
        ..Default::default()
    })
    .unwrap();
    let coord_cpu = Coordinator::new(CuszConfig {
        backend: BackendKind::Cpu,
        eb: ErrorBound::ValRel(1e-4),
        ..Default::default()
    })
    .unwrap();
    println!("cusz engine: {} ({} worker threads)", coord.engine_name(), threads);

    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for ds in Dataset::ALL {
        let field = common::dataset_field(ds);
        let bytes = field.size_bytes();
        let eb = {
            let (lo, hi) = field.value_range();
            (1e-4 * (hi - lo) as f64) as f32
        };
        let kernel_dims = field.kernel_dims();

        // cusz end-to-end
        let mut archive = None;
        let rc = bench.run(&format!("{} cusz C", ds.name()), bytes, || {
            archive = Some(coord.compress(&field).unwrap());
        });
        let archive = archive.unwrap();
        let rd = bench.run(&format!("{} cusz D", ds.name()), bytes, || {
            std::hint::black_box(coord.decompress(&archive).unwrap().len());
        });
        // cusz with the bit-exact CPU engine (same-silicon comparison)
        let rc_cpu = bench.run(&format!("{} cusz-cpu C", ds.name()), bytes, || {
            std::hint::black_box(coord_cpu.compress(&field).unwrap().compressed_bytes());
        });
        let rd_cpu = bench.run(&format!("{} cusz-cpu D", ds.name()), bytes, || {
            std::hint::black_box(coord_cpu.decompress(&archive).unwrap().len());
        });

        // serial classic SZ (predict-quant + huffman, one core)
        let rs = bench.run(&format!("{} serial C", ds.name()), bytes, || {
            let c = cusz::sz::classic::compress(&field.data, &kernel_dims, eb, 1024);
            // serial huffman over the code stream (production SZ encodes too)
            let hist = cusz::huffman::histogram(&c.codes, 1024);
            let freq: Vec<u64> = hist.iter().map(|&x| x as u64).collect();
            let lengths = cusz::huffman::build_lengths(&freq);
            let book = cusz::huffman::CanonicalCodebook::from_lengths(&lengths).unwrap();
            let s = cusz::huffman::deflate_chunks(&c.codes, &book, usize::MAX, 1);
            std::hint::black_box(s.total_bits());
        });
        let rs_d = bench.run(&format!("{} serial D", ds.name()), bytes, || {
            let c = cusz::sz::classic::compress(&field.data, &kernel_dims, eb, 1024);
            std::hint::black_box(cusz::sz::classic::decompress(&c, eb, 1024).len());
        });

        // OpenMP-style chunked classic SZ (3D only, like the paper)
        let is_3d = kernel_dims.len() == 3;
        let romp = if is_3d {
            Some(bench.run(&format!("{} omp C", ds.name()), bytes, || {
                let parts = cusz::sz::classic::compress_openmp_style(
                    &field.data,
                    &kernel_dims,
                    eb,
                    1024,
                    threads,
                );
                std::hint::black_box(parts.len());
            }))
        } else {
            None
        };

        let speedup_serial = rs.mean.as_secs_f64() / rc_cpu.mean.as_secs_f64();
        speedups.push(speedup_serial);
        rows.push(vec![
            ds.name().to_string(),
            format!("{:.3}", rc.gbps()),
            format!("{:.3}", rd.gbps()),
            format!("{:.3}", rc_cpu.gbps()),
            format!("{:.3}", rd_cpu.gbps()),
            format!("{:.4}", rs.gbps()),
            format!("{:.4}", rs_d.gbps()),
            romp.as_ref().map(|r| format!("{:.3}", r.gbps())).unwrap_or("n/a".into()),
            format!("{speedup_serial:.1}x"),
            romp.as_ref()
                .map(|r| format!("{:.1}x", r.mean.as_secs_f64() / rc_cpu.mean.as_secs_f64()))
                .unwrap_or("n/a".into()),
        ]);
    }
    print_table(
        "Figure 5: compression/decompression throughput (GB/s)",
        &[
            "dataset",
            "cusz-pjrt C",
            "cusz-pjrt D",
            "cusz-cpu C",
            "cusz-cpu D",
            "serial-SZ C",
            "serial-SZ D",
            "omp-SZ C",
            "vs serial",
            "vs omp",
        ],
        &rows,
    );
    let min = speedups.iter().copied().fold(f64::INFINITY, f64::min);
    let max = speedups.iter().copied().fold(0.0, f64::max);
    println!(
        "\npaper reference (V100 vs Xeon 6148): 242.9-370.1x vs serial, 11.0-13.1x vs \
         OpenMP(32 cores). Here (same-silicon comparison): {min:.1}-{max:.1}x vs serial."
    );
}
