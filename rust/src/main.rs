//! cusz CLI — leader entrypoint for the cusz-rs framework.
//!
//! Subcommands:
//!   gen         generate a synthetic SDRBench-like field to a raw .f32 file
//!   compress    compress a raw .f32 field to a .cusza archive
//!   decompress  restore a .cusza archive to raw .f32
//!   roundtrip   compress+decompress a dataset field, report CR/PSNR/bound
//!   stats       Table 9-style percentile statistics for a field
//!   selftest    cross-validate the PJRT path against the CPU mirror
//!   store       multi-field `.cuszb` bundle: add / get / ls / rm / fsck
//!   serve       batched streaming compression service into a store
//!
//! Examples:
//!   cusz roundtrip --dataset nyx --field baryon_density --eb 1e-4
//!   cusz gen --dataset cesm --field CLDHGH --out /tmp/cldhgh.f32
//!   cusz compress --input /tmp/cldhgh.f32 --dims 450,900 --eb 1e-4 \
//!        --out /tmp/cldhgh.cusza
//!   cusz store add --store snap.cuszb --dataset nyx --field baryon_density
//!   cusz store get --store snap.cuszb --name NYX/baryon_density --out b.f32
//!   cusz serve --batch --store snap.cuszb --dataset hurricane --count 16

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use cusz::codec::{CodecGranularity, CodecSpec, EncoderChoice};
use cusz::config::{BackendKind, CodewordRepr, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::Archive;
use cusz::coordinator::{Coordinator, StreamHint};
use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::metrics;
use cusz::serve::{BatchCompressor, BatchConfig, BatchDecompressor};
use cusz::store::{Durability, Store};
use cusz::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "gen" => cmd_gen(rest),
        "compress" => cmd_compress(rest),
        "decompress" => cmd_decompress(rest),
        "roundtrip" => cmd_roundtrip(rest),
        "stats" => cmd_stats(rest),
        "selftest" => cmd_selftest(rest),
        "store" => cmd_store(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "bench" => cmd_bench(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "cusz — error-bounded lossy compressor for scientific data (cuSZ, PACT'20)\n\
     \n\
     Subcommands:\n\
       gen         --dataset D --field F [--seed N] [--scale N] --out PATH\n\
       compress    --input PATH --dims d0,d1,.. [--eb E | --abs-eb E] [--out PATH]\n\
       decompress  --input PATH.cusza [--out PATH]\n\
       roundtrip   --dataset D [--field F] [--eb E] [--backend pjrt|cpu]\n\
       stats       --dataset D --field F [--eb E]\n\
       selftest    [--backend pjrt]\n\
       store add   --store B.cuszb (--dataset D --field F | --input PATH \n\
                   --dims d0,.. | --archive PATH.cusza) [--shards N]\n\
       store get   --store B.cuszb (--name NAME [--out PATH] |\n\
                   --all [--out-dir DIR] [--workers W] [--queue N])\n\
       store ls    --store B.cuszb [--verify]\n\
       store rm    --store B.cuszb --name NAME\n\
       store fsck  --store B.cuszb [--repair] [--quarantine] — integrity\n\
                   scrub; exits 0 clean / 1 unrepaired / 2 fatal\n\
       serve       --batch --store B.cuszb --dataset D [--count N]\n\
                   [--workers W] [--queue N] [--shards N]\n\
                   [--compact-threshold F]\n\
       serve       --daemon --store B.cuszb [--addr HOST:PORT]\n\
                   [--workers W] [--queue N] [--max-conns N]\n\
                   [--read-timeout-ms N] [--write-timeout-ms N]\n\
                   [--max-body-mb N | --max-payload BYTES]\n\
                   [--mem-budget BYTES|auto|unlimited] [--durability\n\
                   none|flush|sync] [--scrub-interval-ms N] — long-running\n\
                   TCP front end; requests past the memory budget shed\n\
                   BUSY (length-prefixed frames; see README 'Serving')\n\
       loadgen     [--addr HOST:PORT] [--clients N] [--requests N]\n\
                   [--put-ratio F] [--pattern steady|bursty|diurnal]\n\
                   [--elems N] [--pace-us N] [--max-payload BYTES]\n\
                   [--quick] [--shutdown] [--acked-log PATH]\n\
                   [--out BENCH_serve.json] — drive a running daemon,\n\
                   emit p50/p95/p99 + throughput (cusz-bench-serve/v1)\n\
       bench       [--out BENCH_pipeline.json] [--datasets d1,d2,..]\n\
                   [--scale N] [--quick] — machine-readable pipeline\n\
                   throughput/ratio report (per-stage GB/s, e2e, CR)\n\
     \n\
     Common options: --backend pjrt|cpu, --threads N, --chunk N,\n\
       --dict N, --repr adaptive|u32|u64, --codec huffman|fle|rle|auto,\n\
       --codec-granularity field|chunk, --lossless none|gzip|zstd,\n\
       --target-gbps F (prune auto backends below this decode rate),\n\
       --durability none|flush|sync (how hard store writes are pushed to\n\
       stable storage before the operation/ack completes),\n\
       --artifacts DIR, --metrics-out PATH (cusz-metrics/v1 JSON snapshot)"
        .to_string()
}

fn common_config(cli: &Cli) -> Result<CuszConfig> {
    let eb: f64 = cli.get_parsed("eb")?;
    let abs: f64 = cli.get_parsed("abs-eb")?;
    Ok(CuszConfig {
        backend: match cli.get("backend").as_str() {
            "pjrt" => BackendKind::Pjrt,
            "cpu" => BackendKind::Cpu,
            b => bail!("unknown backend {b}"),
        },
        eb: if abs > 0.0 { ErrorBound::Abs(abs) } else { ErrorBound::ValRel(eb) },
        threads: cli.get_parsed("threads")?,
        chunk_symbols: cli.get_parsed("chunk")?,
        dict_size: cli.get_parsed("dict")?,
        codeword_repr: match cli.get("repr").as_str() {
            "adaptive" => CodewordRepr::Adaptive,
            "u32" => CodewordRepr::U32,
            "u64" => CodewordRepr::U64,
            r => bail!("unknown repr {r}"),
        },
        codec: CodecSpec {
            encoder: EncoderChoice::parse(&cli.get("codec"))?,
            lossless: match cli.get("lossless").as_str() {
                "none" => LosslessStage::None,
                "gzip" => LosslessStage::Gzip,
                "zstd" => LosslessStage::Zstd,
                l => bail!("unknown lossless stage {l}"),
            },
            granularity: CodecGranularity::parse(&cli.get("codec-granularity"))?,
        },
        target_gbps: cli.get_parsed("target-gbps")?,
        artifacts_dir: PathBuf::from(cli.get("artifacts")),
        durability: Durability::parse(&cli.get("durability"))?,
        ..Default::default()
    })
}

fn with_common(cli: Cli) -> Cli {
    cli.opt("backend", "pjrt", "quant engine: pjrt (AOT HLO) or cpu (mirror)")
        .opt("eb", "1e-4", "value-range-relative error bound (valrel)")
        .opt("abs-eb", "0", "absolute error bound (overrides --eb if > 0)")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .opt("chunk", "4096", "deflate chunk size in symbols (Table 6)")
        .opt("dict", "1024", "quantization bins / Huffman symbols (Table 3)")
        .opt("repr", "adaptive", "codeword repr: adaptive|u32|u64 (Table 4)")
        .opt("codec", "huffman", "symbol encoder: huffman|fle|rle|auto")
        .opt(
            "codec-granularity",
            "field",
            "auto-selection grain: field (one backend) or chunk (tag table)",
        )
        .opt("lossless", "none", "final lossless stage: none|gzip|zstd")
        .opt(
            "target-gbps",
            "0",
            "decode-throughput budget in GB/s: `auto` prunes backends whose \
             measured decode rate misses it (0 = off)",
        )
        .opt("artifacts", "artifacts", "AOT artifact directory")
        .opt(
            "durability",
            "flush",
            "store write durability: none (page cache), flush (default; index \
             fsynced before publish), sync (payload + index + directory fsynced \
             before the operation — and any PUT ack — completes)",
        )
        .opt(
            "metrics-out",
            "",
            "write a cusz-metrics/v1 JSON snapshot of the telemetry registry on exit",
        )
}

/// `--metrics-out PATH`: dump the global telemetry registry — every
/// counter, per-stage span aggregate, and latency histogram the command's
/// work recorded — as a versioned JSON snapshot.
fn write_metrics_snapshot(cli: &Cli) -> Result<()> {
    let path = cli.get("metrics-out");
    if path.is_empty() {
        return Ok(());
    }
    std::fs::write(&path, cusz::obs::global().snapshot().to_json())
        .with_context(|| format!("writing metrics snapshot {path}"))?;
    println!("wrote metrics snapshot {path}");
    Ok(())
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    s.split(',').map(|d| d.parse::<usize>().context("parsing dims")).collect()
}

/// One-pass chunked scan of a raw little-endian .f32 file: finite
/// min/max plus finiteness, mirroring `StreamHint::scan` without loading
/// the file. Value-range-relative bounds need this summary before the
/// streaming compress pass can resolve the bound.
fn scan_f32_file(path: &str) -> Result<StreamHint> {
    use std::io::Read;
    let file = std::fs::File::open(path).with_context(|| format!("reading {path}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut buf = vec![0u8; 1 << 20];
    let mut carry: Vec<u8> = Vec::with_capacity(4);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    let mut all_finite = true;
    let mut absorb = |v: f32| {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        } else {
            all_finite = false;
        }
    };
    loop {
        let n = r.read(&mut buf).with_context(|| format!("reading {path}"))?;
        if n == 0 {
            break;
        }
        // short reads can split a value across chunks; carry the tail
        let mut start = 0;
        while !carry.is_empty() && carry.len() < 4 && start < n {
            carry.push(buf[start]);
            start += 1;
        }
        if carry.len() == 4 {
            absorb(f32::from_le_bytes([carry[0], carry[1], carry[2], carry[3]]));
            carry.clear();
        }
        let chunk = &buf[start..n];
        for b in chunk.chunks_exact(4) {
            absorb(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        carry.extend_from_slice(chunk.chunks_exact(4).remainder());
    }
    if lo > hi {
        (lo, hi) = (0.0, 0.0);
    }
    Ok(StreamHint { lo, hi, all_finite })
}

/// Open a raw .f32 file for the streaming compress pass, checking its
/// size against the declared dims up front so a mismatch fails before
/// any bands are consumed.
fn open_f32_stream(path: &str, dims: &[usize]) -> Result<std::io::BufReader<std::fs::File>> {
    let elems: u64 = dims.iter().map(|&d| d as u64).product();
    let file = std::fs::File::open(path).with_context(|| format!("reading {path}"))?;
    let len = file.metadata().with_context(|| format!("reading {path}"))?.len();
    let want = elems.saturating_mul(4);
    if len != want {
        bail!("{path}: {len} bytes but dims {dims:?} need {want} ({elems} f32 values)");
    }
    Ok(std::io::BufReader::new(file))
}

/// Resolve the range hint the streaming compressor needs: value-relative
/// bounds scan the file once; absolute bounds stream blind (the archive
/// bytes are identical either way — see `Coordinator::compress_stream`).
fn stream_hint_for(cfg: &CuszConfig, path: &str) -> Result<Option<StreamHint>> {
    match cfg.eb {
        ErrorBound::Abs(_) => Ok(None),
        _ => Ok(Some(scan_f32_file(path)?)),
    }
}

fn write_f32_file(path: &str, data: &[f32]) -> Result<()> {
    // stream through a bounded arena buffer — no full-field byte image
    // between the decompressed f32 data and the file
    let file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut w = std::io::BufWriter::new(file);
    cusz::field::write_f32_into(data, &mut w).with_context(|| format!("writing {path}"))?;
    use std::io::Write;
    w.flush().with_context(|| format!("flushing {path}"))
}

fn cmd_gen(args: &[String]) -> Result<()> {
    let cli = Cli::new("cusz gen", "generate a synthetic SDRBench-like field")
        .req("dataset", "hacc|cesm|hurricane|nyx|qmcpack")
        .req("field", "field name (e.g. CLOUDf48, baryon_density)")
        .opt("seed", "42", "generator seed")
        .opt("scale", "1", "axis scale multiplier")
        .req("out", "output .f32 path")
        .parse(args)?;
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let field = datagen::generate_scaled(ds, &cli.get("field"), cli.get_parsed("seed")?, cli.get_parsed("scale")?);
    write_f32_file(&cli.get("out"), &field.data)?;
    println!(
        "wrote {} ({} elements, dims {:?}, {:.2} MB)",
        cli.get("out"),
        field.len(),
        field.dims,
        field.size_bytes() as f64 / 1e6
    );
    Ok(())
}

fn cmd_compress(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz compress", "compress a raw .f32 field"))
        .req("input", "input .f32 path")
        .req("dims", "comma-separated dims, slowest first (e.g. 100,500,500)")
        .opt("out", "", "output archive path (default: <input>.cusza)")
        .parse(args)?;
    let cfg = common_config(&cli)?;
    let dims = parse_dims(&cli.get("dims"))?;
    let input = cli.get("input");
    let name = PathBuf::from(&input)
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "field".into());
    let hint = stream_hint_for(&cfg, &input)?;
    let coord = Coordinator::new(cfg)?;
    // stream the file through the bounded band window — peak memory is
    // a few bands plus the archive, not the whole field
    let mut src = open_f32_stream(&input, &dims)?;
    let compressed = coord.compress_stream(&name, &dims, &mut src, hint)?;
    let out = if cli.get("out").is_empty() { format!("{input}.cusza") } else { cli.get("out") };
    std::fs::write(&out, &compressed.bytes)?;
    println!("engine: {}", coord.engine_name());
    println!("{}", compressed.stats.report());
    println!("wrote {out}");
    write_metrics_snapshot(&cli)
}

fn cmd_decompress(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz decompress", "restore a .cusza archive"))
        .req("input", "input .cusza path")
        .opt("out", "", "output .f32 path (default: <input>.out.f32)")
        .parse(args)?;
    let cfg = common_config(&cli)?;
    let input = cli.get("input");
    // thread the CLI budget through to the v3 segmented-tail decode so
    // the parallel tail is exercised outside serve too (0 = all cores)
    let archive = Archive::from_bytes_with_threads(&std::fs::read(&input)?, cfg.threads)?;
    let coord = Coordinator::new(cfg)?;
    let out = if cli.get("out").is_empty() { format!("{input}.out.f32") } else { cli.get("out") };
    // fused slab pass straight into the file — no full-field buffer
    // between the archive and the disk
    let file = std::fs::File::create(&out).with_context(|| format!("creating {out}"))?;
    let mut w = std::io::BufWriter::new(file);
    let stats = coord.decompress_stream_into(&archive, coord.cfg.effective_threads(), &mut w)?;
    use std::io::Write;
    w.flush().with_context(|| format!("flushing {out}"))?;
    println!("engine: {}  decode threads: {}", coord.engine_name(), stats.threads);
    println!("{}", stats.timer.report(stats.original_bytes));
    println!("wrote {out} (dims {:?})", archive.header.dims);
    write_metrics_snapshot(&cli)
}

fn cmd_roundtrip(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz roundtrip", "compress+decompress with quality report"))
        .req("dataset", "hacc|cesm|hurricane|nyx|qmcpack")
        .opt("field", "", "field name (default: first field of the dataset)")
        .opt("seed", "42", "generator seed")
        .opt("scale", "1", "axis scale multiplier")
        .parse(args)?;
    let cfg = common_config(&cli)?;
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let fname = if cli.get("field").is_empty() {
        ds.field_names()[0].to_string()
    } else {
        cli.get("field")
    };
    let field = datagen::generate_scaled(ds, &fname, cli.get_parsed("seed")?, cli.get_parsed("scale")?);
    let coord = Coordinator::new_with_fallback(cfg)?;
    println!("engine: {}   field: {}  dims {:?}", coord.engine_name(), field.name, field.dims);

    let (archive, cstats) = coord.compress_with_stats(&field)?;
    println!("--- compression ---\n{}", cstats.report());
    let (out, dstats) = coord.decompress_with_stats(&archive)?;
    println!("--- decompression ---\n{}", dstats.timer.report(dstats.original_bytes));

    let psnr = metrics::psnr(&field.data, &out.data);
    let maxerr = metrics::max_abs_error(&field.data, &out.data);
    println!("--- quality ---");
    println!("  abs eb       {:.6e}", archive.header.abs_eb);
    println!("  max |err|    {maxerr:.6e}");
    println!("  PSNR         {psnr:.2} dB");
    match metrics::verify_error_bound(&field.data, &out.data, archive.header.abs_eb) {
        None => println!("  error bound  RESPECTED"),
        Some(i) => bail!("error bound VIOLATED at index {i}"),
    }
    write_metrics_snapshot(&cli)
}

fn cmd_stats(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz stats", "Table 9-style field statistics"))
        .req("dataset", "dataset name")
        .req("field", "field name")
        .opt("seed", "42", "generator seed")
        .parse(args)?;
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let field = datagen::generate(ds, &cli.get("field"), cli.get_parsed("seed")?);
    let mut sorted = field.data.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
    let (min, max) = (sorted[0], sorted[sorted.len() - 1]);
    let range = max - min;
    let valrel: f64 = cli.get_parsed("eb")?;
    let eb = (valrel * range as f64) as f32;
    println!("field {}  ({} values)", field.name, field.len());
    println!(
        "  min {min:.3e}  1% {:.3e}  25% {:.3e}  50% {:.3e}  75% {:.3e}  99% {:.3e}  max {max:.3e}  range {range:.3e}",
        pct(0.01), pct(0.25), pct(0.50), pct(0.75), pct(0.99)
    );
    for (label, e) in [("eb", eb), ("eb/10", eb / 10.0)] {
        let near0 = field.data.iter().filter(|&&v| v.abs() <= e).count();
        let nearmin = field.data.iter().filter(|&&v| v - min <= e).count();
        println!(
            "  {label} = {e:.3e}: {:.2}% in [-eb, eb], {:.2}% in [min, min+eb]",
            100.0 * near0 as f64 / field.len() as f64,
            100.0 * nearmin as f64 / field.len() as f64
        );
    }
    write_metrics_snapshot(&cli)
}

fn cmd_store(args: &[String]) -> Result<()> {
    let Some(action) = args.first().map(|s| s.as_str()) else {
        bail!("store needs an action: add | get | ls | rm\n\n{}", usage());
    };
    let rest = &args[1..];
    match action {
        "add" => cmd_store_add(rest),
        "get" => cmd_store_get(rest),
        "ls" => cmd_store_ls(rest),
        "rm" => cmd_store_rm(rest),
        "fsck" => cmd_store_fsck(rest),
        other => bail!("unknown store action '{other}' (add|get|ls|rm|fsck)\n\n{}", usage()),
    }
}

/// `cusz store fsck`: offline integrity scrub over a bundle. Exits with
/// the report's CI-usable code — 0 clean (or fully repaired), 1
/// unrepaired findings remain, 2 fatal (unreadable index, locked store)
/// — instead of the generic error path, so scripts can branch on it.
fn cmd_store_fsck(args: &[String]) -> Result<()> {
    let cli = Cli::new("cusz store fsck", "scan (and optionally repair) a .cuszb bundle")
        .req("store", ".cuszb bundle path")
        .flag(
            "repair",
            "fix what is fixable: finish/roll back an interrupted compaction, \
             truncate torn tails, drop corrupt entries, sweep stale artifacts",
        )
        .flag(
            "quarantine",
            "with --repair: move corrupt payloads into quarantine/ (kept for \
             forensics; GETs answer QUARANTINED until the name is re-PUT)",
        )
        .parse(args)?;
    let opts = cusz::store::FsckOptions {
        repair: cli.has_flag("repair") || cli.has_flag("quarantine"),
        quarantine: cli.has_flag("quarantine"),
    };
    let report = cusz::store::fsck::fsck(cli.get("store"), &opts)?;
    println!("{}", report.render());
    std::process::exit(report.exit_code());
}

fn cmd_store_add(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz store add", "compress a field into a .cuszb bundle"))
        .req("store", ".cuszb bundle path (created if absent)")
        .opt("shards", "4", "shard count when creating a new bundle")
        .opt("dataset", "", "generate this dataset's field instead of reading a file")
        .opt("field", "", "field name for --dataset")
        .opt("seed", "42", "generator seed for --dataset")
        .opt("input", "", "raw .f32 input path (with --dims)")
        .opt("dims", "", "comma-separated dims for --input")
        .opt("archive", "", "pre-compressed .cusza payload to add as-is")
        .opt("name", "", "override the stored field name")
        .parse(args)?;
    let shards: usize = cli.get_parsed("shards")?;

    // Resolve and validate the input source *before* touching the bundle
    // on disk, so a bad invocation never leaves an empty store behind.

    // pre-compressed payload: no coordinator needed
    if !cli.get("archive").is_empty() {
        let payload = std::fs::read(cli.get("archive"))?;
        let name = if cli.get("name").is_empty() {
            Archive::peek_header(&payload)?.field_name
        } else {
            cli.get("name")
        };
        let mut store = Store::open_or_create(cli.get("store"), shards)?;
        store.set_durability(Durability::parse(&cli.get("durability"))?);
        let entry = store.add_bytes(&name, &payload)?;
        println!("added '{}' ({} bytes, shard {})", entry.name, entry.len, entry.shard);
        return write_metrics_snapshot(&cli);
    }

    // raw .f32 file: stream it through the bounded band window instead
    // of materializing the field (same archive bytes — see
    // `Coordinator::compress_stream`)
    if !cli.get("input").is_empty() {
        let input = cli.get("input");
        let dims = parse_dims(&cli.get("dims")).context("--input needs --dims")?;
        let name = if cli.get("name").is_empty() {
            PathBuf::from(&input)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "field".into())
        } else {
            cli.get("name")
        };
        let cfg = common_config(&cli)?;
        let hint = stream_hint_for(&cfg, &input)?;
        let coord = Coordinator::new_with_fallback(cfg)?;
        let mut src = open_f32_stream(&input, &dims)?;
        let compressed = coord.compress_stream(&name, &dims, &mut src, hint)?;
        let mut store = Store::open_or_create(cli.get("store"), shards)?;
        store.set_durability(Durability::parse(&cli.get("durability"))?);
        let entry = store.add_bytes(&compressed.archive.header.field_name, &compressed.bytes)?;
        println!("engine: {}", coord.engine_name());
        println!("{}", compressed.stats.report());
        println!(
            "added '{}' to {} (shard {}, offset {}, {} bytes)",
            entry.name,
            cli.get("store"),
            entry.shard,
            entry.offset,
            entry.len
        );
        return write_metrics_snapshot(&cli);
    }

    let mut field = if !cli.get("dataset").is_empty() {
        let ds = Dataset::parse(&cli.get("dataset"))?;
        let fname = if cli.get("field").is_empty() {
            ds.field_names()[0].to_string()
        } else {
            cli.get("field")
        };
        datagen::generate(ds, &fname, cli.get_parsed("seed")?)
    } else {
        bail!("store add needs --dataset, --input, or --archive");
    };
    if !cli.get("name").is_empty() {
        field.name = cli.get("name");
    }

    let coord = Coordinator::new_with_fallback(common_config(&cli)?)?;
    let compressed = coord.compress_encoded(&field)?;
    let mut store = Store::open_or_create(cli.get("store"), shards)?;
    store.set_durability(Durability::parse(&cli.get("durability"))?);
    // append the worker's single serialization as-is
    let entry = store.add_bytes(&compressed.archive.header.field_name, &compressed.bytes)?;
    println!("engine: {}", coord.engine_name());
    println!("{}", compressed.stats.report());
    println!(
        "added '{}' to {} (shard {}, offset {}, {} bytes)",
        entry.name,
        cli.get("store"),
        entry.shard,
        entry.offset,
        entry.len
    );
    write_metrics_snapshot(&cli)
}

fn cmd_store_get(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz store get", "random-access decompress field(s)"))
        .req("store", ".cuszb bundle path")
        .opt("name", "", "field name (see `cusz store ls`)")
        .flag("all", "drain every field in parallel (batch decompression)")
        .opt("out", "", "output .f32 path (default: print a summary only)")
        .opt("out-dir", "", "output directory for --all (one .f32 per field)")
        .opt("workers", "0", "concurrent decode jobs for --all (0 = all cores)")
        .opt("queue", "4", "bounded queue depth for --all")
        .parse(args)?;
    let store = Store::open(cli.get("store"))?;
    if cli.has_flag("all") {
        return store_get_all(&cli, &store);
    }
    if cli.get("name").is_empty() {
        bail!("store get needs --name NAME or --all");
    }
    let archive = store.get(&cli.get("name"))?;
    let coord = Coordinator::new_with_fallback(common_config(&cli)?)?;
    println!("engine: {}", coord.engine_name());
    if cli.get("out").is_empty() {
        let (field, stats) = coord.decompress_with_stats(&archive)?;
        println!("{}", stats.timer.report(stats.original_bytes));
        println!(
            "field '{}' dims {:?} ({} values, abs_eb {:.3e}) — pass --out to write .f32",
            field.name,
            field.dims,
            field.len(),
            archive.header.abs_eb
        );
    } else {
        // restore straight through the fused slab pass into the file —
        // peak memory is the archive plus a band window, not the field
        let out = cli.get("out");
        let file = std::fs::File::create(&out).with_context(|| format!("creating {out}"))?;
        let mut w = std::io::BufWriter::new(file);
        let stats =
            coord.decompress_stream_into(&archive, coord.cfg.effective_threads(), &mut w)?;
        use std::io::Write;
        w.flush().with_context(|| format!("flushing {out}"))?;
        println!("{}", stats.timer.report(stats.original_bytes));
        println!("wrote {out} (dims {:?})", archive.header.dims);
    }
    write_metrics_snapshot(&cli)
}

/// `store get --all`: batch-decompress the whole bundle via the
/// decompression-side worker pipeline, optionally writing each field to
/// `--out-dir` as `<name>.f32` ('/' in names becomes '_').
fn store_get_all(cli: &Cli, store: &Store) -> Result<()> {
    let mut cfg = common_config(cli)?;
    if cfg.threads == 0 {
        cfg.threads = 2; // job-level concurrency comes from the drain pool
    }
    let coord = std::sync::Arc::new(Coordinator::new_with_fallback(cfg)?);
    let out_dir = cli.get("out-dir");
    if !out_dir.is_empty() {
        std::fs::create_dir_all(&out_dir)
            .with_context(|| format!("creating output dir {out_dir}"))?;
    }
    let drain_cfg = BatchConfig {
        workers: cli.get_parsed("workers")?,
        queue_depth: cli.get_parsed("queue")?,
        ..Default::default()
    };
    println!(
        "engine: {}  workers: {}  fields: {}",
        coord.engine_name(),
        drain_cfg.effective_workers(),
        store.len()
    );
    let drainer = BatchDecompressor::new(coord, drain_cfg);
    // sanitizing '/' can collide distinct field names ("a/b" vs "a_b");
    // pre-assign output names in stable index order so disambiguating
    // suffixes don't depend on decode completion order across runs
    let mut used: std::collections::HashSet<String> = std::collections::HashSet::new();
    let mut out_names: std::collections::HashMap<String, String> =
        std::collections::HashMap::new();
    for e in store.list() {
        let base = e.name.replace('/', "_");
        let mut fname = format!("{base}.f32");
        let mut k = 2;
        while !used.insert(fname.clone()) {
            fname = format!("{base}-{k}.f32");
            k += 1;
        }
        out_names.insert(e.name.clone(), fname);
    }
    let stats = drainer.drain(store, |entry_name, field, _| {
        if out_dir.is_empty() {
            println!("  {entry_name:<34} dims {:?} ({} values)", field.dims, field.len());
        } else {
            // keyed by the store entry name (not the header's field name,
            // which can differ under --name overrides and would collide)
            // the drain iterates the same in-memory listing the map was
            // built from, so a miss is an invariant violation, not a case
            let fname = out_names
                .get(entry_name)
                .cloned()
                .expect("output name pre-assigned from the same store listing");
            let path = PathBuf::from(&out_dir).join(fname);
            write_f32_file(&path.to_string_lossy(), &field.data)?;
            println!("  {entry_name:<34} -> {}", path.display());
        }
        Ok(())
    })?;
    for (name, err) in &stats.errors {
        println!("  {name:<34} FAILED: {err}");
    }
    println!("{}", stats.report());
    // snapshot first so partial-failure drains still leave telemetry behind
    write_metrics_snapshot(cli)?;
    if stats.failed > 0 {
        bail!(
            "{} of {} fields failed to restore (see FAILED lines above)",
            stats.failed,
            stats.failed + stats.jobs
        );
    }
    Ok(())
}

fn cmd_store_ls(args: &[String]) -> Result<()> {
    let cli = Cli::new("cusz store ls", "list bundle contents")
        .req("store", ".cuszb bundle path")
        .flag("verify", "CRC-verify every payload")
        .parse(args)?;
    let store = Store::open(cli.get("store"))?;
    println!(
        "{} — {} fields, {} shards, {:.2} MB live, {:.2} MB dead",
        cli.get("store"),
        store.len(),
        store.n_shards(),
        store.live_bytes() as f64 / 1e6,
        store.dead_bytes() as f64 / 1e6
    );
    println!(
        "{:<34} {:>16} {:>6} {:>12} {:>12} {:>7}",
        "name", "dims", "shard", "offset", "bytes", "CR"
    );
    for e in store.list() {
        let dims = e
            .dims
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        println!(
            "{:<34} {:>16} {:>6} {:>12} {:>12} {:>6.1}x",
            e.name,
            dims,
            e.shard,
            e.offset,
            e.len,
            e.compression_ratio()
        );
    }
    if cli.has_flag("verify") {
        store.verify()?;
        println!("verify: all payload CRCs OK");
    }
    Ok(())
}

fn cmd_store_rm(args: &[String]) -> Result<()> {
    let cli = Cli::new("cusz store rm", "remove a field from a bundle")
        .req("store", ".cuszb bundle path")
        .req("name", "field name to remove")
        .opt("durability", "flush", "index publish durability: none|flush|sync")
        .parse(args)?;
    let mut store = Store::open_writable(cli.get("store"))?;
    store.set_durability(Durability::parse(&cli.get("durability"))?);
    store.remove(&cli.get("name"))?;
    println!(
        "removed '{}' ({} fields remain; payload bytes reclaimed on compaction)",
        cli.get("name"),
        store.len()
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz serve", "batched streaming compression service"))
        .flag("batch", "batch mode: drain a finite field stream")
        .flag("daemon", "daemon mode: long-running TCP front end (README 'Serving')")
        .req("store", "output .cuszb bundle (created if absent)")
        .opt("shards", "4", "shard count when creating the bundle")
        .opt("dataset", "", "hacc|cesm|hurricane|nyx|qmcpack (required with --batch)")
        .opt("count", "8", "number of fields to stream (batch mode)")
        .opt("seed", "42", "base generator seed (batch mode)")
        .opt("workers", "0", "concurrent compression jobs (0 = all cores)")
        .opt("queue", "4", "bounded job-queue depth (daemon: full queue sheds BUSY)")
        .opt(
            "compact-threshold",
            "0",
            "auto-compact after the drain when dead bytes exceed this fraction of live bytes (0 = off)",
        )
        .opt("addr", "127.0.0.1:9599", "daemon listen address")
        .opt("max-conns", "64", "daemon concurrent-connection cap (excess sheds BUSY)")
        .opt("read-timeout-ms", "10000", "daemon per-connection read timeout")
        .opt("write-timeout-ms", "10000", "daemon per-connection write timeout")
        .opt("max-body-mb", "64", "daemon wire-frame body limit in MB")
        .opt(
            "max-payload",
            "",
            "daemon wire-frame body limit as a byte figure (e.g. 4m, 1g); \
             wins over --max-body-mb when set",
        )
        .opt(
            "mem-budget",
            "auto",
            "daemon admission budget in bytes (k/m/g suffix). Requests whose \
             estimated working set would push the in-flight total past this \
             are shed with BUSY before the body is buffered. 'auto' = half \
             of detected RAM; 'unlimited' disables byte-budget shedding",
        )
        .opt(
            "scrub-interval-ms",
            "1000",
            "daemon background scrubber: CRC-verify one stored entry per interval, \
             quarantining corrupt payloads (0 = off)",
        )
        .parse(args)?;
    if cli.has_flag("daemon") {
        if cli.has_flag("batch") {
            bail!("--batch and --daemon are mutually exclusive");
        }
        return serve_daemon(&cli);
    }
    if !cli.has_flag("batch") {
        bail!("pick a mode: --batch (finite stream) or --daemon (socket front end)");
    }
    if cli.get("dataset").is_empty() {
        bail!("--batch requires --dataset");
    }
    let mut cfg = common_config(&cli)?;
    // Job-level concurrency comes from the batch layer; keep each job's
    // internal slab/chunk parallelism narrow to avoid oversubscription.
    if cfg.threads == 0 {
        cfg.threads = 2;
    }
    let coord = std::sync::Arc::new(Coordinator::new_with_fallback(cfg)?);
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let count: usize = cli.get_parsed("count")?;
    let seed: u64 = cli.get_parsed("seed")?;
    let names = ds.field_names();
    let fields: Vec<Field> = (0..count)
        .map(|i| {
            let base = names[i % names.len()];
            let mut f = datagen::generate(ds, base, seed + (i / names.len()) as u64);
            if i >= names.len() {
                f.name = format!("{}#{}", f.name, i / names.len());
            }
            f
        })
        .collect();

    let mut store = Store::open_or_create(cli.get("store"), cli.get_parsed("shards")?)?;
    store.set_durability(Durability::parse(&cli.get("durability"))?);
    let batch_cfg = BatchConfig {
        workers: cli.get_parsed("workers")?,
        queue_depth: cli.get_parsed("queue")?,
        compact_threshold: cli.get_parsed("compact-threshold")?,
    };
    println!(
        "engine: {}  workers: {}  queue: {}  fields: {}",
        coord.engine_name(),
        batch_cfg.effective_workers(),
        batch_cfg.queue_depth,
        fields.len()
    );
    let batch = BatchCompressor::new(coord.clone(), batch_cfg);
    let stats = batch.run_into_store(fields, &mut store)?;
    for (name, job) in &stats.per_job {
        println!(
            "  {:<34} {:>9.2} MB  CR {:>6.2}x  enc {} [{}]",
            name,
            job.original_bytes as f64 / 1e6,
            job.compression_ratio(),
            job.encoder.name(),
            job.chunk_report()
        );
    }
    for (name, err) in &stats.errors {
        println!("  {name:<34} FAILED: {err}");
    }
    println!("{}", stats.report());
    println!("store: {} ({} fields)", cli.get("store"), store.len());
    write_metrics_snapshot(&cli)
}

/// `cusz serve --daemon`: bind the socket front end over a writable
/// store and block until a drain (SIGTERM/SIGINT, wire `SHUTDOWN`)
/// completes, then print the final stats and metrics snapshot.
fn serve_daemon(cli: &Cli) -> Result<()> {
    let mut cfg = common_config(cli)?;
    // per-job parallelism is split across the daemon's worker pool; keep
    // each job narrow by default, same discipline as the batch path
    if cfg.threads == 0 {
        cfg.threads = 2;
    }
    let coord = std::sync::Arc::new(Coordinator::new_with_fallback(cfg)?);
    let mut store = Store::open_or_create(cli.get("store"), cli.get_parsed("shards")?)?;
    // PUT acks are sent only after put_bytes returns, so the configured
    // level decides what an acked write has survived (see README)
    store.set_durability(Durability::parse(&cli.get("durability"))?);
    let read_ms: u64 = cli.get_parsed("read-timeout-ms")?;
    let write_ms: u64 = cli.get_parsed("write-timeout-ms")?;
    let max_body_mb: usize = cli.get_parsed("max-body-mb")?;
    let scrub_ms: u64 = cli.get_parsed("scrub-interval-ms")?;
    let max_body_bytes = if cli.get("max-payload").is_empty() {
        max_body_mb.saturating_mul(1 << 20)
    } else {
        usize::try_from(cusz::util::govern::parse_budget(&cli.get("max-payload"))?)
            .context("--max-payload does not fit in usize")?
    };
    // u64::MAX ('unlimited'/'none') disables admission; any other figure
    // becomes the governor's hard byte budget
    let mem_budget = match cusz::util::govern::parse_budget(&cli.get("mem-budget"))? {
        u64::MAX => None,
        budget => Some(budget),
    };
    let dcfg = cusz::serve::DaemonConfig {
        workers: cli.get_parsed("workers")?,
        queue_depth: cli.get_parsed("queue")?,
        max_connections: cli.get_parsed("max-conns")?,
        read_timeout: std::time::Duration::from_millis(read_ms),
        write_timeout: std::time::Duration::from_millis(write_ms),
        limits: cusz::serve::Limits { max_body_bytes, ..Default::default() },
        mem_budget,
        scrub_interval: if scrub_ms == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(scrub_ms))
        },
        ..Default::default()
    };
    cusz::serve::install_signal_drain();
    let handle = cusz::serve::Daemon::spawn(coord.clone(), store, cli.get("addr"), dcfg)?;
    println!(
        "engine: {}  daemon listening on {}  (SIGTERM or wire SHUTDOWN drains)",
        coord.engine_name(),
        handle.addr()
    );
    let stats = handle.wait()?;
    println!("{}", stats.report());
    write_metrics_snapshot(cli)
}

/// `cusz loadgen`: drive a running daemon with mixed put/get traffic and
/// write the `cusz-bench-serve/v1` report.
fn cmd_loadgen(args: &[String]) -> Result<()> {
    let cli = Cli::new("cusz loadgen", "mixed put/get traffic generator for the serve daemon")
        .opt("addr", "127.0.0.1:9599", "daemon address")
        .opt("clients", "8", "simulated clients (one thread + persistent connection each)")
        .opt("requests", "256", "total requests across all clients")
        .opt("put-ratio", "0.5", "fraction of requests that are PUTs")
        .opt("pattern", "steady", "arrival pattern: steady|bursty|diurnal")
        .opt("elems", "65536", "elements per generated field (4 bytes each)")
        .opt("pace-us", "0", "base inter-arrival delay per client in microseconds (0 = closed loop)")
        .opt("seed", "42", "workload seed")
        .opt("out", "BENCH_serve.json", "report path, empty to skip (cusz-bench-serve/v1)")
        .opt(
            "max-payload",
            "",
            "client-side wire body limit as a byte figure (e.g. 4m, 1g); keep \
             it at or above the daemon's or large GET replies fail client-side",
        )
        .opt(
            "acked-log",
            "",
            "write every daemon-acked PUT name here (one per line) — a \
             post-crash fsck can then audit that no acked write was lost",
        )
        .flag("quick", "CI smoke sizing: 4 clients, 96 requests, 16k elems")
        .flag("shutdown", "send a wire SHUTDOWN to the daemon after the run")
        .parse(args)?;
    let pace_us: u64 = cli.get_parsed("pace-us")?;
    let mut lcfg = cusz::serve::LoadgenConfig {
        addr: cli.get("addr"),
        clients: cli.get_parsed("clients")?,
        requests: cli.get_parsed("requests")?,
        put_ratio: cli.get_parsed("put-ratio")?,
        pattern: cusz::serve::ArrivalPattern::parse(&cli.get("pattern"))?,
        elems: cli.get_parsed("elems")?,
        pace: std::time::Duration::from_micros(pace_us),
        seed: cli.get_parsed("seed")?,
        ..Default::default()
    };
    if cli.has_flag("quick") {
        lcfg.clients = 4;
        lcfg.requests = 96;
        lcfg.elems = 16384;
    }
    if !cli.get("max-payload").is_empty() {
        lcfg.max_body_bytes =
            usize::try_from(cusz::util::govern::parse_budget(&cli.get("max-payload"))?)
                .context("--max-payload does not fit in usize")?;
    }
    let report = cusz::serve::loadgen::run(&lcfg)?;
    println!("{}", report.report());
    // the acked log is the crash-recovery audit trail: write it before
    // any failure bail so a killed daemon still leaves the evidence
    let acked_log = cli.get("acked-log");
    if !acked_log.is_empty() {
        let mut lines = report.acked_names.join("\n");
        if !lines.is_empty() {
            lines.push('\n');
        }
        std::fs::write(&acked_log, lines).with_context(|| format!("writing {acked_log}"))?;
        println!("wrote {} acked names to {acked_log}", report.acked_names.len());
    }
    let out = cli.get("out");
    if !out.is_empty() {
        std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    if cli.has_flag("shutdown") {
        let mut client =
            cusz::serve::Client::connect(&lcfg.addr, lcfg.read_timeout, lcfg.write_timeout)?;
        client.shutdown_server()?;
        println!("sent shutdown to {}", lcfg.addr);
    }
    if report.put.failed + report.get.failed > 0 {
        bail!(
            "loadgen saw {} failed puts and {} failed gets",
            report.put.failed,
            report.get.failed
        );
    }
    Ok(())
}

fn bench_field_name(ds: Dataset) -> &'static str {
    match ds {
        Dataset::Hacc => "vx",
        Dataset::CesmAtm => "CLDHGH",
        Dataset::Hurricane => "CLOUDf48",
        Dataset::Nyx => "baryon_density",
        Dataset::Qmcpack => "einspline",
    }
}

fn jnum(v: f64) -> String {
    if v.is_finite() { format!("{v:.4}") } else { "0".into() }
}

/// Host/commit provenance stamp for bench artifacts. `placeholder` marks
/// numbers that were committed as schema examples, not measured on CI.
fn generated_by_json(placeholder: bool) -> String {
    let clean = |v: String| {
        v.chars().filter(|c| c.is_ascii_alphanumeric() || "-._".contains(*c)).collect::<String>()
    };
    let host = std::env::var("HOSTNAME").map(clean).unwrap_or_default();
    let commit = std::env::var("GITHUB_SHA").map(clean).unwrap_or_default();
    format!(
        "{{\"host\": \"{}\", \"commit\": \"{}\", \"placeholder\": {placeholder}}}",
        if host.is_empty() { "unknown".into() } else { host },
        if commit.is_empty() { "unknown".into() } else { commit },
    )
}

/// Schema-v4 `kernels` section: the gap-array parallel Huffman decode of
/// a single-chunk stream (chunk-level parallelism pinned to zero, so all
/// speedup comes from subchunk fan-out) timed head-to-head against the
/// serial decode of the *same* bitstream, plus the u64-word FLE bitplane
/// kernel. CI's bench-smoke gate reads `huffman_gap_decode.speedup` and
/// fails the build when the gap path regresses to the serial rate on a
/// multicore runner.
fn bench_kernels(
    bench: &cusz::util::bench::Bench,
    threads: usize,
    quick: bool,
    seed: u64,
) -> Result<String> {
    use cusz::codec::{
        huffman_stage, stage_for, EncodeContext, EncoderKind, SymbolSink, SymbolSource,
    };

    let dict = 1024usize;
    let n: usize = if quick { 1 << 20 } else { 1 << 22 };
    let kbytes = n * 4; // GB/s convention: original f32 bytes per symbol
    // deterministic xorshift symbols spread over the dict
    let mut symbols = vec![0u16; n];
    let mut state: u64 = seed | 1;
    for s in symbols.iter_mut() {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        *s = (state % dict as u64) as u16;
    }
    let mut freq = vec![0u64; dict];
    for &s in &symbols {
        freq[s as usize] += 1;
    }
    let ctx = EncodeContext {
        dict_size: dict,
        chunk_symbols: n, // ONE deflate chunk: no chunk-level parallelism
        threads,
        codeword_repr: CodewordRepr::Adaptive,
        freq: &freq,
    };
    let src = SymbolSource::from_slice(&symbols);
    let (enc, gaps) = huffman_stage::encode_source_with_gaps(&src, &ctx)?;
    if enc.stream.chunks.len() != 1 {
        bail!("kernel bench stream must be a single chunk");
    }
    let subchunks = gaps.first().map_or(0, |g| g.len());

    let mut out = vec![0u16; n];
    let r_gap = bench.run("huffman gap-decode (single chunk)", kbytes, || {
        let mut sink = SymbolSink::from_slice(&mut out);
        huffman_stage::decode_into_gap(&enc.aux, &enc.stream, &gaps, dict, threads, &mut sink)
            .unwrap();
    });
    if out != symbols {
        bail!("gap decode does not match the encoded symbols");
    }
    out.fill(0);
    let r_ser = bench.run("huffman serial decode (single chunk)", kbytes, || {
        let mut sink = SymbolSink::from_slice(&mut out);
        huffman_stage::decode_into_gap(&enc.aux, &enc.stream, &[], dict, threads, &mut sink)
            .unwrap();
    });
    if out != symbols {
        bail!("serial decode does not match the encoded symbols");
    }

    let fle = stage_for(EncoderKind::Fle);
    let fenc = fle.encode_source(&src, &ctx)?;
    let r_fle = bench.run("fle word-kernel decode", kbytes, || {
        let mut sink = SymbolSink::from_slice(&mut out);
        fle.decode_into(&fenc.aux, &fenc.stream, dict, threads, &mut sink).unwrap();
    });

    let g = |d: std::time::Duration| kbytes as f64 / d.as_secs_f64().max(1e-12) / 1e9;
    let speedup = r_ser.mean.as_secs_f64() / r_gap.mean.as_secs_f64().max(1e-12);
    println!(
        "kernels: huffman gap-decode {:.3} GB/s vs serial {:.3} GB/s \
         ({speedup:.2}x at {threads} threads, {subchunks} subchunks); \
         fle word-kernel {:.3} GB/s",
        g(r_gap.mean),
        g(r_ser.mean),
        g(r_fle.mean)
    );
    Ok(format!(
        "{{\"huffman_gap_decode\": {{\"gbps\": {}, \"serial_gbps\": {}, \"speedup\": {}, \
         \"threads\": {threads}, \"subchunks\": {subchunks}, \"symbols\": {n}}}, \
         \"fle_word_kernel\": {{\"gbps\": {}, \"threads\": {threads}}}}}",
        jnum(g(r_gap.mean)),
        jnum(g(r_ser.mean)),
        jnum(speedup),
        jnum(g(r_fle.mean)),
    ))
}

/// `cusz bench`: the perf trajectory tracker. Measures per-stage and
/// end-to-end compress/decompress throughput plus compression ratio per
/// datagen profile, and compares (a) the streaming segmented
/// serialization against an emulation of the pre-zero-copy encode path
/// (two single-threaded monolithic serializations per field) and (b) the
/// fused slab-parallel decompress pipeline against the real pre-fusion
/// materializing path (`decompress_materializing`). Emits
/// `BENCH_pipeline.json` (schema `cusz-bench-pipeline/v4`: per-stage
/// GB/s, a `kernels` section timing the gap-array parallel Huffman
/// decode of a single-chunk stream against its serial path plus the
/// u64-word FLE kernel, a `generated_by` host/commit stamp, and an
/// `obs` section embedding the full cusz-metrics/v1 telemetry snapshot
/// the run produced) so CI archives comparable numbers across PRs.
fn cmd_bench(args: &[String]) -> Result<()> {
    use cusz::util::bench::{print_table, Bench};

    let cli = with_common(Cli::new("cusz bench", "machine-readable pipeline throughput report"))
        .opt("out", "BENCH_pipeline.json", "output JSON path")
        .opt("datasets", "", "comma-separated datasets (default: all five)")
        .opt("scale", "1", "axis scale multiplier for the synthetic fields")
        .opt("seed", "42", "generator seed")
        .flag("quick", "smoke-test reps (also via CUSZ_BENCH_QUICK=1)")
        .parse(args)?;
    let quick = cli.has_flag("quick")
        || std::env::var("CUSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let base_cfg = common_config(&cli)?;
    let threads = base_cfg.effective_threads();
    let seed: u64 = cli.get_parsed("seed")?;
    let scale: usize = cli.get_parsed("scale")?;
    let datasets: Vec<Dataset> = if cli.get("datasets").is_empty() {
        Dataset::ALL.to_vec()
    } else {
        cli.get("datasets")
            .split(',')
            .map(Dataset::parse)
            .collect::<Result<_>>()?
    };
    let profiles = [
        ("huffman+zstd", EncoderChoice::Huffman, LosslessStage::Zstd, CodecGranularity::Field),
        ("auto-chunk+zstd", EncoderChoice::Auto, LosslessStage::Zstd, CodecGranularity::Chunk),
        ("huffman+none", EncoderChoice::Huffman, LosslessStage::None, CodecGranularity::Field),
    ];

    let mut rows = Vec::new();
    let mut json_profiles: Vec<String> = Vec::new();
    let mut engine_name = "";
    for &ds in &datasets {
        let field = datagen::generate_scaled(ds, bench_field_name(ds), seed, scale);
        let bytes = field.size_bytes();
        let mb = bytes as f64 / 1e6;
        for (pname, encoder, lossless, granularity) in profiles {
            let mut cfg = base_cfg.clone();
            cfg.codec = CodecSpec { encoder, lossless, granularity };
            let coord = Coordinator::new_with_fallback(cfg)?;
            engine_name = coord.engine_name();

            let mut compressed = None;
            let rc = bench.run(&format!("{} {pname} compress", ds.name()), bytes, || {
                compressed = Some(coord.compress_encoded(&field).unwrap());
            });
            let c = compressed.unwrap();
            let mut dstats = None;
            let rd = bench.run(&format!("{} {pname} decompress", ds.name()), bytes, || {
                let a = Archive::from_bytes(&c.bytes).unwrap();
                let (f, s) = coord.decompress_with_stats(&a).unwrap();
                std::hint::black_box(f.data.len());
                dstats = Some(s);
            });
            let dstats = dstats.unwrap();
            // the pre-fusion baseline: whole-field symbol buffer, serial
            // patch/scatter/verbatim stages — the real old path, kept in
            // the tree so the speedup is measured, not estimated
            let rd_mono =
                bench.run(&format!("{} {pname} decompress-materializing", ds.name()), bytes, || {
                    let a = Archive::from_bytes(&c.bytes).unwrap();
                    let (f, _) = coord.decompress_materializing(&a).unwrap();
                    std::hint::black_box(f.data.len());
                });
            // serialization stage: the new path (one parallel segmented
            // write at the configured thread count — the same write the
            // compress measurement above performed) vs the pre-zero-copy
            // path (two single-threaded monolithic writes per field)
            let rs_seg = bench.run(&format!("{} {pname} serialize", ds.name()), bytes, || {
                c.archive
                    .write_into_with(
                        &mut std::io::sink(),
                        threads,
                        cusz::container::TAIL_SEGMENT_BYTES,
                    )
                    .unwrap();
            });
            let rs_mono =
                bench.run(&format!("{} {pname} serialize-legacy-x2", ds.name()), bytes, || {
                    for _ in 0..2 {
                        c.archive
                            .write_into_with(&mut std::io::sink(), 1, usize::MAX)
                            .unwrap();
                    }
                });
            let g = |d: std::time::Duration| bytes as f64 / d.as_secs_f64().max(1e-12) / 1e9;
            let ratio = bytes as f64 / c.bytes.len().max(1) as f64;
            let stage_speedup =
                rs_mono.mean.as_secs_f64() / rs_seg.mean.as_secs_f64().max(1e-12);
            let old_e2e =
                rc.mean.as_secs_f64() - rs_seg.mean.as_secs_f64() + rs_mono.mean.as_secs_f64();
            let e2e_speedup = old_e2e / rc.mean.as_secs_f64().max(1e-12);
            let d_speedup = rd_mono.mean.as_secs_f64() / rd.mean.as_secs_f64().max(1e-12);
            let t = &c.stats.timer;
            let dt = &dstats.timer;

            rows.push(vec![
                format!("{} {pname}", ds.name()),
                format!("{mb:.0}"),
                format!("{ratio:.2}"),
                format!("{:.3}", g(rc.mean)),
                format!("{:.3}", g(rd.mean)),
                format!("{stage_speedup:.2}x"),
                format!("{e2e_speedup:.2}x"),
                format!("{d_speedup:.2}x"),
            ]);
            // per-stage decompress GB/s come from the last timed rep's
            // instrumented StageTimer (stage shares are stable across reps)
            let dg = |stage: &str| bytes as f64 / dt.total(stage).as_secs_f64().max(1e-12) / 1e9;
            json_profiles.push(format!(
                concat!(
                    "    {{\"dataset\": \"{}\", \"field\": \"{}\", \"codec\": \"{}\", ",
                    "\"lossless\": \"{}\", \"granularity\": \"{}\",\n",
                    "     \"original_mb\": {}, \"compressed_mb\": {}, \"ratio\": {},\n",
                    "     \"compress_gbps\": {}, \"decompress_gbps\": {},\n",
                    "     \"stages\": {{\"predict_quant_gbps\": {}, \"histogram_gbps\": {}, ",
                    "\"codebook_ms\": {}, \"encode_deflate_gbps\": {}, \"container_gbps\": {}}},\n",
                    "     \"decompress_stages\": {{\"decode_gbps\": {}, ",
                    "\"fused_patch_reverse_scatter_gbps\": {}, \"threads\": {}}},\n",
                    "     \"decompress_speedup_e2e_vs_materializing\": {},\n",
                    "     \"serialize\": {{\"segmented_ms\": {}, \"monolithic_x2_ms\": {}, ",
                    "\"stage_speedup\": {}, \"e2e_speedup_vs_monolithic\": {}}}}}"
                ),
                ds.name(),
                bench_field_name(ds),
                encoder.name(),
                match lossless {
                    LosslessStage::None => "none",
                    LosslessStage::Gzip => "gzip",
                    LosslessStage::Zstd => "zstd",
                },
                granularity.name(),
                jnum(mb),
                jnum(c.bytes.len() as f64 / 1e6),
                jnum(ratio),
                jnum(g(rc.mean)),
                jnum(g(rd.mean)),
                jnum(g(t.total("1.predict-quant"))),
                jnum(g(t.total("2.histogram"))),
                jnum(t.total("3.codebook").as_secs_f64() * 1e3),
                jnum(g(t.total("5.encode-deflate"))),
                jnum(g(t.total("6.container"))),
                jnum(dg("1.decode")),
                jnum(dg("2.patch-reverse-scatter")),
                dstats.threads,
                jnum(d_speedup),
                jnum(rs_seg.mean.as_secs_f64() * 1e3),
                jnum(rs_mono.mean.as_secs_f64() * 1e3),
                jnum(stage_speedup),
                jnum(e2e_speedup),
            ));
        }
    }

    print_table(
        "Pipeline bench (GB/s of original data; speedups vs the pre-zero-copy \
         serialization and the materializing decompress path)",
        &["dataset/profile", "MB", "CR", "compress", "decompress", "ser-stage", "e2e", "d-e2e"],
        &rows,
    );

    let kernels_json = bench_kernels(&bench, threads, quick, seed)?;

    // the full telemetry snapshot rides along: every stage span, codec
    // counter, and histogram the benched pipelines recorded
    let obs_json = cusz::obs::global().snapshot().to_json();
    let json = format!(
        "{{\n  \"schema\": \"cusz-bench-pipeline/v4\",\n  \"engine\": \"{}\",\n  \
         \"threads\": {},\n  \"quick\": {},\n  \"scale\": {},\n  \
         \"generated_by\": {},\n  \"kernels\": {},\n  \"profiles\": [\n{}\n  ],\n  \"obs\": {}\n}}\n",
        engine_name,
        threads,
        quick,
        scale,
        generated_by_json(false),
        kernels_json,
        json_profiles.join(",\n"),
        obs_json.trim_end(),
    );
    let out = cli.get("out");
    std::fs::write(&out, &json).with_context(|| format!("writing {out}"))?;
    println!("\nwrote {out} ({} profiles)", json_profiles.len());
    write_metrics_snapshot(&cli)
}

fn cmd_selftest(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz selftest", "cross-validate PJRT vs CPU")).parse(args)?;
    let mut cfg = common_config(&cli)?;
    cfg.backend = BackendKind::Pjrt;
    let pjrt = Coordinator::new(cfg.clone()).context("PJRT engine (run `make artifacts`?)")?;
    cfg.backend = BackendKind::Cpu;
    let cpu = Coordinator::new(cfg)?;
    let mut checked = 0;
    for ds in Dataset::ALL {
        let fname = ds.field_names()[0];
        let field = datagen::generate(ds, fname, 1);
        let a = pjrt.compress(&field)?;
        let b = cpu.compress(&field)?;
        if a.to_bytes() != b.to_bytes() {
            bail!("{}/{fname}: PJRT and CPU archives differ", ds.name());
        }
        let out = pjrt.decompress(&a)?;
        if metrics::verify_error_bound(&field.data, &out.data, a.header.abs_eb).is_some() {
            bail!("{}/{fname}: error bound violated", ds.name());
        }
        println!("  {}/{fname}: OK (bit-exact, bound respected)", ds.name());
        checked += 1;
    }
    println!("selftest passed: {checked} fields bit-exact across PJRT and CPU");
    write_metrics_snapshot(&cli)
}
