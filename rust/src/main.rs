//! cusz CLI — leader entrypoint for the cusz-rs framework.
//!
//! Subcommands:
//!   gen         generate a synthetic SDRBench-like field to a raw .f32 file
//!   compress    compress a raw .f32 field to a .cusza archive
//!   decompress  restore a .cusza archive to raw .f32
//!   roundtrip   compress+decompress a dataset field, report CR/PSNR/bound
//!   stats       Table 9-style percentile statistics for a field
//!   selftest    cross-validate the PJRT path against the CPU mirror
//!
//! Examples:
//!   cusz roundtrip --dataset nyx --field baryon_density --eb 1e-4
//!   cusz gen --dataset cesm --field CLDHGH --out /tmp/cldhgh.f32
//!   cusz compress --input /tmp/cldhgh.f32 --dims 450,900 --eb 1e-4 \
//!        --out /tmp/cldhgh.cusza

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use cusz::config::{BackendKind, CodewordRepr, CuszConfig, ErrorBound, LosslessStage};
use cusz::container::Archive;
use cusz::coordinator::Coordinator;
use cusz::datagen::{self, Dataset};
use cusz::field::Field;
use cusz::metrics;
use cusz::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "gen" => cmd_gen(rest),
        "compress" => cmd_compress(rest),
        "decompress" => cmd_decompress(rest),
        "roundtrip" => cmd_roundtrip(rest),
        "stats" => cmd_stats(rest),
        "selftest" => cmd_selftest(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "cusz — error-bounded lossy compressor for scientific data (cuSZ, PACT'20)\n\
     \n\
     Subcommands:\n\
       gen         --dataset D --field F [--seed N] [--scale N] --out PATH\n\
       compress    --input PATH --dims d0,d1,.. [--eb E | --abs-eb E] [--out PATH]\n\
       decompress  --input PATH.cusza [--out PATH]\n\
       roundtrip   --dataset D [--field F] [--eb E] [--backend pjrt|cpu]\n\
       stats       --dataset D --field F [--eb E]\n\
       selftest    [--backend pjrt]\n\
     \n\
     Common options: --backend pjrt|cpu, --threads N, --chunk N,\n\
       --dict N, --repr adaptive|u32|u64, --lossless none|gzip|zstd,\n\
       --artifacts DIR"
        .to_string()
}

fn common_config(cli: &Cli) -> Result<CuszConfig> {
    let mut cfg = CuszConfig::default();
    cfg.backend = match cli.get("backend").as_str() {
        "pjrt" => BackendKind::Pjrt,
        "cpu" => BackendKind::Cpu,
        b => bail!("unknown backend {b}"),
    };
    let eb: f64 = cli.get_parsed("eb")?;
    let abs: f64 = cli.get_parsed("abs-eb")?;
    cfg.eb = if abs > 0.0 { ErrorBound::Abs(abs) } else { ErrorBound::ValRel(eb) };
    cfg.threads = cli.get_parsed("threads")?;
    cfg.chunk_symbols = cli.get_parsed("chunk")?;
    cfg.dict_size = cli.get_parsed("dict")?;
    cfg.codeword_repr = match cli.get("repr").as_str() {
        "adaptive" => CodewordRepr::Adaptive,
        "u32" => CodewordRepr::U32,
        "u64" => CodewordRepr::U64,
        r => bail!("unknown repr {r}"),
    };
    cfg.lossless = match cli.get("lossless").as_str() {
        "none" => LosslessStage::None,
        "gzip" => LosslessStage::Gzip,
        "zstd" => LosslessStage::Zstd,
        l => bail!("unknown lossless stage {l}"),
    };
    cfg.artifacts_dir = PathBuf::from(cli.get("artifacts"));
    Ok(cfg)
}

fn with_common(cli: Cli) -> Cli {
    cli.opt("backend", "pjrt", "quant engine: pjrt (AOT HLO) or cpu (mirror)")
        .opt("eb", "1e-4", "value-range-relative error bound (valrel)")
        .opt("abs-eb", "0", "absolute error bound (overrides --eb if > 0)")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .opt("chunk", "4096", "deflate chunk size in symbols (Table 6)")
        .opt("dict", "1024", "quantization bins / Huffman symbols (Table 3)")
        .opt("repr", "adaptive", "codeword repr: adaptive|u32|u64 (Table 4)")
        .opt("lossless", "none", "final lossless stage: none|gzip|zstd")
        .opt("artifacts", "artifacts", "AOT artifact directory")
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    s.split(',').map(|d| d.parse::<usize>().context("parsing dims")).collect()
}

fn read_f32_file(path: &str) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path}: size {} not a multiple of 4", bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn write_f32_file(path: &str, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {path}"))
}

fn cmd_gen(args: &[String]) -> Result<()> {
    let cli = Cli::new("cusz gen", "generate a synthetic SDRBench-like field")
        .req("dataset", "hacc|cesm|hurricane|nyx|qmcpack")
        .req("field", "field name (e.g. CLOUDf48, baryon_density)")
        .opt("seed", "42", "generator seed")
        .opt("scale", "1", "axis scale multiplier")
        .req("out", "output .f32 path")
        .parse(args)?;
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let field = datagen::generate_scaled(ds, &cli.get("field"), cli.get_parsed("seed")?, cli.get_parsed("scale")?);
    write_f32_file(&cli.get("out"), &field.data)?;
    println!(
        "wrote {} ({} elements, dims {:?}, {:.2} MB)",
        cli.get("out"),
        field.len(),
        field.dims,
        field.size_bytes() as f64 / 1e6
    );
    Ok(())
}

fn cmd_compress(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz compress", "compress a raw .f32 field"))
        .req("input", "input .f32 path")
        .req("dims", "comma-separated dims, slowest first (e.g. 100,500,500)")
        .opt("out", "", "output archive path (default: <input>.cusza)")
        .parse(args)?;
    let cfg = common_config(&cli)?;
    let dims = parse_dims(&cli.get("dims"))?;
    let input = cli.get("input");
    let data = read_f32_file(&input)?;
    let name = PathBuf::from(&input)
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "field".into());
    let field = Field::new(name, dims, data)?;
    let coord = Coordinator::new(cfg)?;
    let (archive, stats) = coord.compress_with_stats(&field)?;
    let out = if cli.get("out").is_empty() { format!("{input}.cusza") } else { cli.get("out") };
    std::fs::write(&out, archive.to_bytes())?;
    println!("engine: {}", coord.engine_name());
    println!("{}", stats.report());
    println!("wrote {out}");
    Ok(())
}

fn cmd_decompress(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz decompress", "restore a .cusza archive"))
        .req("input", "input .cusza path")
        .opt("out", "", "output .f32 path (default: <input>.out.f32)")
        .parse(args)?;
    let cfg = common_config(&cli)?;
    let input = cli.get("input");
    let archive = Archive::from_bytes(&std::fs::read(&input)?)?;
    let coord = Coordinator::new(cfg)?;
    let (field, stats) = coord.decompress_with_stats(&archive)?;
    let out = if cli.get("out").is_empty() { format!("{input}.out.f32") } else { cli.get("out") };
    write_f32_file(&out, &field.data)?;
    println!("engine: {}", coord.engine_name());
    println!("{}", stats.timer.report(stats.original_bytes));
    println!("wrote {out} (dims {:?})", field.dims);
    Ok(())
}

fn cmd_roundtrip(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz roundtrip", "compress+decompress with quality report"))
        .req("dataset", "hacc|cesm|hurricane|nyx|qmcpack")
        .opt("field", "", "field name (default: first field of the dataset)")
        .opt("seed", "42", "generator seed")
        .opt("scale", "1", "axis scale multiplier")
        .parse(args)?;
    let cfg = common_config(&cli)?;
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let fname = if cli.get("field").is_empty() {
        ds.field_names()[0].to_string()
    } else {
        cli.get("field")
    };
    let field = datagen::generate_scaled(ds, &fname, cli.get_parsed("seed")?, cli.get_parsed("scale")?);
    let coord = Coordinator::new_with_fallback(cfg)?;
    println!("engine: {}   field: {}  dims {:?}", coord.engine_name(), field.name, field.dims);

    let (archive, cstats) = coord.compress_with_stats(&field)?;
    println!("--- compression ---\n{}", cstats.report());
    let (out, dstats) = coord.decompress_with_stats(&archive)?;
    println!("--- decompression ---\n{}", dstats.timer.report(dstats.original_bytes));

    let psnr = metrics::psnr(&field.data, &out.data);
    let maxerr = metrics::max_abs_error(&field.data, &out.data);
    println!("--- quality ---");
    println!("  abs eb       {:.6e}", archive.header.abs_eb);
    println!("  max |err|    {maxerr:.6e}");
    println!("  PSNR         {psnr:.2} dB");
    match metrics::verify_error_bound(&field.data, &out.data, archive.header.abs_eb) {
        None => println!("  error bound  RESPECTED"),
        Some(i) => bail!("error bound VIOLATED at index {i}"),
    }
    Ok(())
}

fn cmd_stats(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz stats", "Table 9-style field statistics"))
        .req("dataset", "dataset name")
        .req("field", "field name")
        .opt("seed", "42", "generator seed")
        .parse(args)?;
    let ds = Dataset::parse(&cli.get("dataset"))?;
    let field = datagen::generate(ds, &cli.get("field"), cli.get_parsed("seed")?);
    let mut sorted = field.data.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
    let (min, max) = (sorted[0], sorted[sorted.len() - 1]);
    let range = max - min;
    let valrel: f64 = cli.get_parsed("eb")?;
    let eb = (valrel * range as f64) as f32;
    println!("field {}  ({} values)", field.name, field.len());
    println!(
        "  min {min:.3e}  1% {:.3e}  25% {:.3e}  50% {:.3e}  75% {:.3e}  99% {:.3e}  max {max:.3e}  range {range:.3e}",
        pct(0.01), pct(0.25), pct(0.50), pct(0.75), pct(0.99)
    );
    for (label, e) in [("eb", eb), ("eb/10", eb / 10.0)] {
        let near0 = field.data.iter().filter(|&&v| v.abs() <= e).count();
        let nearmin = field.data.iter().filter(|&&v| v - min <= e).count();
        println!(
            "  {label} = {e:.3e}: {:.2}% in [-eb, eb], {:.2}% in [min, min+eb]",
            100.0 * near0 as f64 / field.len() as f64,
            100.0 * nearmin as f64 / field.len() as f64
        );
    }
    Ok(())
}

fn cmd_selftest(args: &[String]) -> Result<()> {
    let cli = with_common(Cli::new("cusz selftest", "cross-validate PJRT vs CPU")).parse(args)?;
    let mut cfg = common_config(&cli)?;
    cfg.backend = BackendKind::Pjrt;
    let pjrt = Coordinator::new(cfg.clone()).context("PJRT engine (run `make artifacts`?)")?;
    cfg.backend = BackendKind::Cpu;
    let cpu = Coordinator::new(cfg)?;
    let mut checked = 0;
    for ds in Dataset::ALL {
        let fname = ds.field_names()[0];
        let field = datagen::generate(ds, fname, 1);
        let a = pjrt.compress(&field)?;
        let b = cpu.compress(&field)?;
        if a.to_bytes() != b.to_bytes() {
            bail!("{}/{fname}: PJRT and CPU archives differ", ds.name());
        }
        let out = pjrt.decompress(&a)?;
        if metrics::verify_error_bound(&field.data, &out.data, a.header.abs_eb).is_some() {
            bail!("{}/{fname}: error bound violated", ds.name());
        }
        println!("  {}/{fname}: OK (bit-exact, bound respected)", ds.name());
        checked += 1;
    }
    println!("selftest passed: {checked} fields bit-exact across PJRT and CPU");
    Ok(())
}
