//! # cusz-rs
//!
//! A production-shaped reproduction of **cuSZ** (Tian et al., PACT '20):
//! error-bounded lossy compression for scientific floating-point data,
//! built as a three-layer Rust + JAX + Pallas stack.
//!
//! * **L1/L2** (build time, Python): the DUAL-QUANTIZATION Lorenzo
//!   predict-quant, histogram, and inverse-Lorenzo reconstruction are Pallas
//!   kernels composed into JAX graphs and AOT-lowered to HLO text
//!   (`make artifacts`).
//! * **L3** (this crate): a streaming coordinator that tiles fields into
//!   slabs, executes the AOT executables through PJRT ([`runtime`]),
//!   performs customized canonical Huffman coding ([`huffman`]), and owns
//!   the archive format ([`container`]), baselines ([`sz`], [`zfp`]),
//!   synthetic datasets ([`datagen`]) and metrics ([`metrics`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use cusz::config::{CuszConfig, ErrorBound};
//! use cusz::coordinator::Coordinator;
//! use cusz::datagen::{self, Dataset};
//!
//! let field = datagen::generate(Dataset::Nyx, "baryon_density", 42);
//! let cfg = CuszConfig { eb: ErrorBound::ValRel(1e-4), ..Default::default() };
//! let coord = Coordinator::new(cfg).unwrap();
//! let archive = coord.compress(&field).unwrap();
//! let restored = coord.decompress(&archive).unwrap();
//! ```

pub mod config;
pub mod container;
pub mod coordinator;
pub mod datagen;
pub mod field;
pub mod huffman;
pub mod metrics;
pub mod runtime;
pub mod sz;
pub mod testkit;
pub mod util;
pub mod zfp;

pub use config::{CuszConfig, ErrorBound};
pub use coordinator::Coordinator;
pub use field::Field;
