//! # cusz-rs
//!
//! A production-shaped reproduction of **cuSZ** (Tian et al., PACT '20):
//! error-bounded lossy compression for scientific floating-point data,
//! built as a three-layer Rust + JAX + Pallas stack.
//!
//! * **L1/L2** (build time, Python): the DUAL-QUANTIZATION Lorenzo
//!   predict-quant, histogram, and inverse-Lorenzo reconstruction are Pallas
//!   kernels composed into JAX graphs and AOT-lowered to HLO text
//!   (`make artifacts`).
//! * **L3** (this crate): a streaming coordinator that tiles fields into
//!   slabs, executes the AOT executables through PJRT ([`runtime`]),
//!   encodes quant codes through a pluggable codec pipeline ([`codec`]:
//!   canonical Huffman on the [`huffman`] substrate, an FZ-GPU-style
//!   fixed-length bitshuffle encoder, or a run-length backend — selected
//!   in `auto` mode per field or per chunk by a measured cost model),
//!   and owns the versioned archive format ([`container`]), baselines
//!   ([`sz`], [`zfp`]), synthetic datasets ([`datagen`]) and metrics
//!   ([`metrics`]). Every layer records into the unified telemetry
//!   registry ([`obs`]): lock-free counters, per-stage spans, and latency
//!   histograms, exported as a versioned JSON snapshot or Prometheus text.
//! * **Serving layer**: the [`store`] module bundles many compressed
//!   fields into one sharded `.cuszb` archive with a footer index and
//!   random-access per-field decompression, and [`serve`] runs a batched
//!   streaming compression service (bounded worker pipeline, shared
//!   engine, service-level stats) that writes into a store.
//!
//! ## Quickstart
//!
//! ```no_run
//! use cusz::config::{CuszConfig, ErrorBound};
//! use cusz::coordinator::Coordinator;
//! use cusz::datagen::{self, Dataset};
//!
//! let field = datagen::generate(Dataset::Nyx, "baryon_density", 42);
//! let cfg = CuszConfig { eb: ErrorBound::ValRel(1e-4), ..Default::default() };
//! let coord = Coordinator::new(cfg).unwrap();
//! let archive = coord.compress(&field).unwrap();
//! let restored = coord.decompress(&archive).unwrap();
//! ```
//!
//! ## Batched multi-field serving
//!
//! ```no_run
//! use std::sync::Arc;
//! use cusz::config::{BackendKind, CuszConfig, ErrorBound};
//! use cusz::coordinator::Coordinator;
//! use cusz::datagen::{self, Dataset};
//! use cusz::serve::{BatchCompressor, BatchConfig};
//! use cusz::store::Store;
//!
//! let coord = Arc::new(Coordinator::new_with_fallback(CuszConfig {
//!     backend: BackendKind::Cpu,
//!     eb: ErrorBound::ValRel(1e-4),
//!     threads: 1, // per-job; the batch layer supplies job concurrency
//!     ..Default::default()
//! }).unwrap());
//! let mut store = Store::create("snapshot.cuszb", 4).unwrap();
//! let batch = BatchCompressor::new(coord.clone(), BatchConfig::default());
//! let fields: Vec<_> = Dataset::Nyx
//!     .field_names()
//!     .into_iter()
//!     .map(|f| datagen::generate(Dataset::Nyx, f, 42))
//!     .collect();
//! let stats = batch.run_into_store(fields, &mut store).unwrap();
//! println!("{}", stats.report());
//! // later: random access to one field, no sibling payloads touched
//! let one = store.get("NYX/baryon_density").unwrap();
//! let restored = coord.decompress(&one).unwrap();
//! ```

pub mod codec;
pub mod config;
pub mod container;
pub mod coordinator;
pub mod datagen;
pub mod field;
pub mod huffman;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod sz;
pub mod testkit;
pub mod util;
pub mod zfp;

pub use codec::{CodecGranularity, CodecSpec, EncoderChoice, EncoderKind, SymbolSource};
pub use config::{CuszConfig, ErrorBound};
pub use coordinator::{CompressedField, Coordinator};
pub use field::Field;
pub use serve::{BatchCompressor, BatchConfig, BatchDecompressor, DrainStats, ServiceStats};
pub use serve::{Daemon, DaemonConfig, DaemonHandle, DaemonStats, LoadReport, LoadgenConfig};
pub use store::Store;
