//! Archive header: everything decompression needs besides the payload.
//!
//! The header is versioned. Version 0 is the pre-codec layout (no version
//! byte, Huffman implied) still produced by old archives; version 1
//! prefixes a format-version byte and an encoder tag so the archive is
//! self-describing about which [`crate::codec::EncoderStage`] wrote it;
//! version 2 adds a codec-granularity byte — when it says `Chunk`, the
//! body carries a per-chunk encoder tag table and the header's encoder
//! tag records only the majority backend (an `ls`-level summary).
//! Version 3 keeps the version-2 header layout byte for byte; what it
//! changes is the **body**: a gzip/zstd lossless tail is framed over
//! independent segments so both sides of the tail run chunk-parallel
//! (see `container::mod`). Version 4 likewise keeps the header layout
//! and adds an optional per-chunk Huffman gap-table section to the
//! body. Which parser runs is selected by the container magic
//! ([`crate::container::MAGIC_V0`] / [`crate::container::MAGIC_V1`] /
//! [`crate::container::MAGIC_V3`] / [`crate::container::MAGIC`]), since
//! the legacy layout's first byte is a name-length byte and cannot be
//! distinguished in-band.

use anyhow::{bail, Result};

use super::bytes::{ByteReader, ByteWriter};
use crate::codec::{CodecGranularity, EncoderKind};
use crate::config::ErrorBound;

/// The archive format version this build writes. Version 4 = optional
/// per-chunk Huffman gap tables in the body (subchunk bit-offset index
/// for intra-chunk parallel decode); headers stay layout-identical to
/// v2/v3 — only the body framing and the container magic change.
pub const FORMAT_VERSION: u8 = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LosslessTag {
    None,
    Gzip,
    Zstd,
}

impl LosslessTag {
    fn to_u8(self) -> u8 {
        match self {
            LosslessTag::None => 0,
            LosslessTag::Gzip => 1,
            LosslessTag::Zstd => 2,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => LosslessTag::None,
            1 => LosslessTag::Gzip,
            2 => LosslessTag::Zstd,
            _ => bail!("unknown lossless tag {v}"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Header {
    /// Archive format version: 0 = legacy pre-codec layout (implicit
    /// Huffman), 1 = codec-tagged, 2 = codec-tagged with selection
    /// granularity (and, at chunk granularity, a per-chunk tag table in
    /// the body). Serialization mirrors whichever version is set so
    /// digests of old payloads stay stable.
    pub version: u8,
    /// Which encoder backend produced the symbol stream (at chunk
    /// granularity: the majority backend; the body's tag table governs).
    pub encoder: EncoderKind,
    /// How the encoder was selected (field-uniform vs per chunk).
    pub granularity: CodecGranularity,
    pub field_name: String,
    /// Logical field dims (pre-fold; decompression restores this shape).
    pub dims: Vec<usize>,
    /// Slab variant name (must exist in the artifact manifest or be a
    /// CPU-known spec).
    pub variant: String,
    /// The user-requested bound (mode + value), for provenance.
    pub eb: ErrorBound,
    /// The resolved absolute bound actually applied.
    pub abs_eb: f32,
    pub dict_size: usize,
    pub chunk_symbols: usize,
    /// Codeword representation used at encode time (Huffman: 32 or 64,
    /// Table 4; FLE: widest chunk).
    pub repr_bits: u32,
    pub lossless: LosslessTag,
    pub n_slabs: usize,
}

impl Header {
    pub fn to_bytes(&self) -> Vec<u8> {
        // the legacy layout has no tag byte, so it cannot represent any
        // other encoder — writing one silently would reparse as Huffman
        // and misdecode; fail loudly at the source instead
        assert!(
            self.version >= 1 || self.encoder == EncoderKind::Huffman,
            "version-0 archives cannot represent encoder {:?}",
            self.encoder
        );
        // pre-granularity layouts likewise cannot represent the RLE tag
        // (old readers reject tag 2) or per-chunk selection
        assert!(
            self.version >= 2
                || (self.granularity == CodecGranularity::Field
                    && self.encoder != EncoderKind::Rle),
            "version-{} archives cannot represent {:?}/{:?}",
            self.version,
            self.encoder,
            self.granularity
        );
        let mut w = ByteWriter::new();
        if self.version >= 1 {
            w.u8(self.version);
            w.u8(self.encoder.to_tag());
        }
        if self.version >= 2 {
            w.u8(self.granularity.to_u8());
        }
        w.str(&self.field_name);
        w.u32(self.dims.len() as u32);
        for &d in &self.dims {
            w.u64(d as u64);
        }
        w.str(&self.variant);
        match self.eb {
            ErrorBound::Abs(v) => {
                w.u8(0);
                w.f64(v);
            }
            ErrorBound::ValRel(v) => {
                w.u8(1);
                w.f64(v);
            }
        }
        w.f32(self.abs_eb);
        w.u32(self.dict_size as u32);
        w.u32(self.chunk_symbols as u32);
        w.u32(self.repr_bits);
        w.u8(self.lossless.to_u8());
        w.u64(self.n_slabs as u64);
        w.finish()
    }

    /// Parse a versioned (`CUSZA2`/`CUSZA3`/`CUSZA4` magic) header. Rejects
    /// version bytes this build does not understand, unknown encoder
    /// tags, and unknown granularity tags.
    pub fn from_bytes(bytes: &[u8]) -> Result<Header> {
        let mut r = ByteReader::new(bytes);
        let version = r.u8()?;
        if version == 0 || version > FORMAT_VERSION {
            bail!(
                "unsupported archive format version {version} (this build reads 1..={FORMAT_VERSION})"
            );
        }
        let encoder = EncoderKind::from_tag(r.u8()?)?;
        let granularity = if version >= 2 {
            CodecGranularity::from_u8(r.u8()?)?
        } else {
            CodecGranularity::Field
        };
        if version < 2 && encoder == EncoderKind::Rle {
            bail!("version-{version} archive carries the RLE tag (corrupt header?)");
        }
        Self::read_common(&mut r, version, encoder, granularity)
    }

    /// Parse a legacy (version-0, `CUSZA1` magic) header: the pre-codec
    /// layout with no version byte and Huffman implied.
    pub fn from_bytes_v0(bytes: &[u8]) -> Result<Header> {
        let mut r = ByteReader::new(bytes);
        Self::read_common(&mut r, 0, EncoderKind::Huffman, CodecGranularity::Field)
    }

    fn read_common(
        r: &mut ByteReader<'_>,
        version: u8,
        encoder: EncoderKind,
        granularity: CodecGranularity,
    ) -> Result<Header> {
        let field_name = r.str()?;
        let nd = r.u32()? as usize;
        if nd == 0 || nd > 4 {
            bail!("bad ndim {nd}");
        }
        let mut dims = Vec::with_capacity(nd);
        let mut product: u64 = 1;
        for _ in 0..nd {
            let d = r.u64()?;
            // bound the claimed shape (≤ 2^33 elements ≈ 34 GB of f32):
            // downstream allocation caps are derived from it
            product = product
                .saturating_mul(d.max(1))
                .min(1 << 34);
            if d > 1 << 33 || product > 1 << 33 {
                bail!("implausible field dims (> 2^33 elements)");
            }
            dims.push(d as usize);
        }
        let variant = r.str()?;
        let eb = match r.u8()? {
            0 => ErrorBound::Abs(r.f64()?),
            1 => ErrorBound::ValRel(r.f64()?),
            m => bail!("bad eb mode {m}"),
        };
        let abs_eb = r.f32()?;
        if !(abs_eb > 0.0) {
            bail!("non-positive abs_eb {abs_eb}");
        }
        Ok(Header {
            version,
            encoder,
            granularity,
            field_name,
            dims,
            variant,
            eb,
            abs_eb,
            dict_size: r.u32()? as usize,
            chunk_symbols: r.u32()? as usize,
            repr_bits: r.u32()?,
            lossless: LosslessTag::from_u8(r.u8()?)?,
            n_slabs: r.u64()? as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(version: u8, encoder: EncoderKind, eb: ErrorBound) -> Header {
        Header {
            version,
            encoder,
            granularity: CodecGranularity::Field,
            field_name: "f".into(),
            dims: vec![10, 20],
            variant: "2d_256".into(),
            eb,
            abs_eb: 0.5,
            dict_size: 1024,
            chunk_symbols: 4096,
            repr_bits: 32,
            lossless: LosslessTag::Zstd,
            n_slabs: 3,
        }
    }

    #[test]
    fn roundtrip_both_eb_modes_all_encoders() {
        for eb in [ErrorBound::Abs(0.125), ErrorBound::ValRel(1e-4)] {
            for encoder in EncoderKind::ALL {
                let h = sample(FORMAT_VERSION, encoder, eb);
                let b = Header::from_bytes(&h.to_bytes()).unwrap();
                assert_eq!(h, b);
            }
        }
    }

    #[test]
    fn chunk_granularity_roundtrips_and_v1_stays_fixed_layout() {
        let mut h = sample(FORMAT_VERSION, EncoderKind::Fle, ErrorBound::Abs(0.5));
        h.granularity = CodecGranularity::Chunk;
        let b = Header::from_bytes(&h.to_bytes()).unwrap();
        assert_eq!(b.granularity, CodecGranularity::Chunk);
        assert_eq!(h, b);
        // a version-1 header has no granularity byte and parses as Field
        let h1 = sample(1, EncoderKind::Fle, ErrorBound::Abs(0.5));
        let bytes = h1.to_bytes();
        assert_eq!(bytes.len(), sample(0, EncoderKind::Huffman, ErrorBound::Abs(0.5)).to_bytes().len() + 2);
        let b1 = Header::from_bytes(&bytes).unwrap();
        assert_eq!(b1.granularity, CodecGranularity::Field);
        assert_eq!(h1, b1);
        // an unknown granularity tag under the current version is rejected
        let mut bad = h.to_bytes();
        bad[2] = 9;
        assert!(Header::from_bytes(&bad).unwrap_err().to_string().contains("granularity"));
    }

    #[test]
    fn v1_rle_tag_rejected() {
        // version-1 archives predate RLE: a v1 header claiming tag 2 is
        // corrupt, not a valid combination
        let h1 = sample(1, EncoderKind::Fle, ErrorBound::Abs(0.5));
        let mut bytes = h1.to_bytes();
        bytes[1] = EncoderKind::Rle.to_tag();
        let err = Header::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("RLE"), "{err:#}");
    }

    #[test]
    fn v0_layout_roundtrips_without_prefix() {
        let h = sample(0, EncoderKind::Huffman, ErrorBound::Abs(0.25));
        let bytes = h.to_bytes();
        // legacy layout starts with the name length, not a version byte
        assert_eq!(&bytes[..4], &1u32.to_le_bytes());
        let b = Header::from_bytes_v0(&bytes).unwrap();
        assert_eq!(h, b);
    }

    #[test]
    fn unknown_encoder_tag_rejected_cleanly() {
        let h = sample(FORMAT_VERSION, EncoderKind::Fle, ErrorBound::Abs(1.0));
        let mut bytes = h.to_bytes();
        bytes[1] = 200; // encoder tag byte
        let err = Header::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("encoder tag"), "{err:#}");
    }

    #[test]
    fn future_format_version_rejected_cleanly() {
        let h = sample(FORMAT_VERSION, EncoderKind::Huffman, ErrorBound::Abs(1.0));
        let mut bytes = h.to_bytes();
        bytes[0] = FORMAT_VERSION + 1;
        let err = Header::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("format version"), "{err:#}");
        // and a zero version byte under the current magic is malformed
        bytes[0] = 0;
        assert!(Header::from_bytes(&bytes).is_err());
    }

    #[test]
    fn invalid_headers_rejected() {
        let h = Header {
            version: FORMAT_VERSION,
            encoder: EncoderKind::Huffman,
            granularity: CodecGranularity::Field,
            field_name: "f".into(),
            dims: vec![4],
            variant: "v".into(),
            eb: ErrorBound::Abs(1.0),
            abs_eb: 1.0,
            dict_size: 1024,
            chunk_symbols: 1,
            repr_bits: 64,
            lossless: LosslessTag::None,
            n_slabs: 1,
        };
        let mut bytes = h.to_bytes();
        // corrupt the ndim field (version + tag + granularity + 4-byte
        // len + 1 byte "f")
        bytes[8] = 200;
        assert!(Header::from_bytes(&bytes).is_err());
    }
}
