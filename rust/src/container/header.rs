//! Archive header: everything decompression needs besides the payload.

use anyhow::{bail, Result};

use super::bytes::{ByteReader, ByteWriter};
use crate::config::ErrorBound;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LosslessTag {
    None,
    Gzip,
    Zstd,
}

impl LosslessTag {
    fn to_u8(self) -> u8 {
        match self {
            LosslessTag::None => 0,
            LosslessTag::Gzip => 1,
            LosslessTag::Zstd => 2,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => LosslessTag::None,
            1 => LosslessTag::Gzip,
            2 => LosslessTag::Zstd,
            _ => bail!("unknown lossless tag {v}"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Header {
    pub field_name: String,
    /// Logical field dims (pre-fold; decompression restores this shape).
    pub dims: Vec<usize>,
    /// Slab variant name (must exist in the artifact manifest or be a
    /// CPU-known spec).
    pub variant: String,
    /// The user-requested bound (mode + value), for provenance.
    pub eb: ErrorBound,
    /// The resolved absolute bound actually applied.
    pub abs_eb: f32,
    pub dict_size: usize,
    pub chunk_symbols: usize,
    /// Codeword representation used at encode time (32 or 64), Table 4.
    pub repr_bits: u32,
    pub lossless: LosslessTag,
    pub n_slabs: usize,
}

impl Header {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.str(&self.field_name);
        w.u32(self.dims.len() as u32);
        for &d in &self.dims {
            w.u64(d as u64);
        }
        w.str(&self.variant);
        match self.eb {
            ErrorBound::Abs(v) => {
                w.u8(0);
                w.f64(v);
            }
            ErrorBound::ValRel(v) => {
                w.u8(1);
                w.f64(v);
            }
        }
        w.f32(self.abs_eb);
        w.u32(self.dict_size as u32);
        w.u32(self.chunk_symbols as u32);
        w.u32(self.repr_bits);
        w.u8(self.lossless.to_u8());
        w.u64(self.n_slabs as u64);
        w.finish()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Header> {
        let mut r = ByteReader::new(bytes);
        let field_name = r.str()?;
        let nd = r.u32()? as usize;
        if nd == 0 || nd > 4 {
            bail!("bad ndim {nd}");
        }
        let mut dims = Vec::with_capacity(nd);
        let mut product: u64 = 1;
        for _ in 0..nd {
            let d = r.u64()?;
            // bound the claimed shape (≤ 2^33 elements ≈ 34 GB of f32):
            // downstream allocation caps are derived from it
            product = product
                .saturating_mul(d.max(1))
                .min(1 << 34);
            if d > 1 << 33 || product > 1 << 33 {
                bail!("implausible field dims (> 2^33 elements)");
            }
            dims.push(d as usize);
        }
        let variant = r.str()?;
        let eb = match r.u8()? {
            0 => ErrorBound::Abs(r.f64()?),
            1 => ErrorBound::ValRel(r.f64()?),
            m => bail!("bad eb mode {m}"),
        };
        let abs_eb = r.f32()?;
        if !(abs_eb > 0.0) {
            bail!("non-positive abs_eb {abs_eb}");
        }
        Ok(Header {
            field_name,
            dims,
            variant,
            eb,
            abs_eb,
            dict_size: r.u32()? as usize,
            chunk_symbols: r.u32()? as usize,
            repr_bits: r.u32()?,
            lossless: LosslessTag::from_u8(r.u8()?)?,
            n_slabs: r.u64()? as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_both_eb_modes() {
        for eb in [ErrorBound::Abs(0.125), ErrorBound::ValRel(1e-4)] {
            let h = Header {
                field_name: "f".into(),
                dims: vec![10, 20],
                variant: "2d_256".into(),
                eb,
                abs_eb: 0.5,
                dict_size: 1024,
                chunk_symbols: 4096,
                repr_bits: 32,
                lossless: LosslessTag::Zstd,
                n_slabs: 3,
            };
            let b = Header::from_bytes(&h.to_bytes()).unwrap();
            assert_eq!(h, b);
        }
    }

    #[test]
    fn invalid_headers_rejected() {
        let h = Header {
            field_name: "f".into(),
            dims: vec![4],
            variant: "v".into(),
            eb: ErrorBound::Abs(1.0),
            abs_eb: 1.0,
            dict_size: 1024,
            chunk_symbols: 1,
            repr_bits: 64,
            lossless: LosslessTag::None,
            n_slabs: 1,
        };
        let mut bytes = h.to_bytes();
        // corrupt the ndim field (after name: 4-byte len + 1 byte "f")
        bytes[5] = 200;
        assert!(Header::from_bytes(&bytes).is_err());
    }
}
