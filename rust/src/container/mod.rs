//! The `.cusza` archive format — cuSZ's self-contained compressed output:
//! header, embedded canonical codebook (as its length table), the chunked
//! deflated Huffman bitstream, the outlier side channels, and per-section
//! CRC32s (DESIGN.md §6).

pub mod bytes;
pub mod header;

use anyhow::{bail, Context, Result};

use crate::huffman::deflate::{DeflatedChunk, DeflatedStream};
use bytes::{ByteReader, ByteWriter};
pub use header::{Header, LosslessTag};

pub const MAGIC: &[u8; 8] = b"CUSZA1\0\0";

/// One compressed field.
#[derive(Debug, Clone, PartialEq)]
pub struct Archive {
    pub header: Header,
    /// Canonical codebook as its per-symbol bit-length table.
    pub codebook_lengths: Vec<u8>,
    /// Deflated Huffman bitstream (quantization codes, slab-major order).
    pub stream: DeflatedStream,
    /// Prediction outliers: (global position in the slab-major stream,
    /// exact integer delta). Symbol 0 marks their slots in the stream.
    pub outliers: Vec<(u64, i32)>,
    /// Range outliers: (global position, verbatim f32) — prequant-cap
    /// clamps and non-finite values, overwritten after reconstruction.
    pub verbatim: Vec<(u64, f32)>,
}

impl Archive {
    /// Total compressed size in bytes (what CR is computed against).
    pub fn compressed_bytes(&self) -> usize {
        self.to_bytes().len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.bytes(MAGIC);
        let header_bytes = self.header.to_bytes();
        w.section(&header_bytes);

        let mut body = ByteWriter::new();
        body.u32(self.codebook_lengths.len() as u32);
        body.bytes(&self.codebook_lengths);

        body.u32(self.stream.chunks.len() as u32);
        body.u32(self.stream.chunk_symbols as u32);
        for c in &self.stream.chunks {
            body.u64(c.bits);
            body.u32(c.symbols);
            body.u32(c.words.len() as u32);
            for &wd in &c.words {
                body.u64(wd);
            }
        }

        body.u64(self.outliers.len() as u64);
        for &(pos, delta) in &self.outliers {
            body.u64(pos);
            body.i32(delta);
        }
        body.u64(self.verbatim.len() as u64);
        for &(pos, val) in &self.verbatim {
            body.u64(pos);
            body.f32(val);
        }

        let body_bytes = body.finish();
        let body_bytes = match self.header.lossless {
            LosslessTag::None => body_bytes,
            LosslessTag::Gzip => {
                use flate2::{write::GzEncoder, Compression};
                use std::io::Write;
                let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
                enc.write_all(&body_bytes).expect("gzip");
                enc.finish().expect("gzip finish")
            }
            LosslessTag::Zstd => zstd::encode_all(&body_bytes[..], 3).expect("zstd"),
        };
        w.section(&body_bytes);
        w.finish()
    }

    /// Parse only the header from serialized archive bytes — the cheap
    /// "payload framing" read the multi-field store uses for indexing and
    /// `ls` without touching the (possibly much larger) body section.
    pub fn peek_header(data: &[u8]) -> Result<Header> {
        let mut r = ByteReader::new(data);
        let magic = r.take(8)?;
        if magic != MAGIC {
            bail!("not a cusza archive (bad magic)");
        }
        let header_bytes = r.section().context("header section")?;
        Header::from_bytes(&header_bytes)
    }

    /// CRC32 digest of the serialized header — stored per entry in the
    /// `.cuszb` footer index so `Store::get` can detect a payload that was
    /// swapped or rewritten since indexing.
    pub fn header_digest(&self) -> u32 {
        bytes::crc32(&self.header.to_bytes())
    }

    pub fn from_bytes(data: &[u8]) -> Result<Archive> {
        let mut r = ByteReader::new(data);
        let magic = r.take(8)?;
        if magic != MAGIC {
            bail!("not a cusza archive (bad magic)");
        }
        let header_bytes = r.section().context("header section")?;
        let header = Header::from_bytes(&header_bytes)?;

        let body_raw = r.section().context("body section")?;
        // Cap the decompressed body so a crafted gzip/zstd bomb fails
        // cleanly instead of allocating without bound: a legitimate body
        // is linear in the element count the header itself declares.
        let cap = decompressed_body_cap(&header);
        let body_bytes = match header.lossless {
            LosslessTag::None => body_raw,
            LosslessTag::Gzip => {
                use flate2::read::GzDecoder;
                use std::io::Read;
                let mut out = Vec::new();
                GzDecoder::new(&body_raw[..])
                    .take(cap + 1)
                    .read_to_end(&mut out)
                    .context("gunzip")?;
                if out.len() as u64 > cap {
                    bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
                }
                out
            }
            LosslessTag::Zstd => {
                use std::io::Read;
                let dec = zstd::stream::read::Decoder::new(&body_raw[..]).context("unzstd")?;
                let mut out = Vec::new();
                dec.take(cap + 1).read_to_end(&mut out).context("unzstd")?;
                if out.len() as u64 > cap {
                    bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
                }
                out
            }
        };
        let mut b = ByteReader::new(&body_bytes);

        let nlen = b.u32()? as usize;
        let codebook_lengths = b.take(nlen)?;

        // Every element count below is bounded against the bytes actually
        // present before allocating, so a corrupted count fails cleanly
        // instead of attempting a multi-GB reservation.
        let nchunks = b.u32()? as usize;
        let chunk_symbols = b.u32()? as usize;
        if nchunks > b.remaining() / 16 {
            bail!("corrupt archive: {nchunks} chunks exceeds payload");
        }
        let mut chunks = Vec::with_capacity(nchunks);
        for _ in 0..nchunks {
            let bits = b.u64()?;
            let symbols = b.u32()?;
            let nwords = b.u32()? as usize;
            if nwords > b.remaining() / 8 {
                bail!("corrupt archive: {nwords} chunk words exceeds payload");
            }
            let mut words = Vec::with_capacity(nwords);
            for _ in 0..nwords {
                words.push(b.u64()?);
            }
            chunks.push(DeflatedChunk { words, bits, symbols });
        }

        let nout = b.u64()? as usize;
        if nout > b.remaining() / 12 {
            bail!("corrupt archive: {nout} outliers exceeds payload");
        }
        let mut outliers = Vec::with_capacity(nout);
        for _ in 0..nout {
            outliers.push((b.u64()?, b.i32()?));
        }
        let nverb = b.u64()? as usize;
        if nverb > b.remaining() / 12 {
            bail!("corrupt archive: {nverb} verbatim values exceeds payload");
        }
        let mut verbatim = Vec::with_capacity(nverb);
        for _ in 0..nverb {
            verbatim.push((b.u64()?, b.f32()?));
        }

        Ok(Archive {
            header,
            codebook_lengths,
            stream: DeflatedStream { chunks, chunk_symbols },
            outliers,
            verbatim,
        })
    }
}

/// Upper bound on a plausible decompressed body for `header`: every
/// element contributes at most a few words across the stream, outlier,
/// and verbatim channels, plus fixed slack for the codebook and framing.
fn decompressed_body_cap(header: &Header) -> u64 {
    let n: u64 = header
        .dims
        .iter()
        .fold(1u64, |acc, &d| acc.saturating_mul(d as u64));
    64 * 1024 * 1024 + n.saturating_mul(32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ErrorBound;

    fn sample_archive(lossless: LosslessTag) -> Archive {
        Archive {
            header: Header {
                field_name: "NYX/baryon_density".into(),
                dims: vec![64, 64, 64],
                variant: "3d_64".into(),
                eb: ErrorBound::ValRel(1e-4),
                abs_eb: 0.01,
                dict_size: 1024,
                chunk_symbols: 4096,
                repr_bits: 32,
                lossless,
                n_slabs: 4,
            },
            codebook_lengths: (0..1024).map(|i| (i % 20) as u8).collect(),
            stream: DeflatedStream {
                chunks: vec![
                    DeflatedChunk { words: vec![0xdead, 0xbeef], bits: 100, symbols: 40 },
                    DeflatedChunk { words: vec![42], bits: 17, symbols: 3 },
                ],
                chunk_symbols: 4096,
            },
            outliers: vec![(7, -123456), (99_999, 777)],
            verbatim: vec![(123, f32::NAN), (456, 1e30)],
        }
    }

    #[test]
    fn roundtrip_plain() {
        let a = sample_archive(LosslessTag::None);
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.header, b.header);
        assert_eq!(a.codebook_lengths, b.codebook_lengths);
        assert_eq!(a.stream, b.stream);
        assert_eq!(a.outliers, b.outliers);
        assert_eq!(b.verbatim[0].0, 123);
        assert!(b.verbatim[0].1.is_nan());
        assert_eq!(a.verbatim[1], b.verbatim[1]);
    }

    #[test]
    fn roundtrip_gzip_and_zstd() {
        for tag in [LosslessTag::Gzip, LosslessTag::Zstd] {
            let a = sample_archive(tag);
            let b = Archive::from_bytes(&a.to_bytes()).unwrap();
            assert_eq!(a.stream, b.stream, "{tag:?}");
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        bytes[0] = b'X';
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn corrupt_section_crc_rejected() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        let n = bytes.len();
        bytes[n - 3] ^= 0xff; // flip a bit in the verbatim tail
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn decompression_bomb_is_capped() {
        // a valid-CRC zstd body that inflates far past what the header's
        // dims (64^3 elements -> ~72 MB cap) could legitimately need
        use std::io::Read;
        let header = sample_archive(LosslessTag::Zstd).header;
        let bomb = zstd::encode_all(std::io::repeat(0u8).take(100 * 1024 * 1024), 3).unwrap();
        let mut w = ByteWriter::new();
        w.bytes(MAGIC);
        w.section(&header.to_bytes());
        w.section(&bomb);
        let err = Archive::from_bytes(&w.finish()).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err:#}");
    }

    #[test]
    fn truncated_archive_rejected() {
        let a = sample_archive(LosslessTag::None);
        let bytes = a.to_bytes();
        assert!(Archive::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }
}
