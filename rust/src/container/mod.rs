//! The `.cusza` archive format — cuSZ's self-contained compressed output:
//! versioned codec-tagged header, encoder sidecar (Huffman: canonical
//! codebook lengths; FLE: per-chunk bit widths; RLE: per-chunk value/run
//! widths), the chunked framed bitstream, an optional per-chunk encoder
//! tag table (mixed-granularity archives), the outlier side channels, and
//! per-section CRC32s (DESIGN.md §6).
//!
//! Four magics coexist: [`MAGIC_V0`] marks pre-codec archives (legacy
//! header layout, Huffman implied), [`MAGIC_V1`] marks PR 2's
//! field-tagged archives, and [`MAGIC_V3`] marks the granularity-aware
//! CUSZA3 generation (format versions 2–3) — all still decode
//! byte-for-byte. [`MAGIC`] marks current (version 4) archives, whose
//! body may additionally carry per-chunk Huffman gap tables (the
//! subchunk bit-offset index that makes intra-chunk decode parallel).
//! Unknown magics, versions, and tags all fail cleanly.
//!
//! Serialization is a single streaming pass: [`Archive::write_into`]
//! builds the body once in arena-reused scratch and streams it to any
//! sink; [`Archive::serialized_len`] prices a `None`-tail archive purely
//! arithmetically; and from format version 3 on, a gzip/zstd lossless
//! tail is framed over independent fixed-size segments
//! ([`TAIL_SEGMENT_BYTES`] of raw body each) so both the tail encode and
//! decode run chunk-parallel. Version ≤ 2 payloads keep their monolithic
//! tail byte-for-byte.

pub mod bytes;
pub mod header;

use std::io::{self, Read, Write};

use anyhow::{bail, Context, Result};

use crate::codec::{CodecGranularity, EncoderKind};
use crate::huffman::deflate::{DeflatedChunk, DeflatedStream};
use crate::util::arena;
use crate::util::pool::{effective_threads as tail_threads, parallel_map_range};
use bytes::{ByteReader, ByteWriter};
pub use header::{Header, LosslessTag, FORMAT_VERSION};

/// Magic of legacy (format version 0) archives.
pub const MAGIC_V0: &[u8; 8] = b"CUSZA1\0\0";
/// Magic of format-version-1 (field-tagged, pre-granularity) archives.
pub const MAGIC_V1: &[u8; 8] = b"CUSZA2\0\0";
/// Magic of the granularity-aware, chunk-taggable generation. Format
/// versions 2 (monolithic lossless tail) and 3 (segmented tail) both
/// travel under it; the header's version byte selects the body parser.
pub const MAGIC_V3: &[u8; 8] = b"CUSZA3\0\0";
/// Magic of current (format version 4) archives, whose body may carry
/// per-chunk Huffman gap tables after the chunk-tag section.
pub const MAGIC: &[u8; 8] = b"CUSZA4\0\0";

/// Largest chunk geometry (symbols per chunk) the format accepts. Real
/// configs top out at 2^16; the bound keeps a crafted stream from turning
/// per-chunk symbol counts into unbounded allocations. Enforced on both
/// sides: the parser rejects larger values as corrupt, and the compressor
/// refuses to produce archives it could not read back.
pub const MAX_CHUNK_SYMBOLS: usize = 1 << 24;

/// Raw body bytes per lossless-tail segment in version-3 archives. The
/// segmentation is a property of the *writer* (readers accept any) and
/// must not depend on thread count, so archives stay byte-deterministic;
/// 1 MiB keeps the zstd/gzip ratio loss negligible while giving the tail
/// enough segments to use every core on multi-MB fields.
pub const TAIL_SEGMENT_BYTES: usize = 1 << 20;

/// Floor for the bench/tuning segment-size override: framing overhead is
/// 16 bytes per segment, so segments below this are never worth writing.
const MIN_TAIL_SEGMENT_BYTES: usize = 64 * 1024;

thread_local! {
    /// Lossless-tail encodes performed by this thread — the probe behind
    /// the "exactly one serialization pass per compressed field"
    /// regression test. Thread-local so concurrent tests don't pollute
    /// each other's deltas.
    static TAIL_ENCODES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of gzip/zstd tail encodes this thread has performed (each
/// serialization of a tail-compressed archive counts once, however many
/// segments it frames). Diagnostics / regression tests.
pub fn lossless_tail_encodes() -> u64 {
    TAIL_ENCODES.with(|c| c.get())
}

/// Registry name of the process-wide tail-encode counter (the probe
/// above folded into [`crate::obs`]; the per-thread cell stays for
/// delta-based regression tests).
pub const TAIL_ENCODES_COUNTER: &str = "container.lossless_tail_encodes";

static TAIL_ENCODES_GLOBAL: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new(TAIL_ENCODES_COUNTER);

fn note_tail_encode() {
    TAIL_ENCODES.with(|c| c.set(c.get() + 1));
    TAIL_ENCODES_GLOBAL.incr();
}

/// Write one `[u64 len][u32 crc][payload]` section to a streaming sink.
fn write_section<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<u64> {
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(&bytes::crc32(payload).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(12 + payload.len() as u64)
}

/// Compress one tail segment with the tagged codec (same codecs and
/// levels as the legacy monolithic tail, so v≤2 re-serialization stays
/// byte-compatible).
fn compress_tail_segment(data: &[u8], tag: LosslessTag) -> io::Result<Vec<u8>> {
    match tag {
        LosslessTag::None => unreachable!("None tail never reaches the segment encoder"),
        LosslessTag::Gzip => {
            use flate2::{write::GzEncoder, Compression};
            let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
            enc.write_all(data)?;
            enc.finish()
        }
        LosslessTag::Zstd => zstd::encode_all(data, 3),
    }
}

/// Decompress one tail segment straight into its slot of the body
/// buffer (no intermediate Vec): the segment must yield exactly
/// `dst.len()` bytes — a short stream fails `read_exact`, and a stream
/// with leftover data fails the EOF probe.
fn decompress_tail_segment_into(comp: &[u8], tag: LosslessTag, dst: &mut [u8]) -> Result<()> {
    fn drain_into(mut dec: impl Read, dst: &mut [u8]) -> Result<()> {
        dec.read_exact(dst)
            .context("corrupt archive: tail segment shorter than declared")?;
        let mut probe = [0u8; 1];
        if dec
            .read(&mut probe)
            .context("corrupt archive: tail segment trailing data unreadable")?
            != 0
        {
            bail!(
                "corrupt archive: tail segment decompresses past its declared {} bytes",
                dst.len()
            );
        }
        Ok(())
    }
    match tag {
        LosslessTag::None => unreachable!("None tail never reaches the segment decoder"),
        LosslessTag::Gzip => drain_into(flate2::read::GzDecoder::new(comp), dst),
        LosslessTag::Zstd => drain_into(
            zstd::stream::read::Decoder::new(comp).context("unzstd tail segment")?,
            dst,
        ),
    }
}

/// Frame the serialized body as independent compressed segments (the
/// version-3 tail): `[u64 raw_total][u32 n_segments]` + per-segment
/// `[u64 raw_len][u64 comp_len]` table + concatenated payloads. Segments
/// compress in parallel; output bytes are independent of thread count.
fn encode_segmented_tail(
    body: &[u8],
    tag: LosslessTag,
    threads: usize,
    segment_bytes: usize,
) -> io::Result<Vec<u8>> {
    let seg = segment_bytes.max(MIN_TAIL_SEGMENT_BYTES);
    let nsegs = body.len().div_ceil(seg).max(1);
    let parts: Vec<io::Result<Vec<u8>>> =
        parallel_map_range(tail_threads(threads).min(nsegs), nsegs, |i| {
            let lo = i * seg;
            let hi = ((i + 1) * seg).min(body.len());
            compress_tail_segment(&body[lo..hi], tag)
        });
    let mut payloads = Vec::with_capacity(nsegs);
    for p in parts {
        payloads.push(p?);
    }
    let comp_total: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = ByteWriter::from_vec(Vec::with_capacity(12 + nsegs * 16 + comp_total));
    out.u64(body.len() as u64);
    out.u32(nsegs as u32);
    for (i, p) in payloads.iter().enumerate() {
        let lo = i * seg;
        let hi = ((i + 1) * seg).min(body.len());
        out.u64((hi - lo) as u64);
        out.u64(p.len() as u64);
    }
    for p in &payloads {
        out.bytes(p);
    }
    Ok(out.finish())
}

/// Parse and decompress a version-3 segmented tail. Every count is
/// bounded before allocation: the declared raw total against the
/// header-derived cap, the segment table against the payload size, and
/// each segment's inflation against its declared raw length.
fn decode_segmented_tail(
    payload: &[u8],
    tag: LosslessTag,
    cap: u64,
    threads: usize,
) -> Result<Vec<u8>> {
    let mut b = ByteReader::new(payload);
    let raw_total = b.u64()?;
    if raw_total > cap {
        bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
    }
    let nsegs = b.u32()? as usize;
    if nsegs > b.remaining() / 16 {
        bail!("corrupt archive: {nsegs} tail segments exceeds payload");
    }
    let mut lens = Vec::with_capacity(nsegs);
    let mut sum_raw = 0u64;
    for _ in 0..nsegs {
        let raw = b.u64()?;
        let comp = b.u64()?;
        sum_raw = sum_raw
            .checked_add(raw)
            .context("corrupt archive: segment raw lengths overflow")?;
        lens.push((raw, comp));
    }
    if sum_raw != raw_total {
        bail!("corrupt archive: segment raw lengths sum to {sum_raw}, expected {raw_total}");
    }
    let mut segs = Vec::with_capacity(nsegs);
    for &(_, comp) in &lens {
        segs.push(b.take_ref(comp as usize).context("tail segment payload")?);
    }
    if b.remaining() != 0 {
        bail!(
            "corrupt archive: {} trailing bytes after tail segments",
            b.remaining()
        );
    }
    // decompress every segment straight into its disjoint slot of the
    // one body buffer — no per-segment Vecs, no concatenation pass. The
    // allocation is bounded by the cap check above; the mutexes hand each
    // worker exclusive access to its slice (taken once, uncontended).
    let mut out = vec![0u8; raw_total as usize];
    let mut slots = Vec::with_capacity(nsegs);
    let mut rest: &mut [u8] = &mut out;
    for &(raw, _) in &lens {
        // mem::take so each split reborrows a fresh local, letting the
        // slot borrows outlive the loop body
        let (slot, tail) = std::mem::take(&mut rest).split_at_mut(raw as usize);
        slots.push(std::sync::Mutex::new(slot));
        rest = tail;
    }
    let parts: Vec<Result<()>> =
        parallel_map_range(tail_threads(threads).min(nsegs.max(1)), nsegs, |i| {
            let mut slot = slots[i].lock().expect("slot mutex poisoned");
            decompress_tail_segment_into(segs[i], tag, &mut **slot)
        });
    for p in parts {
        p?;
    }
    drop(slots);
    Ok(out)
}

/// One compressed field.
#[derive(Debug, Clone, PartialEq)]
pub struct Archive {
    pub header: Header,
    /// Encoder sidecar: what the tagged encoder's decoder needs (Huffman:
    /// per-symbol code-length table; FLE: per-chunk bit widths; RLE:
    /// per-chunk `[w, r]` records). In a mixed-granularity archive this
    /// holds the codebook length table shared by Huffman-tagged chunks.
    pub encoder_aux: Vec<u8>,
    /// Per-chunk encoder tags (one [`EncoderKind::to_tag`] byte per
    /// stream chunk) for mixed-granularity archives; empty when the
    /// header's field-level encoder tag applies uniformly.
    pub chunk_tags: Vec<u8>,
    /// Per-chunk sidecar records for mixed-granularity archives (FLE:
    /// `[w]`; RLE: `[w, r]`; Huffman: empty — it uses `encoder_aux`);
    /// empty when `chunk_tags` is.
    pub chunk_aux: Vec<Vec<u8>>,
    /// Framed chunked bitstream (quantization codes, slab-major order).
    pub stream: DeflatedStream,
    /// Prediction outliers: (global position in the slab-major stream,
    /// exact integer delta). Symbol 0 marks their slots in the stream.
    /// Format contract: positions are strictly increasing — the
    /// compressor emits them slab-major in order and the slab-parallel
    /// decoder splits the channel per slab with `partition_point`.
    pub outliers: Vec<(u64, i32)>,
    /// Range outliers: (global position, verbatim f32) — prequant-cap
    /// clamps and non-finite values, overwritten after reconstruction.
    /// Format contract: positions are sorted ascending across slabs
    /// (within-slab duplicates/order are tolerated; the owning slab's
    /// worker applies its range in list order), same `partition_point`
    /// split as `outliers`.
    pub verbatim: Vec<(u64, f32)>,
    /// Per-chunk Huffman gap tables (format version ≥ 4): for each
    /// stream chunk, the `(bit_offset, symbol_count)` subchunk index
    /// recorded at deflate time, empty for chunks with no table (small
    /// chunks, non-Huffman chunks). An empty outer vec means the archive
    /// carries no gap section content (it still frames a zero count at
    /// v≥4). Treated as untrusted input on read: the decoder validates
    /// every table against the chunk's bit/symbol totals before any
    /// subchunk decodes in parallel.
    pub gap_tables: Vec<Vec<(u64, u32)>>,
}

impl Archive {
    /// Total compressed size in bytes (what CR is computed against).
    /// Delegates to [`Archive::serialized_len`]: arithmetic (no
    /// serialization at all) for `None`-tail archives, one tail encode
    /// otherwise — never a full `to_bytes` materialization.
    pub fn compressed_bytes(&self) -> usize {
        self.serialized_len()
    }

    /// Exact on-disk size of this archive. For `LosslessTag::None` the
    /// answer is computed arithmetically from the container layout
    /// (header + tag table + stream words + outlier/verbatim records) —
    /// no byte is serialized. For gzip/zstd tails the compressed size is
    /// not knowable without compressing, so this performs one streaming
    /// serialization into a counting sink (one lossless-tail encode; hot
    /// paths that also need the bytes should use
    /// [`Archive::write_into`]/[`Archive::to_bytes`] once instead).
    pub fn serialized_len(&self) -> usize {
        match self.header.lossless {
            LosslessTag::None => {
                let header_len = self.header.to_bytes().len();
                // magic + header section framing + body section framing
                8 + 12 + header_len + 12 + self.body_raw_len()
            }
            _ => self
                .write_into(&mut io::sink())
                .expect("counting serialization cannot fail") as usize,
        }
    }

    /// Cheap capacity hint for a serialization buffer: exact for `None`
    /// tails, a compressed-size guess otherwise. Never encodes anything.
    pub fn serialized_len_hint(&self) -> usize {
        match self.header.lossless {
            LosslessTag::None => self.serialized_len(),
            _ => 1024 + self.body_raw_len() / 3,
        }
    }

    /// Arithmetic length of the serialized (uncompressed) body.
    fn body_raw_len(&self) -> usize {
        let mut n = 4 + self.encoder_aux.len(); // aux length + bytes
        n += 8; // chunk count + chunk geometry
        for c in &self.stream.chunks {
            n += 8 + 4 + 4 + c.words.len() * 8;
        }
        if self.header.version >= 2 {
            n += 4 + self.chunk_tags.len();
            if !self.chunk_tags.is_empty() {
                n += self.chunk_aux.iter().map(|a| 1 + a.len()).sum::<usize>();
            }
        }
        if self.header.version >= 4 {
            n += 4; // gap-table chunk count
            if !self.gap_tables.is_empty() {
                n += self.gap_tables.iter().map(|g| 4 + g.len() * 12).sum::<usize>();
            }
        }
        n += 8 + self.outliers.len() * 12;
        n += 8 + self.verbatim.len() * 12;
        n
    }

    /// Serialize the body fields (everything between the header section
    /// and the lossless tail) into `body`.
    fn write_body(&self, body: &mut ByteWriter) {
        body.u32(self.encoder_aux.len() as u32);
        body.bytes(&self.encoder_aux);

        body.u32(self.stream.chunks.len() as u32);
        body.u32(self.stream.chunk_symbols as u32);
        for c in &self.stream.chunks {
            body.u64(c.bits);
            body.u32(c.symbols);
            body.u32(c.words.len() as u32);
            for &wd in &c.words {
                body.u64(wd);
            }
        }

        if self.header.version >= 2 {
            body.u32(self.chunk_tags.len() as u32);
            body.bytes(&self.chunk_tags);
            if !self.chunk_tags.is_empty() {
                for aux in &self.chunk_aux {
                    // u8 length prefix: a wider record would wrap modulo
                    // 256 and silently desynchronize the reader — any
                    // future backend needing more must grow the framing
                    assert!(
                        aux.len() <= u8::MAX as usize,
                        "per-chunk sidecar record of {} bytes exceeds the u8 length prefix",
                        aux.len()
                    );
                    body.u8(aux.len() as u8);
                    body.bytes(aux);
                }
            }
        }

        if self.header.version >= 4 {
            // gap-table section: all-or-nothing like the tag table — the
            // outer count is 0 (no gap content) or exactly the chunk
            // count, with per-chunk tables allowed to be empty
            body.u32(self.gap_tables.len() as u32);
            for gaps in &self.gap_tables {
                body.u32(gaps.len() as u32);
                for &(off, count) in gaps {
                    body.u64(off);
                    body.u32(count);
                }
            }
        }

        body.u64(self.outliers.len() as u64);
        for &(pos, delta) in &self.outliers {
            body.u64(pos);
            body.i32(delta);
        }
        body.u64(self.verbatim.len() as u64);
        for &(pos, val) in &self.verbatim {
            body.u64(pos);
            body.f32(val);
        }
    }

    /// Stream the archive into any writer — the single serialization
    /// path (`to_bytes`, `Store::add`, the serve sinks, and the CLI all
    /// sit on top of it). The body is built once in an arena-reused
    /// scratch buffer and flows straight to the sink; no second
    /// full-archive buffer exists. Returns the bytes written.
    pub fn write_into<W: Write>(&self, w: &mut W) -> io::Result<u64> {
        self.write_into_with(w, 0, TAIL_SEGMENT_BYTES)
    }

    /// [`Archive::write_into`] with explicit knobs: `threads` for the
    /// parallel tail segment encode (0 = all cores; output bytes never
    /// depend on it) and `segment_bytes` for the raw bytes per tail
    /// segment (a bench/tuning override — changing it changes the wire
    /// bytes, so production writers stick to [`TAIL_SEGMENT_BYTES`]).
    pub fn write_into_with<W: Write>(
        &self,
        w: &mut W,
        threads: usize,
        segment_bytes: usize,
    ) -> io::Result<u64> {
        // pre-granularity layouts have no chunk-tag sections: writing one
        // silently would decode wrong under an old parser — fail loudly
        assert!(
            self.header.version >= 2
                || (self.chunk_tags.is_empty() && self.chunk_aux.is_empty()),
            "version-{} archives cannot carry a per-chunk tag table",
            self.header.version
        );
        // pre-v4 layouts likewise have no gap-table section
        assert!(
            self.header.version >= 4 || self.gap_tables.is_empty(),
            "version-{} archives cannot carry Huffman gap tables",
            self.header.version
        );
        let mut total = 0u64;
        // headers serialize in their own version's layout, so each must
        // travel under the matching magic for parsers to agree
        w.write_all(match self.header.version {
            0 => MAGIC_V0,
            1 => MAGIC_V1,
            2 | 3 => MAGIC_V3,
            _ => MAGIC,
        })?;
        total += 8;
        total += write_section(w, &self.header.to_bytes())?;

        total += arena::with_u8(|scratch| -> io::Result<u64> {
            let mut bw = ByteWriter::from_vec(std::mem::take(scratch));
            self.write_body(&mut bw);
            let body = bw.finish();
            let written = match self.header.lossless {
                LosslessTag::None => write_section(w, &body)?,
                tag => {
                    note_tail_encode();
                    if self.header.version >= 3 {
                        let tail = encode_segmented_tail(&body, tag, threads, segment_bytes)?;
                        write_section(w, &tail)?
                    } else {
                        // legacy monolithic tail: byte-compatible with
                        // the v0–v2 writers (same codecs, same levels)
                        let blob = compress_tail_segment(&body, tag)?;
                        write_section(w, &blob)?
                    }
                }
            };
            *scratch = body; // return the capacity to the arena
            Ok(written)
        })?;
        Ok(total)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        // exact for uncompressed tails, a no-encode estimate otherwise —
        // never serialized_len() here, which would encode a compressed
        // tail a second time just to size the buffer
        let mut out = Vec::with_capacity(self.serialized_len_hint());
        self.write_into(&mut out).expect("writing to a Vec cannot fail");
        out
    }

    /// Read the magic + header section, dispatching to the right header
    /// parser per format version. The magic and the header's version byte
    /// must agree — a mismatch means the payload was spliced or corrupted.
    fn read_header(r: &mut ByteReader<'_>) -> Result<Header> {
        let magic = r.take(8)?;
        let legacy = if magic == MAGIC_V0 {
            true
        } else if magic == MAGIC_V1 || magic == MAGIC_V3 || magic == MAGIC {
            false
        } else {
            bail!("not a cusza archive (bad magic)");
        };
        let header_bytes = r.section().context("header section")?;
        if legacy {
            return Header::from_bytes_v0(&header_bytes);
        }
        let header = Header::from_bytes(&header_bytes)?;
        // each magic admits exactly its own version range: V1 ↔ 1,
        // V3 ↔ 2–3, current ↔ 4+ — a mismatch means a spliced payload
        let version_ok = if magic == MAGIC_V1 {
            header.version == 1
        } else if magic == MAGIC_V3 {
            header.version == 2 || header.version == 3
        } else {
            header.version >= 4
        };
        if !version_ok {
            bail!(
                "archive magic disagrees with header version {} (spliced payload?)",
                header.version
            );
        }
        Ok(header)
    }

    /// Parse only the header from serialized archive bytes — the cheap
    /// "payload framing" read the multi-field store uses for indexing and
    /// `ls` without touching the (possibly much larger) body section.
    pub fn peek_header(data: &[u8]) -> Result<Header> {
        Self::read_header(&mut ByteReader::new(data))
    }

    /// CRC32 digest of the serialized header — stored per entry in the
    /// `.cuszb` footer index so `Store::get` can detect a payload that was
    /// swapped or rewritten since indexing.
    pub fn header_digest(&self) -> u32 {
        bytes::crc32(&self.header.to_bytes())
    }

    pub fn from_bytes(data: &[u8]) -> Result<Archive> {
        Self::from_bytes_with_threads(data, 0)
    }

    /// [`Archive::from_bytes`] with an explicit worker count for the
    /// parallel segmented-tail decode (0 = all cores). Batch pipelines
    /// that already fan out across fields pass their per-job thread
    /// budget to avoid oversubscription.
    pub fn from_bytes_with_threads(data: &[u8], threads: usize) -> Result<Archive> {
        let mut r = ByteReader::new(data);
        let header = Self::read_header(&mut r)?;

        let body_raw = r.section().context("body section")?;
        // Cap the decompressed body so a crafted gzip/zstd bomb fails
        // cleanly instead of allocating without bound: a legitimate body
        // is linear in the element count the header itself declares.
        let cap = decompressed_body_cap(&header);
        let body_bytes = match header.lossless {
            LosslessTag::None => body_raw,
            // version-3 tails are segment-framed and decode in parallel
            tag if header.version >= 3 => decode_segmented_tail(&body_raw, tag, cap, threads)?,
            LosslessTag::Gzip => {
                use flate2::read::GzDecoder;
                let mut out = Vec::new();
                GzDecoder::new(&body_raw[..])
                    .take(cap + 1)
                    .read_to_end(&mut out)
                    .context("gunzip")?;
                if out.len() as u64 > cap {
                    bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
                }
                out
            }
            LosslessTag::Zstd => {
                let dec = zstd::stream::read::Decoder::new(&body_raw[..]).context("unzstd")?;
                let mut out = Vec::new();
                dec.take(cap + 1).read_to_end(&mut out).context("unzstd")?;
                if out.len() as u64 > cap {
                    bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
                }
                out
            }
        };
        let mut b = ByteReader::new(&body_bytes);

        let nlen = b.u32()? as usize;
        let encoder_aux = b.take(nlen)?;

        // Every element count below is bounded against the bytes actually
        // present before allocating, so a corrupted count fails cleanly
        // instead of attempting a multi-GB reservation.
        let nchunks = b.u32()? as usize;
        let chunk_symbols = b.u32()? as usize;
        if chunk_symbols > MAX_CHUNK_SYMBOLS {
            bail!("corrupt archive: implausible chunk size {chunk_symbols}");
        }
        if nchunks > b.remaining() / 16 {
            bail!("corrupt archive: {nchunks} chunks exceeds payload");
        }
        let mut chunks = Vec::with_capacity(nchunks);
        for _ in 0..nchunks {
            let bits = b.u64()?;
            let symbols = b.u32()?;
            let nwords = b.u32()? as usize;
            if nwords > b.remaining() / 8 {
                bail!("corrupt archive: {nwords} chunk words exceeds payload");
            }
            let mut words = Vec::with_capacity(nwords);
            for _ in 0..nwords {
                words.push(b.u64()?);
            }
            chunks.push(DeflatedChunk { words, bits, symbols });
        }

        // per-chunk tag table (format version >= 2). The header's
        // granularity byte and the table's presence must agree, every tag
        // must be known, and the sidecar record list must cover exactly
        // the tagged chunks — all checked here so downstream decode never
        // sees a structurally inconsistent archive.
        let (chunk_tags, chunk_aux) = if header.version >= 2 {
            let ntags = b.u32()? as usize;
            if ntags != 0 && ntags != nchunks {
                bail!("corrupt archive: {ntags} chunk tags for {nchunks} chunks");
            }
            if (header.granularity == CodecGranularity::Chunk) != (ntags > 0) {
                bail!(
                    "corrupt archive: {} granularity with {ntags} chunk tags",
                    header.granularity.name()
                );
            }
            let tags = b.take(ntags)?;
            for &t in &tags {
                EncoderKind::from_tag(t)?;
            }
            let mut aux = Vec::with_capacity(ntags);
            for _ in 0..ntags {
                let alen = b.u8()? as usize;
                aux.push(b.take(alen)?);
            }
            (tags, aux)
        } else {
            (Vec::new(), Vec::new())
        };

        // per-chunk Huffman gap tables (format version >= 4). Untrusted:
        // counts are bounded against the bytes present before allocating;
        // the *semantic* validation (offsets monotone, within the chunk's
        // bit length, symbol counts summing to the chunk total) happens in
        // the gap decoder, which re-checks every table it actually uses.
        let gap_tables = if header.version >= 4 {
            let ngap = b.u32()? as usize;
            if ngap != 0 && ngap != nchunks {
                bail!("corrupt archive: {ngap} gap tables for {nchunks} chunks");
            }
            let mut tables = Vec::with_capacity(ngap);
            for _ in 0..ngap {
                let nentries = b.u32()? as usize;
                if nentries > b.remaining() / 12 {
                    bail!("corrupt archive: {nentries} gap entries exceeds payload");
                }
                let mut gaps = Vec::with_capacity(nentries);
                for _ in 0..nentries {
                    gaps.push((b.u64()?, b.u32()?));
                }
                tables.push(gaps);
            }
            tables
        } else {
            Vec::new()
        };

        let nout = b.u64()? as usize;
        if nout > b.remaining() / 12 {
            bail!("corrupt archive: {nout} outliers exceeds payload");
        }
        let mut outliers = Vec::with_capacity(nout);
        for _ in 0..nout {
            outliers.push((b.u64()?, b.i32()?));
        }
        let nverb = b.u64()? as usize;
        if nverb > b.remaining() / 12 {
            bail!("corrupt archive: {nverb} verbatim values exceeds payload");
        }
        let mut verbatim = Vec::with_capacity(nverb);
        for _ in 0..nverb {
            verbatim.push((b.u64()?, b.f32()?));
        }

        Ok(Archive {
            header,
            encoder_aux,
            chunk_tags,
            chunk_aux,
            stream: DeflatedStream { chunks, chunk_symbols },
            outliers,
            verbatim,
            gap_tables,
        })
    }
}

/// Upper bound on a plausible decompressed body for `header`: every
/// element contributes at most a few words across the stream, outlier,
/// and verbatim channels, plus fixed slack for the codebook and framing.
fn decompressed_body_cap(header: &Header) -> u64 {
    let n: u64 = header
        .dims
        .iter()
        .fold(1u64, |acc, &d| acc.saturating_mul(d as u64));
    64 * 1024 * 1024 + n.saturating_mul(32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::EncoderKind;
    use crate::config::ErrorBound;

    fn sample_archive(lossless: LosslessTag) -> Archive {
        Archive {
            header: Header {
                version: FORMAT_VERSION,
                encoder: EncoderKind::Huffman,
                granularity: CodecGranularity::Field,
                field_name: "NYX/baryon_density".into(),
                dims: vec![64, 64, 64],
                variant: "3d_64".into(),
                eb: ErrorBound::ValRel(1e-4),
                abs_eb: 0.01,
                dict_size: 1024,
                chunk_symbols: 4096,
                repr_bits: 32,
                lossless,
                n_slabs: 4,
            },
            encoder_aux: (0..1024).map(|i| (i % 20) as u8).collect(),
            chunk_tags: Vec::new(),
            chunk_aux: Vec::new(),
            stream: DeflatedStream {
                chunks: vec![
                    DeflatedChunk { words: vec![0xdead, 0xbeef], bits: 100, symbols: 40 },
                    DeflatedChunk { words: vec![42], bits: 17, symbols: 3 },
                ],
                chunk_symbols: 4096,
            },
            outliers: vec![(7, -123456), (99_999, 777)],
            verbatim: vec![(123, f32::NAN), (456, 1e30)],
            gap_tables: Vec::new(),
        }
    }

    /// A v4 archive carrying a gap table for each chunk (second empty:
    /// chunks below the subchunk granularity record no entries).
    fn sample_gap_archive(lossless: LosslessTag) -> Archive {
        let mut a = sample_archive(lossless);
        a.gap_tables = vec![vec![(0, 20), (57, 20)], Vec::new()];
        a
    }

    fn sample_mixed_archive() -> Archive {
        let mut a = sample_archive(LosslessTag::None);
        a.header.granularity = CodecGranularity::Chunk;
        a.chunk_tags = vec![EncoderKind::Fle.to_tag(), EncoderKind::Rle.to_tag()];
        a.chunk_aux = vec![vec![9], vec![3, 7]];
        a.encoder_aux = Vec::new();
        a
    }

    #[test]
    fn roundtrip_plain() {
        let a = sample_archive(LosslessTag::None);
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.header, b.header);
        assert_eq!(a.encoder_aux, b.encoder_aux);
        assert_eq!(a.stream, b.stream);
        assert_eq!(a.outliers, b.outliers);
        assert_eq!(b.verbatim[0].0, 123);
        assert!(b.verbatim[0].1.is_nan());
        assert_eq!(a.verbatim[1], b.verbatim[1]);
    }

    #[test]
    fn roundtrip_gzip_and_zstd() {
        for tag in [LosslessTag::Gzip, LosslessTag::Zstd] {
            let a = sample_archive(tag);
            let b = Archive::from_bytes(&a.to_bytes()).unwrap();
            assert_eq!(a.stream, b.stream, "{tag:?}");
        }
    }

    #[test]
    fn roundtrip_fle_tag() {
        let mut a = sample_archive(LosslessTag::None);
        a.header.encoder = EncoderKind::Fle;
        a.encoder_aux = vec![9, 9]; // per-chunk widths
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.header.encoder, EncoderKind::Fle);
        assert_eq!(b.encoder_aux, vec![9, 9]);
    }

    #[test]
    fn v0_archive_bytes_still_parse() {
        // a pre-codec archive: version-0 header under the legacy magic
        let mut a = sample_archive(LosslessTag::None);
        a.header.version = 0;
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V0);
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.version, 0);
        assert_eq!(b.header.encoder, EncoderKind::Huffman);
        assert_eq!(b.stream, a.stream);
        assert_eq!(Archive::peek_header(&bytes).unwrap(), b.header);
    }

    #[test]
    fn current_archive_carries_version_and_tag() {
        let a = sample_archive(LosslessTag::None);
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC);
        let h = Archive::peek_header(&bytes).unwrap();
        assert_eq!(h.version, FORMAT_VERSION);
        assert_eq!(h.encoder, EncoderKind::Huffman);
        assert_eq!(h.granularity, CodecGranularity::Field);
    }

    #[test]
    fn v1_archive_bytes_still_parse() {
        // a PR 2 archive: version-1 header under the CUSZA2 magic
        let mut a = sample_archive(LosslessTag::None);
        a.header.version = 1;
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V1);
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.version, 1);
        assert_eq!(b.header.granularity, CodecGranularity::Field);
        assert!(b.chunk_tags.is_empty());
        assert_eq!(b.stream, a.stream);
        assert_eq!(Archive::peek_header(&bytes).unwrap(), b.header);
    }

    #[test]
    fn mixed_archive_tag_table_roundtrips() {
        let a = sample_mixed_archive();
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC);
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.granularity, CodecGranularity::Chunk);
        assert_eq!(b.chunk_tags, a.chunk_tags);
        assert_eq!(b.chunk_aux, a.chunk_aux);
        assert_eq!(b, a);
    }

    #[test]
    fn granularity_and_tag_table_must_agree() {
        // chunk granularity without a tag table
        let mut a = sample_mixed_archive();
        a.chunk_tags = Vec::new();
        a.chunk_aux = Vec::new();
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
        // field granularity with a tag table
        let mut a = sample_mixed_archive();
        a.header.granularity = CodecGranularity::Field;
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
        // tag count must match the chunk count
        let mut a = sample_mixed_archive();
        a.chunk_tags.push(EncoderKind::Fle.to_tag());
        a.chunk_aux.push(vec![4]);
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
        // unknown tag in the table
        let mut a = sample_mixed_archive();
        a.chunk_tags[1] = 44;
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
    }

    #[test]
    fn spliced_magic_version_mismatch_rejected() {
        // a version-4 header smuggled under an older magic (and vice
        // versa) must be rejected even though both parts are well-formed
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        bytes[..8].copy_from_slice(MAGIC_V1);
        assert!(Archive::from_bytes(&bytes).is_err());
        let mut bytes = a.to_bytes();
        bytes[..8].copy_from_slice(MAGIC_V3);
        assert!(Archive::from_bytes(&bytes).is_err());
        let mut a1 = sample_archive(LosslessTag::None);
        a1.header.version = 1;
        let mut bytes = a1.to_bytes();
        bytes[..8].copy_from_slice(MAGIC);
        assert!(Archive::from_bytes(&bytes).is_err());
        // a v3 (CUSZA3) archive relabeled with the current magic would
        // misparse its gap-less body — rejected at the header gate
        let mut a3 = sample_archive(LosslessTag::None);
        a3.header.version = 3;
        let mut bytes = a3.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V3);
        bytes[..8].copy_from_slice(MAGIC);
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn v4_gap_tables_roundtrip() {
        for tag in [LosslessTag::None, LosslessTag::Zstd] {
            let a = sample_gap_archive(tag);
            let bytes = a.to_bytes();
            assert_eq!(&bytes[..8], MAGIC);
            let b = Archive::from_bytes(&bytes).unwrap();
            assert_eq!(b.gap_tables, a.gap_tables, "{tag:?}");
            assert_eq!(b, a, "{tag:?}");
        }
        // a gap-less v4 archive frames a zero table count and reads back
        // with an empty outer vec
        let plain = sample_archive(LosslessTag::None);
        let b = Archive::from_bytes(&plain.to_bytes()).unwrap();
        assert!(b.gap_tables.is_empty());
    }

    #[test]
    fn hostile_gap_section_fails_cleanly() {
        let a = sample_gap_archive(LosslessTag::None);
        let bytes = a.to_bytes();
        let off = body_payload_offset(&bytes);
        // the gap section sits after aux (4+1024), chunk geometry (8),
        // two chunks (8+4+4+16 and 8+4+4+8), and the v2 tag section (4)
        let gap_off = off + 4 + 1024 + 8 + 32 + 24 + 4;
        assert_eq!(
            u32::from_le_bytes(bytes[gap_off..gap_off + 4].try_into().unwrap()),
            2,
            "gap section not where the layout arithmetic says"
        );

        // outer count that matches neither 0 nor nchunks
        let mut wrong = bytes.clone();
        wrong[gap_off..gap_off + 4].copy_from_slice(&1u32.to_le_bytes());
        rewrite_body_crc(&mut wrong);
        let err = Archive::from_bytes(&wrong).unwrap_err();
        assert!(err.to_string().contains("gap tables"), "{err:#}");

        // entry count inflated past the payload: bounded before allocation
        let mut bloated = bytes.clone();
        bloated[gap_off + 4..gap_off + 8].copy_from_slice(&u32::MAX.to_le_bytes());
        rewrite_body_crc(&mut bloated);
        let err = Archive::from_bytes(&bloated).unwrap_err();
        assert!(err.to_string().contains("gap entries"), "{err:#}");
    }

    #[test]
    fn unknown_encoder_tag_fails_cleanly() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        // the encoder tag is the second byte of the header section:
        // 8 magic + 8 len + 4 crc + 1 version byte
        bytes[21] = 77;
        // CRC now mismatches; rewrite the section frame around the edit
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let crc = bytes::crc32(&bytes[20..20 + header_len]);
        bytes[16..20].copy_from_slice(&crc.to_le_bytes());
        let err = Archive::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("encoder tag"), "{err:#}");
    }

    #[test]
    fn bad_magic_rejected() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        bytes[0] = b'X';
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn corrupt_section_crc_rejected() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        let n = bytes.len();
        bytes[n - 3] ^= 0xff; // flip a bit in the verbatim tail
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn decompression_bomb_is_capped() {
        // a valid-CRC zstd body that inflates far past what the header's
        // dims (64^3 elements -> ~72 MB cap) could legitimately need
        use std::io::Read;
        let header = sample_archive(LosslessTag::Zstd).header;
        let bomb = zstd::encode_all(std::io::repeat(0u8).take(100 * 1024 * 1024), 3).unwrap();
        let mut w = ByteWriter::new();
        w.bytes(MAGIC);
        w.section(&header.to_bytes());
        w.section(&bomb);
        let err = Archive::from_bytes(&w.finish()).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err:#}");
    }

    #[test]
    fn truncated_archive_rejected() {
        let a = sample_archive(LosslessTag::None);
        let bytes = a.to_bytes();
        assert!(Archive::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }

    /// An archive whose raw body comfortably exceeds the minimum tail
    /// segment size, so small segment overrides produce real multi-
    /// segment tails.
    fn big_archive(lossless: LosslessTag) -> Archive {
        let mut a = sample_archive(lossless);
        a.stream = DeflatedStream {
            chunks: (0..8)
                .map(|c| DeflatedChunk {
                    words: (0..4096u64).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15) ^ c).collect(),
                    bits: 4096 * 64,
                    symbols: 4096,
                })
                .collect(),
            chunk_symbols: 4096,
        };
        a
    }

    /// Locate the body section and recompute its CRC (hostile-writer
    /// simulation: structurally-corrupt but CRC-consistent payloads).
    fn rewrite_body_crc(bytes: &mut [u8]) {
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let off = 20 + header_len;
        let body_len = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
        let crc = bytes::crc32(&bytes[off + 12..off + 12 + body_len]);
        bytes[off + 8..off + 12].copy_from_slice(&crc.to_le_bytes());
    }

    fn body_payload_offset(bytes: &[u8]) -> usize {
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        20 + header_len + 12
    }

    #[test]
    fn serialized_len_matches_to_bytes_for_every_tail() {
        for tag in [LosslessTag::None, LosslessTag::Gzip, LosslessTag::Zstd] {
            for a in [sample_archive(tag), big_archive(tag)] {
                assert_eq!(a.serialized_len(), a.to_bytes().len(), "{tag:?}");
                assert_eq!(a.compressed_bytes(), a.serialized_len(), "{tag:?}");
            }
            let mut mixed = sample_mixed_archive();
            mixed.header.lossless = tag;
            assert_eq!(mixed.serialized_len(), mixed.to_bytes().len(), "mixed {tag:?}");
            let gap = sample_gap_archive(tag);
            assert_eq!(gap.serialized_len(), gap.to_bytes().len(), "gap {tag:?}");
        }
        // legacy versions: the arithmetic covers the version-gated
        // sections too
        for version in [0u8, 1, 2, 3] {
            let mut a = sample_archive(LosslessTag::None);
            a.header.version = version;
            assert_eq!(a.serialized_len(), a.to_bytes().len(), "v{version}");
        }
    }

    #[test]
    fn write_into_matches_to_bytes_and_ignores_thread_count() {
        for tag in [LosslessTag::None, LosslessTag::Zstd, LosslessTag::Gzip] {
            let a = big_archive(tag);
            let reference = a.to_bytes();
            for threads in [1usize, 3, 8] {
                let mut out = Vec::new();
                let n = a.write_into_with(&mut out, threads, TAIL_SEGMENT_BYTES).unwrap();
                assert_eq!(n as usize, out.len());
                assert_eq!(out, reference, "{tag:?} threads={threads}");
            }
            assert_eq!(Archive::from_bytes(&reference).unwrap(), a, "{tag:?}");
        }
    }

    #[test]
    fn v3_segmented_tail_roundtrips_multisegment() {
        for tag in [LosslessTag::Gzip, LosslessTag::Zstd] {
            let a = big_archive(tag);
            // force small segments so the ~256 KB body splits
            let mut bytes = Vec::new();
            a.write_into_with(&mut bytes, 4, 64 * 1024).unwrap();
            let off = body_payload_offset(&bytes);
            let nsegs = u32::from_le_bytes(bytes[off + 8..off + 12].try_into().unwrap());
            assert!(nsegs > 1, "{tag:?}: expected a multi-segment tail, got {nsegs}");
            for threads in [0usize, 1, 5] {
                let b = Archive::from_bytes_with_threads(&bytes, threads).unwrap();
                assert_eq!(b, a, "{tag:?} threads={threads}");
            }
        }
    }

    #[test]
    fn v2_archives_keep_the_monolithic_tail() {
        let mut a = big_archive(LosslessTag::Gzip);
        a.header.version = 2;
        let bytes = a.to_bytes();
        let off = body_payload_offset(&bytes);
        // a v2 gzip body starts with the gzip magic, not a segment table
        assert_eq!(&bytes[off..off + 2], &[0x1f, 0x8b]);
        assert_eq!(Archive::from_bytes(&bytes).unwrap(), a);
        // while the v3 body starts with its raw-length word
        let v3 = big_archive(LosslessTag::Gzip).to_bytes();
        let off3 = body_payload_offset(&v3);
        assert_ne!(&v3[off3..off3 + 2], &[0x1f, 0x8b]);
    }

    #[test]
    fn corrupt_tail_segments_fail_cleanly() {
        let a = big_archive(LosslessTag::Zstd);
        let mut bytes = Vec::new();
        a.write_into_with(&mut bytes, 2, 64 * 1024).unwrap();
        let off = body_payload_offset(&bytes);

        // a bit flip in a segment payload is caught by the section CRC
        let mut flipped = bytes.clone();
        let n = flipped.len();
        flipped[n - 9] ^= 0x40;
        assert!(Archive::from_bytes(&flipped).is_err());

        // hostile writer: inflate a segment's raw length (CRC fixed up) —
        // the sum check must reject before any decode allocates for it
        let mut lied = bytes.clone();
        lied[off + 12..off + 20].copy_from_slice(&u64::MAX.to_le_bytes());
        rewrite_body_crc(&mut lied);
        assert!(Archive::from_bytes(&lied).is_err());

        // hostile writer: raw total past the header cap (and the matching
        // first-segment raw length, so the sum check is not what trips)
        let mut bomb = bytes.clone();
        let huge = 1u64 << 62;
        bomb[off..off + 8].copy_from_slice(&huge.to_le_bytes());
        bomb[off + 12..off + 20].copy_from_slice(&huge.to_le_bytes());
        rewrite_body_crc(&mut bomb);
        let err = Archive::from_bytes(&bomb).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err:#}");

        // hostile writer: segment count inflated past the payload
        let mut many = bytes.clone();
        many[off + 8..off + 12].copy_from_slice(&u32::MAX.to_le_bytes());
        rewrite_body_crc(&mut many);
        assert!(Archive::from_bytes(&many).is_err());

        // truncation anywhere in the tail is rejected
        assert!(Archive::from_bytes(&bytes[..bytes.len() - 5]).is_err());
    }

    #[test]
    fn tail_encodes_exactly_once_per_serialization() {
        let plain = sample_archive(LosslessTag::None);
        let before = lossless_tail_encodes();
        let _ = plain.to_bytes();
        let _ = plain.serialized_len();
        assert_eq!(lossless_tail_encodes() - before, 0, "None tail never encodes");

        let zstd = sample_archive(LosslessTag::Zstd);
        let before = lossless_tail_encodes();
        let bytes = zstd.to_bytes();
        assert_eq!(lossless_tail_encodes() - before, 1, "one encode per to_bytes");
        let _ = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(lossless_tail_encodes() - before, 1, "decode never re-encodes");
        let _ = zstd.serialized_len();
        assert_eq!(lossless_tail_encodes() - before, 2, "serialized_len on a compressed tail is one more encode");
    }
}
