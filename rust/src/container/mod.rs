//! The `.cusza` archive format — cuSZ's self-contained compressed output:
//! versioned codec-tagged header, encoder sidecar (Huffman: canonical
//! codebook lengths; FLE: per-chunk bit widths; RLE: per-chunk value/run
//! widths), the chunked framed bitstream, an optional per-chunk encoder
//! tag table (mixed-granularity archives), the outlier side channels, and
//! per-section CRC32s (DESIGN.md §6).
//!
//! Three magics coexist: [`MAGIC_V0`] marks pre-codec archives (legacy
//! header layout, Huffman implied), [`MAGIC_V1`] marks PR 2's
//! field-tagged archives — both still decode. [`MAGIC`] marks current
//! archives, whose header adds a codec-granularity byte and whose body
//! may carry a per-chunk tag table + per-chunk sidecar records. Unknown
//! magics, versions, and tags all fail cleanly.

pub mod bytes;
pub mod header;

use anyhow::{bail, Context, Result};

use crate::codec::{CodecGranularity, EncoderKind};
use crate::huffman::deflate::{DeflatedChunk, DeflatedStream};
use bytes::{ByteReader, ByteWriter};
pub use header::{Header, LosslessTag, FORMAT_VERSION};

/// Magic of legacy (format version 0) archives.
pub const MAGIC_V0: &[u8; 8] = b"CUSZA1\0\0";
/// Magic of format-version-1 (field-tagged, pre-granularity) archives.
pub const MAGIC_V1: &[u8; 8] = b"CUSZA2\0\0";
/// Magic of current (granularity-aware, chunk-taggable) archives.
pub const MAGIC: &[u8; 8] = b"CUSZA3\0\0";

/// Largest chunk geometry (symbols per chunk) the format accepts. Real
/// configs top out at 2^16; the bound keeps a crafted stream from turning
/// per-chunk symbol counts into unbounded allocations. Enforced on both
/// sides: the parser rejects larger values as corrupt, and the compressor
/// refuses to produce archives it could not read back.
pub const MAX_CHUNK_SYMBOLS: usize = 1 << 24;

/// One compressed field.
#[derive(Debug, Clone, PartialEq)]
pub struct Archive {
    pub header: Header,
    /// Encoder sidecar: what the tagged encoder's decoder needs (Huffman:
    /// per-symbol code-length table; FLE: per-chunk bit widths; RLE:
    /// per-chunk `[w, r]` records). In a mixed-granularity archive this
    /// holds the codebook length table shared by Huffman-tagged chunks.
    pub encoder_aux: Vec<u8>,
    /// Per-chunk encoder tags (one [`EncoderKind::to_tag`] byte per
    /// stream chunk) for mixed-granularity archives; empty when the
    /// header's field-level encoder tag applies uniformly.
    pub chunk_tags: Vec<u8>,
    /// Per-chunk sidecar records for mixed-granularity archives (FLE:
    /// `[w]`; RLE: `[w, r]`; Huffman: empty — it uses `encoder_aux`);
    /// empty when `chunk_tags` is.
    pub chunk_aux: Vec<Vec<u8>>,
    /// Framed chunked bitstream (quantization codes, slab-major order).
    pub stream: DeflatedStream,
    /// Prediction outliers: (global position in the slab-major stream,
    /// exact integer delta). Symbol 0 marks their slots in the stream.
    pub outliers: Vec<(u64, i32)>,
    /// Range outliers: (global position, verbatim f32) — prequant-cap
    /// clamps and non-finite values, overwritten after reconstruction.
    pub verbatim: Vec<(u64, f32)>,
}

impl Archive {
    /// Total compressed size in bytes (what CR is computed against).
    pub fn compressed_bytes(&self) -> usize {
        self.to_bytes().len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        // pre-granularity layouts have no chunk-tag sections: writing one
        // silently would decode wrong under an old parser — fail loudly
        assert!(
            self.header.version >= 2
                || (self.chunk_tags.is_empty() && self.chunk_aux.is_empty()),
            "version-{} archives cannot carry a per-chunk tag table",
            self.header.version
        );
        let mut w = ByteWriter::new();
        // headers serialize in their own version's layout, so each must
        // travel under the matching magic for parsers to agree
        w.bytes(match self.header.version {
            0 => MAGIC_V0,
            1 => MAGIC_V1,
            _ => MAGIC,
        });
        let header_bytes = self.header.to_bytes();
        w.section(&header_bytes);

        let mut body = ByteWriter::new();
        body.u32(self.encoder_aux.len() as u32);
        body.bytes(&self.encoder_aux);

        body.u32(self.stream.chunks.len() as u32);
        body.u32(self.stream.chunk_symbols as u32);
        for c in &self.stream.chunks {
            body.u64(c.bits);
            body.u32(c.symbols);
            body.u32(c.words.len() as u32);
            for &wd in &c.words {
                body.u64(wd);
            }
        }

        if self.header.version >= 2 {
            body.u32(self.chunk_tags.len() as u32);
            body.bytes(&self.chunk_tags);
            if !self.chunk_tags.is_empty() {
                for aux in &self.chunk_aux {
                    // u8 length prefix: a wider record would wrap modulo
                    // 256 and silently desynchronize the reader — any
                    // future backend needing more must grow the framing
                    assert!(
                        aux.len() <= u8::MAX as usize,
                        "per-chunk sidecar record of {} bytes exceeds the u8 length prefix",
                        aux.len()
                    );
                    body.u8(aux.len() as u8);
                    body.bytes(aux);
                }
            }
        }

        body.u64(self.outliers.len() as u64);
        for &(pos, delta) in &self.outliers {
            body.u64(pos);
            body.i32(delta);
        }
        body.u64(self.verbatim.len() as u64);
        for &(pos, val) in &self.verbatim {
            body.u64(pos);
            body.f32(val);
        }

        let body_bytes = body.finish();
        let body_bytes = match self.header.lossless {
            LosslessTag::None => body_bytes,
            LosslessTag::Gzip => {
                use flate2::{write::GzEncoder, Compression};
                use std::io::Write;
                let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
                enc.write_all(&body_bytes).expect("gzip");
                enc.finish().expect("gzip finish")
            }
            LosslessTag::Zstd => zstd::encode_all(&body_bytes[..], 3).expect("zstd"),
        };
        w.section(&body_bytes);
        w.finish()
    }

    /// Read the magic + header section, dispatching to the right header
    /// parser per format version. The magic and the header's version byte
    /// must agree — a mismatch means the payload was spliced or corrupted.
    fn read_header(r: &mut ByteReader<'_>) -> Result<Header> {
        let magic = r.take(8)?;
        let legacy = if magic == MAGIC_V0 {
            true
        } else if magic == MAGIC_V1 || magic == MAGIC {
            false
        } else {
            bail!("not a cusza archive (bad magic)");
        };
        let header_bytes = r.section().context("header section")?;
        if legacy {
            return Header::from_bytes_v0(&header_bytes);
        }
        let header = Header::from_bytes(&header_bytes)?;
        let expect_v1 = magic == MAGIC_V1;
        if expect_v1 != (header.version == 1) {
            bail!(
                "archive magic disagrees with header version {} (spliced payload?)",
                header.version
            );
        }
        Ok(header)
    }

    /// Parse only the header from serialized archive bytes — the cheap
    /// "payload framing" read the multi-field store uses for indexing and
    /// `ls` without touching the (possibly much larger) body section.
    pub fn peek_header(data: &[u8]) -> Result<Header> {
        Self::read_header(&mut ByteReader::new(data))
    }

    /// CRC32 digest of the serialized header — stored per entry in the
    /// `.cuszb` footer index so `Store::get` can detect a payload that was
    /// swapped or rewritten since indexing.
    pub fn header_digest(&self) -> u32 {
        bytes::crc32(&self.header.to_bytes())
    }

    pub fn from_bytes(data: &[u8]) -> Result<Archive> {
        let mut r = ByteReader::new(data);
        let header = Self::read_header(&mut r)?;

        let body_raw = r.section().context("body section")?;
        // Cap the decompressed body so a crafted gzip/zstd bomb fails
        // cleanly instead of allocating without bound: a legitimate body
        // is linear in the element count the header itself declares.
        let cap = decompressed_body_cap(&header);
        let body_bytes = match header.lossless {
            LosslessTag::None => body_raw,
            LosslessTag::Gzip => {
                use flate2::read::GzDecoder;
                use std::io::Read;
                let mut out = Vec::new();
                GzDecoder::new(&body_raw[..])
                    .take(cap + 1)
                    .read_to_end(&mut out)
                    .context("gunzip")?;
                if out.len() as u64 > cap {
                    bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
                }
                out
            }
            LosslessTag::Zstd => {
                use std::io::Read;
                let dec = zstd::stream::read::Decoder::new(&body_raw[..]).context("unzstd")?;
                let mut out = Vec::new();
                dec.take(cap + 1).read_to_end(&mut out).context("unzstd")?;
                if out.len() as u64 > cap {
                    bail!("corrupt archive: decompressed body exceeds {cap}-byte cap");
                }
                out
            }
        };
        let mut b = ByteReader::new(&body_bytes);

        let nlen = b.u32()? as usize;
        let encoder_aux = b.take(nlen)?;

        // Every element count below is bounded against the bytes actually
        // present before allocating, so a corrupted count fails cleanly
        // instead of attempting a multi-GB reservation.
        let nchunks = b.u32()? as usize;
        let chunk_symbols = b.u32()? as usize;
        if chunk_symbols > MAX_CHUNK_SYMBOLS {
            bail!("corrupt archive: implausible chunk size {chunk_symbols}");
        }
        if nchunks > b.remaining() / 16 {
            bail!("corrupt archive: {nchunks} chunks exceeds payload");
        }
        let mut chunks = Vec::with_capacity(nchunks);
        for _ in 0..nchunks {
            let bits = b.u64()?;
            let symbols = b.u32()?;
            let nwords = b.u32()? as usize;
            if nwords > b.remaining() / 8 {
                bail!("corrupt archive: {nwords} chunk words exceeds payload");
            }
            let mut words = Vec::with_capacity(nwords);
            for _ in 0..nwords {
                words.push(b.u64()?);
            }
            chunks.push(DeflatedChunk { words, bits, symbols });
        }

        // per-chunk tag table (format version >= 2). The header's
        // granularity byte and the table's presence must agree, every tag
        // must be known, and the sidecar record list must cover exactly
        // the tagged chunks — all checked here so downstream decode never
        // sees a structurally inconsistent archive.
        let (chunk_tags, chunk_aux) = if header.version >= 2 {
            let ntags = b.u32()? as usize;
            if ntags != 0 && ntags != nchunks {
                bail!("corrupt archive: {ntags} chunk tags for {nchunks} chunks");
            }
            if (header.granularity == CodecGranularity::Chunk) != (ntags > 0) {
                bail!(
                    "corrupt archive: {} granularity with {ntags} chunk tags",
                    header.granularity.name()
                );
            }
            let tags = b.take(ntags)?;
            for &t in &tags {
                EncoderKind::from_tag(t)?;
            }
            let mut aux = Vec::with_capacity(ntags);
            for _ in 0..ntags {
                let alen = b.u8()? as usize;
                aux.push(b.take(alen)?);
            }
            (tags, aux)
        } else {
            (Vec::new(), Vec::new())
        };

        let nout = b.u64()? as usize;
        if nout > b.remaining() / 12 {
            bail!("corrupt archive: {nout} outliers exceeds payload");
        }
        let mut outliers = Vec::with_capacity(nout);
        for _ in 0..nout {
            outliers.push((b.u64()?, b.i32()?));
        }
        let nverb = b.u64()? as usize;
        if nverb > b.remaining() / 12 {
            bail!("corrupt archive: {nverb} verbatim values exceeds payload");
        }
        let mut verbatim = Vec::with_capacity(nverb);
        for _ in 0..nverb {
            verbatim.push((b.u64()?, b.f32()?));
        }

        Ok(Archive {
            header,
            encoder_aux,
            chunk_tags,
            chunk_aux,
            stream: DeflatedStream { chunks, chunk_symbols },
            outliers,
            verbatim,
        })
    }
}

/// Upper bound on a plausible decompressed body for `header`: every
/// element contributes at most a few words across the stream, outlier,
/// and verbatim channels, plus fixed slack for the codebook and framing.
fn decompressed_body_cap(header: &Header) -> u64 {
    let n: u64 = header
        .dims
        .iter()
        .fold(1u64, |acc, &d| acc.saturating_mul(d as u64));
    64 * 1024 * 1024 + n.saturating_mul(32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::EncoderKind;
    use crate::config::ErrorBound;

    fn sample_archive(lossless: LosslessTag) -> Archive {
        Archive {
            header: Header {
                version: FORMAT_VERSION,
                encoder: EncoderKind::Huffman,
                granularity: CodecGranularity::Field,
                field_name: "NYX/baryon_density".into(),
                dims: vec![64, 64, 64],
                variant: "3d_64".into(),
                eb: ErrorBound::ValRel(1e-4),
                abs_eb: 0.01,
                dict_size: 1024,
                chunk_symbols: 4096,
                repr_bits: 32,
                lossless,
                n_slabs: 4,
            },
            encoder_aux: (0..1024).map(|i| (i % 20) as u8).collect(),
            chunk_tags: Vec::new(),
            chunk_aux: Vec::new(),
            stream: DeflatedStream {
                chunks: vec![
                    DeflatedChunk { words: vec![0xdead, 0xbeef], bits: 100, symbols: 40 },
                    DeflatedChunk { words: vec![42], bits: 17, symbols: 3 },
                ],
                chunk_symbols: 4096,
            },
            outliers: vec![(7, -123456), (99_999, 777)],
            verbatim: vec![(123, f32::NAN), (456, 1e30)],
        }
    }

    fn sample_mixed_archive() -> Archive {
        let mut a = sample_archive(LosslessTag::None);
        a.header.granularity = CodecGranularity::Chunk;
        a.chunk_tags = vec![EncoderKind::Fle.to_tag(), EncoderKind::Rle.to_tag()];
        a.chunk_aux = vec![vec![9], vec![3, 7]];
        a.encoder_aux = Vec::new();
        a
    }

    #[test]
    fn roundtrip_plain() {
        let a = sample_archive(LosslessTag::None);
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.header, b.header);
        assert_eq!(a.encoder_aux, b.encoder_aux);
        assert_eq!(a.stream, b.stream);
        assert_eq!(a.outliers, b.outliers);
        assert_eq!(b.verbatim[0].0, 123);
        assert!(b.verbatim[0].1.is_nan());
        assert_eq!(a.verbatim[1], b.verbatim[1]);
    }

    #[test]
    fn roundtrip_gzip_and_zstd() {
        for tag in [LosslessTag::Gzip, LosslessTag::Zstd] {
            let a = sample_archive(tag);
            let b = Archive::from_bytes(&a.to_bytes()).unwrap();
            assert_eq!(a.stream, b.stream, "{tag:?}");
        }
    }

    #[test]
    fn roundtrip_fle_tag() {
        let mut a = sample_archive(LosslessTag::None);
        a.header.encoder = EncoderKind::Fle;
        a.encoder_aux = vec![9, 9]; // per-chunk widths
        let b = Archive::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.header.encoder, EncoderKind::Fle);
        assert_eq!(b.encoder_aux, vec![9, 9]);
    }

    #[test]
    fn v0_archive_bytes_still_parse() {
        // a pre-codec archive: version-0 header under the legacy magic
        let mut a = sample_archive(LosslessTag::None);
        a.header.version = 0;
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V0);
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.version, 0);
        assert_eq!(b.header.encoder, EncoderKind::Huffman);
        assert_eq!(b.stream, a.stream);
        assert_eq!(Archive::peek_header(&bytes).unwrap(), b.header);
    }

    #[test]
    fn current_archive_carries_version_and_tag() {
        let a = sample_archive(LosslessTag::None);
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC);
        let h = Archive::peek_header(&bytes).unwrap();
        assert_eq!(h.version, FORMAT_VERSION);
        assert_eq!(h.encoder, EncoderKind::Huffman);
        assert_eq!(h.granularity, CodecGranularity::Field);
    }

    #[test]
    fn v1_archive_bytes_still_parse() {
        // a PR 2 archive: version-1 header under the CUSZA2 magic
        let mut a = sample_archive(LosslessTag::None);
        a.header.version = 1;
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V1);
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.version, 1);
        assert_eq!(b.header.granularity, CodecGranularity::Field);
        assert!(b.chunk_tags.is_empty());
        assert_eq!(b.stream, a.stream);
        assert_eq!(Archive::peek_header(&bytes).unwrap(), b.header);
    }

    #[test]
    fn mixed_archive_tag_table_roundtrips() {
        let a = sample_mixed_archive();
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..8], MAGIC);
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.header.granularity, CodecGranularity::Chunk);
        assert_eq!(b.chunk_tags, a.chunk_tags);
        assert_eq!(b.chunk_aux, a.chunk_aux);
        assert_eq!(b, a);
    }

    #[test]
    fn granularity_and_tag_table_must_agree() {
        // chunk granularity without a tag table
        let mut a = sample_mixed_archive();
        a.chunk_tags = Vec::new();
        a.chunk_aux = Vec::new();
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
        // field granularity with a tag table
        let mut a = sample_mixed_archive();
        a.header.granularity = CodecGranularity::Field;
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
        // tag count must match the chunk count
        let mut a = sample_mixed_archive();
        a.chunk_tags.push(EncoderKind::Fle.to_tag());
        a.chunk_aux.push(vec![4]);
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
        // unknown tag in the table
        let mut a = sample_mixed_archive();
        a.chunk_tags[1] = 44;
        assert!(Archive::from_bytes(&a.to_bytes()).is_err());
    }

    #[test]
    fn spliced_magic_version_mismatch_rejected() {
        // a version-2 header smuggled under the CUSZA2 magic (and vice
        // versa) must be rejected even though both parts are well-formed
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        bytes[..8].copy_from_slice(MAGIC_V1);
        assert!(Archive::from_bytes(&bytes).is_err());
        let mut a1 = sample_archive(LosslessTag::None);
        a1.header.version = 1;
        let mut bytes = a1.to_bytes();
        bytes[..8].copy_from_slice(MAGIC);
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn unknown_encoder_tag_fails_cleanly() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        // the encoder tag is the second byte of the header section:
        // 8 magic + 8 len + 4 crc + 1 version byte
        bytes[21] = 77;
        // CRC now mismatches; rewrite the section frame around the edit
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let crc = bytes::crc32(&bytes[20..20 + header_len]);
        bytes[16..20].copy_from_slice(&crc.to_le_bytes());
        let err = Archive::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("encoder tag"), "{err:#}");
    }

    #[test]
    fn bad_magic_rejected() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        bytes[0] = b'X';
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn corrupt_section_crc_rejected() {
        let a = sample_archive(LosslessTag::None);
        let mut bytes = a.to_bytes();
        let n = bytes.len();
        bytes[n - 3] ^= 0xff; // flip a bit in the verbatim tail
        assert!(Archive::from_bytes(&bytes).is_err());
    }

    #[test]
    fn decompression_bomb_is_capped() {
        // a valid-CRC zstd body that inflates far past what the header's
        // dims (64^3 elements -> ~72 MB cap) could legitimately need
        use std::io::Read;
        let header = sample_archive(LosslessTag::Zstd).header;
        let bomb = zstd::encode_all(std::io::repeat(0u8).take(100 * 1024 * 1024), 3).unwrap();
        let mut w = ByteWriter::new();
        w.bytes(MAGIC);
        w.section(&header.to_bytes());
        w.section(&bomb);
        let err = Archive::from_bytes(&w.finish()).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err:#}");
    }

    #[test]
    fn truncated_archive_rejected() {
        let a = sample_archive(LosslessTag::None);
        let bytes = a.to_bytes();
        assert!(Archive::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }
}
