//! Little-endian byte (de)serialization with CRC32-framed sections.

use anyhow::{bail, Result};

#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a writer over a caller-provided buffer (cleared first) so
    /// arena-loaned scratch keeps its capacity across serializations.
    /// Pair with [`ByteWriter::finish`] and hand the Vec back.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        ByteWriter { buf }
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }

    /// Length + CRC32 framed section.
    pub fn section(&mut self, payload: &[u8]) {
        self.u64(payload.len() as u64);
        self.u32(crc32(payload));
        self.bytes(payload);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<Vec<u8>> {
        Ok(self.take_ref(n)?.to_vec())
    }

    /// Borrow the next `n` bytes without copying (segment payloads and
    /// other windows that are decoded in place).
    pub fn take_ref(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: n comes from untrusted length fields and may be
        // near usize::MAX after corruption
        match self.pos.checked_add(n) {
            Some(end) if end <= self.buf.len() => {
                let out = &self.buf[self.pos..end];
                self.pos = end;
                Ok(out)
            }
            _ => bail!("archive truncated: need {n} bytes at {}", self.pos),
        }
    }

    /// Bytes left to read — the sanity bound for untrusted element counts.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        if self.pos + N > self.buf.len() {
            bail!("archive truncated at {}", self.pos);
        }
        let mut a = [0u8; N];
        a.copy_from_slice(&self.buf[self.pos..self.pos + N]);
        self.pos += N;
        Ok(a)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.arr::<1>()?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.arr()?))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.arr()?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.arr()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.arr()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.arr()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        Ok(String::from_utf8(b)?)
    }

    /// Read a CRC-framed section, verifying integrity.
    pub fn section(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        let crc = self.u32()?;
        let payload = self.take(n)?;
        if crc32(&payload) != crc {
            bail!("section CRC mismatch (corrupt archive)");
        }
        Ok(payload)
    }
}

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(data);
    h.finish()
}

/// Incremental CRC-32 — lets streaming writers (e.g. a shard append that
/// never buffers the payload) digest data as it flows past.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: !0u32 }
    }

    pub fn update(&mut self, data: &[u8]) {
        let table = crc_table();
        let mut c = self.state;
        for &b in data {
            c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        !self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xdeadbeef);
        w.i32(-42);
        w.u64(u64::MAX - 1);
        w.f32(3.25);
        w.f64(-1e300);
        w.str("hello");
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdeadbeef);
        assert_eq!(r.i32().unwrap(), -42);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap(), 3.25);
        assert_eq!(r.f64().unwrap(), -1e300);
        assert_eq!(r.str().unwrap(), "hello");
    }

    #[test]
    fn crc32_known_vector() {
        // "123456789" -> 0xCBF43926 (IEEE CRC-32 check value)
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
    }

    #[test]
    fn incremental_crc_matches_one_shot() {
        let mut h = Crc32::new();
        h.update(b"123");
        h.update(b"");
        h.update(b"456789");
        assert_eq!(h.finish(), 0xcbf43926);
    }

    #[test]
    fn take_ref_borrows_without_copy() {
        let buf = [1u8, 2, 3, 4, 5];
        let mut r = ByteReader::new(&buf);
        let a = r.take_ref(2).unwrap();
        assert_eq!(a, &buf[..2]);
        assert_eq!(a.as_ptr(), buf.as_ptr());
        assert_eq!(r.remaining(), 3);
        assert!(r.take_ref(4).is_err());
    }

    #[test]
    fn from_vec_reuses_capacity() {
        let mut w = ByteWriter::from_vec(Vec::with_capacity(128));
        assert!(w.is_empty());
        w.u32(9);
        assert_eq!(w.len(), 4);
        let v = w.finish();
        assert!(v.capacity() >= 128);
        // and residue is cleared on reuse
        let w2 = ByteWriter::from_vec(v);
        assert!(w2.is_empty());
    }

    #[test]
    fn section_detects_corruption() {
        let mut w = ByteWriter::new();
        w.section(b"payload-data");
        let mut buf = w.finish();
        let n = buf.len();
        buf[n - 1] ^= 1;
        assert!(ByteReader::new(&buf).section().is_err());
    }

    #[test]
    fn read_past_end_errors() {
        let mut r = ByteReader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }
}
