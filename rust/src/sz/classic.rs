//! Algorithm 1: the ORIGINAL SZ-1.4-style sequential predict-quant with the
//! loop-carried RAW cascade, in float space (predictions read decompressed
//! values, reconstruction is written back in situ).
//!
//! This is the CPU-SZ baseline of Figure 5 / Table 7 and the SZ-1.4 column
//! of Table 8. Differences from DUAL-QUANT that the paper calls out and
//! that this implementation reproduces:
//!   * float-space arithmetic (error at exact zeros is nonzero, so
//!     zero-dominated fields score lower PSNR than cuSZ — Table 8);
//!   * outer-layer (first row/column/plane) points are stored verbatim as
//!     unpredictable data (§3.1.1 "the original SZ ... saved as
//!     unpredictable data directly");
//!   * strictly sequential: every point waits for its predecessors.

use super::block_for_ndim;

#[derive(Debug, Clone)]
pub struct ClassicCompressed {
    pub codes: Vec<u16>,
    /// (index, verbatim f32) for outer-layer + out-of-cap points (code 0).
    pub outliers: Vec<(u32, f32)>,
    pub shape: Vec<usize>,
}

/// Sequential SZ-1.4 compression. `dict_size` bins, bin 0 = unpredictable.
pub fn compress(data: &[f32], shape: &[usize], eb: f32, dict_size: usize) -> ClassicCompressed {
    let radius = (dict_size / 2) as i32;
    let n: usize = shape.iter().product();
    assert_eq!(n, data.len());
    let mut recon = vec![0f32; n];
    let mut codes = vec![0u16; n];
    let mut outliers = Vec::new();
    let strides = row_major_strides(shape);
    let nd = shape.len();

    let mut coord = vec![0usize; nd];
    for (i, &d) in data.iter().enumerate() {
        let outer = coord.iter().any(|&c| c == 0);
        if outer {
            // Outer layer: verbatim (exact) storage.
            codes[i] = 0;
            outliers.push((i as u32, d));
            recon[i] = d;
        } else {
            let p = lorenzo_float(&recon, i, &strides, nd);
            let e = d - p;
            let k = (e / (2.0 * eb)).round_ties_even();
            let code_delta = k as i32;
            let rehearsal = p + code_delta as f32 * 2.0 * eb;
            // WATCHDOG (Algorithm 1 line 7): quantized residual must still
            // honor the bound, else fall back to OUTLIER.
            if code_delta > -radius
                && code_delta < radius
                && (rehearsal - d).abs() <= eb
                && d.is_finite()
            {
                codes[i] = (code_delta + radius) as u16;
                recon[i] = rehearsal; // RAW write-back
            } else {
                codes[i] = 0;
                outliers.push((i as u32, d));
                recon[i] = d;
            }
        }
        bump(&mut coord, shape);
    }
    ClassicCompressed { codes, outliers, shape: shape.to_vec() }
}

/// Sequential decompression: cascading reconstruction.
pub fn decompress(c: &ClassicCompressed, eb: f32, dict_size: usize) -> Vec<f32> {
    let radius = (dict_size / 2) as i32;
    let n: usize = c.shape.iter().product();
    let mut recon = vec![0f32; n];
    let strides = row_major_strides(&c.shape);
    let nd = c.shape.len();
    let mut outlier_iter = c.outliers.iter().peekable();

    let mut coord = vec![0usize; nd];
    for i in 0..n {
        let code = c.codes[i];
        if code == 0 {
            let (idx, v) = outlier_iter.next().copied().unwrap_or((i as u32, 0.0));
            debug_assert_eq!(idx as usize, i, "outlier order");
            recon[i] = v;
        } else {
            let p = lorenzo_float(&recon, i, &strides, nd);
            recon[i] = p + (code as i32 - radius) as f32 * 2.0 * eb;
        }
        bump(&mut coord, &c.shape);
    }
    recon
}

/// Compressed size estimate in bytes (codes after Huffman + outliers),
/// used for CR accounting in the baseline benches.
pub fn compressed_bytes(c: &ClassicCompressed, huffman_bits: u64) -> usize {
    (huffman_bits as usize).div_ceil(8) + c.outliers.len() * 8
}

#[inline]
fn lorenzo_float(recon: &[f32], i: usize, strides: &[usize], nd: usize) -> f32 {
    // Interior-only call: all neighbors exist.
    match nd {
        1 => recon[i - 1],
        2 => recon[i - 1] + recon[i - strides[0]] - recon[i - strides[0] - 1],
        3 => {
            let (s0, s1) = (strides[0], strides[1]);
            recon[i - 1] + recon[i - s1] + recon[i - s0]
                - recon[i - s1 - 1]
                - recon[i - s0 - 1]
                - recon[i - s0 - s1]
                + recon[i - s0 - s1 - 1]
        }
        _ => unreachable!(),
    }
}

fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let nd = shape.len();
    let mut s = vec![1usize; nd];
    for ax in (0..nd.saturating_sub(1)).rev() {
        s[ax] = s[ax + 1] * shape[ax + 1];
    }
    s
}

#[inline]
fn bump(coord: &mut [usize], shape: &[usize]) {
    for ax in (0..shape.len()).rev() {
        coord[ax] += 1;
        if coord[ax] < shape[ax] {
            return;
        }
        coord[ax] = 0;
    }
}

/// Chunked-parallel classic SZ: the OpenMP-SZ baseline (§4.2.1). Each
/// thread runs the unmodified sequential algorithm on its own block; block
/// borders are zero-seeded like cuSZ (Figure 2 note in the paper).
pub fn compress_openmp_style(
    data: &[f32],
    shape: &[usize],
    eb: f32,
    dict_size: usize,
    threads: usize,
) -> Vec<ClassicCompressed> {
    use crate::sz::blocks::{gather_slab, tile_grid, SlabSpec};
    // One OpenMP block ~ a slab of 8x the Lorenzo block per axis.
    let block = block_for_ndim(shape.len());
    let slab_shape: Vec<usize> =
        block.iter().zip(shape).map(|(b, s)| (b * 8).min(s.next_power_of_two().max(*b))).collect();
    let slab_shape: Vec<usize> =
        slab_shape.iter().zip(&block).map(|(s, b)| s.div_ceil(*b) * *b).collect();
    let spec = SlabSpec::new("omp", &slab_shape, &block);
    let grid = tile_grid(shape, &spec);
    crate::util::pool::parallel_map(threads, &grid, |_, idx| {
        let slab = gather_slab(data, shape, &spec, idx);
        compress(&slab, &spec.shape, eb, dict_size)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn smooth(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut acc = 0f32;
        (0..n)
            .map(|_| {
                acc += rng.normal() * 0.01;
                acc
            })
            .collect()
    }

    #[test]
    fn roundtrip_1d_within_eb() {
        let data = smooth(1000, 1);
        let eb = 1e-3;
        let c = compress(&data, &[1000], eb, 1024);
        let out = decompress(&c, eb, 1024);
        for (o, d) in out.iter().zip(&data) {
            assert!((o - d).abs() <= eb * 1.0001, "{o} vs {d}");
        }
    }

    #[test]
    fn roundtrip_2d_within_eb() {
        let data = smooth(64 * 64, 2);
        let eb = 1e-3;
        let c = compress(&data, &[64, 64], eb, 1024);
        let out = decompress(&c, eb, 1024);
        for (o, d) in out.iter().zip(&data) {
            assert!((o - d).abs() <= eb * 1.0001);
        }
    }

    #[test]
    fn roundtrip_3d_within_eb() {
        let data = smooth(16 * 16 * 16, 3);
        let eb = 1e-2;
        let c = compress(&data, &[16, 16, 16], eb, 1024);
        let out = decompress(&c, eb, 1024);
        for (o, d) in out.iter().zip(&data) {
            assert!((o - d).abs() <= eb * 1.0001);
        }
    }

    #[test]
    fn outer_layer_is_verbatim() {
        let data = smooth(32 * 32, 4);
        let c = compress(&data, &[32, 32], 1e-3, 1024);
        let out = decompress(&c, 1e-3, 1024);
        // first row and column reconstruct exactly
        for j in 0..32 {
            assert_eq!(out[j], data[j]);
            assert_eq!(out[j * 32], data[j * 32]);
        }
    }

    #[test]
    fn smooth_fields_mostly_predictable() {
        let data = smooth(10_000, 5);
        let c = compress(&data, &[10_000], 1e-3, 1024);
        let frac = c.outliers.len() as f64 / data.len() as f64;
        assert!(frac < 0.02, "outlier fraction {frac}");
    }

    #[test]
    fn spiky_data_falls_back_to_outliers() {
        let mut rng = Rng::new(6);
        let data: Vec<f32> = (0..1000).map(|_| rng.normal() * 1e6).collect();
        let c = compress(&data, &[1000], 1e-6, 1024);
        let out = decompress(&c, 1e-6, 1024);
        for (o, d) in out.iter().zip(&data) {
            assert!((o - d).abs() <= 1e-6 * 1.001 + d.abs() * 1e-6);
        }
    }
}
