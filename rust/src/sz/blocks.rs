//! Slab tiling and zero padding (paper §3.1.1, Figure 2).
//!
//! AOT executables have fixed shapes, so fields are tiled into fixed-shape
//! slabs; the trailing partial slab in each axis is zero-padded. Padding
//! predicts perfectly under the zero-initialized Lorenzo layer, costing
//! only near-zero-entropy symbols.

/// Fixed slab geometry (mirrors python/compile/variants.py).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlabSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub block: Vec<usize>,
}

impl SlabSpec {
    pub fn new(name: &str, shape: &[usize], block: &[usize]) -> Self {
        assert_eq!(shape.len(), block.len());
        for (s, b) in shape.iter().zip(block) {
            assert!(s % b == 0, "slab {shape:?} not block-aligned {block:?}");
        }
        SlabSpec { name: name.to_string(), shape: shape.to_vec(), block: block.to_vec() }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }
}

/// The built-in slab variants — must mirror python/compile/variants.py
/// (the AOT manifest is authoritative when artifacts are present; the CPU
/// backend uses this table so both backends pick identical geometry).
pub fn builtin_variants() -> Vec<SlabSpec> {
    vec![
        SlabSpec::new("1d_64k", &[1 << 16], &[32]),
        SlabSpec::new("1d_1m", &[1 << 20], &[32]),
        SlabSpec::new("2d_256", &[256, 256], &[16, 16]),
        SlabSpec::new("2d_1k", &[1024, 1024], &[16, 16]),
        SlabSpec::new("3d_32", &[32, 32, 32], &[8, 8, 8]),
        SlabSpec::new("3d_64", &[64, 64, 64], &[8, 8, 8]),
        SlabSpec::new("3d_128", &[128, 128, 128], &[8, 8, 8]),
    ]
}

/// Total elements after tiling `dims` with slabs of `spec` (incl. padding).
pub fn padded_volume(dims: &[usize], spec: &SlabSpec) -> usize {
    dims.iter()
        .zip(&spec.shape)
        .map(|(d, s)| d.div_ceil(*s) * s)
        .product()
}

/// Select the slab variant for a field's kernel dims: minimize the padded
/// volume (bounding both wasted compute and wasted bitrate); ties go to
/// the larger slab (fewer dispatches).
pub fn select_spec<'a>(specs: &'a [SlabSpec], kernel_dims: &[usize]) -> Option<&'a SlabSpec> {
    specs
        .iter()
        .filter(|s| s.ndim() == kernel_dims.len())
        .min_by_key(|s| (padded_volume(kernel_dims, s), usize::MAX - s.len()))
}

/// Location of one slab within the field's tile grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlabIndex {
    /// Tile coordinates (per axis).
    pub tile: Vec<usize>,
    /// Origin element offset (per axis) in the field.
    pub origin: Vec<usize>,
    /// Valid (unpadded) extent per axis.
    pub valid: Vec<usize>,
}

/// Enumerate the tile grid covering `dims` with slabs of `spec.shape`.
pub fn tile_grid(dims: &[usize], spec: &SlabSpec) -> Vec<SlabIndex> {
    assert_eq!(dims.len(), spec.ndim());
    let counts: Vec<usize> =
        dims.iter().zip(&spec.shape).map(|(d, s)| d.div_ceil(*s)).collect();
    let total: usize = counts.iter().product();
    let mut out = Vec::with_capacity(total);
    for flat in 0..total {
        let mut rem = flat;
        let mut tile = vec![0usize; dims.len()];
        for ax in (0..dims.len()).rev() {
            tile[ax] = rem % counts[ax];
            rem /= counts[ax];
        }
        let origin: Vec<usize> =
            tile.iter().zip(&spec.shape).map(|(t, s)| t * s).collect();
        let valid: Vec<usize> = origin
            .iter()
            .zip(dims)
            .zip(&spec.shape)
            .map(|((o, d), s)| (*d - *o).min(*s))
            .collect();
        out.push(SlabIndex { tile, origin, valid });
    }
    out
}

/// Copy one slab out of the field (row-major), zero-padding beyond `valid`.
pub fn gather_slab(data: &[f32], dims: &[usize], spec: &SlabSpec, idx: &SlabIndex) -> Vec<f32> {
    let mut slab = vec![0f32; spec.len()];
    gather_slab_into(data, dims, spec, idx, &mut slab);
    slab
}

/// Gather into a caller-provided buffer (must be pre-zeroed if the slab is
/// partial — only valid rows are written).
pub fn gather_slab_into(data: &[f32], dims: &[usize], spec: &SlabSpec, idx: &SlabIndex, slab: &mut [f32]) {
    assert_eq!(slab.len(), spec.len());
    copy_slab(dims, spec, idx, |src_off, dst_off, n| {
        slab[dst_off..dst_off + n].copy_from_slice(&data[src_off..src_off + n]);
    });
}

/// Scatter a reconstructed slab back into the field, dropping padding.
pub fn scatter_slab(out: &mut [f32], dims: &[usize], spec: &SlabSpec, idx: &SlabIndex, slab: &[f32]) {
    assert_eq!(slab.len(), spec.len());
    copy_slab(dims, spec, idx, |src_off, dst_off, n| {
        out[src_off..src_off + n].copy_from_slice(&slab[dst_off..dst_off + n]);
    });
}

/// A partitioned view of the output field for *parallel* slab scatter.
///
/// `tile_grid` assigns every slab index a disjoint valid region of the
/// field (tiles partition the index space; each scatter writes only its
/// tile's `valid` extent), so concurrent [`PartitionedField::scatter`]
/// calls for **distinct** indices of one grid never alias — the same
/// disjoint-write discipline as `util::pool::parallel_map_range`. This is
/// what lets the fused decompress pass retire the old collect-then-serial-
/// scatter loop (and its `Mutex<Vec<i32>>` cells).
///
/// Contract: callers must scatter each grid index at most once per view.
pub struct PartitionedField<'a> {
    data: *mut f32,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: the raw pointer is only written through `scatter`, whose
// per-slab regions are disjoint for distinct grid indices (see above),
// and the `&'a mut` borrow in the constructor keeps every other access
// to the buffer out for the view's lifetime.
unsafe impl Send for PartitionedField<'_> {}
unsafe impl Sync for PartitionedField<'_> {}

impl<'a> PartitionedField<'a> {
    pub fn new(out: &'a mut [f32]) -> PartitionedField<'a> {
        PartitionedField {
            data: out.as_mut_ptr(),
            len: out.len(),
            _borrow: std::marker::PhantomData,
        }
    }

    /// Scatter `slab` into `idx`'s region, dropping padding — the
    /// shared-view equivalent of [`scatter_slab`].
    pub fn scatter(&self, dims: &[usize], spec: &SlabSpec, idx: &SlabIndex, slab: &[f32]) {
        assert_eq!(slab.len(), spec.len());
        copy_slab(dims, spec, idx, |field_off, slab_off, n| {
            assert!(field_off + n <= self.len, "scatter row outside the field");
            // SAFETY: rows of distinct grid indices are disjoint (see the
            // type-level argument) and bounds-checked just above.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    slab.as_ptr().add(slab_off),
                    self.data.add(field_off),
                    n,
                );
            }
        });
    }
}

/// One *band* of the tile grid: every slab sharing the same `tile[0]`.
///
/// [`tile_grid`] enumerates tiles with axis 0 slowest, so a band is (a) a
/// contiguous run `slab_lo..slab_hi` of grid order — and therefore of the
/// slab-major symbol stream — and (b) a contiguous row-major region of
/// the field: rows `row_lo..row_lo + rows` along axis 0, full extent on
/// every other axis. That double contiguity is what the streaming tier
/// leans on: a band of the raw field can be read off any `Read` source
/// (or written to any `Write` sink) as one flat byte run, while its slabs
/// gather/scatter against a band-local buffer of dims
/// `[rows, dims[1..]]` using [`band_local`] indices — `copy_slab`
/// computes strides from whatever dims it is given, so the band buffer
/// behaves exactly like a short field.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Band {
    /// First grid/slab index of the band (inclusive).
    pub slab_lo: usize,
    /// One past the last grid/slab index of the band.
    pub slab_hi: usize,
    /// First axis-0 row the band covers.
    pub row_lo: usize,
    /// Valid axis-0 extent: `min(spec.shape[0], dims[0] - row_lo)`.
    pub rows: usize,
}

impl Band {
    /// Elements of the raw field the band covers (`rows * dims[1..]`).
    pub fn field_elems(&self, dims: &[usize]) -> usize {
        self.rows * dims[1..].iter().product::<usize>()
    }
}

/// Split `grid` (from [`tile_grid`] over the same `dims`/`spec`) into its
/// bands, in field order.
pub fn band_plan(dims: &[usize], spec: &SlabSpec, grid: &[SlabIndex]) -> Vec<Band> {
    assert_eq!(dims.len(), spec.ndim());
    let tiles0 = dims[0].div_ceil(spec.shape[0]);
    let per_band = if tiles0 == 0 { 0 } else { grid.len() / tiles0 };
    let mut out = Vec::with_capacity(tiles0);
    for t in 0..tiles0 {
        let row_lo = t * spec.shape[0];
        out.push(Band {
            slab_lo: t * per_band,
            slab_hi: (t + 1) * per_band,
            row_lo,
            rows: (dims[0] - row_lo).min(spec.shape[0]),
        });
    }
    out
}

/// Re-base a slab index into its band's local frame: axis-0 origin drops
/// to zero so the index addresses a band buffer of dims
/// `[band.rows, dims[1..]]`. The valid extents are unchanged (every slab
/// of a band shares `origin[0] == band.row_lo`, so `valid[0] <=
/// band.rows` holds by construction).
pub fn band_local(idx: &SlabIndex, band: &Band) -> SlabIndex {
    debug_assert_eq!(idx.origin[0], band.row_lo, "slab not in this band");
    let mut local = idx.clone();
    local.origin[0] = 0;
    local
}

/// Visit each contiguous valid row: f(field_offset, slab_offset, len).
fn copy_slab<F: FnMut(usize, usize, usize)>(
    dims: &[usize],
    spec: &SlabSpec,
    idx: &SlabIndex,
    mut f: F,
) {
    let nd = dims.len();
    let row = idx.valid[nd - 1];
    if row == 0 {
        return;
    }
    // strides
    let mut fstride = vec![1usize; nd];
    let mut sstride = vec![1usize; nd];
    for ax in (0..nd - 1).rev() {
        fstride[ax] = fstride[ax + 1] * dims[ax + 1];
        sstride[ax] = sstride[ax + 1] * spec.shape[ax + 1];
    }
    let outer: usize = idx.valid[..nd - 1].iter().product();
    for flat in 0..outer.max(1) {
        let mut rem = flat;
        let mut foff = 0usize;
        let mut soff = 0usize;
        for ax in (0..nd - 1).rev() {
            let c = rem % idx.valid[ax];
            rem /= idx.valid[ax];
            foff += (idx.origin[ax] + c) * fstride[ax];
            soff += c * sstride[ax];
        }
        foff += idx.origin[nd - 1];
        f(foff, soff, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec2d() -> SlabSpec {
        SlabSpec::new("t", &[4, 4], &[2, 2])
    }

    #[test]
    fn grid_covers_field_with_padding() {
        let g = tile_grid(&[5, 7], &spec2d());
        assert_eq!(g.len(), 2 * 2); // ceil(5/4) x ceil(7/4)
        assert_eq!(g[0].valid, vec![4, 4]);
        assert_eq!(g[3].valid, vec![1, 3]); // corner tile
        assert_eq!(g[3].origin, vec![4, 4]);
    }

    #[test]
    fn gather_scatter_roundtrip_2d() {
        let dims = [5usize, 7];
        let data: Vec<f32> = (0..35).map(|i| i as f32).collect();
        let spec = spec2d();
        let grid = tile_grid(&dims, &spec);
        let mut out = vec![-1f32; 35];
        for idx in &grid {
            let slab = gather_slab(&data, &dims, &spec, idx);
            // padded region must be zero
            for r in 0..4 {
                for c in 0..4 {
                    let v = slab[r * 4 + c];
                    if r >= idx.valid[0] || c >= idx.valid[1] {
                        assert_eq!(v, 0.0, "pad at {r},{c}");
                    }
                }
            }
            scatter_slab(&mut out, &dims, &spec, idx, &slab);
        }
        assert_eq!(out, data);
    }

    #[test]
    fn gather_scatter_roundtrip_3d() {
        let dims = [3usize, 5, 6];
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let spec = SlabSpec::new("t3", &[2, 4, 4], &[2, 2, 2]);
        let grid = tile_grid(&dims, &spec);
        let mut out = vec![f32::NAN; n];
        for idx in &grid {
            let slab = gather_slab(&data, &dims, &spec, idx);
            scatter_slab(&mut out, &dims, &spec, idx, &slab);
        }
        assert_eq!(out, data);
    }

    #[test]
    fn parallel_partitioned_scatter_matches_serial() {
        use crate::util::pool::parallel_map_range;
        let dims = [37usize, 53];
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let spec = SlabSpec::new("t", &[16, 16], &[4, 4]);
        let grid = tile_grid(&dims, &spec);
        let slabs: Vec<Vec<f32>> =
            grid.iter().map(|idx| gather_slab(&data, &dims, &spec, idx)).collect();

        let mut serial = vec![f32::NAN; n];
        for (idx, slab) in grid.iter().zip(&slabs) {
            scatter_slab(&mut serial, &dims, &spec, idx, slab);
        }

        let mut parallel = vec![f32::NAN; n];
        {
            let view = PartitionedField::new(&mut parallel);
            parallel_map_range(4, grid.len(), |si| {
                view.scatter(&dims, &spec, &grid[si], &slabs[si]);
            });
        }
        assert_eq!(parallel, serial);
        assert_eq!(parallel, data);
    }

    #[test]
    fn band_plan_partitions_grid_and_rows() {
        let dims = [5usize, 7];
        let spec = spec2d();
        let grid = tile_grid(&dims, &spec);
        let bands = band_plan(&dims, &spec, &grid);
        assert_eq!(bands.len(), 2); // ceil(5/4)
        assert_eq!(bands[0], Band { slab_lo: 0, slab_hi: 2, row_lo: 0, rows: 4 });
        assert_eq!(bands[1], Band { slab_lo: 2, slab_hi: 4, row_lo: 4, rows: 1 });
        // bands tile the grid contiguously and the rows exactly
        assert_eq!(bands.iter().map(|b| b.slab_hi - b.slab_lo).sum::<usize>(), grid.len());
        assert_eq!(bands.iter().map(|b| b.rows).sum::<usize>(), dims[0]);
        assert_eq!(bands[0].field_elems(&dims), 4 * 7);
        assert_eq!(bands[1].field_elems(&dims), 7);
        // every slab in a band shares the band's axis-0 origin
        for b in &bands {
            for idx in &grid[b.slab_lo..b.slab_hi] {
                assert_eq!(idx.origin[0], b.row_lo);
                assert_eq!(idx.valid[0], b.rows);
            }
        }
    }

    #[test]
    fn band_local_gather_matches_whole_field_gather() {
        let dims = [5usize, 7, 3];
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 7.0).collect();
        let spec = SlabSpec::new("t3", &[2, 4, 4], &[2, 2, 2]);
        let grid = tile_grid(&dims, &spec);
        let bands = band_plan(&dims, &spec, &grid);
        let row_elems: usize = dims[1..].iter().product();
        let mut reconstructed = vec![f32::NAN; n];
        for band in &bands {
            // the band's field region is one contiguous row-major run
            let lo = band.row_lo * row_elems;
            let band_buf = &data[lo..lo + band.field_elems(&dims)];
            let band_dims = [band.rows, dims[1], dims[2]];
            let out_band = &mut reconstructed[lo..lo + band_buf.len()];
            let view = PartitionedField::new(out_band);
            for gi in band.slab_lo..band.slab_hi {
                let local = band_local(&grid[gi], band);
                // band-local gather must equal the whole-field gather
                let from_band = {
                    let mut s = vec![0f32; spec.len()];
                    gather_slab_into(band_buf, &band_dims, &spec, &local, &mut s);
                    s
                };
                let from_field = gather_slab(&data, &dims, &spec, &grid[gi]);
                assert_eq!(from_band, from_field, "slab {gi}");
                // and the band-local scatter round-trips the region
                view.scatter(&band_dims, &spec, &local, &from_band);
            }
        }
        assert_eq!(reconstructed, data);
    }

    #[test]
    fn band_plan_1d_one_slab_per_band() {
        let spec = SlabSpec::new("t1", &[64], &[32]);
        let grid = tile_grid(&[100], &spec);
        let bands = band_plan(&[100], &spec, &grid);
        assert_eq!(bands.len(), 2);
        assert_eq!(bands[1], Band { slab_lo: 1, slab_hi: 2, row_lo: 64, rows: 36 });
        assert_eq!(bands[1].field_elems(&[100]), 36);
    }

    #[test]
    fn gather_scatter_roundtrip_1d() {
        let data: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let spec = SlabSpec::new("t1", &[64], &[32]);
        let grid = tile_grid(&[100], &spec);
        assert_eq!(grid.len(), 2);
        let mut out = vec![0f32; 100];
        for idx in &grid {
            let slab = gather_slab(&data, &[100], &spec, idx);
            scatter_slab(&mut out, &[100], &spec, idx, &slab);
        }
        assert_eq!(out, data);
    }
}
