//! Slab tiling and zero padding (paper §3.1.1, Figure 2).
//!
//! AOT executables have fixed shapes, so fields are tiled into fixed-shape
//! slabs; the trailing partial slab in each axis is zero-padded. Padding
//! predicts perfectly under the zero-initialized Lorenzo layer, costing
//! only near-zero-entropy symbols.

/// Fixed slab geometry (mirrors python/compile/variants.py).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlabSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub block: Vec<usize>,
}

impl SlabSpec {
    pub fn new(name: &str, shape: &[usize], block: &[usize]) -> Self {
        assert_eq!(shape.len(), block.len());
        for (s, b) in shape.iter().zip(block) {
            assert!(s % b == 0, "slab {shape:?} not block-aligned {block:?}");
        }
        SlabSpec { name: name.to_string(), shape: shape.to_vec(), block: block.to_vec() }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }
}

/// The built-in slab variants — must mirror python/compile/variants.py
/// (the AOT manifest is authoritative when artifacts are present; the CPU
/// backend uses this table so both backends pick identical geometry).
pub fn builtin_variants() -> Vec<SlabSpec> {
    vec![
        SlabSpec::new("1d_64k", &[1 << 16], &[32]),
        SlabSpec::new("1d_1m", &[1 << 20], &[32]),
        SlabSpec::new("2d_256", &[256, 256], &[16, 16]),
        SlabSpec::new("2d_1k", &[1024, 1024], &[16, 16]),
        SlabSpec::new("3d_32", &[32, 32, 32], &[8, 8, 8]),
        SlabSpec::new("3d_64", &[64, 64, 64], &[8, 8, 8]),
        SlabSpec::new("3d_128", &[128, 128, 128], &[8, 8, 8]),
    ]
}

/// Total elements after tiling `dims` with slabs of `spec` (incl. padding).
pub fn padded_volume(dims: &[usize], spec: &SlabSpec) -> usize {
    dims.iter()
        .zip(&spec.shape)
        .map(|(d, s)| d.div_ceil(*s) * s)
        .product()
}

/// Select the slab variant for a field's kernel dims: minimize the padded
/// volume (bounding both wasted compute and wasted bitrate); ties go to
/// the larger slab (fewer dispatches).
pub fn select_spec<'a>(specs: &'a [SlabSpec], kernel_dims: &[usize]) -> Option<&'a SlabSpec> {
    specs
        .iter()
        .filter(|s| s.ndim() == kernel_dims.len())
        .min_by_key(|s| (padded_volume(kernel_dims, s), usize::MAX - s.len()))
}

/// Location of one slab within the field's tile grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlabIndex {
    /// Tile coordinates (per axis).
    pub tile: Vec<usize>,
    /// Origin element offset (per axis) in the field.
    pub origin: Vec<usize>,
    /// Valid (unpadded) extent per axis.
    pub valid: Vec<usize>,
}

/// Enumerate the tile grid covering `dims` with slabs of `spec.shape`.
pub fn tile_grid(dims: &[usize], spec: &SlabSpec) -> Vec<SlabIndex> {
    assert_eq!(dims.len(), spec.ndim());
    let counts: Vec<usize> =
        dims.iter().zip(&spec.shape).map(|(d, s)| d.div_ceil(*s)).collect();
    let total: usize = counts.iter().product();
    let mut out = Vec::with_capacity(total);
    for flat in 0..total {
        let mut rem = flat;
        let mut tile = vec![0usize; dims.len()];
        for ax in (0..dims.len()).rev() {
            tile[ax] = rem % counts[ax];
            rem /= counts[ax];
        }
        let origin: Vec<usize> =
            tile.iter().zip(&spec.shape).map(|(t, s)| t * s).collect();
        let valid: Vec<usize> = origin
            .iter()
            .zip(dims)
            .zip(&spec.shape)
            .map(|((o, d), s)| (*d - *o).min(*s))
            .collect();
        out.push(SlabIndex { tile, origin, valid });
    }
    out
}

/// Copy one slab out of the field (row-major), zero-padding beyond `valid`.
pub fn gather_slab(data: &[f32], dims: &[usize], spec: &SlabSpec, idx: &SlabIndex) -> Vec<f32> {
    let mut slab = vec![0f32; spec.len()];
    gather_slab_into(data, dims, spec, idx, &mut slab);
    slab
}

/// Gather into a caller-provided buffer (must be pre-zeroed if the slab is
/// partial — only valid rows are written).
pub fn gather_slab_into(data: &[f32], dims: &[usize], spec: &SlabSpec, idx: &SlabIndex, slab: &mut [f32]) {
    assert_eq!(slab.len(), spec.len());
    copy_slab(dims, spec, idx, |src_off, dst_off, n| {
        slab[dst_off..dst_off + n].copy_from_slice(&data[src_off..src_off + n]);
    });
}

/// Scatter a reconstructed slab back into the field, dropping padding.
pub fn scatter_slab(out: &mut [f32], dims: &[usize], spec: &SlabSpec, idx: &SlabIndex, slab: &[f32]) {
    assert_eq!(slab.len(), spec.len());
    copy_slab(dims, spec, idx, |src_off, dst_off, n| {
        out[src_off..src_off + n].copy_from_slice(&slab[dst_off..dst_off + n]);
    });
}

/// A partitioned view of the output field for *parallel* slab scatter.
///
/// `tile_grid` assigns every slab index a disjoint valid region of the
/// field (tiles partition the index space; each scatter writes only its
/// tile's `valid` extent), so concurrent [`PartitionedField::scatter`]
/// calls for **distinct** indices of one grid never alias — the same
/// disjoint-write discipline as `util::pool::parallel_map_range`. This is
/// what lets the fused decompress pass retire the old collect-then-serial-
/// scatter loop (and its `Mutex<Vec<i32>>` cells).
///
/// Contract: callers must scatter each grid index at most once per view.
pub struct PartitionedField<'a> {
    data: *mut f32,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: the raw pointer is only written through `scatter`, whose
// per-slab regions are disjoint for distinct grid indices (see above),
// and the `&'a mut` borrow in the constructor keeps every other access
// to the buffer out for the view's lifetime.
unsafe impl Send for PartitionedField<'_> {}
unsafe impl Sync for PartitionedField<'_> {}

impl<'a> PartitionedField<'a> {
    pub fn new(out: &'a mut [f32]) -> PartitionedField<'a> {
        PartitionedField {
            data: out.as_mut_ptr(),
            len: out.len(),
            _borrow: std::marker::PhantomData,
        }
    }

    /// Scatter `slab` into `idx`'s region, dropping padding — the
    /// shared-view equivalent of [`scatter_slab`].
    pub fn scatter(&self, dims: &[usize], spec: &SlabSpec, idx: &SlabIndex, slab: &[f32]) {
        assert_eq!(slab.len(), spec.len());
        copy_slab(dims, spec, idx, |field_off, slab_off, n| {
            assert!(field_off + n <= self.len, "scatter row outside the field");
            // SAFETY: rows of distinct grid indices are disjoint (see the
            // type-level argument) and bounds-checked just above.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    slab.as_ptr().add(slab_off),
                    self.data.add(field_off),
                    n,
                );
            }
        });
    }
}

/// Visit each contiguous valid row: f(field_offset, slab_offset, len).
fn copy_slab<F: FnMut(usize, usize, usize)>(
    dims: &[usize],
    spec: &SlabSpec,
    idx: &SlabIndex,
    mut f: F,
) {
    let nd = dims.len();
    let row = idx.valid[nd - 1];
    if row == 0 {
        return;
    }
    // strides
    let mut fstride = vec![1usize; nd];
    let mut sstride = vec![1usize; nd];
    for ax in (0..nd - 1).rev() {
        fstride[ax] = fstride[ax + 1] * dims[ax + 1];
        sstride[ax] = sstride[ax + 1] * spec.shape[ax + 1];
    }
    let outer: usize = idx.valid[..nd - 1].iter().product();
    for flat in 0..outer.max(1) {
        let mut rem = flat;
        let mut foff = 0usize;
        let mut soff = 0usize;
        for ax in (0..nd - 1).rev() {
            let c = rem % idx.valid[ax];
            rem /= idx.valid[ax];
            foff += (idx.origin[ax] + c) * fstride[ax];
            soff += c * sstride[ax];
        }
        foff += idx.origin[nd - 1];
        f(foff, soff, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec2d() -> SlabSpec {
        SlabSpec::new("t", &[4, 4], &[2, 2])
    }

    #[test]
    fn grid_covers_field_with_padding() {
        let g = tile_grid(&[5, 7], &spec2d());
        assert_eq!(g.len(), 2 * 2); // ceil(5/4) x ceil(7/4)
        assert_eq!(g[0].valid, vec![4, 4]);
        assert_eq!(g[3].valid, vec![1, 3]); // corner tile
        assert_eq!(g[3].origin, vec![4, 4]);
    }

    #[test]
    fn gather_scatter_roundtrip_2d() {
        let dims = [5usize, 7];
        let data: Vec<f32> = (0..35).map(|i| i as f32).collect();
        let spec = spec2d();
        let grid = tile_grid(&dims, &spec);
        let mut out = vec![-1f32; 35];
        for idx in &grid {
            let slab = gather_slab(&data, &dims, &spec, idx);
            // padded region must be zero
            for r in 0..4 {
                for c in 0..4 {
                    let v = slab[r * 4 + c];
                    if r >= idx.valid[0] || c >= idx.valid[1] {
                        assert_eq!(v, 0.0, "pad at {r},{c}");
                    }
                }
            }
            scatter_slab(&mut out, &dims, &spec, idx, &slab);
        }
        assert_eq!(out, data);
    }

    #[test]
    fn gather_scatter_roundtrip_3d() {
        let dims = [3usize, 5, 6];
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let spec = SlabSpec::new("t3", &[2, 4, 4], &[2, 2, 2]);
        let grid = tile_grid(&dims, &spec);
        let mut out = vec![f32::NAN; n];
        for idx in &grid {
            let slab = gather_slab(&data, &dims, &spec, idx);
            scatter_slab(&mut out, &dims, &spec, idx, &slab);
        }
        assert_eq!(out, data);
    }

    #[test]
    fn parallel_partitioned_scatter_matches_serial() {
        use crate::util::pool::parallel_map_range;
        let dims = [37usize, 53];
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let spec = SlabSpec::new("t", &[16, 16], &[4, 4]);
        let grid = tile_grid(&dims, &spec);
        let slabs: Vec<Vec<f32>> =
            grid.iter().map(|idx| gather_slab(&data, &dims, &spec, idx)).collect();

        let mut serial = vec![f32::NAN; n];
        for (idx, slab) in grid.iter().zip(&slabs) {
            scatter_slab(&mut serial, &dims, &spec, idx, slab);
        }

        let mut parallel = vec![f32::NAN; n];
        {
            let view = PartitionedField::new(&mut parallel);
            parallel_map_range(4, grid.len(), |si| {
                view.scatter(&dims, &spec, &grid[si], &slabs[si]);
            });
        }
        assert_eq!(parallel, serial);
        assert_eq!(parallel, data);
    }

    #[test]
    fn gather_scatter_roundtrip_1d() {
        let data: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let spec = SlabSpec::new("t1", &[64], &[32]);
        let grid = tile_grid(&[100], &spec);
        assert_eq!(grid.len(), 2);
        let mut out = vec![0f32; 100];
        for idx in &grid {
            let slab = gather_slab(&data, &[100], &spec, idx);
            scatter_slab(&mut out, &[100], &spec, idx, &slab);
        }
        assert_eq!(out, data);
    }
}
