//! First-order Lorenzo prediction and its inverse, blockwise with the
//! zero-initialized padding layer (paper §3.1.1-3.1.2), over exact-integer
//! (prequantized) i32 fields.
//!
//! `delta_*` computes `δ = d° − ℓ(d°_sr)` (compression direction, no RAW:
//! reads only the immutable prequant field). `reconstruct_*` computes the
//! inverse as per-axis inclusive prefix sums within each block (DESIGN.md
//! §3.2) — bit-exact with the cascading Algorithm 1.

/// 1D: p = d[i-1], zero at block starts.
pub fn delta_1d(dq: &[i32], block: usize, out: &mut [i32]) {
    assert_eq!(dq.len() % block, 0);
    assert_eq!(dq.len(), out.len());
    for (bo, chunk) in dq.chunks_exact(block).enumerate() {
        let base = bo * block;
        out[base] = chunk[0];
        for i in 1..block {
            out[base + i] = chunk[i] - chunk[i - 1];
        }
    }
}

/// 1D inverse: prefix sum per block.
pub fn reconstruct_1d(delta: &mut [i32], block: usize) {
    for chunk in delta.chunks_exact_mut(block) {
        let mut acc = 0i32;
        for v in chunk {
            acc += *v;
            *v = acc;
        }
    }
}

/// 2D: p = left + up - upleft within each bh x bw block of a rows x cols field.
///
/// Hot path: rows are split at block boundaries so the interior loop is
/// branch-free (auto-vectorizes); the `r % bh == 0` top rows fall back to
/// the 1D predictor per the padding-layer semantics.
pub fn delta_2d(dq: &[i32], rows: usize, cols: usize, bh: usize, bw: usize, out: &mut [i32]) {
    assert_eq!(dq.len(), rows * cols);
    assert_eq!(rows % bh, 0);
    assert_eq!(cols % bw, 0);
    for r in 0..rows {
        let row = r * cols;
        let cur = &dq[row..row + cols];
        let dst = &mut out[row..row + cols];
        if r % bh == 0 {
            // top row of a block row: up/upleft are padding zeros -> 1D
            delta_row_1d(cur, bw, dst);
        } else {
            let prev = &dq[row - cols..row];
            for cb in (0..cols).step_by(bw) {
                // block-leading column: left/upleft are padding zeros
                dst[cb] = cur[cb] - prev[cb];
                // interior: full 2D stencil, branch-free
                for c in cb + 1..cb + bw {
                    dst[c] = cur[c] - (cur[c - 1] + prev[c] - prev[c - 1]);
                }
            }
        }
    }
}

#[inline]
fn delta_row_1d(cur: &[i32], bw: usize, dst: &mut [i32]) {
    for cb in (0..cur.len()).step_by(bw) {
        dst[cb] = cur[cb];
        for c in cb + 1..cb + bw {
            dst[c] = cur[c] - cur[c - 1];
        }
    }
}

/// 2D inverse: cumsum along columns then rows, blockwise.
pub fn reconstruct_2d(delta: &mut [i32], rows: usize, cols: usize, bh: usize, bw: usize) {
    // cumsum along axis 1 (within each bw run)
    for r in 0..rows {
        let row = r * cols;
        let mut acc = 0i32;
        for c in 0..cols {
            if c % bw == 0 {
                acc = 0;
            }
            acc += delta[row + c];
            delta[row + c] = acc;
        }
    }
    // cumsum along axis 0 (within each bh run)
    for r in 1..rows {
        if r % bh == 0 {
            continue;
        }
        let (prev_rows, cur_rows) = delta.split_at_mut(r * cols);
        let prev = &prev_rows[(r - 1) * cols..];
        let cur = &mut cur_rows[..cols];
        for c in 0..cols {
            cur[c] += prev[c];
        }
    }
}

/// 3D: 7-neighbor Lorenzo within each b0 x b1 x b2 block.
#[allow(clippy::too_many_arguments)]
pub fn delta_3d(
    dq: &[i32],
    d0: usize,
    d1: usize,
    d2: usize,
    b0: usize,
    b1: usize,
    b2: usize,
    out: &mut [i32],
) {
    assert_eq!(dq.len(), d0 * d1 * d2);
    assert!(d0 % b0 == 0 && d1 % b1 == 0 && d2 % b2 == 0);
    let s0 = d1 * d2;
    let s1 = d2;
    // Rows (fixed i, j) are dispatched to one of four specialized kernels
    // depending on which upper faces are padding; each splits at k-block
    // boundaries so the interior loop is branch-free and vectorizable.
    for i in 0..d0 {
        let i_in = i % b0 != 0;
        for j in 0..d1 {
            let j_in = j % b1 != 0;
            let base = i * s0 + j * s1;
            let cur = &dq[base..base + d2];
            let dst = &mut out[base..base + d2];
            match (i_in, j_in) {
                (false, false) => delta_row_1d(cur, b2, dst),
                (false, true) => {
                    // 2D stencil against the j-1 row
                    let pj = &dq[base - s1..base - s1 + d2];
                    row_stencil_2d(cur, pj, b2, dst);
                }
                (true, false) => {
                    // 2D stencil against the i-1 plane's row
                    let pi = &dq[base - s0..base - s0 + d2];
                    row_stencil_2d(cur, pi, b2, dst);
                }
                (true, true) => {
                    let pi = &dq[base - s0..base - s0 + d2];
                    let pj = &dq[base - s1..base - s1 + d2];
                    let pij = &dq[base - s0 - s1..base - s0 - s1 + d2];
                    for kb in (0..d2).step_by(b2) {
                        dst[kb] = cur[kb] - (pi[kb] + pj[kb] - pij[kb]);
                        for k in kb + 1..kb + b2 {
                            // full 7-neighbor Lorenzo, branch-free
                            let pred = cur[k - 1] + pj[k] + pi[k]
                                - pj[k - 1]
                                - pi[k - 1]
                                - pij[k]
                                + pij[k - 1];
                            dst[k] = cur[k] - pred;
                        }
                    }
                }
            }
        }
    }
}

/// Row kernel: 2D Lorenzo against one upper row (the other face is pad).
#[inline]
fn row_stencil_2d(cur: &[i32], up: &[i32], bw: usize, dst: &mut [i32]) {
    for cb in (0..cur.len()).step_by(bw) {
        dst[cb] = cur[cb] - up[cb];
        for c in cb + 1..cb + bw {
            dst[c] = cur[c] - (cur[c - 1] + up[c] - up[c - 1]);
        }
    }
}

/// 3D inverse: cumsum along each axis in turn, blockwise.
pub fn reconstruct_3d(delta: &mut [i32], d0: usize, d1: usize, d2: usize, b0: usize, b1: usize, b2: usize) {
    let s0 = d1 * d2;
    let s1 = d2;
    // axis 2
    for i in 0..d0 {
        for j in 0..d1 {
            let base = i * s0 + j * s1;
            let mut acc = 0i32;
            for k in 0..d2 {
                if k % b2 == 0 {
                    acc = 0;
                }
                acc += delta[base + k];
                delta[base + k] = acc;
            }
        }
    }
    // axis 1
    for i in 0..d0 {
        for j in 1..d1 {
            if j % b1 == 0 {
                continue;
            }
            let base = i * s0 + j * s1;
            let prev = base - s1;
            for k in 0..d2 {
                delta[base + k] += delta[prev + k];
            }
        }
    }
    // axis 0
    for i in 1..d0 {
        if i % b0 == 0 {
            continue;
        }
        let base = i * s0;
        let prev = base - s0;
        for idx in 0..s0 {
            delta[base + idx] += delta[prev + idx];
        }
    }
}

/// Dispatch helpers over shape/block vectors (1..=3 dims).
pub fn delta_nd(dq: &[i32], shape: &[usize], block: &[usize], out: &mut [i32]) {
    match shape.len() {
        1 => delta_1d(dq, block[0], out),
        2 => delta_2d(dq, shape[0], shape[1], block[0], block[1], out),
        3 => delta_3d(dq, shape[0], shape[1], shape[2], block[0], block[1], block[2], out),
        n => panic!("unsupported ndim {n}"),
    }
}

pub fn reconstruct_nd(delta: &mut [i32], shape: &[usize], block: &[usize]) {
    match shape.len() {
        1 => reconstruct_1d(delta, block[0]),
        2 => reconstruct_2d(delta, shape[0], shape[1], block[0], block[1]),
        3 => reconstruct_3d(delta, shape[0], shape[1], shape[2], block[0], block[1], block[2]),
        n => panic!("unsupported ndim {n}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_dq(n: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.below(2001) as i32) - 1000).collect()
    }

    #[test]
    fn roundtrip_1d() {
        let dq = rand_dq(256, 1);
        let mut delta = vec![0i32; 256];
        delta_1d(&dq, 32, &mut delta);
        reconstruct_1d(&mut delta, 32);
        assert_eq!(delta, dq);
    }

    #[test]
    fn roundtrip_2d() {
        let dq = rand_dq(64 * 48, 2);
        let mut delta = vec![0i32; dq.len()];
        delta_2d(&dq, 64, 48, 16, 16, &mut delta);
        reconstruct_2d(&mut delta, 64, 48, 16, 16);
        assert_eq!(delta, dq);
    }

    #[test]
    fn roundtrip_3d() {
        let dq = rand_dq(16 * 24 * 8, 3);
        let mut delta = vec![0i32; dq.len()];
        delta_3d(&dq, 16, 24, 8, 8, 8, 8, &mut delta);
        reconstruct_3d(&mut delta, 16, 24, 8, 8, 8, 8);
        assert_eq!(delta, dq);
    }

    #[test]
    fn smooth_field_yields_small_deltas() {
        // A linear ramp has constant first differences: 2D Lorenzo residual 0
        // except at block borders.
        let (rows, cols) = (32, 32);
        let dq: Vec<i32> = (0..rows * cols).map(|i| (i / cols + i % cols) as i32).collect();
        let mut delta = vec![0i32; dq.len()];
        delta_2d(&dq, rows, cols, 16, 16, &mut delta);
        // interior points: perfectly predicted
        for r in 1..rows {
            for c in 1..cols {
                if r % 16 != 0 && c % 16 != 0 {
                    assert_eq!(delta[r * cols + c], 0, "at {r},{c}");
                }
            }
        }
    }

    #[test]
    fn block_isolation_2d() {
        // Changing data in one block must not change deltas in another.
        let mut dq = rand_dq(32 * 32, 4);
        let mut d1 = vec![0i32; dq.len()];
        delta_2d(&dq, 32, 32, 16, 16, &mut d1);
        dq[0] += 1000; // block (0,0)
        let mut d2 = vec![0i32; dq.len()];
        delta_2d(&dq, 32, 32, 16, 16, &mut d2);
        for r in 16..32 {
            for c in 16..32 {
                assert_eq!(d1[r * 32 + c], d2[r * 32 + c]);
            }
        }
    }
}
