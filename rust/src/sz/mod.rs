//! The SZ-1.4 algorithm substrate: Lorenzo predictors, the paper's
//! DUAL-QUANTIZATION (Algorithm 2) on CPU, the original cascading
//! predict-quant (Algorithm 1) used as the CPU-SZ baseline, and slab
//! tiling/padding (Figure 2).
//!
//! The CPU dual-quant is **bit-exact** with the Pallas/HLO path (same f32
//! expressions, same round-ties-even, same i32 integer pipeline), which the
//! integration tests assert; it doubles as the OpenMP-SZ-style multicore
//! baseline and the fallback backend.

pub mod blocks;
pub mod classic;
pub mod dual_quant;
pub mod lorenzo;

/// Prequantized magnitudes are clamped here so every integer step stays
/// exact in i32 (matches python/compile/variants.py::PREQUANT_CAP).
pub const PREQUANT_CAP: i32 = 1 << 23;

/// Paper block shapes (§3.1.1): 32 / 16x16 / 8x8x8.
pub fn block_for_ndim(ndim: usize) -> Vec<usize> {
    match ndim {
        1 => vec![32],
        2 => vec![16, 16],
        3 => vec![8, 8, 8],
        _ => panic!("kernel ndim must be 1..=3 (4D folds first)"),
    }
}

/// PREQUANT: f32 -> exact-integer i32, `round_ties_even(d * (0.5/eb))`.
/// Must match XLA `rint(d * (0.5 / eb))` bit-for-bit.
#[inline]
pub fn prequant(d: f32, half_inv_eb: f32) -> i32 {
    let v = (d * half_inv_eb).round_ties_even();
    v.clamp(-(PREQUANT_CAP as f32), PREQUANT_CAP as f32) as i32
}

/// POSTQUANT code from an exact delta: bin index in [0, dict), 0 = outlier.
#[inline]
pub fn code_of_delta(delta: i32, radius: i32) -> u16 {
    if delta > -radius && delta < radius {
        (delta + radius) as u16
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prequant_rounds_ties_to_even() {
        // half_inv_eb = 1.0 (eb = 0.5) => prequant is plain rint
        assert_eq!(prequant(0.5, 1.0), 0);
        assert_eq!(prequant(1.5, 1.0), 2);
        assert_eq!(prequant(2.5, 1.0), 2);
        assert_eq!(prequant(-0.5, 1.0), 0);
        assert_eq!(prequant(-1.5, 1.0), -2);
    }

    #[test]
    fn prequant_clamps_at_cap() {
        assert_eq!(prequant(1e12, 1.0), PREQUANT_CAP);
        assert_eq!(prequant(-1e12, 1.0), -PREQUANT_CAP);
    }

    #[test]
    fn code_reserves_zero_for_outliers() {
        assert_eq!(code_of_delta(0, 512), 512);
        assert_eq!(code_of_delta(511, 512), 1023);
        assert_eq!(code_of_delta(512, 512), 0);
        assert_eq!(code_of_delta(-511, 512), 1);
        assert_eq!(code_of_delta(-512, 512), 0);
        assert_eq!(code_of_delta(i32::MAX, 512), 0);
    }
}
