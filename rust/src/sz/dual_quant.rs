//! Algorithm 2 (DUAL-QUANT) on CPU — bit-exact mirror of the Pallas/HLO
//! path, used as the fallback backend, the multicore baseline, and the
//! cross-validation oracle for PJRT outputs.

use std::cell::RefCell;

use super::{blocks::SlabSpec, lorenzo, prequant, PREQUANT_CAP};

thread_local! {
    /// Reused prequant scratch: avoids an 8 MB allocation + page-fault
    /// storm per slab (EXPERIMENTS.md §Perf, iteration 3).
    static DQ_SCRATCH: RefCell<Vec<i32>> = const { RefCell::new(Vec::new()) };
}

/// Fully-fused CPU compression of one slab: prequant + Lorenzo delta +
/// code/histogram/outlier extraction in minimal passes.
pub struct SlabCompressed {
    pub delta: Vec<i32>,
    pub codes: Vec<u16>,
    pub hist: Vec<u32>,
    /// (in-slab position, exact delta) for out-of-cap (code 0) points.
    pub outliers: Vec<(u32, i32)>,
}

pub fn dual_quant_full(data: &[f32], spec: &SlabSpec, eb: f32, dict_size: usize) -> SlabCompressed {
    assert_eq!(data.len(), spec.len());
    let n = data.len();
    let half_inv_eb = 0.5f32 / eb;
    let radius = (dict_size / 2) as i32;

    DQ_SCRATCH.with(|cell| {
        let mut dq = cell.borrow_mut();
        dq.clear();
        dq.extend(data.iter().map(|&d| prequant(d, half_inv_eb)));

        let mut delta = vec![0i32; n];
        lorenzo::delta_nd(&dq, &spec.shape, &spec.block, &mut delta);

        // fused postquant: codes + histogram + outlier capture, one pass
        let mut codes = vec![0u16; n];
        let mut hist = vec![0u32; dict_size];
        let mut outliers = Vec::new();
        for (i, (&dv, c)) in delta.iter().zip(codes.iter_mut()).enumerate() {
            let code = super::code_of_delta(dv, radius);
            *c = code;
            hist[code as usize] += 1;
            if code == 0 {
                outliers.push((i as u32, dv));
            }
        }
        SlabCompressed { delta, codes, hist, outliers }
    })
}

/// Compress direction: data -> (delta, histogram-of-codes).
/// Matches the AOT `compress` executable: hist is over `code_of_delta`
/// with `radius = dict_size/2`, including the reserved outlier bin 0.
pub fn dual_quant_slab(data: &[f32], spec: &SlabSpec, eb: f32, dict_size: usize) -> (Vec<i32>, Vec<u32>) {
    let radius = (dict_size / 2) as i32;
    let delta = dual_quant_delta(data, spec, eb);
    let mut hist = vec![0u32; dict_size];
    for &dv in &delta {
        hist[super::code_of_delta(dv, radius) as usize] += 1;
    }
    (delta, hist)
}

/// Delta-only compression (the AOT `compress` executable contract).
pub fn dual_quant_delta(data: &[f32], spec: &SlabSpec, eb: f32) -> Vec<i32> {
    assert_eq!(data.len(), spec.len());
    let half_inv_eb = 0.5f32 / eb;
    DQ_SCRATCH.with(|cell| {
        let mut dq = cell.borrow_mut();
        dq.clear();
        dq.extend(data.iter().map(|&d| prequant(d, half_inv_eb)));
        let mut delta = vec![0i32; data.len()];
        lorenzo::delta_nd(&dq, &spec.shape, &spec.block, &mut delta);
        delta
    })
}

/// Decompress direction: patched delta field -> f32 values.
/// Matches the AOT `decompress` executable: blockwise prefix sums then
/// `as f32 * (2*eb)`.
pub fn reconstruct_slab(delta: &[i32], spec: &SlabSpec, eb: f32) -> Vec<f32> {
    reconstruct_slab_owned(delta.to_vec(), spec, eb)
}

/// Allocation-free variant: reconstructs in place and converts the i32
/// buffer to f32 without reallocating (same size/alignment).
pub fn reconstruct_slab_owned(mut acc: Vec<i32>, spec: &SlabSpec, eb: f32) -> Vec<f32> {
    assert_eq!(acc.len(), spec.len());
    lorenzo::reconstruct_nd(&mut acc, &spec.shape, &spec.block);
    let scale = 2.0f32 * eb;
    for v in acc.iter_mut() {
        *v = ((*v as f32) * scale).to_bits() as i32;
    }
    // SAFETY: i32 and f32 have identical size and alignment; every element
    // now holds valid f32 bits.
    let mut md = std::mem::ManuallyDrop::new(acc);
    unsafe { Vec::from_raw_parts(md.as_mut_ptr() as *mut f32, md.len(), md.capacity()) }
}

/// Buffer-to-buffer variant for the fused decompress pass: `delta` is
/// consumed as reconstruction scratch (left holding the prefix-summed
/// integers) and the scaled f32 output lands in `out` — no allocation at
/// all, so both buffers can be loaned from the thread-local arena.
/// Bit-exact with [`reconstruct_slab_owned`] (same kernel, same scale
/// expression).
pub fn reconstruct_slab_into(delta: &mut [i32], spec: &SlabSpec, eb: f32, out: &mut [f32]) {
    assert_eq!(delta.len(), spec.len());
    assert_eq!(out.len(), spec.len());
    lorenzo::reconstruct_nd(delta, &spec.shape, &spec.block);
    let scale = 2.0f32 * eb;
    for (o, &v) in out.iter_mut().zip(delta.iter()) {
        *o = v as f32 * scale;
    }
}

/// True when no value in `data` can clamp at the prequant cap for this eb —
/// the common fast path that lets the coordinator skip the verbatim scan.
pub fn range_safe(max_abs: f32, eb: f32) -> bool {
    // Conservative: strict inequality with one bin of slack.
    (max_abs as f64) < (PREQUANT_CAP as f64 - 1.0) * 2.0 * eb as f64
}

/// Indices whose prequant value clamps (need verbatim f32 storage).
pub fn find_range_outliers(data: &[f32], eb: f32) -> Vec<(u32, f32)> {
    let half_inv_eb = 0.5f32 / eb;
    let capf = PREQUANT_CAP as f32;
    data.iter()
        .enumerate()
        .filter_map(|(i, &d)| {
            let v = (d * half_inv_eb).round_ties_even();
            if v.abs() >= capf || !d.is_finite() {
                Some((i as u32, d))
            } else {
                None
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn spec() -> SlabSpec {
        SlabSpec::new("t2", &[64, 64], &[16, 16])
    }

    #[test]
    fn roundtrip_within_eb() {
        let mut rng = Rng::new(9);
        let s = spec();
        let data: Vec<f32> = (0..s.len()).map(|_| rng.normal() * 10.0).collect();
        let eb = 1e-3f32;
        let (delta, hist) = dual_quant_slab(&data, &s, eb, 1024);
        assert_eq!(hist.iter().map(|&h| h as usize).sum::<usize>(), s.len());
        // patch outliers with their exact deltas (already exact in `delta`)
        let out = reconstruct_slab(&delta, &s, eb);
        let slack = 4.0 * f32::EPSILON * data.iter().fold(0f32, |a, &b| a.max(b.abs()));
        for (o, d) in out.iter().zip(&data) {
            assert!((o - d).abs() <= eb + slack, "{o} vs {d}");
        }
    }

    #[test]
    fn reconstruct_into_is_bit_exact_with_owned() {
        let mut rng = Rng::new(31);
        let s = spec();
        let data: Vec<f32> = (0..s.len()).map(|_| rng.normal() * 5.0).collect();
        let eb = 1e-3f32;
        let delta = dual_quant_delta(&data, &s, eb);
        let owned = reconstruct_slab_owned(delta.clone(), &s, eb);
        let mut scratch = delta.clone();
        let mut out = vec![0f32; s.len()];
        reconstruct_slab_into(&mut scratch, &s, eb, &mut out);
        for (a, b) in owned.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn histogram_counts_outlier_bin() {
        let s = SlabSpec::new("t1", &[64], &[32]);
        let mut data = vec![0f32; 64];
        data[5] = 1_000.0; // large spike => outlier symbol at 5 and 6
        let (delta, hist) = dual_quant_slab(&data, &s, 0.01, 1024);
        assert_eq!(hist[0], 2);
        assert_eq!(delta[5], 50_000);
        assert_eq!(delta[6], -50_000);
    }

    #[test]
    fn range_safety_detection() {
        assert!(range_safe(1.0, 1e-4));
        assert!(!range_safe(4e19, 1e-4));
        let data = vec![0.0f32, 1e12, -3.0];
        let outl = find_range_outliers(&data, 1e-6);
        assert_eq!(outl.len(), 1);
        assert_eq!(outl[0].0, 1);
    }

    #[test]
    fn nonfinite_values_become_verbatim() {
        let data = vec![0.0f32, f32::NAN, f32::INFINITY];
        let outl = find_range_outliers(&data, 1e-3);
        assert_eq!(outl.len(), 2);
    }
}
