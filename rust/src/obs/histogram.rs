//! Streaming log2-bucketed histograms with percentile readout.
//!
//! 256 buckets cover the whole `u64` range: values below 16 get exact
//! unit buckets; above that, each power-of-two decade is split into four
//! quarter-decade sub-buckets (an HDR-style layout), bounding relative
//! error at a bucket midpoint to ~12.5%. Recording is a handful of
//! relaxed atomic ops — safe from any worker thread, no locks.

use std::sync::atomic::{AtomicU64, Ordering};

pub const BUCKETS: usize = 256;
const LINEAR_LIMIT: u64 = 16;

#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

/// Bucket index for a value. Exact below `LINEAR_LIMIT`; otherwise
/// `16 + (exponent - 4) * 4 + quarter` where `quarter` is the two bits
/// below the leading one.
pub fn bucket_of(v: u64) -> usize {
    if v < LINEAR_LIMIT {
        return v as usize;
    }
    let e = 63 - v.leading_zeros() as usize; // >= 4
    let sub = ((v >> (e - 2)) & 3) as usize;
    (16 + (e - 4) * 4 + sub).min(BUCKETS - 1)
}

/// Inclusive `(lo, hi)` value range of bucket `b`. Buckets tile the u64
/// range contiguously: `bounds(b).1 + 1 == bounds(b + 1).0`.
pub fn bucket_bounds(b: usize) -> (u64, u64) {
    debug_assert!(b < BUCKETS);
    if b < LINEAR_LIMIT as usize {
        return (b as u64, b as u64);
    }
    let e = 4 + (b - 16) / 4;
    let sub = ((b - 16) % 4) as u64;
    let width = 1u64 << (e - 2);
    let lo = (1u64 << e) + sub * width;
    (lo, lo + (width - 1))
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Consistent-enough point-in-time copy for readout. Concurrent
    /// recorders may land between field reads; telemetry tolerates that.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let mut nonzero = Vec::new();
        for (b, slot) in self.buckets.iter().enumerate() {
            let c = slot.load(Ordering::Relaxed);
            if c > 0 {
                let (lo, hi) = bucket_bounds(b);
                nonzero.push((lo, hi, c));
            }
        }
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            buckets: nonzero,
        }
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Materialized histogram state: only non-empty buckets, as
/// `(lo, hi, count)` triples in ascending value order.
#[derive(Debug, Clone, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: Vec<(u64, u64, u64)>,
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate via rank scan with linear interpolation inside
    /// the target bucket, clamped to the observed min/max. `q` in [0, 1].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = q * (self.count as f64 - 1.0);
        let mut cum = 0u64;
        for &(lo, hi, c) in &self.buckets {
            if (cum + c) as f64 > target {
                let lo = lo.max(self.min) as f64;
                let hi = hi.min(self.max) as f64;
                let frac = if c > 1 { (target - cum as f64) / (c - 1) as f64 } else { 0.5 };
                return lo + frac * (hi - lo).max(0.0);
            }
            cum += c;
        }
        self.max as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_tile_u64_contiguously() {
        let mut prev_hi = None::<u64>;
        for b in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(b);
            if let Some(p) = prev_hi {
                assert_eq!(lo, p + 1, "gap before bucket {b}");
            }
            assert!(lo <= hi);
            prev_hi = Some(hi);
        }
        assert_eq!(prev_hi, Some(u64::MAX));
    }

    #[test]
    fn every_value_lands_in_its_bounds() {
        let probes: Vec<u64> = (0..2000)
            .chain([1 << 20, (1 << 20) + 1, u64::MAX, 1 << 62, (1 << 63) - 1, 1 << 63])
            .chain((4..63).map(|e| 1u64 << e))
            .chain((4..63).map(|e| (1u64 << e) - 1))
            .collect();
        for v in probes {
            let b = bucket_of(v);
            let (lo, hi) = bucket_bounds(b);
            assert!(lo <= v && v <= hi, "value {v} outside bucket {b} = [{lo}, {hi}]");
        }
    }

    #[test]
    fn record_and_snapshot() {
        let h = Histogram::new();
        for v in [3u64, 3, 100, 5000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 5106);
        assert_eq!(s.min, 3);
        assert_eq!(s.max, 5000);
        assert_eq!(s.buckets.iter().map(|&(_, _, c)| c).sum::<u64>(), 4);
        h.reset();
        assert_eq!(h.snapshot().count, 0);
    }

    #[test]
    fn empty_percentile_is_zero() {
        assert_eq!(HistogramSnapshot::default().percentile(0.5), 0.0);
    }
}
