//! Lock-free monotonic counters, sharded across cache lines so the fused
//! slab-parallel paths can bump them from every worker thread without
//! bouncing a single hot line (the same trick cuSZ's kernel counters use
//! on-device: per-block partials merged at readout).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

pub(crate) const SHARDS: usize = 16;

#[repr(align(64))]
#[derive(Debug, Default)]
struct PaddedU64(AtomicU64);

/// A monotonically increasing counter. `add` touches one cache-line-padded
/// shard chosen per thread; `get` sums all shards (reads may race writes,
/// which is fine for telemetry — each shard read is itself atomic).
#[derive(Debug)]
pub struct Counter {
    shards: [PaddedU64; SHARDS],
}

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SHARD: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % SHARDS;
}

#[inline]
fn shard_index() -> usize {
    SHARD.with(|s| *s)
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

impl Counter {
    pub fn new() -> Self {
        Counter { shards: std::array::from_fn(|_| PaddedU64::default()) }
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.shards[shard_index()].0.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Sum of all shards.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }

    /// Zero every shard in place (the `Arc` identity is preserved so
    /// `StaticCounter` caches stay valid).
    pub fn reset(&self) {
        for s in &self.shards {
            s.0.store(0, Ordering::Relaxed);
        }
    }
}

/// Static-key fast path: resolves the registry entry once per process and
/// caches the `Arc`, so hot-path call sites pay one `OnceLock` load plus a
/// relaxed `fetch_add` — no map lookup, no lock.
pub struct StaticCounter {
    name: &'static str,
    cell: OnceLock<Arc<Counter>>,
}

impl StaticCounter {
    pub const fn new(name: &'static str) -> Self {
        StaticCounter { name, cell: OnceLock::new() }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    fn cell(&self) -> &Arc<Counter> {
        self.cell.get_or_init(|| crate::obs::global().counter(self.name))
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.cell().add(v);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.cell().get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_get() {
        let c = Counter::new();
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn sums_across_threads() {
        let c = std::sync::Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.add(2);
                    }
                });
            }
        });
        assert_eq!(c.get(), 16_000);
    }
}
