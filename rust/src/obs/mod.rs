//! Unified telemetry layer: a process-wide registry of named counters,
//! per-stage span aggregates, and latency histograms.
//!
//! Everything is std-only and lock-free on the hot path: counters and
//! stage stats are sharded `AtomicU64`s (see [`counter`]), histograms are
//! atomic bucket arrays (see [`histogram`]), and the registry maps are
//! behind an `RwLock` that instrumented code touches only on first use of
//! a key (static call sites cache the `Arc` via [`StaticCounter`]).
//!
//! Readout comes in three forms: a versioned JSON [`Snapshot`]
//! (`cusz … --metrics-out <path>`), Prometheus text exposition
//! ([`Registry::render_text`]), and the per-run [`RunTimings`] that
//! feeds `CompressStats`/`DecompressStats` reports — the same numbers
//! cuSZ's Table 7 stage breakdown is built from.

pub mod counter;
pub mod histogram;
pub mod span;

pub use counter::{Counter, StaticCounter};
pub use histogram::{bucket_bounds, bucket_of, Histogram, HistogramSnapshot};
pub use span::{Span, StageStat};

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// Documented metric names. Stage keys follow `<phase>.<stage>`; every
/// key in [`keys::DOCUMENTED_STAGES`] is recorded by a full
/// compress+decompress roundtrip and locked by a regression test.
pub mod keys {
    pub const COMPRESS_PREDICT_QUANT: &str = "compress.predict_quant";
    pub const COMPRESS_HISTOGRAM: &str = "compress.histogram";
    pub const COMPRESS_CODEBOOK: &str = "compress.codebook";
    pub const COMPRESS_GATHER_OUTLIERS: &str = "compress.gather_outliers";
    pub const COMPRESS_ENCODE: &str = "compress.encode";
    pub const COMPRESS_CONTAINER: &str = "compress.container";
    pub const COMPRESS_TOTAL: &str = "compress.total";
    pub const DECOMPRESS_DECODE: &str = "decompress.decode";
    pub const DECOMPRESS_FUSED_RECONSTRUCT: &str = "decompress.fused_reconstruct";
    pub const DECOMPRESS_TOTAL: &str = "decompress.total";

    /// Stage keys every compress→decompress roundtrip must record.
    pub const DOCUMENTED_STAGES: &[&str] = &[
        COMPRESS_PREDICT_QUANT,
        COMPRESS_HISTOGRAM,
        COMPRESS_CODEBOOK,
        COMPRESS_GATHER_OUTLIERS,
        COMPRESS_ENCODE,
        COMPRESS_CONTAINER,
        COMPRESS_TOTAL,
        DECOMPRESS_DECODE,
        DECOMPRESS_FUSED_RECONSTRUCT,
        DECOMPRESS_TOTAL,
    ];

    // Streaming pipeline / batch service spans.
    pub const PIPELINE_COMPRESS: &str = "pipeline.compress";
    pub const PIPELINE_SINK: &str = "pipeline.sink";
    pub const SERVE_COMPRESS_JOB: &str = "serve.compress.job";
    pub const SERVE_DECOMPRESS_JOB: &str = "serve.decompress.job";

    // Latency histograms (values are nanoseconds).
    pub const HIST_COMPRESS_JOB_NS: &str = "serve.compress.job_ns";
    pub const HIST_DECOMPRESS_JOB_NS: &str = "serve.decompress.job_ns";

    // Queue-depth counter pair: depth = enqueued - dequeued.
    pub const SERVE_QUEUE_ENQUEUED: &str = "serve.queue.enqueued";
    pub const SERVE_QUEUE_DEQUEUED: &str = "serve.queue.dequeued";

    // Serve daemon (`cusz serve --daemon`): per-request spans, latency
    // histograms, and admission-control counters.
    pub const SERVE_DAEMON_PUT: &str = "serve.daemon.put";
    pub const SERVE_DAEMON_GET: &str = "serve.daemon.get";
    pub const HIST_DAEMON_PUT_NS: &str = "serve.daemon.put_ns";
    pub const HIST_DAEMON_GET_NS: &str = "serve.daemon.get_ns";
    pub const SERVE_DAEMON_CONNECTIONS: &str = "serve.daemon.connections";
    pub const SERVE_DAEMON_REQUESTS: &str = "serve.daemon.requests";
    /// Admissions refused (queue full or connection cap) with `BUSY`.
    pub const SERVE_DAEMON_SHED: &str = "serve.daemon.shed";
    pub const SERVE_DAEMON_ERRORS: &str = "serve.daemon.errors";
    // Daemon job-queue depth pair: depth = enqueued - dequeued.
    pub const SERVE_DAEMON_QUEUE_ENQUEUED: &str = "serve.daemon.queue.enqueued";
    pub const SERVE_DAEMON_QUEUE_DEQUEUED: &str = "serve.daemon.queue.dequeued";

    // Background incremental store scrubber (daemon `--scrub-interval-ms`):
    // entries CRC-checked, corruptions detected, fields quarantined, and
    // payload bytes scanned. GETs refused because the field sits in
    // quarantine are counted separately from generic errors.
    pub const STORE_SCRUB_CHECKED: &str = "store.scrub.checked";
    pub const STORE_SCRUB_CORRUPT: &str = "store.scrub.corrupt";
    pub const STORE_SCRUB_QUARANTINED: &str = "store.scrub.quarantined";
    pub const STORE_SCRUB_BYTES: &str = "store.scrub.bytes";
    pub const SERVE_DAEMON_GET_QUARANTINED: &str = "serve.daemon.get_quarantined";

    // Memory governor (daemon `--mem-budget` byte-budget admission):
    // cumulative bytes admitted, monotonic high-water mark of
    // concurrently reserved bytes, and reservations refused with `BUSY`.
    pub const SERVE_MEM_RESERVED: &str = "serve.mem.reserved";
    pub const SERVE_MEM_PEAK: &str = "serve.mem.peak";
    pub const SERVE_MEM_SHED: &str = "serve.mem.shed";
}

/// Process-wide registry of counters, stage aggregates, and histograms.
/// Keys are `&'static str` by design: instrumentation uses fixed names,
/// and snapshots iterate `BTreeMap`s so output ordering is deterministic.
#[derive(Debug, Default)]
pub struct Registry {
    counters: RwLock<BTreeMap<&'static str, Arc<Counter>>>,
    stages: RwLock<BTreeMap<&'static str, Arc<StageStat>>>,
    hists: RwLock<BTreeMap<&'static str, Arc<Histogram>>>,
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry all built-in instrumentation records into.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

/// Open a span against a stage of the global registry.
pub fn span(key: &'static str) -> Span {
    global().span(key)
}

fn get_or_insert<T: Default>(
    map: &RwLock<BTreeMap<&'static str, Arc<T>>>,
    name: &'static str,
) -> Arc<T> {
    if let Some(v) = map.read().expect("obs registry poisoned").get(name) {
        return v.clone();
    }
    map.write()
        .expect("obs registry poisoned")
        .entry(name)
        .or_default()
        .clone()
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        get_or_insert(&self.counters, name)
    }

    pub fn stage(&self, name: &'static str) -> Arc<StageStat> {
        get_or_insert(&self.stages, name)
    }

    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        get_or_insert(&self.hists, name)
    }

    /// Current value of a counter, 0 if never registered.
    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters
            .read()
            .expect("obs registry poisoned")
            .get(name)
            .map(|c| c.get())
            .unwrap_or(0)
    }

    /// Total recorded nanoseconds for a stage, 0 if never registered.
    pub fn stage_ns(&self, name: &str) -> u64 {
        self.stages
            .read()
            .expect("obs registry poisoned")
            .get(name)
            .map(|s| s.total_ns())
            .unwrap_or(0)
    }

    pub fn add(&self, name: &'static str, v: u64) {
        self.counter(name).add(v);
    }

    pub fn span(&self, key: &'static str) -> Span {
        Span::enter(self.stage(key))
    }

    /// Zero every registered instrument in place. Registered names (and
    /// the `Arc`s cached by `StaticCounter`s) survive.
    pub fn reset(&self) {
        for c in self.counters.read().expect("obs registry poisoned").values() {
            c.reset();
        }
        for s in self.stages.read().expect("obs registry poisoned").values() {
            s.reset();
        }
        for h in self.hists.read().expect("obs registry poisoned").values() {
            h.reset();
        }
    }

    /// Point-in-time copy of every instrument, sorted by name.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .read()
            .expect("obs registry poisoned")
            .iter()
            .map(|(&k, v)| (k.to_string(), v.get()))
            .collect();
        let stages = self
            .stages
            .read()
            .expect("obs registry poisoned")
            .iter()
            .map(|(&k, v)| {
                (
                    k.to_string(),
                    StageSnapshot { ns: v.total_ns(), calls: v.calls(), bytes: v.bytes() },
                )
            })
            .collect();
        let histograms = self
            .hists
            .read()
            .expect("obs registry poisoned")
            .iter()
            .map(|(&k, v)| (k.to_string(), v.snapshot()))
            .collect();
        Snapshot { counters, stages, histograms }
    }

    /// Prometheus text exposition (one sample per line). Metric names are
    /// fixed; instrument names become label values with `.` preserved.
    pub fn render_text(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        out.push_str("# TYPE cusz_counter counter\n");
        for (name, v) in &snap.counters {
            out.push_str(&format!("cusz_counter{{name=\"{name}\"}} {v}\n"));
        }
        out.push_str("# TYPE cusz_stage_ns_total counter\n");
        out.push_str("# TYPE cusz_stage_calls_total counter\n");
        out.push_str("# TYPE cusz_stage_bytes_total counter\n");
        for (name, s) in &snap.stages {
            out.push_str(&format!("cusz_stage_ns_total{{stage=\"{name}\"}} {}\n", s.ns));
            out.push_str(&format!("cusz_stage_calls_total{{stage=\"{name}\"}} {}\n", s.calls));
            out.push_str(&format!("cusz_stage_bytes_total{{stage=\"{name}\"}} {}\n", s.bytes));
        }
        out.push_str("# TYPE cusz_histogram_count counter\n");
        out.push_str("# TYPE cusz_histogram_quantile gauge\n");
        for (name, h) in &snap.histograms {
            out.push_str(&format!("cusz_histogram_count{{hist=\"{name}\"}} {}\n", h.count));
            out.push_str(&format!("cusz_histogram_sum{{hist=\"{name}\"}} {}\n", h.sum));
            for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                out.push_str(&format!(
                    "cusz_histogram_quantile{{hist=\"{name}\",quantile=\"{label}\"}} {}\n",
                    jnum(h.percentile(q))
                ));
            }
        }
        out
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct StageSnapshot {
    pub ns: u64,
    pub calls: u64,
    pub bytes: u64,
}

impl StageSnapshot {
    /// GB/s against the recorded byte volume (bytes/ns == GB/s).
    pub fn gbps(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.bytes as f64 / self.ns as f64
        }
    }
}

/// Versioned, self-describing snapshot — the payload behind
/// `--metrics-out` and the `obs` section of `BENCH_pipeline.json`.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub stages: Vec<(String, StageSnapshot)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

/// Render a float as a JSON-safe number (non-finite collapses to 0).
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "0".to_string()
    }
}

impl Snapshot {
    pub const SCHEMA: &'static str = "cusz-metrics/v1";

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(k, _)| k == name).map(|&(_, v)| v).unwrap_or(0)
    }

    pub fn stage(&self, name: &str) -> Option<StageSnapshot> {
        self.stages.iter().find(|(k, _)| k == name).map(|&(_, s)| s)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(k, _)| k == name).map(|(_, h)| h)
    }

    /// Hand-rolled JSON (names are fixed identifiers, no escaping
    /// needed). Deterministic: maps are emitted in sorted key order.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{{\n  \"schema\": \"{}\",\n", Self::SCHEMA));
        s.push_str("  \"counters\": {");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{name}\": {v}"));
        }
        s.push_str("\n  },\n  \"stages\": {");
        for (i, (name, st)) in self.stages.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    \"{name}\": {{\"ns\": {}, \"calls\": {}, \"bytes\": {}, \"gbps\": {}}}",
                st.ns,
                st.calls,
                st.bytes,
                jnum(st.gbps())
            ));
        }
        s.push_str("\n  },\n  \"histograms\": {");
        for (i, (name, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let buckets = h
                .buckets
                .iter()
                .map(|&(lo, hi, c)| format!("[{lo}, {hi}, {c}]"))
                .collect::<Vec<_>>()
                .join(", ");
            s.push_str(&format!(
                "\n    \"{name}\": {{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \
                 \"p50\": {}, \"p95\": {}, \"p99\": {}, \"buckets\": [{buckets}]}}",
                h.count,
                h.sum,
                h.min,
                h.max,
                jnum(h.percentile(0.50)),
                jnum(h.percentile(0.95)),
                jnum(h.percentile(0.99)),
            ));
        }
        s.push_str("\n  }\n}\n");
        s
    }
}

/// Per-run stage accounting: the drop-in successor of the old
/// `metrics::StageTimer`, carried inside `CompressStats`/`DecompressStats`
/// so per-field reports keep their exact shape. Unlike the old timer it
/// can also mirror each measurement into the global [`Registry`] (see
/// [`RunTimings::add_recorded`]), which is where worker threads merge.
#[derive(Debug, Clone, Default)]
pub struct RunTimings {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl RunTimings {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`, accumulating locally only.
    pub fn time<R>(&mut self, stage: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(stage, t0.elapsed());
        r
    }

    /// Accumulate locally only (used by baseline paths that must not
    /// pollute the global registry, e.g. the materializing decompressor).
    pub fn add(&mut self, stage: &str, d: Duration) {
        *self.totals.entry(stage.to_string()).or_default() += d;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    /// Accumulate locally *and* record `(d, bytes)` into the global
    /// registry under `key` — the bridge from per-run reports to the
    /// process-wide snapshot.
    pub fn add_recorded(&mut self, stage: &str, key: &'static str, d: Duration, bytes: u64) {
        self.add(stage, d);
        global().stage(key).record(d, bytes);
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.totals.get(stage).copied().unwrap_or_default()
    }

    pub fn merge(&mut self, other: &RunTimings) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    /// (stage, total, calls, GB/s against `bytes`) rows, name-sorted.
    pub fn rows(&self, bytes: usize) -> Vec<(String, Duration, u64, f64)> {
        self.totals
            .iter()
            .map(|(k, &d)| {
                let gbps = if d.as_nanos() > 0 {
                    bytes as f64 / d.as_secs_f64() / 1e9
                } else {
                    f64::INFINITY
                };
                (k.clone(), d, self.counts[k], gbps)
            })
            .collect()
    }

    pub fn report(&self, bytes: usize) -> String {
        let mut s = String::new();
        for (stage, d, n, gbps) in self.rows(bytes) {
            s.push_str(&format!(
                "  {stage:<28} {:>10.3} ms  x{n:<5} {gbps:>9.3} GB/s\n",
                d.as_secs_f64() * 1e3
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_counters_and_stages() {
        let r = Registry::new();
        r.add("t.counter", 5);
        r.add("t.counter", 2);
        assert_eq!(r.counter_value("t.counter"), 7);
        assert_eq!(r.counter_value("t.never"), 0);
        r.stage("t.stage").record(Duration::from_millis(2), 64);
        assert!(r.stage_ns("t.stage") > 0);
        let snap = r.snapshot();
        assert_eq!(snap.counter("t.counter"), 7);
        assert_eq!(snap.stage("t.stage").unwrap().bytes, 64);
        r.reset();
        assert_eq!(r.counter_value("t.counter"), 0);
    }

    #[test]
    fn snapshot_json_shape() {
        let r = Registry::new();
        r.add("t.c", 1);
        r.stage("t.s").record(Duration::from_micros(10), 1000);
        r.histogram("t.h").record(42);
        let json = r.snapshot().to_json();
        assert!(json.contains("\"schema\": \"cusz-metrics/v1\""));
        assert!(json.contains("\"t.c\": 1"));
        assert!(json.contains("\"t.s\""));
        assert!(json.contains("\"buckets\""));
        // must parse as a single balanced object
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn render_text_exposition() {
        let r = Registry::new();
        r.add("t.c", 3);
        r.histogram("t.h").record(1000);
        let text = r.render_text();
        assert!(text.contains("cusz_counter{name=\"t.c\"} 3"));
        assert!(text.contains("cusz_histogram_count{hist=\"t.h\"} 1"));
    }

    #[test]
    fn run_timings_matches_legacy_behavior() {
        let mut t = RunTimings::new();
        t.add("quant", Duration::from_millis(10));
        t.add("quant", Duration::from_millis(5));
        t.add("huffman", Duration::from_millis(1));
        assert_eq!(t.total("quant"), Duration::from_millis(15));
        assert_eq!(t.rows(0).len(), 2);
        let mut b = RunTimings::new();
        b.add("quant", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        t.merge(&b);
        assert_eq!(t.total("quant"), Duration::from_millis(17));
        assert_eq!(t.total("y"), Duration::from_millis(3));
        let report = t.report(1 << 20);
        assert!(report.contains("quant"));
        assert!(report.contains("GB/s"));
    }

    #[test]
    fn add_recorded_mirrors_into_global() {
        let key = keys::PIPELINE_SINK; // reuse a static key for the test
        let before = global().stage_ns(key);
        let mut t = RunTimings::new();
        t.add_recorded("sink", key, Duration::from_micros(7), 9);
        assert_eq!(t.total("sink"), Duration::from_micros(7));
        assert!(global().stage_ns(key) > before);
    }
}
