//! Per-stage aggregates and RAII span timers.
//!
//! A `StageStat` is three sharded counters — nanoseconds, calls, bytes —
//! so any number of worker threads can close spans against the same stage
//! concurrently. A `Span` measures one timed region and folds itself into
//! its stage (and optionally a latency histogram) on drop.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::counter::Counter;
use super::histogram::Histogram;

#[derive(Debug, Default)]
pub struct StageStat {
    ns: Counter,
    calls: Counter,
    bytes: Counter,
}

impl StageStat {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&self, elapsed: Duration, bytes: u64) {
        self.ns.add(elapsed.as_nanos() as u64);
        self.calls.incr();
        self.bytes.add(bytes);
    }

    pub fn total_ns(&self) -> u64 {
        self.ns.get()
    }

    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.get()
    }

    /// Throughput against the recorded byte volume.
    pub fn gbps(&self) -> f64 {
        let ns = self.ns.get();
        if ns == 0 {
            0.0
        } else {
            self.bytes.get() as f64 / ns as f64
        }
    }

    pub fn reset(&self) {
        self.ns.reset();
        self.calls.reset();
        self.bytes.reset();
    }
}

/// RAII timer: created via [`crate::obs::Registry::span`] (or
/// [`Span::enter`]), records wall time + byte volume into its stage when
/// dropped. Attach bytes with [`Span::with_bytes`]/[`Span::add_bytes`];
/// attach a latency histogram (elapsed ns) with [`Span::with_histogram`].
#[must_use = "a span records on drop; binding it to _ ends it immediately"]
pub struct Span {
    stat: Arc<StageStat>,
    hist: Option<Arc<Histogram>>,
    bytes: u64,
    t0: Instant,
}

impl Span {
    pub fn enter(stat: Arc<StageStat>) -> Self {
        Span { stat, hist: None, bytes: 0, t0: Instant::now() }
    }

    pub fn with_bytes(mut self, bytes: u64) -> Self {
        self.bytes = bytes;
        self
    }

    pub fn with_histogram(mut self, hist: Arc<Histogram>) -> Self {
        self.hist = Some(hist);
        self
    }

    pub fn add_bytes(&mut self, bytes: u64) {
        self.bytes += bytes;
    }

    /// End the span now, returning the elapsed wall time.
    pub fn finish(self) -> Duration {
        let d = self.t0.elapsed();
        drop(self);
        d
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let d = self.t0.elapsed();
        self.stat.record(d, self.bytes);
        if let Some(h) = &self.hist {
            h.record(d.as_nanos() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_on_drop() {
        let stat = Arc::new(StageStat::new());
        {
            let mut s = Span::enter(stat.clone()).with_bytes(100);
            s.add_bytes(28);
        }
        assert_eq!(stat.calls(), 1);
        assert_eq!(stat.bytes(), 128);
        assert!(stat.total_ns() > 0);
    }

    #[test]
    fn span_feeds_histogram() {
        let stat = Arc::new(StageStat::new());
        let hist = Arc::new(Histogram::new());
        let d = Span::enter(stat.clone()).with_histogram(hist.clone()).finish();
        assert!(d.as_nanos() > 0 || d.is_zero()); // finish returns elapsed
        assert_eq!(hist.snapshot().count, 1);
        assert_eq!(stat.calls(), 1);
    }

    #[test]
    fn concurrent_spans_merge_exactly() {
        let stat = Arc::new(StageStat::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let stat = stat.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let _span = Span::enter(stat.clone()).with_bytes(64);
                    }
                });
            }
        });
        assert_eq!(stat.calls(), 400);
        assert_eq!(stat.bytes(), 400 * 64);
    }
}
