//! Shared field fixtures for tests and benches: the three data regimes the
//! python tests also use (smooth / noisy / zero-dominated).

use crate::util::prng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    Smooth,
    Noisy,
    Zeros,
}

impl Regime {
    pub const ALL: [Regime; 3] = [Regime::Smooth, Regime::Noisy, Regime::Zeros];
}

pub fn make(regime: Regime, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    match regime {
        Regime::Smooth => {
            let mut acc = 0f32;
            (0..n)
                .map(|_| {
                    acc += rng.normal() * 0.02;
                    acc
                })
                .collect()
        }
        Regime::Noisy => (0..n).map(|_| rng.normal() * 10.0).collect(),
        Regime::Zeros => (0..n)
            .map(|_| if rng.f32() < 0.03 { rng.normal() * 100.0 } else { 0.0 })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regimes_have_expected_character() {
        let s = make(Regime::Smooth, 10_000, 1);
        let z = make(Regime::Zeros, 10_000, 1);
        let max_step = s.windows(2).map(|w| (w[1] - w[0]).abs()).fold(0f32, f32::max);
        assert!(max_step < 0.2);
        let zero_frac = z.iter().filter(|&&v| v == 0.0).count() as f32 / z.len() as f32;
        assert!(zero_frac > 0.9);
    }
}
