//! Test infrastructure: a mini property-testing kit (offline substitute
//! for proptest, DESIGN.md §4) and shared field fixtures.

pub mod fields;
pub mod prop;
