//! Test infrastructure: a mini property-testing kit (offline substitute
//! for proptest, DESIGN.md §4), shared field fixtures, and a tempdir
//! helper (offline substitute for the tempfile crate).

pub mod fields;
pub mod prop;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Create a fresh unique directory under the system temp dir. Callers are
/// expected to remove it when done (tests may leave it on panic — paths
/// embed the pid so reruns never collide).
pub fn tmp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    let dir = std::env::temp_dir().join(format!(
        "cusz-{tag}-{}-{seq}-{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("create tmp dir");
    dir
}
