//! Mini property-testing kit: deterministic seeded cases with failure
//! reporting. Set `CUSZ_PROP_CASES` / `CUSZ_PROP_SEED` to widen or replay.

use crate::util::prng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let cases = std::env::var("CUSZ_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("CUSZ_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xc052_2020);
        PropConfig { cases, seed }
    }
}

/// Run `prop` for each case with a per-case RNG; panics with the failing
/// case seed so `CUSZ_PROP_SEED=<seed> CUSZ_PROP_CASES=1` replays it.
pub fn check(name: &str, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    check_with(PropConfig::default(), name, prop)
}

pub fn check_with(cfg: PropConfig, name: &str, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with \
                 CUSZ_PROP_SEED={case_seed} CUSZ_PROP_CASES=1): {msg}"
            );
        }
    }
}

/// Generators.
pub mod gen {
    use crate::util::prng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    /// Random small shape with block-aligned axes for the given block.
    pub fn aligned_shape(rng: &mut Rng, block: &[usize], max_blocks: usize) -> Vec<usize> {
        block
            .iter()
            .map(|&b| b * usize_in(rng, 1, max_blocks))
            .collect()
    }

    pub fn pick<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
        &items[rng.below(items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        check_with(PropConfig { cases: 10, seed: 1 }, "trivial", |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check_with(PropConfig { cases: 5, seed: 7 }, "fails", |rng| {
            if rng.f32() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = crate::util::prng::Rng::new(3);
        for _ in 0..100 {
            let v = gen::usize_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&v));
        }
        let shape = gen::aligned_shape(&mut rng, &[16, 16], 4);
        assert!(shape[0] % 16 == 0 && shape[0] <= 64);
    }
}
