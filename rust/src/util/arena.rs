//! Thread-local scratch arenas: reusable buffers for the hot encode and
//! decode paths.
//!
//! The compressor's and decompressor's inner loops need short-lived
//! scratch — the slab gather buffer, the chunk stitch buffer when a
//! codec window straddles a slab boundary, the serialized archive body,
//! the fused decompress pass's per-slab delta and reconstruction
//! buffers — and allocating them per call turns the hot paths into an
//! allocator benchmark. Each `with_*`
//! helper loans a `Vec` from a small per-thread pool and returns it when
//! the closure exits, so a worker that processes many chunks (or a
//! long-lived `serve` worker that processes many fields) pays for the
//! allocation once and reuses the capacity thereafter.
//!
//! Contract: the loaned buffer's **contents and length are unspecified**
//! (it arrives exactly as the previous user left it) — callers must
//! `clear()`/`resize()` for their own needs. This is deliberate: the slab
//! gather path overwrites every element of a full slab and must not pay
//! for a redundant zeroing pass (EXPERIMENTS.md §Perf iteration 3).
//!
//! Pools are bounded both in entry count and per-buffer capacity so a
//! one-off huge loan on a long-lived thread does not pin memory forever;
//! a buffer that grew beyond [`MAX_RETAINED_BYTES`] is dropped instead of
//! pooled. Panic safety: if the closure unwinds, the buffer is simply
//! dropped — the pool never sees a poisoned entry.

use std::cell::RefCell;

/// Max buffers retained per type per thread.
const MAX_POOLED: usize = 4;
/// Total capacity budget (in bytes) a pool may retain, per element type
/// per thread. 256 MiB covers one serialized body for the largest bench
/// fields; the budget is for the whole pool, so a worker that once saw a
/// huge field pins at most one body-sized buffer, not `MAX_POOLED` of
/// them.
const MAX_RETAINED_BYTES: usize = 256 << 20;

/// Watermark used by long-lived services ([`trim_to_watermark`]) after
/// each job/drain: scratch retained beyond this (per thread, across all
/// pools) is released back to the allocator. 64 MiB keeps the common
/// slab-sized buffers warm while letting one-off large-field peaks fall
/// back.
pub const DEFAULT_TRIM_WATERMARK: usize = 64 << 20;

macro_rules! scratch_pool {
    ($(#[$doc:meta])* $pool:ident, $with:ident, $retained:ident, $trim:ident, $t:ty) => {
        thread_local! {
            static $pool: RefCell<Vec<Vec<$t>>> = const { RefCell::new(Vec::new()) };
        }

        $(#[$doc])*
        pub fn $with<R>(f: impl FnOnce(&mut Vec<$t>) -> R) -> R {
            let mut buf: Vec<$t> = $pool
                .with(|p| p.borrow_mut().pop())
                .unwrap_or_default();
            let out = f(&mut buf);
            if buf.capacity() > 0 {
                $pool.with(|p| {
                    let mut p = p.borrow_mut();
                    let retained: usize = p
                        .iter()
                        .map(|b| b.capacity() * std::mem::size_of::<$t>())
                        .sum();
                    if p.len() < MAX_POOLED
                        && retained + buf.capacity() * std::mem::size_of::<$t>()
                            <= MAX_RETAINED_BYTES
                    {
                        p.push(buf);
                    }
                });
            }
            out
        }

        /// Bytes of capacity this thread's pool currently retains.
        fn $retained() -> usize {
            $pool.with(|p| {
                p.borrow()
                    .iter()
                    .map(|b| b.capacity() * std::mem::size_of::<$t>())
                    .sum()
            })
        }

        /// Drop this thread's pooled buffers, largest first, until the
        /// pool retains at most `cap` bytes. Returns retained bytes after.
        fn $trim(cap: usize) -> usize {
            $pool.with(|p| {
                let mut p = p.borrow_mut();
                p.sort_by_key(|b| b.capacity());
                let mut retained: usize = p
                    .iter()
                    .map(|b| b.capacity() * std::mem::size_of::<$t>())
                    .sum();
                while retained > cap {
                    match p.pop() {
                        Some(b) => retained -= b.capacity() * std::mem::size_of::<$t>(),
                        None => break,
                    }
                }
                retained
            })
        }
    };
}

scratch_pool!(
    /// Loan a `Vec<u16>` — the codec chunk stitch buffer (symbol windows
    /// that straddle slab boundaries).
    U16_POOL, with_u16, retained_u16, trim_u16, u16
);
scratch_pool!(
    /// Loan a `Vec<u8>` — serialized-body and lossless-tail scratch.
    U8_POOL, with_u8, retained_u8, trim_u8, u8
);
scratch_pool!(
    /// Loan a `Vec<f32>` — the per-slab gather buffer (encode) and the
    /// per-slab reconstruction buffer (the fused decompress pass).
    F32_POOL, with_f32, retained_f32, trim_f32, f32
);
scratch_pool!(
    /// Loan a `Vec<i32>` — the per-slab delta buffer of the fused
    /// decompress pass (patched quant deltas, consumed in place by the
    /// inverse-Lorenzo kernel).
    I32_POOL, with_i32, retained_i32, trim_i32, i32
);

/// Bytes of scratch capacity the calling thread's pools retain in total.
pub fn retained_bytes() -> usize {
    retained_u16() + retained_u8() + retained_f32() + retained_i32()
}

/// Trim the calling thread's pools so their total retained capacity falls
/// to `watermark` bytes or below, dropping the largest buffers first.
/// Pools are thread-local, so long-lived services must call this on the
/// worker thread that did the work (the daemon does, after every job).
pub fn trim_to_watermark(watermark: usize) {
    let total = retained_bytes();
    if total <= watermark {
        return;
    }
    // Give each pool an equal share of the watermark; a pool under its
    // share keeps everything, one over it drops largest-first. The result
    // is at most the watermark in total.
    let share = watermark / 4;
    trim_u16(share);
    trim_u8(share);
    trim_f32(share);
    trim_i32(share);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_is_reused_across_loans() {
        // warm the pool with a grown buffer...
        with_u16(|b| {
            b.clear();
            b.resize(10_000, 7);
        });
        // ...and the next loan on this thread starts with that capacity
        let cap = with_u16(|b| b.capacity());
        assert!(cap >= 10_000, "pool did not retain capacity ({cap})");
    }

    #[test]
    fn contents_are_unspecified_but_owned() {
        with_u8(|b| {
            b.clear();
            b.extend_from_slice(b"residue");
        });
        // a second loan may see the residue — that is the documented
        // contract; clearing makes it usable
        with_u8(|b| {
            b.clear();
            assert!(b.is_empty());
        });
    }

    #[test]
    fn nested_loans_get_distinct_buffers() {
        with_f32(|outer| {
            outer.clear();
            outer.push(1.0);
            with_f32(|inner| {
                inner.clear();
                inner.push(2.0);
                assert_ne!(outer.as_ptr(), inner.as_ptr());
            });
            assert_eq!(outer[0], 1.0);
        });
    }

    #[test]
    fn threads_have_isolated_pools() {
        with_u16(|b| {
            b.clear();
            b.resize(5000, 1);
        });
        let other_cap = std::thread::spawn(|| with_u16(|b| b.capacity()))
            .join()
            .unwrap();
        // a fresh thread starts cold (0 capacity from a default Vec)
        assert_eq!(other_cap, 0);
    }

    #[test]
    fn trim_returns_retained_bytes_under_watermark() {
        // run in a fresh thread so this test owns its pools
        std::thread::spawn(|| {
            // a "large job": grow several pools well past the watermark
            with_f32(|b| {
                b.clear();
                b.resize(2 << 20, 0.0); // 8 MiB
            });
            with_u8(|b| {
                b.clear();
                b.resize(6 << 20, 0); // 6 MiB
            });
            with_u16(|b| {
                b.clear();
                b.resize(1 << 20, 0); // 2 MiB
            });
            assert!(retained_bytes() > 1 << 20, "pools did not grow");
            let watermark = 1 << 20; // 1 MiB
            trim_to_watermark(watermark);
            let after = retained_bytes();
            assert!(
                after <= watermark,
                "retained {after} bytes still above watermark {watermark}"
            );
            // under the watermark the hook is a no-op
            trim_to_watermark(usize::MAX);
            assert_eq!(retained_bytes(), after);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn trim_keeps_small_buffers_and_drops_large_ones() {
        std::thread::spawn(|| {
            // two distinct buffers in one pool: small (8 KiB) and large
            // (4 MiB) — nested so the second loan cannot reuse the first
            with_u8(|small| {
                small.clear();
                small.resize(8 << 10, 0);
                with_u8(|large| {
                    large.clear();
                    large.resize(4 << 20, 0);
                });
            });
            trim_to_watermark(256 << 10);
            // the large buffer is gone, the small one survived
            assert!(retained_bytes() <= 256 << 10);
            let cap = with_u8(|b| b.capacity());
            assert!(cap >= 8 << 10, "small warm buffer was dropped ({cap})");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn oversized_buffers_are_not_hoarded() {
        let huge = MAX_RETAINED_BYTES + 16;
        with_u8(|b| {
            b.clear();
            b.reserve_exact(huge);
        });
        // next loan must not hand back the >cap buffer
        with_u8(|b| assert!(b.capacity() * std::mem::size_of::<u8>() <= MAX_RETAINED_BYTES));
    }
}
