//! Infrastructure utilities. Several of these replace crates that are not
//! available in the offline build environment (see DESIGN.md §4):
//! [`pool`] ~ a bounded-queue worker pool (tokio substitute for this
//! pipeline's needs), [`cli`] ~ clap, [`bench`] ~ criterion.

pub mod arena;
pub mod bench;
pub mod bitio;
pub mod cli;
pub mod govern;
pub mod pool;
pub mod prng;
