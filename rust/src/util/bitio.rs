//! Bit-level I/O over u64 words — the deflate/inflate substrate.
//!
//! `BitWriter` packs variable-length codewords LSB-first into a `Vec<u64>`;
//! `BitReader` consumes them in the same order. The hot paths are
//! branch-light: one shift/or per write plus a spill every 64 bits,
//! mirroring the barrel-shifter scheme of E2MC that the paper cites (§5.2).

/// LSB-first bit packer.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Bits already used in the trailing partial word.
    acc: u64,
    fill: u32,
    len_bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        BitWriter { words: Vec::with_capacity(bits / 64 + 1), ..Default::default() }
    }

    /// Append the low `n` bits of `value` (n in 0..=57 fast path; up to 64
    /// supported via the split path).
    #[inline]
    pub fn write(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let value = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        self.acc |= value << self.fill;
        let used = 64 - self.fill;
        if n >= used {
            // Spill the filled word; carry the remainder.
            self.words.push(self.acc);
            self.acc = if used == 64 { 0 } else { value >> used };
            self.fill = n - used;
        } else {
            self.fill += n;
        }
        self.len_bits += n as u64;
    }

    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write(bit as u64, 1);
    }

    pub fn len_bits(&self) -> u64 {
        self.len_bits
    }

    /// Finish, returning the packed words and total bit count.
    pub fn finish(mut self) -> (Vec<u64>, u64) {
        if self.fill > 0 {
            self.words.push(self.acc);
        }
        (self.words, self.len_bits)
    }
}

/// LSB-first bit reader over packed words.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    words: &'a [u64],
    pos_bits: u64,
    len_bits: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u64], len_bits: u64) -> Self {
        debug_assert!(len_bits as usize <= words.len() * 64);
        BitReader { words, pos_bits: 0, len_bits }
    }

    /// Reader starting mid-stream at `pos_bits` — the gap-array decode
    /// entry point, where each subchunk resumes at a recorded bit offset.
    /// `pos_bits` is clamped to `len_bits` so a hostile offset can at
    /// worst read nothing, never out of bounds.
    pub fn new_at(words: &'a [u64], len_bits: u64, pos_bits: u64) -> Self {
        debug_assert!(len_bits as usize <= words.len() * 64);
        BitReader { words, pos_bits: pos_bits.min(len_bits), len_bits }
    }

    /// Absolute bit position from the start of the stream.
    #[inline]
    pub fn position(&self) -> u64 {
        self.pos_bits
    }

    #[inline]
    pub fn remaining(&self) -> u64 {
        self.len_bits - self.pos_bits
    }

    /// Read `n` bits (LSB-first). Returns None past the end.
    #[inline]
    pub fn read(&mut self, n: u32) -> Option<u64> {
        if n == 0 {
            return Some(0);
        }
        if self.pos_bits + n as u64 > self.len_bits {
            return None;
        }
        let word = (self.pos_bits / 64) as usize;
        let off = (self.pos_bits % 64) as u32;
        let mut v = self.words[word] >> off;
        let got = 64 - off;
        if n > got {
            v |= self.words[word + 1] << got;
        }
        self.pos_bits += n as u64;
        Some(if n == 64 { v } else { v & ((1u64 << n) - 1) })
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        self.read(1).map(|b| b != 0)
    }

    /// Peek up to `n` bits without consuming (zero-padded past the end).
    #[inline]
    pub fn peek(&self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        let word = (self.pos_bits / 64) as usize;
        let off = (self.pos_bits % 64) as u32;
        if word >= self.words.len() {
            return 0;
        }
        let mut v = self.words[word] >> off;
        let got = 64 - off;
        if n > got && word + 1 < self.words.len() {
            v |= self.words[word + 1] << got;
        }
        v & ((1u64 << n) - 1)
    }

    /// Advance by `n` bits (after a successful peek-decode).
    #[inline]
    pub fn skip(&mut self, n: u32) {
        self.pos_bits += n as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        for i in 0..1000u64 {
            w.write(i, 10);
        }
        let (words, bits) = w.finish();
        assert_eq!(bits, 10_000);
        let mut r = BitReader::new(&words, bits);
        for i in 0..1000u64 {
            assert_eq!(r.read(10), Some(i & 0x3ff));
        }
        assert_eq!(r.read(1), None);
    }

    #[test]
    fn roundtrip_random_widths() {
        let mut rng = Rng::new(11);
        let items: Vec<(u64, u32)> = (0..5000)
            .map(|_| {
                let n = 1 + (rng.below(64)) as u32;
                let v = rng.next_u64() & if n == 64 { u64::MAX } else { (1 << n) - 1 };
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write(v, n);
        }
        let (words, bits) = w.finish();
        let mut r = BitReader::new(&words, bits);
        for &(v, n) in &items {
            assert_eq!(r.read(n), Some(v), "width {n}");
        }
    }

    #[test]
    fn peek_then_skip_equals_read() {
        let mut w = BitWriter::new();
        w.write(0xdead_beef_1234, 48);
        w.write(0x7, 3);
        let (words, bits) = w.finish();
        let mut a = BitReader::new(&words, bits);
        let mut b = BitReader::new(&words, bits);
        let p = a.peek(20);
        a.skip(20);
        assert_eq!(Some(p), b.read(20));
        assert_eq!(a.read(31), b.read(31));
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        w.write(u64::MAX, 60);
        w.write(0b1011, 4); // exactly fills word 0
        w.write(0x5555, 16);
        let (words, bits) = w.finish();
        assert_eq!(bits, 80);
        let mut r = BitReader::new(&words, bits);
        assert_eq!(r.read(60), Some((1u64 << 60) - 1));
        assert_eq!(r.read(4), Some(0b1011));
        assert_eq!(r.read(16), Some(0x5555));
    }

    #[test]
    fn empty_writer() {
        let (words, bits) = BitWriter::new().finish();
        assert!(words.is_empty());
        assert_eq!(bits, 0);
    }

    #[test]
    fn new_at_resumes_mid_stream() {
        let mut w = BitWriter::new();
        for i in 0..200u64 {
            w.write(i, 11);
        }
        let (words, bits) = w.finish();
        for start in [0usize, 1, 5, 63, 64, 100, 199] {
            let mut r = BitReader::new_at(&words, bits, start as u64 * 11);
            assert_eq!(r.position(), start as u64 * 11);
            for i in start as u64..200 {
                assert_eq!(r.read(11), Some(i), "resume at {start}");
            }
            assert_eq!(r.read(1), None);
        }
        // hostile offsets clamp instead of reading out of bounds
        let mut past = BitReader::new_at(&words, bits, bits + 1000);
        assert_eq!(past.remaining(), 0);
        assert_eq!(past.read(1), None);
    }
}
