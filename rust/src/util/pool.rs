//! Worker pool for coarse-grained (chunk-wise) parallelism — the L3
//! analogue of the paper's "one GPU thread per deflate chunk" scheme, and
//! the offline substitute for tokio (DESIGN.md §4): std threads, bounded
//! channels for backpressure, scoped parallel-map helpers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};

/// Resolve a worker-count knob: positive values pass through, 0 means
/// one per available core (fallback 4 when the core count is unknown).
/// The one home of this fallback — `CuszConfig::effective_threads`,
/// `BatchConfig::effective_workers`, and the container's tail codec all
/// delegate here.
pub fn effective_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }
}

/// Run `f(i, &items[i])` for every index across `threads` workers and
/// collect results in order. Built on the range-native
/// [`parallel_map_range`], so no index vector is ever materialized.
pub fn parallel_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    parallel_map_range(threads, items.len(), |i| f(i, &items[i]))
}

/// Run `f(i)` for every `i in 0..n` across `threads` workers and collect
/// results in order. Work-stealing via an atomic cursor keeps load
/// balanced when per-index costs vary (tail chunks, zero-heavy blocks).
/// Range-native: the work list is the range itself — nothing is
/// materialized per item, and the `threads <= 1` path collects directly
/// with no `Vec<Option<R>>` slots and no atomics.
pub fn parallel_map_range<R, F>(threads: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        // single-thread fast path: straight collect, no slot vector
        return (0..n).map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let out_ptr = out_ptr; // copy the Send wrapper into the thread
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    // SAFETY: each index is claimed exactly once by the
                    // atomic cursor, so writes are disjoint; the scope
                    // guarantees `out` outlives all workers.
                    unsafe { *out_ptr.0.add(i) = Some(r) };
                }
            });
        }
    });

    out.into_iter().map(|r| r.expect("slot filled")).collect()
}

struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: used only for disjoint index writes inside a scope.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A bounded pipeline stage: spawns a worker thread that applies `f` to
/// every item from `rx` and forwards results; the bounded channel provides
/// backpressure (the paper's streaming-orchestrator role for L3).
pub struct Stage<O: Send + 'static> {
    pub rx: Receiver<O>,
    handle: std::thread::JoinHandle<()>,
}

impl<O: Send + 'static> Stage<O> {
    pub fn spawn<I, F>(rx_in: Receiver<I>, depth: usize, name: &str, f: F) -> Self
    where
        I: Send + 'static,
        F: FnMut(I) -> O + Send + 'static,
    {
        let (tx, rx) = sync_channel::<O>(depth);
        let mut f = f;
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                for item in rx_in {
                    if tx.send(f(item)).is_err() {
                        break; // downstream hung up
                    }
                }
            })
            .expect("spawn stage");
        Stage { rx, handle }
    }

    pub fn join(self) {
        drop(self.rx);
        let _ = self.handle.join();
    }
}

/// A bounded fan-out stage: `workers` threads pull items from one shared
/// input queue, apply `f`, and push results (in completion order) into one
/// bounded output channel. The multi-worker generalization of [`Stage`]
/// for stages whose per-item cost dwarfs the rest of the pipeline — e.g.
/// whole-field compression in the batch service.
pub struct FanStage<O: Send + 'static> {
    pub rx: Receiver<O>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl<O: Send + 'static> FanStage<O> {
    pub fn spawn<I, F>(rx_in: Receiver<I>, workers: usize, depth: usize, name: &str, f: F) -> Self
    where
        I: Send + 'static,
        F: Fn(I) -> O + Send + Sync + 'static,
    {
        Self::try_spawn(rx_in, workers, depth, name, f).expect("spawn fan stage")
    }

    /// Fallible spawn: thread creation failure (resource exhaustion)
    /// becomes an error the service layer can report per-request instead
    /// of a process abort. On partial failure the successfully spawned
    /// workers are self-cleaning — the caller drops the input sender and
    /// they drain to hang-up.
    pub fn try_spawn<I, F>(
        rx_in: Receiver<I>,
        workers: usize,
        depth: usize,
        name: &str,
        f: F,
    ) -> std::io::Result<Self>
    where
        I: Send + 'static,
        F: Fn(I) -> O + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let (tx, rx) = sync_channel::<O>(depth.max(1));
        let shared_rx = Arc::new(Mutex::new(rx_in));
        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx_in = Arc::clone(&shared_rx);
            let tx = tx.clone();
            let f = Arc::clone(&f);
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{w}"))
                .spawn(move || loop {
                    // Hold the queue lock only for the dequeue, never for
                    // the work itself.
                    let item = match rx_in.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break, // a sibling worker panicked
                    };
                    let Ok(item) = item else {
                        break; // producer hung up
                    };
                    if tx.send(f(item)).is_err() {
                        break; // downstream hung up
                    }
                })?;
            handles.push(handle);
        }
        Ok(FanStage { rx, handles })
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Join all workers, re-raising the first worker panic (the same
    /// contract as [`parallel_map`]: a panicking job must not vanish).
    pub fn join(self) {
        drop(self.rx);
        for h in self.handles {
            if let Err(payload) = h.join() {
                std::panic::resume_unwind(payload);
            }
        }
    }
}

/// Create the head of a pipeline: a bounded producer channel.
pub fn bounded<T: Send>(depth: usize) -> (SyncSender<T>, Receiver<T>) {
    sync_channel(depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(8, &items, |_, &x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_runs_every_item_once() {
        let count = AtomicU64::new(0);
        let items: Vec<u32> = (0..512).collect();
        parallel_map(4, &items, |_, _| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 512);
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(8, &empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(8, &[42u32], |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn parallel_map_range_matches_sequential_reference() {
        for threads in [1usize, 2, 7, 32] {
            for n in [0usize, 1, 2, 63, 1000] {
                let out = parallel_map_range(threads, n, |i| i * i + 1);
                let want: Vec<usize> = (0..n).map(|i| i * i + 1).collect();
                assert_eq!(out, want, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn parallel_map_range_runs_every_index_once() {
        let count = AtomicU64::new(0);
        let seen_sum = AtomicU64::new(0);
        parallel_map_range(4, 777, |i| {
            count.fetch_add(1, Ordering::Relaxed);
            seen_sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 777);
        assert_eq!(seen_sum.load(Ordering::Relaxed), 776 * 777 / 2);
    }

    #[test]
    fn single_thread_path_runs_on_calling_thread() {
        // the fast path must not spawn: thread-identity observable via
        // a thread-local side effect
        thread_local! {
            static HITS: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
        }
        HITS.with(|h| h.set(0));
        parallel_map_range(1, 100, |_| HITS.with(|h| h.set(h.get() + 1)));
        assert_eq!(HITS.with(|h| h.get()), 100);
    }

    #[test]
    fn fan_stage_processes_every_item_once() {
        let (tx, rx) = bounded::<u32>(4);
        let fan = FanStage::spawn(rx, 4, 4, "fan", |x: u32| x * 2);
        assert_eq!(fan.workers(), 4);
        let producer = std::thread::spawn(move || {
            for i in 0..500 {
                tx.send(i).unwrap();
            }
        });
        let mut got: Vec<u32> = fan.rx.iter().collect();
        producer.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..500).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn fan_stage_joins_cleanly_after_input_closes() {
        let (tx, rx) = bounded::<u32>(1);
        let fan = FanStage::spawn(rx, 2, 1, "fan", |x: u32| x);
        tx.send(1).unwrap();
        assert_eq!(fan.rx.recv().unwrap(), 1);
        drop(tx); // close the input so workers drain and exit
        fan.join();
    }

    #[test]
    fn staged_pipeline_flows_with_backpressure() {
        let (tx, rx) = bounded::<u32>(2);
        let stage1 = Stage::spawn(rx, 2, "double", |x: u32| x * 2);
        let stage2 = Stage::spawn(stage1.rx, 2, "inc", |x: u32| x + 1);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<u32> = stage2.rx.iter().collect();
        producer.join().unwrap();
        assert_eq!(got, (0..100).map(|i| i * 2 + 1).collect::<Vec<_>>());
    }
}
