//! Minimal declarative CLI parser (offline substitute for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! getters with defaults, and auto-generated usage text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: &'static str,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &'static str) -> Self {
        Cli { program: program.to_string(), about, ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, takes_value: true, default: Some(default.to_string()) });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, takes_value: true, default: None });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let mut line = format!("  --{}", spec.name);
            if spec.takes_value {
                line.push_str(" <value>");
            }
            let _ = write!(s, "{line:<32}{}", spec.help);
            if let Some(d) = &spec.default {
                let _ = write!(s, " [default: {d}]");
            }
            s.push('\n');
        }
        s
    }

    /// Parse the given args (exclusive of argv[0]).
    pub fn parse(mut self, args: &[String]) -> Result<Self> {
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("--{key} requires a value"))?
                            .clone(),
                    };
                    self.values.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        bail!("--{key} takes no value");
                    }
                    self.flags.push(key);
                }
            } else {
                self.positional.push(a.clone());
            }
        }
        // required options present?
        for spec in &self.specs {
            if spec.takes_value && spec.default.is_none() && !self.values.contains_key(spec.name) {
                bail!("missing required option --{}\n\n{}", spec.name, self.usage());
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name);
        raw.parse::<T>()
            .map_err(|e| anyhow!("invalid --{name} '{raw}': {e}"))
            .context("argument parsing")
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("eb", "1e-4", "error bound")
            .opt("threads", "0", "worker threads")
            .flag("verbose", "chatty")
            .req("input", "input path")
    }

    #[test]
    fn parses_values_flags_positional() {
        let c = cli()
            .parse(&args(&["--eb", "0.01", "--verbose", "--input=x.bin", "extra"]))
            .unwrap();
        assert_eq!(c.get("eb"), "0.01");
        assert_eq!(c.get("input"), "x.bin");
        assert!(c.has_flag("verbose"));
        assert_eq!(c.positional, vec!["extra"]);
        let eb: f64 = c.get_parsed("eb").unwrap();
        assert!((eb - 0.01).abs() < 1e-12);
    }

    #[test]
    fn default_applies() {
        let c = cli().parse(&args(&["--input", "y"])).unwrap();
        assert_eq!(c.get("threads"), "0");
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&args(&["--eb", "1"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&args(&["--nope", "--input", "y"])).is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let c = cli().parse(&args(&["--eb", "zzz", "--input", "y"])).unwrap();
        assert!(c.get_parsed::<f64>("eb").is_err());
    }
}
