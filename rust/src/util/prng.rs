//! Deterministic PRNG (splitmix64 core + xoshiro256++) used by datagen and
//! the property-testing kit. Deterministic seeding keeps every experiment
//! reproducible from the CLI seed.

/// splitmix64: seeds the main generator and is handy for hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// xoshiro256++ — fast, high-quality, no_std-style implementation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method would be overkill here.
        self.next_u64() % n.max(1)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (cached spare omitted for simplicity).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64().max(1e-300)) as f32;
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Log-normal with given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f32, sigma: f32) -> f32 {
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
