//! Process-wide memory governor: a byte budget with RAII reservations.
//!
//! The serve daemon admits work by *bytes*, not just job count: before a
//! request body is read off the socket, the connection thread sizes a
//! [`Reservation`] from the (already limit-checked) frame header and asks
//! the governor for it. A refusal becomes an up-front `BUSY` — the body
//! is drained and discarded, nothing is buffered — so a burst of large
//! requests degrades into sheds instead of an OOM kill. Accepted work is
//! never dropped: the reservation rides with the job and releases when
//! the job's memory actually dies.
//!
//! ## Admission rule
//!
//! `try_reserve(bytes)` grants iff the governor is **idle** (nothing
//! reserved) or the request fits: `reserved + bytes <= budget`. The idle
//! grant is the liveness escape hatch, and it is what "zero budget
//! degrades to a serial minimum" means: with `budget = 0` (or any budget
//! smaller than a single job) the governor still admits exactly one
//! reservation at a time instead of deadlocking or refusing everything.
//! Under load, admission is strict — an oversize request is refused up
//! front while smaller ones keep fitting into the remaining budget.
//!
//! The governor tracks its own accounting (`reserved_now`, `peak_bytes`,
//! `shed_count`); the daemon mirrors those into the `serve.mem.*`
//! registry counters at its admission points so the numbers land in the
//! standard `cusz-metrics/v1` snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Fraction of detected RAM used when no explicit budget is configured:
/// budget = MemTotal / `DEFAULT_RAM_FRACTION_DENOM`.
const DEFAULT_RAM_FRACTION_DENOM: u64 = 2;

/// Fallback budget when total RAM cannot be detected (non-Linux, or an
/// unreadable `/proc/meminfo`): 2 GiB, conservative for CI containers.
const FALLBACK_BUDGET: u64 = 2 << 30;

/// A process-wide byte budget with RAII reservations.
#[derive(Debug)]
pub struct MemoryGovernor {
    /// Budget in bytes. `u64::MAX` disables governing (everything fits).
    budget: u64,
    /// Currently reserved bytes, guarded for the condvar handshake.
    reserved: Mutex<u64>,
    /// Wakes blocked [`MemoryGovernor::reserve`] callers on release.
    released: Condvar,
    /// High-water mark of `reserved` (monotonic).
    peak: AtomicU64,
    /// Refused reservations (monotonic).
    shed: AtomicU64,
    /// Cumulative bytes ever granted (monotonic).
    granted: AtomicU64,
}

/// An admitted byte reservation; returns its bytes to the budget on drop.
#[derive(Debug)]
pub struct Reservation {
    gov: Arc<MemoryGovernor>,
    bytes: u64,
}

impl Reservation {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.gov.release(self.bytes);
    }
}

impl MemoryGovernor {
    /// A governor with an explicit byte budget. `0` is legal and means
    /// "one reservation at a time" (see the module docs).
    pub fn new(budget: u64) -> Arc<MemoryGovernor> {
        Arc::new(MemoryGovernor {
            budget,
            reserved: Mutex::new(0),
            released: Condvar::new(),
            peak: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            granted: AtomicU64::new(0),
        })
    }

    /// A governor that admits everything (accounting still runs).
    pub fn unbounded() -> Arc<MemoryGovernor> {
        MemoryGovernor::new(u64::MAX)
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Non-blocking admission: grant when idle or when the bytes fit,
    /// refuse otherwise. A refusal is counted in [`shed_count`].
    ///
    /// [`shed_count`]: MemoryGovernor::shed_count
    pub fn try_reserve(self: &Arc<Self>, bytes: u64) -> Option<Reservation> {
        let mut reserved = self.reserved.lock().unwrap_or_else(|p| p.into_inner());
        let fits = *reserved == 0 || reserved.checked_add(bytes).is_some_and(|t| t <= self.budget);
        if !fits {
            drop(reserved);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        *reserved += bytes;
        self.note_grant(*reserved, bytes);
        Some(Reservation { gov: Arc::clone(self), bytes })
    }

    /// Blocking admission: wait until the bytes fit (or the governor goes
    /// idle, the oversize escape hatch), then grant. Never sheds.
    pub fn reserve(self: &Arc<Self>, bytes: u64) -> Reservation {
        let mut reserved = self.reserved.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            let fits = *reserved == 0
                || reserved.checked_add(bytes).is_some_and(|t| t <= self.budget);
            if fits {
                *reserved += bytes;
                self.note_grant(*reserved, bytes);
                return Reservation { gov: Arc::clone(self), bytes };
            }
            reserved = self
                .released
                .wait(reserved)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    fn note_grant(&self, reserved_now: u64, bytes: u64) {
        self.granted.fetch_add(bytes, Ordering::Relaxed);
        self.peak.fetch_max(reserved_now, Ordering::Relaxed);
    }

    fn release(&self, bytes: u64) {
        let mut reserved = self.reserved.lock().unwrap_or_else(|p| p.into_inner());
        *reserved = reserved.saturating_sub(bytes);
        drop(reserved);
        self.released.notify_all();
    }

    /// Bytes currently reserved.
    pub fn reserved_now(&self) -> u64 {
        *self.reserved.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// High-water mark of concurrently reserved bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Reservations refused by `try_reserve`.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Cumulative bytes ever granted (monotonic; mirrors the
    /// `serve.mem.reserved` registry counter).
    pub fn granted_bytes(&self) -> u64 {
        self.granted.load(Ordering::Relaxed)
    }
}

/// The default budget when `--mem-budget` is not given: a fraction of
/// detected RAM (`/proc/meminfo` `MemTotal`), falling back to a fixed
/// conservative figure where detection is unavailable.
pub fn default_budget() -> u64 {
    detect_total_ram().unwrap_or(FALLBACK_BUDGET * DEFAULT_RAM_FRACTION_DENOM)
        / DEFAULT_RAM_FRACTION_DENOM
}

/// Total physical RAM in bytes, when detectable (Linux `/proc/meminfo`).
pub fn detect_total_ram() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/meminfo").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("MemTotal:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Parse a human byte figure: plain bytes, or a `k`/`m`/`g` suffix
/// (binary units). `"auto"`/`"0"` means the detected-RAM default,
/// `"unlimited"`/`"none"` disables governing.
pub fn parse_budget(s: &str) -> anyhow::Result<u64> {
    let s = s.trim().to_ascii_lowercase();
    match s.as_str() {
        "auto" | "0" => return Ok(default_budget()),
        "unlimited" | "none" => return Ok(u64::MAX),
        _ => {}
    }
    let (digits, mult) = match s.chars().last() {
        Some('k') => (&s[..s.len() - 1], 1u64 << 10),
        Some('m') => (&s[..s.len() - 1], 1u64 << 20),
        Some('g') => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s.as_str(), 1),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte figure '{s}' (use e.g. 512m, 2g, auto)"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte figure '{s}' overflows u64"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn reserve_release_accounting() {
        let gov = MemoryGovernor::new(1000);
        assert_eq!(gov.reserved_now(), 0);
        let a = gov.try_reserve(400).expect("fits");
        let b = gov.try_reserve(500).expect("fits");
        assert_eq!(gov.reserved_now(), 900);
        assert_eq!(gov.peak_bytes(), 900);
        assert_eq!(gov.granted_bytes(), 900);
        // 200 more would exceed the budget while loaded: shed
        assert!(gov.try_reserve(200).is_none());
        assert_eq!(gov.shed_count(), 1);
        drop(a);
        assert_eq!(gov.reserved_now(), 500);
        // now it fits
        let c = gov.try_reserve(200).expect("fits after release");
        assert_eq!(c.bytes(), 200);
        drop(b);
        drop(c);
        assert_eq!(gov.reserved_now(), 0);
        // peak is sticky
        assert_eq!(gov.peak_bytes(), 900);
    }

    #[test]
    fn idle_governor_grants_oversize() {
        let gov = MemoryGovernor::new(100);
        // oversize, but nothing is reserved: the serial-minimum grant
        let big = gov.try_reserve(1_000_000).expect("idle grant");
        // while it is held, everything else sheds
        assert!(gov.try_reserve(1).is_none());
        drop(big);
        assert!(gov.try_reserve(1).is_some());
    }

    #[test]
    fn concurrent_reservers_never_exceed_budget() {
        let budget = 10_000u64;
        let gov = MemoryGovernor::new(budget);
        let violated = AtomicBool::new(false);
        std::thread::scope(|s| {
            for t in 0..8 {
                let gov = &gov;
                let violated = &violated;
                s.spawn(move || {
                    for i in 0..200 {
                        let bytes = 500 + ((t * 37 + i * 13) % 1500) as u64;
                        if let Some(r) = gov.try_reserve(bytes) {
                            // invariant: while more than one reservation is
                            // live, the total must fit the budget (a single
                            // reservation may be an idle-grant oversize)
                            let now = gov.reserved_now();
                            if now > budget && now != r.bytes() {
                                violated.store(true, Ordering::Relaxed);
                            }
                            std::hint::black_box(&r);
                        }
                    }
                });
            }
        });
        assert!(!violated.load(Ordering::Relaxed), "budget exceeded under contention");
        assert_eq!(gov.reserved_now(), 0, "all reservations released");
        // peak may exceed budget only via a lone idle grant; with these
        // sizes (max 2000 <= budget) it must stay within budget
        assert!(gov.peak_bytes() <= budget, "peak {} > budget", gov.peak_bytes());
    }

    #[test]
    fn zero_budget_degrades_to_serial_not_deadlock() {
        let gov = MemoryGovernor::new(0);
        // blocking reservers take turns: all must complete
        let done = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let gov = Arc::clone(&gov);
                    s.spawn(move || {
                        for _ in 0..50 {
                            let r = gov.reserve(4096);
                            std::hint::black_box(&r);
                        }
                        true
                    })
                })
                .collect();
            handles.into_iter().all(|h| h.join().unwrap())
        });
        assert!(done);
        assert_eq!(gov.reserved_now(), 0);
        // and try_reserve still admits exactly one at a time
        let one = gov.try_reserve(10).expect("serial minimum");
        assert!(gov.try_reserve(1).is_none());
        drop(one);
    }

    #[test]
    fn unbounded_admits_everything_concurrently() {
        let gov = MemoryGovernor::unbounded();
        let a = gov.try_reserve(u64::MAX / 2).unwrap();
        let b = gov.try_reserve(u64::MAX / 4).unwrap();
        assert_eq!(gov.shed_count(), 0);
        drop(a);
        drop(b);
    }

    #[test]
    fn budget_parsing() {
        assert_eq!(parse_budget("1024").unwrap(), 1024);
        assert_eq!(parse_budget("16k").unwrap(), 16 << 10);
        assert_eq!(parse_budget("512M").unwrap(), 512 << 20);
        assert_eq!(parse_budget("2g").unwrap(), 2 << 30);
        assert_eq!(parse_budget("unlimited").unwrap(), u64::MAX);
        assert!(parse_budget("auto").unwrap() > 0);
        assert!(parse_budget("12q").is_err());
        assert!(parse_budget("").is_err());
    }

    #[test]
    fn default_budget_is_positive_fraction_of_ram() {
        let b = default_budget();
        assert!(b > 0);
        if let Some(total) = detect_total_ram() {
            assert!(b <= total);
        }
    }
}
