//! Micro-benchmark harness (offline substitute for criterion): warmup,
//! repeated timed runs, mean/σ/min, and GB/s throughput computed against
//! the *original* data size — matching the paper's footnote 4 ("all
//! throughputs ... measured based on the original data size and time").

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    /// Bytes of original data processed per rep (for GB/s).
    pub bytes: usize,
}

impl BenchResult {
    pub fn gbps(&self) -> f64 {
        if self.mean.as_nanos() == 0 {
            return f64::INFINITY;
        }
        self.bytes as f64 / self.mean.as_secs_f64() / 1e9
    }

    pub fn mbps(&self) -> f64 {
        self.gbps() * 1000.0
    }

    pub fn row(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms ±{:>7.3} ms  min {:>10.3} ms  {:>9.3} GB/s",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.stddev.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.gbps()
        )
    }
}

pub struct Bench {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, reps: 5 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, reps: 3 }
    }

    /// Time `f`, which processes `bytes` of original data per call.
    pub fn run<F: FnMut()>(&self, name: &str, bytes: usize, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        summarize(name, bytes, &samples)
    }
}

fn summarize(name: &str, bytes: usize, samples: &[Duration]) -> BenchResult {
    let n = samples.len().max(1) as f64;
    let mean_s = samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n;
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n;
    BenchResult {
        name: name.to_string(),
        reps: samples.len(),
        mean: Duration::from_secs_f64(mean_s),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: samples.iter().min().copied().unwrap_or_default(),
        bytes,
    }
}

/// Render a markdown-ish table, used by every bench binary so the output
/// lines up with the paper's tables.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        s
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            reps: 1,
            mean: Duration::from_millis(100),
            stddev: Duration::ZERO,
            min: Duration::from_millis(100),
            bytes: 1_000_000_000,
        };
        assert!((r.gbps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn run_collects_samples() {
        let b = Bench { warmup: 0, reps: 4 };
        let mut count = 0usize;
        let r = b.run("noop", 8, || count += 1);
        assert_eq!(count, 4);
        assert_eq!(r.reps, 4);
    }
}
