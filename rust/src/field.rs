//! `Field`: an n-dimensional f32 scientific variable (one SDRBench "field").

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    /// Logical dimensions, slowest-varying first (1 to 4 dims).
    pub dims: Vec<usize>,
    /// Row-major data, `len == dims.iter().product()`.
    pub data: Vec<f32>,
    /// Human-readable name, e.g. "CLOUDf48".
    pub name: String,
}

impl Field {
    pub fn new(name: impl Into<String>, dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if dims.is_empty() || dims.len() > 4 {
            bail!("field must have 1..=4 dims, got {}", dims.len());
        }
        if n != data.len() {
            bail!("dims {:?} imply {} elements, got {}", dims, n, data.len());
        }
        Ok(Field { dims, data, name: name.into() })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// (min, max) over finite values.
    pub fn value_range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        if lo > hi {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    /// Effective dimensionality for kernel selection: 4D fields fold their
    /// trailing two axes (QMCPACK einspline handling, DESIGN.md §3.4).
    pub fn kernel_dims(&self) -> Vec<usize> {
        if self.dims.len() == 4 {
            vec![self.dims[0], self.dims[1], self.dims[2] * self.dims[3]]
        } else {
            self.dims.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_mismatched_dims() {
        assert!(Field::new("x", vec![4, 4], vec![0.0; 15]).is_err());
        assert!(Field::new("x", vec![], vec![]).is_err());
        assert!(Field::new("x", vec![2, 2, 2, 2, 2], vec![0.0; 32]).is_err());
    }

    #[test]
    fn range_ignores_non_finite() {
        let f = Field::new("x", vec![4], vec![1.0, f32::NAN, -3.0, 2.0]).unwrap();
        assert_eq!(f.value_range(), (-3.0, 2.0));
    }

    #[test]
    fn four_d_folds_to_three() {
        let f = Field::new("q", vec![2, 3, 4, 5], vec![0.0; 120]).unwrap();
        assert_eq!(f.kernel_dims(), vec![2, 3, 20]);
    }
}
