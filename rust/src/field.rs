//! `Field`: an n-dimensional f32 scientific variable (one SDRBench "field").

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    /// Logical dimensions, slowest-varying first (1 to 4 dims).
    pub dims: Vec<usize>,
    /// Row-major data, `len == dims.iter().product()`.
    pub data: Vec<f32>,
    /// Human-readable name, e.g. "CLOUDf48".
    pub name: String,
}

impl Field {
    pub fn new(name: impl Into<String>, dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if dims.is_empty() || dims.len() > 4 {
            bail!("field must have 1..=4 dims, got {}", dims.len());
        }
        if n != data.len() {
            bail!("dims {:?} imply {} elements, got {}", dims, n, data.len());
        }
        Ok(Field { dims, data, name: name.into() })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// (min, max) over finite values.
    pub fn value_range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        if lo > hi {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    /// Effective dimensionality for kernel selection: 4D fields fold their
    /// trailing two axes (QMCPACK einspline handling, DESIGN.md §3.4).
    pub fn kernel_dims(&self) -> Vec<usize> {
        kernel_dims_of(&self.dims)
    }

    /// Stream this field's raw little-endian f32 bytes into `w` —
    /// see [`write_f32_into`].
    pub fn write_f32_into<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        write_f32_into(&self.data, w)
    }
}

/// [`Field::kernel_dims`] for a bare dims slice — used by the streaming
/// compress path, which never constructs a `Field`. The fold only merges
/// trailing axes, so row-major layout (and hence the raw byte stream) is
/// identical in logical and kernel space.
pub fn kernel_dims_of(dims: &[usize]) -> Vec<usize> {
    if dims.len() == 4 {
        vec![dims[0], dims[1], dims[2] * dims[3]]
    } else {
        dims.to_vec()
    }
}

/// Fill `out` from `r`'s little-endian f32 bytes through a bounded,
/// arena-loaned chunk buffer — the read-side mirror of
/// [`write_f32_into`], used by the streaming compress path to pull one
/// band of the field at a time off a file or socket without ever
/// materializing the whole field.
pub fn read_f32_into<R: std::io::Read>(r: &mut R, out: &mut [f32]) -> std::io::Result<()> {
    const CHUNK_VALUES: usize = 16 * 1024;
    crate::util::arena::with_u8(|buf| {
        for vals in out.chunks_mut(CHUNK_VALUES) {
            buf.clear();
            buf.resize(vals.len() * 4, 0);
            r.read_exact(buf)?;
            for (v, b) in vals.iter_mut().zip(buf.chunks_exact(4)) {
                *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
        }
        Ok(())
    })
}

/// Stream `data` as little-endian f32 bytes into `w` through a bounded,
/// arena-loaned chunk buffer — the decompressed-field output path for the
/// CLI and `store get --all`. The old path built the entire byte image
/// in memory first (a second full-field buffer next to the f32 data);
/// this one tops out at one ~64 KiB scratch buffer per thread, reused
/// across fields.
pub fn write_f32_into<W: std::io::Write>(data: &[f32], w: &mut W) -> std::io::Result<()> {
    const CHUNK_VALUES: usize = 16 * 1024;
    crate::util::arena::with_u8(|buf| {
        for vals in data.chunks(CHUNK_VALUES) {
            buf.clear();
            buf.reserve(vals.len() * 4);
            for v in vals {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            w.write_all(buf)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_mismatched_dims() {
        assert!(Field::new("x", vec![4, 4], vec![0.0; 15]).is_err());
        assert!(Field::new("x", vec![], vec![]).is_err());
        assert!(Field::new("x", vec![2, 2, 2, 2, 2], vec![0.0; 32]).is_err());
    }

    #[test]
    fn range_ignores_non_finite() {
        let f = Field::new("x", vec![4], vec![1.0, f32::NAN, -3.0, 2.0]).unwrap();
        assert_eq!(f.value_range(), (-3.0, 2.0));
    }

    #[test]
    fn four_d_folds_to_three() {
        let f = Field::new("q", vec![2, 3, 4, 5], vec![0.0; 120]).unwrap();
        assert_eq!(f.kernel_dims(), vec![2, 3, 20]);
    }

    #[test]
    fn streamed_f32_bytes_match_the_materialized_image() {
        // crosses the chunk boundary (16 Ki values) and covers specials
        let mut data: Vec<f32> = (0..40_000).map(|i| (i as f32).sin() * 1e3).collect();
        data[7] = f32::NAN;
        data[9] = f32::NEG_INFINITY;
        let mut streamed = Vec::new();
        write_f32_into(&data, &mut streamed).unwrap();
        let mut reference = Vec::with_capacity(data.len() * 4);
        for v in &data {
            reference.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(streamed, reference);
        // empty fields write nothing
        let mut empty = Vec::new();
        write_f32_into(&[], &mut empty).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn read_f32_into_mirrors_write() {
        let data: Vec<f32> = (0..40_000).map(|i| (i as f32).cos() * 5.0 - 1.0).collect();
        let mut bytes = Vec::new();
        write_f32_into(&data, &mut bytes).unwrap();
        let mut back = vec![0f32; data.len()];
        read_f32_into(&mut std::io::Cursor::new(&bytes), &mut back).unwrap();
        assert_eq!(back, data);
        // short input is an error, not silent truncation
        let mut short = std::io::Cursor::new(&bytes[..bytes.len() - 1]);
        assert!(read_f32_into(&mut short, &mut back).is_err());
    }
}
