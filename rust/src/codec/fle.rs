//! Fixed-length bitshuffle encoder (FZ-GPU style, arXiv:2304.12557) as the
//! second [`EncoderStage`] backend.
//!
//! Per chunk: quant codes are mapped to small unsigned magnitudes
//! (outlier marker 0 stays 0; everything else is zigzag of its distance
//! from the radius, shifted by one), the chunk's bit width `w` is the
//! width of the largest mapped value, and the values are emitted
//! bitplane-shuffled — for every group of 64 values, plane 0 of all 64,
//! then plane 1, … up to plane `w-1`. The shuffle groups same-significance
//! bits so the archive's lossless tail stage (gzip/zstd) sees long
//! near-constant runs where Huffman would have interleaved them.
//!
//! Ratio is `w` bits/symbol before the lossless stage (vs entropy for
//! Huffman), but the hot loop is branch-light, table-free, and touches
//! each set bit once — the throughput-first end of the encoder family.
//!
//! The sidecar is one byte per chunk: its bit width.

use anyhow::{bail, Result};

use super::{EncodeContext, EncodedSymbols, EncoderKind, EncoderStage, SymbolSource};
use crate::huffman::deflate::{DeflatedChunk, DeflatedStream};
use crate::util::bitio::{BitReader, BitWriter};

/// Hard ceiling on a chunk's bit width: the transform of any u16 symbol
/// at any radius fits 17 bits, so anything larger in a sidecar is corrupt.
pub const MAX_WIDTH: u32 = 17;

pub struct FleStage;

/// Outlier marker 0 maps to 0; code `s` maps to `zigzag(s - radius) + 1`
/// so codes near the radius (the common case after Lorenzo prediction)
/// become small magnitudes. Shared with the RLE backend and the cost
/// probe, which price the same transformed-magnitude domain.
#[inline]
pub(super) fn transform(s: u16, radius: i32) -> u32 {
    if s == 0 {
        0
    } else {
        zigzag(s as i32 - radius) + 1
    }
}

#[inline]
pub(super) fn untransform(v: u32, radius: i32, dict: usize) -> Result<u16> {
    if v == 0 {
        return Ok(0);
    }
    let s = unzigzag(v - 1) as i64 + radius as i64;
    // the nonzero path never produces symbol 0 (the marker has its own
    // encoding), so 0 here means a corrupt stream, not an outlier
    if s <= 0 || s >= dict as i64 {
        bail!("corrupt FLE stream: value {v} decodes outside dict {dict}");
    }
    Ok(s as u16)
}

#[inline]
fn zigzag(d: i32) -> u32 {
    ((d << 1) ^ (d >> 31)) as u32
}

#[inline]
fn unzigzag(v: u32) -> i32 {
    ((v >> 1) as i32) ^ -((v & 1) as i32)
}

/// Bit width FLE would need for a symbol distribution — the fixed-length
/// cost [`super::auto_select`] weighs against the entropy. 0 means only
/// outlier markers are present.
pub fn width_for_histogram(freq: &[u64]) -> u32 {
    let radius = (freq.len() / 2) as i32;
    let mut all = 0u32;
    for (s, &c) in freq.iter().enumerate() {
        if c > 0 {
            all |= transform(s as u16, radius);
        }
    }
    32 - all.leading_zeros()
}

/// In-place 64×64 bit-matrix transpose under the LSB-first convention
/// (bit `c` of word `r` ⇄ bit `r` of word `c`): the classic shift/mask
/// butterfly — 6 stages of 32 masked word swaps, no per-bit branches.
/// This is the word kernel both the bitplane scatter (encode) and gather
/// (decode) ride: one transpose moves 64 symbols' worth of bits per call.
#[inline]
fn transpose_64x64(m: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut mask = 0x0000_0000_FFFF_FFFFu64;
    loop {
        let mut k = 0usize;
        while k < 64 {
            let t = ((m[k] >> j) ^ m[k + j]) & mask;
            m[k] ^= t << j;
            m[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        if j == 0 {
            break;
        }
        mask ^= mask << j;
    }
}

/// Encode one chunk: each 64-symbol group is loaded as a 64×64 bit matrix
/// (row `i` = transformed value `i`) and transposed with the shift/mask
/// butterfly, so row `b` of the result *is* bitplane `b` — 64 symbols per
/// word op, no per-bit scatter branches. Planes `0..w` (`w` = width of
/// the OR of all values) are then written out group-major. Public within
/// the codec so mixed-granularity archives can tag individual chunks as
/// FLE.
pub(super) fn encode_chunk(symbols: &[u16], radius: i32) -> (u8, DeflatedChunk) {
    let n = symbols.len();
    let ngroups = n.div_ceil(64);
    let mut planes = vec![[0u64; MAX_WIDTH as usize]; ngroups];
    let mut all = 0u32;
    for (g, group) in symbols.chunks(64).enumerate() {
        let mut tile = [0u64; 64];
        for (row, &s) in tile.iter_mut().zip(group.iter()) {
            let v = transform(s, radius);
            all |= v;
            *row = v as u64;
        }
        // values fit MAX_WIDTH bits, so transposed rows >= MAX_WIDTH are
        // all zero and only the plane-sized prefix needs keeping
        transpose_64x64(&mut tile);
        planes[g].copy_from_slice(&tile[..MAX_WIDTH as usize]);
    }
    let w = 32 - all.leading_zeros();
    let mut writer = BitWriter::with_capacity_bits(n * w as usize);
    let mut rem = n;
    for p in &planes {
        let gl = rem.min(64) as u32;
        for plane in p.iter().take(w as usize) {
            writer.write(*plane, gl);
        }
        rem -= gl as usize;
    }
    let (words, bits) = writer.finish();
    debug_assert_eq!(bits, n as u64 * w as u64);
    (w as u8, DeflatedChunk { words, bits, symbols: n as u32 })
}

/// Decode one chunk straight into its destination window (a `SymbolSink`
/// slab slice or stitch buffer); the window length is authoritative and
/// the chunk's claimed symbol count must match it.
pub(super) fn decode_chunk_into(
    chunk: &DeflatedChunk,
    width: u8,
    radius: i32,
    dict: usize,
    out: &mut [u16],
) -> Result<()> {
    let n = out.len();
    if chunk.symbols as usize != n {
        bail!(
            "corrupt FLE chunk: claims {} symbols for a {n}-symbol window",
            chunk.symbols
        );
    }
    let w = width as u32;
    if w > MAX_WIDTH {
        bail!("corrupt FLE sidecar: width {w} exceeds {MAX_WIDTH}");
    }
    if chunk.bits != n as u64 * w as u64 {
        bail!(
            "corrupt FLE chunk: {} bits for {n} symbols at width {w}",
            chunk.bits
        );
    }
    if chunk.bits > chunk.words.len() as u64 * 64 {
        bail!("corrupt FLE chunk: {} bits in {} words", chunk.bits, chunk.words.len());
    }
    let mut r = BitReader::new(&chunk.words, chunk.bits);
    let mut done = 0usize;
    while done < n {
        let gl = (n - done).min(64) as u32;
        // gather via the same transpose kernel as encode: plane words load
        // as rows, one butterfly transpose turns row `i` back into value
        // `i` — no per-bit gather branches
        let mut tile = [0u64; 64];
        for row in tile.iter_mut().take(w as usize) {
            let Some(word) = r.read(gl) else {
                bail!("corrupt FLE chunk: truncated bitplanes");
            };
            *row = word;
        }
        transpose_64x64(&mut tile);
        for (slot, &v) in out[done..done + gl as usize].iter_mut().zip(tile.iter()) {
            *slot = untransform(v as u32, radius, dict)?;
        }
        done += gl as usize;
    }
    Ok(())
}

impl EncoderStage for FleStage {
    fn kind(&self) -> EncoderKind {
        EncoderKind::Fle
    }

    fn encode_source(
        &self,
        src: &SymbolSource<'_>,
        ctx: &EncodeContext,
    ) -> Result<EncodedSymbols> {
        let radius = (ctx.dict_size / 2) as i32;
        let cs = ctx.chunk_symbols.max(1);
        let encoded: Vec<(u8, DeflatedChunk)> =
            src.map_chunks(cs, ctx.threads, |_, chunk| encode_chunk(chunk, radius));
        let nchunks = encoded.len();
        let mut aux = Vec::with_capacity(nchunks);
        let mut chunks = Vec::with_capacity(nchunks);
        let mut max_w = 0u32;
        for (w, c) in encoded {
            aux.push(w);
            max_w = max_w.max(w as u32);
            chunks.push(c);
        }
        Ok(EncodedSymbols {
            aux,
            stream: DeflatedStream { chunks, chunk_symbols: cs },
            repr_bits: max_w.max(1),
            codebook_time: std::time::Duration::ZERO,
        })
    }

    fn decode_into(
        &self,
        aux: &[u8],
        stream: &DeflatedStream,
        dict_size: usize,
        threads: usize,
        sink: &mut crate::codec::SymbolSink<'_>,
    ) -> Result<()> {
        if aux.len() != stream.chunks.len() {
            bail!(
                "FLE sidecar has {} widths for {} chunks",
                aux.len(),
                stream.chunks.len()
            );
        }
        // width > 0 chunks are bounded by their backing words; zero-width
        // chunks carry no payload at all, but the sink's window partition
        // caps every claimed count against the expected total, so a tiny
        // crafted archive cannot claim terabytes of zero symbols
        let radius = (dict_size / 2) as i32;
        sink.fill_chunks(stream, threads, |ci, window| {
            decode_chunk_into(&stream.chunks[ci], aux[ci], radius, dict_size, window)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CodewordRepr;
    use crate::util::prng::Rng;

    fn ctx(freq: &[u64], chunk: usize, threads: usize) -> EncodeContext<'_> {
        EncodeContext {
            dict_size: freq.len(),
            chunk_symbols: chunk,
            threads,
            codeword_repr: CodewordRepr::Adaptive,
            freq,
        }
    }

    fn roundtrip(symbols: &[u16], dict: usize, chunk: usize) {
        let freq = vec![0u64; dict];
        let stage = FleStage;
        let enc = stage.encode(symbols, &ctx(&freq, chunk, 4)).unwrap();
        let out = stage.decode(&enc.aux, &enc.stream, dict, 4, symbols.len()).unwrap();
        assert_eq!(out, symbols);
    }

    /// The pre-kernel per-bit scatter loop, kept verbatim as the oracle
    /// the u64-word transpose kernel is locked against.
    fn encode_chunk_scalar(symbols: &[u16], radius: i32) -> (u8, DeflatedChunk) {
        let n = symbols.len();
        let ngroups = n.div_ceil(64);
        let mut planes = vec![[0u64; MAX_WIDTH as usize]; ngroups];
        let mut all = 0u32;
        for (g, group) in symbols.chunks(64).enumerate() {
            let p = &mut planes[g];
            for (i, &s) in group.iter().enumerate() {
                let mut v = transform(s, radius);
                all |= v;
                while v != 0 {
                    let b = v.trailing_zeros() as usize;
                    p[b] |= 1u64 << i;
                    v &= v - 1;
                }
            }
        }
        let w = 32 - all.leading_zeros();
        let mut writer = BitWriter::with_capacity_bits(n * w as usize);
        let mut rem = n;
        for p in &planes {
            let gl = rem.min(64) as u32;
            for plane in p.iter().take(w as usize) {
                writer.write(*plane, gl);
            }
            rem -= gl as usize;
        }
        let (words, bits) = writer.finish();
        (w as u8, DeflatedChunk { words, bits, symbols: n as u32 })
    }

    /// The pre-kernel per-bit gather loop, the decode oracle.
    fn decode_chunk_scalar(
        chunk: &DeflatedChunk,
        width: u8,
        radius: i32,
        dict: usize,
        out: &mut [u16],
    ) -> Result<()> {
        let n = out.len();
        let w = width as u32;
        let mut r = BitReader::new(&chunk.words, chunk.bits);
        let mut done = 0usize;
        while done < n {
            let gl = (n - done).min(64) as u32;
            let mut vals = [0u32; 64];
            for b in 0..w {
                let Some(mut word) = r.read(gl) else {
                    bail!("truncated");
                };
                while word != 0 {
                    let i = word.trailing_zeros() as usize;
                    vals[i] |= 1u32 << b;
                    word &= word - 1;
                }
            }
            for (slot, &v) in out[done..done + gl as usize].iter_mut().zip(vals.iter()) {
                *slot = untransform(v, radius, dict)?;
            }
            done += gl as usize;
        }
        Ok(())
    }

    #[test]
    fn word_kernel_matches_scalar_oracle_bit_for_bit() {
        let mut rng = Rng::new(61);
        let dict = 1024usize;
        let radius = (dict / 2) as i32;
        for n in [0usize, 1, 63, 64, 65, 127, 128, 4096, 10_001] {
            let symbols: Vec<u16> = (0..n)
                .map(|_| {
                    if rng.f32() < 0.05 {
                        0
                    } else {
                        ((rng.normal() * 40.0) as i32 + 512).clamp(1, dict as i32 - 1) as u16
                    }
                })
                .collect();
            let (w_k, c_k) = encode_chunk(&symbols, radius);
            let (w_s, c_s) = encode_chunk_scalar(&symbols, radius);
            assert_eq!(w_k, w_s, "n={n}");
            assert_eq!(c_k, c_s, "n={n}: kernel encode diverged from scalar oracle");
            let mut via_kernel = vec![0u16; n];
            let mut via_scalar = vec![0u16; n];
            decode_chunk_into(&c_k, w_k, radius, dict, &mut via_kernel).unwrap();
            decode_chunk_scalar(&c_k, w_k, radius, dict, &mut via_scalar).unwrap();
            assert_eq!(via_kernel, via_scalar, "n={n}");
            assert_eq!(via_kernel, symbols, "n={n}");
        }
    }

    #[test]
    fn transpose_is_an_involution_and_moves_single_bits() {
        let mut rng = Rng::new(77);
        let mut m = [0u64; 64];
        for w in m.iter_mut() {
            *w = rng.next_u64();
        }
        let orig = m;
        transpose_64x64(&mut m);
        for (r, row) in orig.iter().enumerate() {
            for c in 0..64usize {
                assert_eq!((row >> c) & 1, (m[c] >> r) & 1, "bit ({r},{c})");
            }
        }
        transpose_64x64(&mut m);
        assert_eq!(m, orig);
    }

    #[test]
    fn transform_is_bijective_over_the_dict() {
        for dict in [128usize, 1024, 65536] {
            let radius = (dict / 2) as i32;
            // spot-check the full structure: marker, center, extremes
            for s in [0u16, 1, (dict / 2) as u16, (dict / 2 + 1) as u16, (dict - 1) as u16] {
                let v = transform(s, radius);
                assert!(v < 1 << MAX_WIDTH, "dict {dict} sym {s} -> {v}");
                assert_eq!(untransform(v, radius, dict).unwrap(), s, "dict {dict}");
            }
        }
    }

    #[test]
    fn full_bijection_small_dict() {
        let dict = 512usize;
        let radius = (dict / 2) as i32;
        let mut seen = std::collections::HashSet::new();
        for s in 0..dict as u16 {
            let v = transform(s, radius);
            assert!(seen.insert(v), "collision at symbol {s}");
            assert_eq!(untransform(v, radius, dict).unwrap(), s);
        }
    }

    #[test]
    fn roundtrip_random_streams() {
        let mut rng = Rng::new(17);
        let dict = 1024usize;
        for n in [0usize, 1, 63, 64, 65, 1000, 4096, 10_001] {
            let symbols: Vec<u16> = (0..n)
                .map(|_| {
                    if rng.f32() < 0.05 {
                        0 // outlier marker
                    } else {
                        ((rng.normal() * 30.0) as i32 + 512).clamp(1, dict as i32 - 1) as u16
                    }
                })
                .collect();
            roundtrip(&symbols, dict, 4096);
            roundtrip(&symbols, dict, 100); // irregular tail chunks
        }
    }

    #[test]
    fn zero_width_chunks_for_all_marker_streams() {
        let symbols = vec![0u16; 5000];
        let freq = vec![0u64; 1024];
        let enc = FleStage.encode(&symbols, &ctx(&freq, 4096, 2)).unwrap();
        assert!(enc.aux.iter().all(|&w| w == 0));
        assert_eq!(enc.stream.total_bits(), 0);
        let out = FleStage.decode(&enc.aux, &enc.stream, 1024, 2, symbols.len()).unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn stream_is_fixed_width_per_chunk() {
        // codes in radius +/- 4 -> zigzag+1 max 9 -> width 4
        let symbols: Vec<u16> = (0..8192).map(|i| (512 + (i % 9) - 4) as u16).collect();
        let freq = vec![0u64; 1024];
        let enc = FleStage.encode(&symbols, &ctx(&freq, 4096, 1)).unwrap();
        for (c, &w) in enc.stream.chunks.iter().zip(&enc.aux) {
            assert_eq!(c.bits, c.symbols as u64 * w as u64);
            assert_eq!(w, 4);
        }
    }

    #[test]
    fn corrupt_sidecar_and_chunks_rejected() {
        let symbols: Vec<u16> = (0..2000).map(|i| (500 + i % 30) as u16).collect();
        let freq = vec![0u64; 1024];
        let enc = FleStage.encode(&symbols, &ctx(&freq, 512, 1)).unwrap();

        // sidecar length mismatch
        let mut short = enc.aux.clone();
        short.pop();
        assert!(FleStage.decode(&short, &enc.stream, 1024, 1, symbols.len()).is_err());

        // width beyond the ceiling
        let mut wide = enc.aux.clone();
        wide[0] = (MAX_WIDTH + 1) as u8;
        assert!(FleStage.decode(&wide, &enc.stream, 1024, 1, symbols.len()).is_err());

        // width inconsistent with the chunk's bit count
        let mut wrong = enc.aux.clone();
        wrong[0] += 1;
        assert!(FleStage.decode(&wrong, &enc.stream, 1024, 1, symbols.len()).is_err());

        // bit count exceeding the backing words
        let mut stream = enc.stream.clone();
        let extra_syms = stream.chunks[0].symbols as u64 + 64;
        stream.chunks[0].symbols += 64;
        stream.chunks[0].bits = extra_syms * enc.aux[0] as u64;
        assert!(FleStage.decode(&enc.aux, &stream, 1024, 1, usize::MAX).is_err());
    }

    #[test]
    fn parallel_encode_is_deterministic() {
        let mut rng = Rng::new(9);
        let symbols: Vec<u16> = (0..50_000)
            .map(|_| ((rng.normal() * 50.0) as i32 + 512).clamp(0, 1023) as u16)
            .collect();
        let freq = vec![0u64; 1024];
        let a = FleStage.encode(&symbols, &ctx(&freq, 2048, 1)).unwrap();
        let b = FleStage.encode(&symbols, &ctx(&freq, 2048, 8)).unwrap();
        assert_eq!(a.aux, b.aux);
        assert_eq!(a.stream, b.stream);
    }

    #[test]
    fn width_for_histogram_matches_encode() {
        let dict = 1024usize;
        let mut freq = vec![0u64; dict];
        for s in 500..525u16 {
            freq[s as usize] = 10;
        }
        let w = width_for_histogram(&freq);
        let symbols: Vec<u16> = (500..525).collect();
        let enc = FleStage.encode(&symbols, &ctx(&freq, 4096, 1)).unwrap();
        assert_eq!(enc.aux[0] as u32, w);
    }
}
