//! The chunked canonical-Huffman encoder as a pluggable [`EncoderStage`]
//! — the paper's §3.2 path (tree → canonical codebook → fused
//! encode+deflate), extracted from the old monolithic compressor. The
//! sidecar is the per-symbol code-length table; the decoder re-canonizes
//! (§3.2.3) so codewords never travel.

use std::time::Instant;

use anyhow::{bail, Result};

use super::{EncodeContext, EncodedSymbols, EncoderKind, EncoderStage, SymbolSource};
use crate::config::CodewordRepr;
use crate::huffman::deflate::{deflate_one, DeflatedStream};
use crate::huffman::{self, CanonicalCodebook, ReverseCodebook};

pub struct HuffmanStage;

impl EncoderStage for HuffmanStage {
    fn kind(&self) -> EncoderKind {
        EncoderKind::Huffman
    }

    fn encode_source(
        &self,
        src: &SymbolSource<'_>,
        ctx: &EncodeContext,
    ) -> Result<EncodedSymbols> {
        if ctx.freq.len() != ctx.dict_size {
            bail!(
                "histogram has {} bins for dict size {}",
                ctx.freq.len(),
                ctx.dict_size
            );
        }
        let t0 = Instant::now();
        let lengths = huffman::build_lengths(ctx.freq);
        let book = CanonicalCodebook::from_lengths(&lengths)?;
        let codebook_time = t0.elapsed();
        let repr_bits = match ctx.codeword_repr {
            CodewordRepr::U32 => 32,
            CodewordRepr::U64 => 64,
            CodewordRepr::Adaptive => book.repr_bits(),
        };
        let cs = ctx.chunk_symbols.max(1);
        let chunks = src.map_chunks(cs, ctx.threads, |_, chunk| deflate_one(chunk, &book));
        let stream = DeflatedStream { chunks, chunk_symbols: cs };
        Ok(EncodedSymbols { aux: lengths, stream, repr_bits, codebook_time })
    }

    fn decode_into(
        &self,
        aux: &[u8],
        stream: &crate::huffman::deflate::DeflatedStream,
        dict_size: usize,
        threads: usize,
        sink: &mut crate::codec::SymbolSink<'_>,
    ) -> Result<()> {
        if aux.len() > dict_size {
            bail!("codebook has {} lengths for dict size {dict_size}", aux.len());
        }
        let rev = ReverseCodebook::from_lengths(aux)?;
        sink.fill_chunks(stream, threads, |ci, window| {
            huffman::inflate::inflate_one_into_strict(&stream.chunks[ci], &rev, window)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_matches_direct_huffman_path() {
        let dict = 1024usize;
        let mut rng = Rng::new(5);
        let symbols: Vec<u16> = (0..60_000)
            .map(|_| ((rng.normal() * 12.0) as i32 + 512).clamp(0, dict as i32 - 1) as u16)
            .collect();
        let mut freq = vec![0u64; dict];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let ctx = EncodeContext {
            dict_size: dict,
            chunk_symbols: 4096,
            threads: 4,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        let stage = HuffmanStage;
        let enc = stage.encode(&symbols, &ctx).unwrap();
        // identical to calling the huffman substrate directly
        let lengths = huffman::build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let direct = huffman::deflate_chunks(&symbols, &book, 4096, 4);
        assert_eq!(enc.stream, direct);
        assert_eq!(enc.aux, lengths);
        let out = stage.decode(&enc.aux, &enc.stream, dict, 4, symbols.len()).unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn histogram_size_mismatch_rejected() {
        let freq = vec![1u64; 16];
        let ctx = EncodeContext {
            dict_size: 1024,
            chunk_symbols: 4096,
            threads: 1,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        assert!(HuffmanStage.encode(&[1, 2, 3], &ctx).is_err());
    }
}
