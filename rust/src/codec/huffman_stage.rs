//! The chunked canonical-Huffman encoder as a pluggable [`EncoderStage`]
//! — the paper's §3.2 path (tree → canonical codebook → fused
//! encode+deflate), extracted from the old monolithic compressor. The
//! sidecar is the per-symbol code-length table; the decoder re-canonizes
//! (§3.2.3) so codewords never travel.

use std::time::Instant;

use anyhow::{bail, Result};

use super::{EncodeContext, EncodedSymbols, EncoderKind, EncoderStage, SymbolSource};
use crate::config::CodewordRepr;
use crate::huffman::deflate::{deflate_one, deflate_one_gap, DeflatedStream, GapTable};
use crate::huffman::{self, CanonicalCodebook, ReverseCodebook};

pub struct HuffmanStage;

/// [`HuffmanStage::encode_source`] with gap-table recording: deflates
/// through [`deflate_one_gap`], so every chunk larger than the subchunk
/// granularity also yields its `(bit_offset, symbol_count)` index. The
/// bitstream is bit-identical to the plain path; only the sidecar table
/// is new. Telemetry is recorded here (this entry point bypasses the
/// `Instrumented` wrapper behind [`super::stage_for`]).
pub fn encode_source_with_gaps(
    src: &SymbolSource<'_>,
    ctx: &EncodeContext,
) -> Result<(EncodedSymbols, Vec<GapTable>)> {
    let t0 = Instant::now();
    let out = encode_source_gap_inner(src, ctx)?;
    super::record_codec_encode(
        EncoderKind::Huffman,
        src.len() as u64,
        (out.0.stream.payload_bytes() + out.0.aux.len()) as u64,
        t0.elapsed().as_nanos() as u64,
    );
    Ok(out)
}

fn encode_source_gap_inner(
    src: &SymbolSource<'_>,
    ctx: &EncodeContext,
) -> Result<(EncodedSymbols, Vec<GapTable>)> {
    if ctx.freq.len() != ctx.dict_size {
        bail!(
            "histogram has {} bins for dict size {}",
            ctx.freq.len(),
            ctx.dict_size
        );
    }
    let t0 = Instant::now();
    let lengths = huffman::build_lengths(ctx.freq);
    let book = CanonicalCodebook::from_lengths(&lengths)?;
    let codebook_time = t0.elapsed();
    let repr_bits = match ctx.codeword_repr {
        CodewordRepr::U32 => 32,
        CodewordRepr::U64 => 64,
        CodewordRepr::Adaptive => book.repr_bits(),
    };
    let cs = ctx.chunk_symbols.max(1);
    let parts = src.map_chunks(cs, ctx.threads, |_, chunk| deflate_one_gap(chunk, &book));
    let mut chunks = Vec::with_capacity(parts.len());
    let mut gaps = Vec::with_capacity(parts.len());
    for (c, g) in parts {
        chunks.push(c);
        gaps.push(g);
    }
    let stream = DeflatedStream { chunks, chunk_symbols: cs };
    Ok((EncodedSymbols { aux: lengths, stream, repr_bits, codebook_time }, gaps))
}

/// Gap-aware inverse of [`encode_source_with_gaps`]: chunks whose gap
/// table is non-empty decode subchunk-parallel through
/// [`huffman::inflate_one_gap_into_strict`] with the thread budget that
/// remains after the outer chunk fan-out, so a single large chunk still
/// saturates all cores. `gaps` comes from an untrusted archive — the gap
/// decoder validates every table before any subchunk decodes. Telemetry
/// is recorded here (this entry point bypasses the `Instrumented`
/// wrapper behind [`super::stage_for`]).
pub fn decode_into_gap(
    aux: &[u8],
    stream: &DeflatedStream,
    gaps: &[GapTable],
    dict_size: usize,
    threads: usize,
    sink: &mut crate::codec::SymbolSink<'_>,
) -> Result<()> {
    if aux.len() > dict_size {
        bail!("codebook has {} lengths for dict size {dict_size}", aux.len());
    }
    if !gaps.is_empty() && gaps.len() != stream.chunks.len() {
        bail!(
            "gap sidecar has {} tables for {} chunks",
            gaps.len(),
            stream.chunks.len()
        );
    }
    let t0 = Instant::now();
    let rev = ReverseCodebook::from_lengths(aux)?;
    // threads left per chunk once the outer fan-out has claimed its share:
    // a single-chunk stream hands the whole budget to the subchunk pass
    let inner = (threads / stream.chunks.len().max(1)).max(1);
    sink.fill_chunks(stream, threads, |ci, window| {
        let table = gaps.get(ci).map(|g| g.as_slice()).unwrap_or(&[]);
        huffman::inflate_one_gap_into_strict(&stream.chunks[ci], table, &rev, window, inner)
    })?;
    super::record_codec_decode(
        EncoderKind::Huffman,
        stream.total_symbols(),
        (stream.payload_bytes() + aux.len()) as u64,
        t0.elapsed().as_nanos() as u64,
    );
    Ok(())
}

impl EncoderStage for HuffmanStage {
    fn kind(&self) -> EncoderKind {
        EncoderKind::Huffman
    }

    fn encode_source(
        &self,
        src: &SymbolSource<'_>,
        ctx: &EncodeContext,
    ) -> Result<EncodedSymbols> {
        if ctx.freq.len() != ctx.dict_size {
            bail!(
                "histogram has {} bins for dict size {}",
                ctx.freq.len(),
                ctx.dict_size
            );
        }
        let t0 = Instant::now();
        let lengths = huffman::build_lengths(ctx.freq);
        let book = CanonicalCodebook::from_lengths(&lengths)?;
        let codebook_time = t0.elapsed();
        let repr_bits = match ctx.codeword_repr {
            CodewordRepr::U32 => 32,
            CodewordRepr::U64 => 64,
            CodewordRepr::Adaptive => book.repr_bits(),
        };
        let cs = ctx.chunk_symbols.max(1);
        let chunks = src.map_chunks(cs, ctx.threads, |_, chunk| deflate_one(chunk, &book));
        let stream = DeflatedStream { chunks, chunk_symbols: cs };
        Ok(EncodedSymbols { aux: lengths, stream, repr_bits, codebook_time })
    }

    fn decode_into(
        &self,
        aux: &[u8],
        stream: &crate::huffman::deflate::DeflatedStream,
        dict_size: usize,
        threads: usize,
        sink: &mut crate::codec::SymbolSink<'_>,
    ) -> Result<()> {
        if aux.len() > dict_size {
            bail!("codebook has {} lengths for dict size {dict_size}", aux.len());
        }
        let rev = ReverseCodebook::from_lengths(aux)?;
        sink.fill_chunks(stream, threads, |ci, window| {
            huffman::inflate::inflate_one_into_strict(&stream.chunks[ci], &rev, window)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_matches_direct_huffman_path() {
        let dict = 1024usize;
        let mut rng = Rng::new(5);
        let symbols: Vec<u16> = (0..60_000)
            .map(|_| ((rng.normal() * 12.0) as i32 + 512).clamp(0, dict as i32 - 1) as u16)
            .collect();
        let mut freq = vec![0u64; dict];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let ctx = EncodeContext {
            dict_size: dict,
            chunk_symbols: 4096,
            threads: 4,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        let stage = HuffmanStage;
        let enc = stage.encode(&symbols, &ctx).unwrap();
        // identical to calling the huffman substrate directly
        let lengths = huffman::build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let direct = huffman::deflate_chunks(&symbols, &book, 4096, 4);
        assert_eq!(enc.stream, direct);
        assert_eq!(enc.aux, lengths);
        let out = stage.decode(&enc.aux, &enc.stream, dict, 4, symbols.len()).unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn gap_encode_matches_plain_and_decodes_parallel() {
        let dict = 1024usize;
        let mut rng = Rng::new(17);
        // one chunk spanning several subchunks: the single-large-chunk
        // decode shape the gap path exists for
        let n = crate::huffman::GAP_SUBCHUNK * 5 + 321;
        let symbols: Vec<u16> = (0..n)
            .map(|_| ((rng.normal() * 12.0) as i32 + 512).clamp(0, dict as i32 - 1) as u16)
            .collect();
        let mut freq = vec![0u64; dict];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let ctx = EncodeContext {
            dict_size: dict,
            chunk_symbols: n,
            threads: 4,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        let src = crate::codec::SymbolSource::from_slice(&symbols);
        let (enc, gaps) = encode_source_with_gaps(&src, &ctx).unwrap();
        let plain = HuffmanStage.encode_source(&src, &ctx).unwrap();
        assert_eq!(enc.stream, plain.stream, "gap recording changed the bitstream");
        assert_eq!(enc.aux, plain.aux);
        assert_eq!(gaps.len(), 1);
        assert_eq!(gaps[0].len(), n.div_ceil(crate::huffman::GAP_SUBCHUNK));
        for threads in [1usize, 2, 8] {
            let mut out = vec![0u16; n];
            decode_into_gap(
                &enc.aux,
                &enc.stream,
                &gaps,
                dict,
                threads,
                &mut crate::codec::SymbolSink::from_slice(&mut out),
            )
            .unwrap();
            assert_eq!(out, symbols, "threads={threads}");
        }
        // an empty gap list falls back to the serial per-chunk decode
        let mut out = vec![0u16; n];
        decode_into_gap(
            &enc.aux,
            &enc.stream,
            &[],
            dict,
            4,
            &mut crate::codec::SymbolSink::from_slice(&mut out),
        )
        .unwrap();
        assert_eq!(out, symbols);
        // a gap list of the wrong cardinality is rejected
        let mut out = vec![0u16; n];
        assert!(decode_into_gap(
            &enc.aux,
            &enc.stream,
            &[gaps[0].clone(), gaps[0].clone()],
            dict,
            4,
            &mut crate::codec::SymbolSink::from_slice(&mut out),
        )
        .is_err());
    }

    #[test]
    fn histogram_size_mismatch_rejected() {
        let freq = vec![1u64; 16];
        let ctx = EncodeContext {
            dict_size: 1024,
            chunk_symbols: 4096,
            threads: 1,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        assert!(HuffmanStage.encode(&[1, 2, 3], &ctx).is_err());
    }
}
