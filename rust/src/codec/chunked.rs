//! Mixed-granularity encoding: one archive, one encoder tag *per chunk*.
//!
//! Per-field selection (PR 2) loses whenever a field mixes smoothness
//! regimes — any single backend is wrong for part of the stream. Here the
//! compressor probes every chunk ([`cost::probe_chunk`]), picks the
//! backend with the smallest measured encoded size, and records the
//! choice in a per-chunk tag table that travels in the `CUSZA3` body.
//! Huffman-tagged chunks share the one field-level codebook (the
//! `shared_aux` length table); FLE/RLE chunks carry their tiny per-chunk
//! sidecar records.
//!
//! Decoding is self-describing: the tag table picks the stage per chunk,
//! so a mixed archive decodes on any coordinator regardless of its
//! configured codec.

use std::time::Instant;

use anyhow::{bail, Result};

use super::cost::{self, CostModel};
use super::{fle, rle, EncodeContext, EncoderKind, SymbolSource};
use crate::huffman::{self, CanonicalCodebook, ReverseCodebook};
use crate::huffman::deflate::{DeflatedChunk, DeflatedStream, GapTable};

/// Output of a per-chunk encode: the tag table plus everything each tag's
/// decoder needs.
pub struct ChunkedEncoded {
    /// One [`EncoderKind`] tag byte per chunk.
    pub tags: Vec<u8>,
    /// Field-level sidecar shared by every Huffman-tagged chunk (the
    /// code-length table); empty when no chunk picked Huffman.
    pub shared_aux: Vec<u8>,
    /// Per-chunk sidecar records (FLE: `[w]`; RLE: `[w, r]`; Huffman:
    /// empty — it uses `shared_aux`).
    pub chunk_aux: Vec<Vec<u8>>,
    pub stream: DeflatedStream,
    /// Per-chunk Huffman gap tables (subchunk bit-offset index for the
    /// parallel decode path); empty inner vecs for FLE/RLE chunks and for
    /// Huffman chunks below the subchunk granularity.
    pub gaps: Vec<GapTable>,
    /// Chunk tally per backend, indexed by [`EncoderKind::to_tag`] — the
    /// `CompressStats` / `ServiceStats` adaptive-selection report.
    pub counts: [usize; EncoderKind::ALL.len()],
    pub repr_bits: u32,
    pub codebook_time: std::time::Duration,
}

/// Encode a symbol stream choosing the cheapest backend per chunk.
/// Chunk windows are pulled straight out of the per-slab source (stitch
/// buffers loaned from the thread-local arena when a window straddles a
/// slab boundary) — no field-wide flatten.
pub fn encode_chunked(
    src: &SymbolSource<'_>,
    ctx: &EncodeContext,
    model: &CostModel,
) -> Result<ChunkedEncoded> {
    encode_chunked_within(src, ctx, model, [true; 3])
}

/// [`encode_chunked`] with the per-chunk argmin restricted to the
/// backends `allowed` leaves open (indexed by `EncoderKind::to_tag`) —
/// the `--target-gbps` pruning hook. At least one entry must be true.
pub fn encode_chunked_within(
    src: &SymbolSource<'_>,
    ctx: &EncodeContext,
    model: &CostModel,
    allowed: [bool; 3],
) -> Result<ChunkedEncoded> {
    if ctx.freq.len() != ctx.dict_size {
        bail!(
            "histogram has {} bins for dict size {}",
            ctx.freq.len(),
            ctx.dict_size
        );
    }
    // the field codebook is built unconditionally: the probe needs its
    // length table to price Huffman even if no chunk ends up picking it
    let t0 = Instant::now();
    let lengths = huffman::build_lengths(ctx.freq);
    let book = CanonicalCodebook::from_lengths(&lengths)?;
    let codebook_time = t0.elapsed();

    let radius = (ctx.dict_size / 2) as i32;
    let cs = ctx.chunk_symbols.max(1);
    let parts: Vec<(EncoderKind, Vec<u8>, DeflatedChunk, GapTable)> =
        src.map_chunks(cs, ctx.threads, |_, chunk| {
            let probe = cost::probe_chunk(chunk, &lengths, radius);
            let kind = model.select_chunk_within(&probe, allowed);
            // per-chunk telemetry: one Instant pair + three static-key
            // counter bumps against microseconds of encode work
            let t0 = Instant::now();
            let (aux, c, gaps) = match kind {
                EncoderKind::Huffman => {
                    let (c, gaps) = huffman::deflate_one_gap(chunk, &book);
                    (Vec::new(), c, gaps)
                }
                EncoderKind::Fle => {
                    let (w, c) = fle::encode_chunk(chunk, radius);
                    (vec![w], c, GapTable::new())
                }
                EncoderKind::Rle => {
                    let (rec, c) = rle::encode_chunk(chunk, radius);
                    (rec.to_vec(), c, GapTable::new())
                }
            };
            super::record_codec_encode(
                kind,
                chunk.len() as u64,
                (c.words.len() * 8 + aux.len()) as u64,
                t0.elapsed().as_nanos() as u64,
            );
            (kind, aux, c, gaps)
        });

    let nchunks = parts.len();
    let mut tags = Vec::with_capacity(nchunks);
    let mut chunk_aux = Vec::with_capacity(nchunks);
    let mut chunks = Vec::with_capacity(nchunks);
    let mut gaps = Vec::with_capacity(nchunks);
    let mut counts = [0usize; EncoderKind::ALL.len()];
    let mut max_w = 0u32;
    for (kind, aux, c, g) in parts {
        counts[kind.to_tag() as usize] += 1;
        if kind != EncoderKind::Huffman {
            max_w = max_w.max(aux.iter().map(|&b| b as u32).sum());
        }
        tags.push(kind.to_tag());
        chunk_aux.push(aux);
        chunks.push(c);
        gaps.push(g);
    }
    let any_huffman = counts[EncoderKind::Huffman.to_tag() as usize] > 0;
    let repr_bits = if any_huffman { book.repr_bits() } else { max_w.max(1) };
    Ok(ChunkedEncoded {
        tags,
        shared_aux: if any_huffman { lengths } else { Vec::new() },
        chunk_aux,
        stream: DeflatedStream { chunks, chunk_symbols: cs },
        gaps,
        counts,
        repr_bits,
        codebook_time,
    })
}

/// Decode a mixed archive's symbol stream straight into `sink`'s per-slab
/// destination windows — the zero-copy decompress path. All inputs are
/// untrusted: tag/sidecar/stream inconsistencies must error (never
/// panic), and the sink's window partition rejects any claimed symbol
/// count that disagrees with the expected total before a chunk decodes.
pub fn decode_chunked_into(
    tags: &[u8],
    shared_aux: &[u8],
    chunk_aux: &[Vec<u8>],
    stream: &DeflatedStream,
    dict_size: usize,
    threads: usize,
    sink: &mut super::SymbolSink<'_>,
) -> Result<()> {
    decode_chunked_into_with_gaps(tags, shared_aux, chunk_aux, stream, &[], dict_size, threads, sink)
}

/// [`decode_chunked_into`] with per-chunk Huffman gap tables: a
/// Huffman-tagged chunk whose table is non-empty decodes
/// subchunk-parallel with the thread budget left over after the outer
/// chunk fan-out. `gaps` is untrusted (it travels in the archive body) —
/// empty means no gap content, otherwise one table per chunk, each
/// validated by the gap decoder before any subchunk decodes.
#[allow(clippy::too_many_arguments)]
pub fn decode_chunked_into_with_gaps(
    tags: &[u8],
    shared_aux: &[u8],
    chunk_aux: &[Vec<u8>],
    stream: &DeflatedStream,
    gaps: &[GapTable],
    dict_size: usize,
    threads: usize,
    sink: &mut super::SymbolSink<'_>,
) -> Result<()> {
    if !gaps.is_empty() && gaps.len() != stream.chunks.len() {
        bail!(
            "gap sidecar has {} tables for {} chunks",
            gaps.len(),
            stream.chunks.len()
        );
    }
    if tags.len() != stream.chunks.len() {
        bail!(
            "chunk tag table has {} tags for {} chunks",
            tags.len(),
            stream.chunks.len()
        );
    }
    if chunk_aux.len() != stream.chunks.len() {
        bail!(
            "per-chunk sidecar has {} records for {} chunks",
            chunk_aux.len(),
            stream.chunks.len()
        );
    }
    let kinds: Vec<EncoderKind> = tags
        .iter()
        .map(|&t| EncoderKind::from_tag(t))
        .collect::<Result<_>>()?;
    let rev = if kinds.contains(&EncoderKind::Huffman) {
        if shared_aux.len() > dict_size {
            bail!(
                "shared codebook has {} lengths for dict size {dict_size}",
                shared_aux.len()
            );
        }
        Some(ReverseCodebook::from_lengths(shared_aux)?)
    } else {
        None
    };
    let radius = (dict_size / 2) as i32;
    let cs = stream.chunk_symbols.max(1);
    // subchunk budget per gap-tabled Huffman chunk once the outer chunk
    // fan-out has claimed its share of the workers
    let inner = (threads / stream.chunks.len().max(1)).max(1);
    sink.fill_chunks(stream, threads, |ci, window| {
        let chunk = &stream.chunks[ci];
        // per-chunk symbol counts are untrusted too: bound by the chunk
        // geometry on top of the sink's total-count partition
        if chunk.symbols as usize > cs {
            bail!(
                "corrupt chunk {ci}: {} symbols exceeds chunk geometry {cs}",
                chunk.symbols
            );
        }
        let kind = kinds[ci];
        let t0 = Instant::now();
        let result = match kind {
            EncoderKind::Huffman => {
                if !chunk_aux[ci].is_empty() {
                    bail!(
                        "corrupt chunk {ci}: huffman-tagged chunk carries a {}-byte sidecar",
                        chunk_aux[ci].len()
                    );
                }
                let table = gaps.get(ci).map(|g| g.as_slice()).unwrap_or(&[]);
                huffman::inflate_one_gap_into_strict(
                    chunk,
                    table,
                    rev.as_ref().expect("rev built"),
                    window,
                    inner,
                )
            }
            EncoderKind::Fle => {
                let &[w] = chunk_aux[ci].as_slice() else {
                    bail!(
                        "corrupt chunk {ci}: FLE sidecar record has {} bytes, want 1",
                        chunk_aux[ci].len()
                    );
                };
                fle::decode_chunk_into(chunk, w, radius, dict_size, window)
            }
            EncoderKind::Rle => {
                rle::decode_chunk_into(chunk, &chunk_aux[ci], radius, dict_size, window)
            }
        };
        if result.is_ok() {
            super::record_codec_decode(
                kind,
                chunk.symbols as u64,
                (chunk.words.len() * 8 + chunk_aux[ci].len()) as u64,
                t0.elapsed().as_nanos() as u64,
            );
        }
        result
    })
}

/// Materializing adapter over [`decode_chunked_into`] (tests, benches,
/// the pre-fusion baseline): rejects a claimed symbol total beyond
/// `max_symbols` before allocating, and counts against the
/// [`super::symbol_buffer_materializations`] probe.
pub fn decode_chunked(
    tags: &[u8],
    shared_aux: &[u8],
    chunk_aux: &[Vec<u8>],
    stream: &DeflatedStream,
    dict_size: usize,
    threads: usize,
    max_symbols: usize,
) -> Result<Vec<u16>> {
    if stream.total_symbols() > max_symbols as u64 {
        bail!(
            "chunked stream claims {} symbols, caller expects at most {max_symbols}",
            stream.total_symbols()
        );
    }
    super::note_symbol_materialization();
    let mut out = vec![0u16; stream.total_symbols() as usize];
    decode_chunked_into(
        tags,
        shared_aux,
        chunk_aux,
        stream,
        dict_size,
        threads,
        &mut super::SymbolSink::from_slice(&mut out),
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CodewordRepr;
    use crate::util::prng::Rng;

    /// A field that mixes smoothness regimes chunk by chunk: constant
    /// segments (RLE territory), near-radius gaussian segments (Huffman),
    /// and wide uniform segments (FLE).
    fn mixed_symbols(n_chunks: usize, cs: usize, seed: u64) -> Vec<u16> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(n_chunks * cs);
        for c in 0..n_chunks {
            for _ in 0..cs {
                let s = match c % 3 {
                    0 => 512,
                    1 => ((rng.normal() * 4.0) as i32 + 512).clamp(1, 1023) as u16,
                    _ => (384 + rng.below(257)) as u16,
                };
                out.push(s);
            }
        }
        out
    }

    fn ctx<'a>(freq: &'a [u64], cs: usize) -> EncodeContext<'a> {
        EncodeContext {
            dict_size: freq.len(),
            chunk_symbols: cs,
            threads: 4,
            codeword_repr: CodewordRepr::Adaptive,
            freq,
        }
    }

    fn encode_mixed(cs: usize, seed: u64) -> (Vec<u16>, ChunkedEncoded) {
        let symbols = mixed_symbols(9, cs, seed);
        let mut freq = vec![0u64; 1024];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let enc = encode_chunked(
            &SymbolSource::from_slice(&symbols),
            &ctx(&freq, cs),
            &CostModel::MEASURED,
        )
        .unwrap();
        (symbols, enc)
    }

    #[test]
    fn mixed_field_uses_multiple_backends_and_roundtrips() {
        let (symbols, enc) = encode_mixed(2048, 1);
        // all three regimes are represented, so all three backends fire
        let used = enc.counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 2, "counts {:?}", enc.counts);
        assert_eq!(enc.counts.iter().sum::<usize>(), 9);
        assert_eq!(enc.tags.len(), 9);
        let out = decode_chunked(
            &enc.tags,
            &enc.shared_aux,
            &enc.chunk_aux,
            &enc.stream,
            1024,
            4,
            symbols.len(),
        )
        .unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn per_chunk_beats_every_uniform_backend_on_mixed_fields() {
        use super::super::{stage_for, EncoderKind};
        let (symbols, enc) = encode_mixed(2048, 2);
        let mut freq = vec![0u64; 1024];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let mixed_bytes = enc.stream.payload_bytes()
            + enc.shared_aux.len()
            + enc.chunk_aux.iter().map(|a| a.len()).sum::<usize>()
            + enc.tags.len();
        for kind in EncoderKind::ALL {
            let uni = stage_for(kind).encode(&symbols, &ctx(&freq, 2048)).unwrap();
            let uni_bytes = uni.stream.payload_bytes() + uni.aux.len();
            assert!(
                mixed_bytes <= uni_bytes + enc.tags.len() + enc.shared_aux.len(),
                "{}: mixed {mixed_bytes} vs uniform {uni_bytes}",
                kind.name()
            );
        }
    }

    #[test]
    fn gap_tables_cover_huffman_chunks_and_decode_parallel() {
        // chunks larger than the subchunk granularity, so Huffman-tagged
        // chunks record real gap tables
        let cs = crate::huffman::GAP_SUBCHUNK + 1500;
        let symbols = mixed_symbols(9, cs, 7);
        let mut freq = vec![0u64; 1024];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let enc = encode_chunked(
            &SymbolSource::from_slice(&symbols),
            &ctx(&freq, cs),
            &CostModel::MEASURED,
        )
        .unwrap();
        assert_eq!(enc.gaps.len(), enc.tags.len());
        let huffman_tag = EncoderKind::Huffman.to_tag();
        for (ci, tag) in enc.tags.iter().enumerate() {
            if *tag == huffman_tag {
                assert!(!enc.gaps[ci].is_empty(), "chunk {ci}: huffman chunk lost its table");
            } else {
                assert!(enc.gaps[ci].is_empty(), "chunk {ci}: non-huffman chunk has a table");
            }
        }
        assert!(enc.counts[huffman_tag as usize] > 0, "no huffman chunk in the mix");
        for threads in [1usize, 4, 16] {
            let mut out = vec![0u16; symbols.len()];
            decode_chunked_into_with_gaps(
                &enc.tags,
                &enc.shared_aux,
                &enc.chunk_aux,
                &enc.stream,
                &enc.gaps,
                1024,
                threads,
                &mut super::super::SymbolSink::from_slice(&mut out),
            )
            .unwrap();
            assert_eq!(out, symbols, "threads={threads}");
        }
        // gap-less decode of the same stream agrees (serial fallback)
        let out = decode_chunked(
            &enc.tags,
            &enc.shared_aux,
            &enc.chunk_aux,
            &enc.stream,
            1024,
            2,
            symbols.len(),
        )
        .unwrap();
        assert_eq!(out, symbols);
        // wrong-cardinality gap sidecar is rejected
        let mut out = vec![0u16; symbols.len()];
        assert!(decode_chunked_into_with_gaps(
            &enc.tags,
            &enc.shared_aux,
            &enc.chunk_aux,
            &enc.stream,
            &enc.gaps[..enc.gaps.len() - 1],
            1024,
            2,
            &mut super::super::SymbolSink::from_slice(&mut out),
        )
        .is_err());
    }

    #[test]
    fn encode_is_deterministic_across_thread_counts() {
        let symbols = mixed_symbols(6, 1000, 3);
        let mut freq = vec![0u64; 1024];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let mut c1 = ctx(&freq, 1000);
        c1.threads = 1;
        let mut c8 = ctx(&freq, 1000);
        c8.threads = 8;
        let src = SymbolSource::from_slice(&symbols);
        let a = encode_chunked(&src, &c1, &CostModel::MEASURED).unwrap();
        let b = encode_chunked(&src, &c8, &CostModel::MEASURED).unwrap();
        assert_eq!(a.tags, b.tags);
        assert_eq!(a.chunk_aux, b.chunk_aux);
        assert_eq!(a.stream, b.stream);
    }

    /// The zero-copy multi-slab source must encode byte-identically to
    /// the old flatten-then-encode path, including when chunk windows
    /// straddle slab boundaries (chunk size not dividing the slab len).
    #[test]
    fn slab_source_matches_flattened_encode() {
        let symbols = mixed_symbols(9, 1500, 11); // 13_500 symbols
        let slab_len = 2700;
        let slabs: Vec<&[u16]> = symbols.chunks(slab_len).collect();
        let src = SymbolSource::from_slabs(slabs, slab_len).unwrap();
        let mut freq = vec![0u64; 1024];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        // chunk 1000 straddles every slab boundary; threads > 1 exercises
        // the arena stitch buffers across workers
        let c = ctx(&freq, 1000);
        let from_slabs = encode_chunked(&src, &c, &CostModel::MEASURED).unwrap();
        let flat = encode_chunked(
            &SymbolSource::from_slice(&symbols),
            &c,
            &CostModel::MEASURED,
        )
        .unwrap();
        assert_eq!(from_slabs.tags, flat.tags);
        assert_eq!(from_slabs.chunk_aux, flat.chunk_aux);
        assert_eq!(from_slabs.stream, flat.stream);
        assert_eq!(from_slabs.shared_aux, flat.shared_aux);
        let out = decode_chunked(
            &from_slabs.tags,
            &from_slabs.shared_aux,
            &from_slabs.chunk_aux,
            &from_slabs.stream,
            1024,
            4,
            symbols.len(),
        )
        .unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn corrupt_tag_table_and_sidecars_rejected() {
        let (symbols, enc) = encode_mixed(1024, 4);
        let n = symbols.len();
        let ok = |tags: &[u8], shared: &[u8], aux: &[Vec<u8>], stream: &DeflatedStream| {
            decode_chunked(tags, shared, aux, stream, 1024, 2, n)
        };
        assert!(ok(&enc.tags, &enc.shared_aux, &enc.chunk_aux, &enc.stream).is_ok());

        // truncated tag table
        assert!(ok(&enc.tags[..enc.tags.len() - 1], &enc.shared_aux, &enc.chunk_aux, &enc.stream)
            .is_err());
        // unknown tag value
        let mut tags = enc.tags.clone();
        tags[0] = 99;
        assert!(ok(&tags, &enc.shared_aux, &enc.chunk_aux, &enc.stream).is_err());
        // swapped tag (decode a chunk with the wrong backend)
        let (hi, lo) = (EncoderKind::Huffman.to_tag(), EncoderKind::Rle.to_tag());
        if let (Some(h), Some(r)) = (
            enc.tags.iter().position(|&t| t == hi),
            enc.tags.iter().position(|&t| t == lo),
        ) {
            let mut tags = enc.tags.clone();
            tags.swap(h, r);
            assert!(ok(&tags, &enc.shared_aux, &enc.chunk_aux, &enc.stream).is_err());
        }
        // truncated per-chunk sidecar list
        assert!(ok(
            &enc.tags,
            &enc.shared_aux,
            &enc.chunk_aux[..enc.chunk_aux.len() - 1],
            &enc.stream
        )
        .is_err());
        // oversized shared codebook
        let big = vec![1u8; 4096];
        assert!(ok(&enc.tags, &big, &enc.chunk_aux, &enc.stream).is_err());
        // symbol-count inflation must fail before allocating
        let mut stream = enc.stream.clone();
        stream.chunks[0].symbols = u32::MAX;
        assert!(ok(&enc.tags, &enc.shared_aux, &enc.chunk_aux, &stream).is_err());
    }
}
