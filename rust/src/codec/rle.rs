//! Run-length encoder over radius-centered quant codes — the third
//! [`EncoderStage`] backend, for the zero/constant-dominated fields of
//! Table 9 (and FZ-GPU's observation, arXiv:2304.12557, that run-style
//! coding dominates when most prediction deltas are identical).
//!
//! Per chunk: quant codes pass through the same magnitude transform as
//! FLE (outlier marker 0 stays 0, everything else is `zigzag(s − radius)
//! + 1`), consecutive equal values coalesce into runs, and each run is
//! emitted as `(value, run_len − 1)` at two fixed chunk-local widths: `w`
//! bits for the value (width of the largest transformed value) and `r`
//! bits for the length (width of the longest run minus one). A chunk
//! that is one constant — the common case on zero-dominated fields —
//! costs `w + r` bits total.
//!
//! The sidecar is two bytes per chunk: `[w, r]`. The outlier escape is
//! inherited from the transform: marker slots encode as value 0 and the
//! exact deltas travel in the archive's outlier side channel, so runs of
//! outliers coalesce like any other constant.

use anyhow::{bail, Result};

use super::fle::{transform, untransform, MAX_WIDTH};
use super::{EncodeContext, EncodedSymbols, EncoderKind, EncoderStage, SymbolSource};
use crate::huffman::deflate::{DeflatedChunk, DeflatedStream};
use crate::util::bitio::{BitReader, BitWriter};

/// Hard ceiling on the run-length field width: run lengths are bounded by
/// the chunk geometry (≤ 2^24 symbols), so a wider sidecar is corrupt.
pub const MAX_RUN_WIDTH: u32 = 24;

/// Sidecar bytes per chunk (`[value_width, run_width]`).
pub const SIDECAR_BYTES: usize = 2;

pub struct RleStage;

/// Four u16 lanes packed little-endian into one u64 scan word.
#[inline]
fn pack4(s: &[u16], k: usize) -> u64 {
    (s[k] as u64)
        | (s[k + 1] as u64) << 16
        | (s[k + 2] as u64) << 32
        | (s[k + 3] as u64) << 48
}

/// Run detection as a u64-word kernel: the packed window is XORed with
/// itself shifted one lane, so each 16-bit lane (two byte lanes — 8 byte
/// lanes per word) is nonzero exactly where consecutive symbols differ.
/// A byte-mask collapse plus `trailing_zeros` finds the first boundary;
/// an all-zero word extends the run by four symbols per op. Returns the
/// exclusive end of the run starting at `i`.
///
/// Scanning raw symbols is sound because the magnitude transform is
/// injective (locked by `fle::tests::full_bijection_small_dict`): equal
/// transformed values ⇔ equal symbols.
#[inline]
fn run_end(symbols: &[u16], i: usize) -> usize {
    let n = symbols.len();
    let mut j = i + 1;
    while j + 4 <= n {
        let x = pack4(symbols, j - 1) ^ pack4(symbols, j);
        if x == 0 {
            j += 4;
            continue;
        }
        // collapse each 16-bit lane into its low byte, then locate the
        // first nonzero lane
        let m = (x | (x >> 8)) & 0x00FF_00FF_00FF_00FF;
        return j + (m.trailing_zeros() / 16) as usize;
    }
    while j < n && symbols[j] == symbols[i] {
        j += 1;
    }
    j
}

/// Encode one chunk; returns the `[w, r]` sidecar record and the framed
/// run stream. Public within the codec so mixed-granularity archives can
/// tag individual chunks as RLE.
pub(super) fn encode_chunk(symbols: &[u16], radius: i32) -> ([u8; 2], DeflatedChunk) {
    let mut runs: Vec<(u32, u32)> = Vec::new();
    let mut all = 0u32;
    let mut max_run = 1u32;
    let mut i = 0usize;
    while i < symbols.len() {
        let j = run_end(symbols, i);
        let v = transform(symbols[i], radius);
        let len = (j - i) as u32;
        all |= v;
        max_run = max_run.max(len);
        runs.push((v, len));
        i = j;
    }
    let w = 32 - all.leading_zeros();
    let r = if max_run <= 1 { 0 } else { 32 - (max_run - 1).leading_zeros() };
    let mut writer = BitWriter::with_capacity_bits(runs.len() * (w + r) as usize);
    for &(v, len) in &runs {
        writer.write(v as u64, w);
        writer.write((len - 1) as u64, r);
    }
    let (words, bits) = writer.finish();
    debug_assert_eq!(bits, runs.len() as u64 * (w + r) as u64);
    ([w as u8, r as u8], DeflatedChunk { words, bits, symbols: symbols.len() as u32 })
}

/// Decode one chunk's run stream straight into its destination window (a
/// `SymbolSink` slab slice or stitch buffer); the window length is
/// authoritative — runs expand at most to it, so a crafted chunk cannot
/// turn a few run bits into an unbounded expansion.
pub(super) fn decode_chunk_into(
    chunk: &DeflatedChunk,
    aux: &[u8],
    radius: i32,
    dict: usize,
    out: &mut [u16],
) -> Result<()> {
    let &[w, r] = aux else {
        bail!("corrupt RLE sidecar: record has {} bytes, want {SIDECAR_BYTES}", aux.len());
    };
    let (w, r) = (w as u32, r as u32);
    if w > MAX_WIDTH {
        bail!("corrupt RLE sidecar: value width {w} exceeds {MAX_WIDTH}");
    }
    if r > MAX_RUN_WIDTH {
        bail!("corrupt RLE sidecar: run width {r} exceeds {MAX_RUN_WIDTH}");
    }
    let n = out.len();
    if chunk.symbols as usize != n {
        bail!(
            "corrupt RLE chunk: claims {} symbols for a {n}-symbol window",
            chunk.symbols
        );
    }
    if chunk.bits > chunk.words.len() as u64 * 64 {
        bail!("corrupt RLE chunk: {} bits in {} words", chunk.bits, chunk.words.len());
    }
    // w == r == 0 can only legitimately encode a single-symbol chunk of
    // the marker value (one run, zero bits); anything longer would have
    // coalesced into a run needing r > 0
    if w + r == 0 && n > 1 {
        bail!("corrupt RLE chunk: zero-width runs claim {n} symbols");
    }
    let mut reader = BitReader::new(&chunk.words, chunk.bits);
    let mut filled = 0usize;
    while filled < n {
        let Some(v) = reader.read(w) else {
            bail!("corrupt RLE chunk: truncated run stream");
        };
        let Some(lm1) = reader.read(r) else {
            bail!("corrupt RLE chunk: truncated run length");
        };
        let len = lm1 as usize + 1;
        if filled + len > n {
            bail!("corrupt RLE chunk: run of {len} overruns {n} symbols");
        }
        let sym = untransform(v as u32, radius, dict)?;
        out[filled..filled + len].fill(sym);
        filled += len;
    }
    if reader.remaining() != 0 {
        bail!("corrupt RLE chunk: {} trailing bits", reader.remaining());
    }
    Ok(())
}

impl EncoderStage for RleStage {
    fn kind(&self) -> EncoderKind {
        EncoderKind::Rle
    }

    fn encode_source(
        &self,
        src: &SymbolSource<'_>,
        ctx: &EncodeContext,
    ) -> Result<EncodedSymbols> {
        let radius = (ctx.dict_size / 2) as i32;
        let cs = ctx.chunk_symbols.max(1);
        let encoded: Vec<([u8; 2], DeflatedChunk)> =
            src.map_chunks(cs, ctx.threads, |_, chunk| encode_chunk(chunk, radius));
        let nchunks = encoded.len();
        let mut aux = Vec::with_capacity(nchunks * SIDECAR_BYTES);
        let mut chunks = Vec::with_capacity(nchunks);
        let mut max_w = 0u32;
        for (rec, c) in encoded {
            max_w = max_w.max(rec[0] as u32 + rec[1] as u32);
            aux.extend_from_slice(&rec);
            chunks.push(c);
        }
        Ok(EncodedSymbols {
            aux,
            stream: DeflatedStream { chunks, chunk_symbols: cs },
            repr_bits: max_w.max(1),
            codebook_time: std::time::Duration::ZERO,
        })
    }

    fn decode_into(
        &self,
        aux: &[u8],
        stream: &DeflatedStream,
        dict_size: usize,
        threads: usize,
        sink: &mut crate::codec::SymbolSink<'_>,
    ) -> Result<()> {
        if aux.len() != stream.chunks.len() * SIDECAR_BYTES {
            bail!(
                "RLE sidecar has {} bytes for {} chunks",
                aux.len(),
                stream.chunks.len()
            );
        }
        // run streams expand: the sink's window partition caps every
        // claimed count against the expected total before any chunk
        // decodes (mirrors the FLE zero-width-chunk hardening)
        let radius = (dict_size / 2) as i32;
        sink.fill_chunks(stream, threads, |ci, window| {
            decode_chunk_into(
                &stream.chunks[ci],
                &aux[ci * SIDECAR_BYTES..(ci + 1) * SIDECAR_BYTES],
                radius,
                dict_size,
                window,
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CodewordRepr;
    use crate::util::prng::Rng;

    fn ctx(freq: &[u64], chunk: usize, threads: usize) -> EncodeContext<'_> {
        EncodeContext {
            dict_size: freq.len(),
            chunk_symbols: chunk,
            threads,
            codeword_repr: CodewordRepr::Adaptive,
            freq,
        }
    }

    fn roundtrip(symbols: &[u16], dict: usize, chunk: usize) {
        let freq = vec![0u64; dict];
        let enc = RleStage.encode(symbols, &ctx(&freq, chunk, 4)).unwrap();
        let out = RleStage.decode(&enc.aux, &enc.stream, dict, 4, symbols.len()).unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn roundtrip_constant_and_mixed_streams() {
        // one constant run (delta 0 everywhere)
        roundtrip(&vec![512u16; 10_000], 1024, 4096);
        // all outlier markers
        roundtrip(&vec![0u16; 5000], 1024, 4096);
        // alternating short runs and singletons
        let mut symbols = Vec::new();
        for i in 0..500u16 {
            symbols.extend(std::iter::repeat(512 + (i % 7)).take(1 + (i as usize % 40)));
        }
        roundtrip(&symbols, 1024, 4096);
        roundtrip(&symbols, 1024, 100); // irregular tail chunks
        roundtrip(&[], 1024, 4096);
        roundtrip(&[700], 1024, 4096);
    }

    #[test]
    fn roundtrip_random_streams() {
        let mut rng = Rng::new(23);
        let dict = 1024usize;
        for n in [1usize, 63, 64, 65, 1000, 4096, 10_001] {
            let symbols: Vec<u16> = (0..n)
                .map(|_| {
                    if rng.f32() < 0.7 {
                        512 // dominant constant: long runs
                    } else if rng.f32() < 0.1 {
                        0 // outlier marker
                    } else {
                        ((rng.normal() * 20.0) as i32 + 512).clamp(1, dict as i32 - 1) as u16
                    }
                })
                .collect();
            roundtrip(&symbols, dict, 4096);
            roundtrip(&symbols, dict, 257);
        }
    }

    /// The pre-kernel symbol-at-a-time run builder, kept verbatim as the
    /// oracle the u64 XOR+byte-mask scan is locked against.
    fn encode_chunk_scalar(symbols: &[u16], radius: i32) -> ([u8; 2], DeflatedChunk) {
        let mut runs: Vec<(u32, u32)> = Vec::new();
        let mut all = 0u32;
        let mut max_run = 1u32;
        for &s in symbols {
            let v = transform(s, radius);
            all |= v;
            match runs.last_mut() {
                Some((pv, len)) if *pv == v => {
                    *len += 1;
                    max_run = max_run.max(*len);
                }
                _ => runs.push((v, 1)),
            }
        }
        let w = 32 - all.leading_zeros();
        let r = if max_run <= 1 { 0 } else { 32 - (max_run - 1).leading_zeros() };
        let mut writer = BitWriter::with_capacity_bits(runs.len() * (w + r) as usize);
        for &(v, len) in &runs {
            writer.write(v as u64, w);
            writer.write((len - 1) as u64, r);
        }
        let (words, bits) = writer.finish();
        ([w as u8, r as u8], DeflatedChunk { words, bits, symbols: symbols.len() as u32 })
    }

    #[test]
    fn word_scan_matches_scalar_oracle_bit_for_bit() {
        let mut rng = Rng::new(91);
        let radius = 512i32;
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 63, 64, 65, 1000, 4096, 10_001] {
            // adversarial run structure: geometric run lengths from 1 up,
            // boundaries landing on every lane alignment
            let mut symbols = Vec::with_capacity(n);
            let mut v = 512u16;
            while symbols.len() < n {
                let len = 1 + (rng.below(9) * rng.below(9)) as usize;
                let take = len.min(n - symbols.len());
                symbols.extend(std::iter::repeat(v).take(take));
                v = if rng.f32() < 0.1 { 0 } else { (500 + rng.below(25)) as u16 };
            }
            let (aux_k, c_k) = encode_chunk(&symbols, radius);
            let (aux_s, c_s) = encode_chunk_scalar(&symbols, radius);
            assert_eq!(aux_k, aux_s, "n={n}");
            assert_eq!(c_k, c_s, "n={n}: kernel scan diverged from scalar oracle");
        }
    }

    #[test]
    fn run_end_finds_every_boundary_alignment() {
        // runs of every length 1..=20 back to back: boundaries hit every
        // position of the 4-lane scan window
        let mut symbols = Vec::new();
        for len in 1usize..=20 {
            symbols.extend(std::iter::repeat((100 + len) as u16).take(len));
        }
        let mut i = 0usize;
        for len in 1usize..=20 {
            let j = super::run_end(&symbols, i);
            assert_eq!(j - i, len, "run starting at {i}");
            i = j;
        }
        assert_eq!(i, symbols.len());
    }

    #[test]
    fn constant_chunk_costs_one_run() {
        let symbols = vec![512u16; 4096];
        let freq = vec![0u64; 1024];
        let enc = RleStage.encode(&symbols, &ctx(&freq, 4096, 1)).unwrap();
        assert_eq!(enc.stream.chunks.len(), 1);
        let w = enc.aux[0] as u64;
        let r = enc.aux[1] as u64;
        assert_eq!((w, r), (1, 12)); // value width 1, run width bits(4095)
        assert_eq!(enc.stream.chunks[0].bits, w + r);
    }

    #[test]
    fn rle_beats_fle_on_zero_dominated_and_loses_on_noise() {
        let mut rng = Rng::new(5);
        let freq = vec![0u64; 1024];
        let zeros: Vec<u16> = (0..20_000)
            .map(|_| if rng.f32() < 0.02 { 520 } else { 512 })
            .collect();
        let noise: Vec<u16> = (0..20_000)
            .map(|_| (512 + (rng.below(257) as i32 - 128)).clamp(1, 1023) as u16)
            .collect();
        let rle_z = RleStage.encode(&zeros, &ctx(&freq, 4096, 2)).unwrap();
        let fle_z = super::super::FleStage.encode(&zeros, &ctx(&freq, 4096, 2)).unwrap();
        assert!(rle_z.stream.total_bits() < fle_z.stream.total_bits() / 4);
        let rle_n = RleStage.encode(&noise, &ctx(&freq, 4096, 2)).unwrap();
        let fle_n = super::super::FleStage.encode(&noise, &ctx(&freq, 4096, 2)).unwrap();
        assert!(rle_n.stream.total_bits() > fle_n.stream.total_bits());
    }

    #[test]
    fn parallel_encode_is_deterministic() {
        let mut rng = Rng::new(7);
        let symbols: Vec<u16> = (0..50_000)
            .map(|_| if rng.f32() < 0.8 { 512 } else { (500 + rng.below(25)) as u16 })
            .collect();
        let freq = vec![0u64; 1024];
        let a = RleStage.encode(&symbols, &ctx(&freq, 2048, 1)).unwrap();
        let b = RleStage.encode(&symbols, &ctx(&freq, 2048, 8)).unwrap();
        assert_eq!(a.aux, b.aux);
        assert_eq!(a.stream, b.stream);
    }

    #[test]
    fn corrupt_sidecar_and_chunks_rejected() {
        let symbols: Vec<u16> = (0..2000)
            .map(|i| if i % 5 == 0 { 512 } else { (500 + i % 30) as u16 })
            .collect();
        let freq = vec![0u64; 1024];
        let enc = RleStage.encode(&symbols, &ctx(&freq, 512, 1)).unwrap();

        // sidecar length mismatch
        let mut short = enc.aux.clone();
        short.pop();
        assert!(RleStage.decode(&short, &enc.stream, 1024, 1, symbols.len()).is_err());

        // widths beyond their ceilings
        for (i, bad) in [(0, (MAX_WIDTH + 1) as u8), (1, (MAX_RUN_WIDTH + 1) as u8)] {
            let mut wide = enc.aux.clone();
            wide[i] = bad;
            assert!(RleStage.decode(&wide, &enc.stream, 1024, 1, symbols.len()).is_err());
        }

        // widths inconsistent with the chunk's bit count
        let mut wrong = enc.aux.clone();
        wrong[0] += 1;
        assert!(RleStage.decode(&wrong, &enc.stream, 1024, 1, symbols.len()).is_err());

        // symbol count beyond the chunk geometry must not allocate
        let mut stream = enc.stream.clone();
        stream.chunks[0].symbols = u32::MAX;
        assert!(RleStage.decode(&enc.aux, &stream, 1024, 1, usize::MAX).is_err());

        // bit count exceeding the backing words
        let mut stream = enc.stream.clone();
        stream.chunks[0].bits = stream.chunks[0].words.len() as u64 * 64 + 1;
        assert!(RleStage.decode(&enc.aux, &stream, 1024, 1, symbols.len()).is_err());

        // total symbols above the caller's cap
        assert!(RleStage.decode(&enc.aux, &enc.stream, 1024, 1, 10).is_err());
    }

    #[test]
    fn zero_width_single_marker_chunk_roundtrips_but_longer_is_rejected() {
        let enc = RleStage.encode(&[0u16], &ctx(&vec![0u64; 1024], 4096, 1)).unwrap();
        assert_eq!(enc.aux, vec![0, 0]);
        assert_eq!(enc.stream.total_bits(), 0);
        let out = RleStage.decode(&enc.aux, &enc.stream, 1024, 1, 1).unwrap();
        assert_eq!(out, vec![0]);
        // a crafted zero-width chunk claiming many symbols fails cleanly
        let mut stream = enc.stream.clone();
        stream.chunks[0].symbols = 4096;
        assert!(RleStage.decode(&enc.aux, &stream, 1024, 1, 4096).is_err());
    }
}
