//! [`SymbolSink`]: the decode-side counterpart of [`super::SymbolSource`]
//! — a writable, logically-contiguous view over per-slab destination
//! windows, replacing the whole-field `Vec<u16>` the decoders used to
//! return (and the concatenation copy that built it).
//!
//! Decode stages produce the symbol stream chunk by chunk, and the
//! stream is the slab-major concatenation of the per-slab code buffers
//! (every slab padded to the same `slab_len`). So instead of decoding
//! every chunk into its own vector and gluing them into one monolithic
//! buffer that the decompressor immediately re-splits per slab, the
//! stages write each decoded chunk window straight into its slice of the
//! per-slab destinations: a window inside one slab is a plain mutable
//! subslice, and a window straddling a slab boundary decodes into an
//! arena-loaned stitch buffer that is copied out to the spanned slabs.
//! Either way each symbol is written once — by its decoder — and the
//! whole-field symbol buffer never exists (regression-locked by the
//! [`super::symbol_buffer_materializations`] probe).

use anyhow::{bail, Context, Result};

use crate::huffman::deflate::DeflatedStream;
use crate::util::arena;
use crate::util::pool::parallel_map_range;

/// A borrowed, logically-contiguous u16 symbol destination backed by one
/// or more equal-length slab slices. Construct with [`SymbolSink::from_slabs`]
/// (the decompressor's per-slab code buffers) or [`SymbolSink::from_slice`]
/// (the materializing compatibility adapter).
pub struct SymbolSink<'a> {
    /// One pointer per slab; each points at `slab_len` writable slots.
    slabs: Vec<*mut u16>,
    slab_len: usize,
    total: usize,
    _borrow: std::marker::PhantomData<&'a mut [u16]>,
}

// SAFETY: the raw pointers are only dereferenced inside
// `fill_chunks`, which hands every worker a *disjoint* window of the
// logical stream (windows are the prefix-sum partition of the chunk
// symbol counts), and the `&mut self` receiver guarantees no other
// access to the underlying buffers for the duration of the fill — the
// same disjoint-index discipline as `util::pool::parallel_map_range`.
unsafe impl Send for SymbolSink<'_> {}
unsafe impl Sync for SymbolSink<'_> {}

impl<'a> SymbolSink<'a> {
    /// View one contiguous buffer as the whole stream (the materializing
    /// [`super::EncoderStage::decode`] adapter and tests).
    pub fn from_slice(buf: &'a mut [u16]) -> SymbolSink<'a> {
        SymbolSink {
            total: buf.len(),
            slab_len: buf.len().max(1),
            slabs: vec![buf.as_mut_ptr()],
            _borrow: std::marker::PhantomData,
        }
    }

    /// View the slab-major concatenation of `slabs` as the destination;
    /// each slab must be exactly `slab_len` symbols (the compressor pads
    /// every slab to the spec length).
    pub fn from_slabs(slabs: Vec<&'a mut [u16]>, slab_len: usize) -> Result<SymbolSink<'a>> {
        if slab_len == 0 {
            bail!("slab length must be positive");
        }
        let mut ptrs = Vec::with_capacity(slabs.len());
        for (i, s) in slabs.into_iter().enumerate() {
            if s.len() != slab_len {
                bail!("slab {i} has {} symbol slots, expected {slab_len}", s.len());
            }
            ptrs.push(s.as_mut_ptr());
        }
        Ok(SymbolSink {
            total: slab_len * ptrs.len(),
            slab_len,
            slabs: ptrs,
            _borrow: std::marker::PhantomData,
        })
    }

    /// Total symbol slots in the destination.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Run `f(chunk_index, window)` for every chunk of `stream`, across
    /// `threads` workers, where `window` is the chunk's slice of the
    /// logical destination (the prefix-sum partition of the per-chunk
    /// symbol counts). This is THE chunk-windowing idiom every decoder
    /// backend shares — the mirror of `SymbolSource::map_chunks`: windows
    /// inside one slab are written in place, windows straddling a slab
    /// boundary decode into an arena-loaned stitch buffer that is copied
    /// out afterwards.
    ///
    /// The per-chunk symbol counts are untrusted: the partition is
    /// validated against the sink's total *before* any chunk decodes, so
    /// a stream claiming the wrong symbol count fails cleanly here and a
    /// lying count can never write outside its window.
    pub fn fill_chunks<F>(&mut self, stream: &DeflatedStream, threads: usize, f: F) -> Result<()>
    where
        F: Fn(usize, &mut [u16]) -> Result<()> + Sync,
    {
        let mut offsets = Vec::with_capacity(stream.chunks.len() + 1);
        let mut acc = 0u64;
        offsets.push(0usize);
        for (ci, c) in stream.chunks.iter().enumerate() {
            acc += c.symbols as u64;
            if acc > self.total as u64 {
                bail!(
                    "chunk {ci} pushes the stream past the expected {} symbols",
                    self.total
                );
            }
            offsets.push(acc as usize);
        }
        if acc != self.total as u64 {
            bail!("stream yields {acc} symbols, expected {}", self.total);
        }
        let results: Vec<Result<()>> = parallel_map_range(threads, stream.chunks.len(), |ci| {
            self.with_window(offsets[ci], offsets[ci + 1], |w| f(ci, w))
        });
        for (ci, r) in results.into_iter().enumerate() {
            r.with_context(|| format!("decoding chunk {ci}"))?;
        }
        Ok(())
    }

    /// Hand `f` the writable window `[lo, hi)` of the logical stream: a
    /// direct subslice when the window lies within one slab, otherwise an
    /// arena-loaned stitch buffer whose contents are copied out to the
    /// spanned slabs after `f` returns (even on error — the caller bails,
    /// so partially-decoded residue is never observed).
    ///
    /// Private: callers go through [`SymbolSink::fill_chunks`], whose
    /// prefix-sum partition is what makes concurrent windows disjoint.
    fn with_window<R>(&self, lo: usize, hi: usize, f: impl FnOnce(&mut [u16]) -> R) -> R {
        debug_assert!(lo <= hi && hi <= self.total, "window {lo}..{hi} outside 0..{}", self.total);
        if lo == hi {
            return f(&mut []);
        }
        let si = lo / self.slab_len;
        let off = lo - si * self.slab_len;
        if hi <= (si + 1) * self.slab_len {
            // SAFETY: `fill_chunks` hands each worker a disjoint [lo, hi)
            // window and holds `&mut self`, so no other reference to
            // these slots exists; the pointer stays valid for `'a`.
            let w = unsafe { std::slice::from_raw_parts_mut(self.slabs[si].add(off), hi - lo) };
            return f(w);
        }
        arena::with_u16(|stitch| {
            stitch.clear();
            stitch.resize(hi - lo, 0);
            let r = f(stitch);
            let mut pos = lo;
            let mut src = 0usize;
            while pos < hi {
                let si = pos / self.slab_len;
                let off = pos - si * self.slab_len;
                let take = (self.slab_len - off).min(hi - pos);
                // SAFETY: same disjoint-window argument as above; the
                // stitch buffer and the slab storage never overlap.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        stitch.as_ptr().add(src),
                        self.slabs[si].add(off),
                        take,
                    );
                }
                pos += take;
                src += take;
            }
            r
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::deflate::DeflatedChunk;

    /// A stream whose chunks carry only symbol counts — enough to drive
    /// the window partition; the fill closures ignore the chunk payloads.
    fn counts_stream(counts: &[u32], cs: usize) -> DeflatedStream {
        DeflatedStream {
            chunks: counts
                .iter()
                .map(|&symbols| DeflatedChunk { words: Vec::new(), bits: 0, symbols })
                .collect(),
            chunk_symbols: cs,
        }
    }

    #[test]
    fn fill_chunks_matches_flat_reference_including_straddles() {
        // slab_len 100, chunk 70: most windows straddle slab boundaries
        for threads in [1usize, 4] {
            let mut slabs: Vec<Vec<u16>> = vec![vec![0; 100]; 3];
            {
                let views: Vec<&mut [u16]> =
                    slabs.iter_mut().map(|v| v.as_mut_slice()).collect();
                let mut sink = SymbolSink::from_slabs(views, 100).unwrap();
                let stream = counts_stream(&[70, 70, 70, 70, 20], 70);
                sink.fill_chunks(&stream, threads, |ci, w| {
                    for (k, slot) in w.iter_mut().enumerate() {
                        *slot = (ci * 70 + k) as u16;
                    }
                    Ok(())
                })
                .unwrap();
            }
            let flat: Vec<u16> = slabs.iter().flatten().copied().collect();
            let want: Vec<u16> = (0..300u16).collect();
            assert_eq!(flat, want, "threads={threads}");
        }
    }

    #[test]
    fn from_slice_covers_the_whole_buffer() {
        let mut buf = vec![0u16; 257];
        let mut sink = SymbolSink::from_slice(&mut buf);
        assert_eq!(sink.len(), 257);
        assert!(!sink.is_empty());
        let stream = counts_stream(&[100, 100, 57], 100);
        sink.fill_chunks(&stream, 2, |ci, w| {
            w.fill(ci as u16 + 1);
            Ok(())
        })
        .unwrap();
        assert!(buf[..100].iter().all(|&v| v == 1));
        assert!(buf[100..200].iter().all(|&v| v == 2));
        assert!(buf[200..].iter().all(|&v| v == 3));
    }

    #[test]
    fn symbol_count_mismatches_are_rejected_before_decoding() {
        let mut buf = vec![0u16; 100];
        let mut sink = SymbolSink::from_slice(&mut buf);
        // short stream
        let stream = counts_stream(&[40, 40], 40);
        assert!(sink.fill_chunks(&stream, 1, |_, _| Ok(())).is_err());
        // a chunk pushing past the sink must fail before its decoder runs
        let stream = counts_stream(&[40, u32::MAX], 40);
        let calls = std::sync::atomic::AtomicUsize::new(0);
        assert!(sink
            .fill_chunks(&stream, 1, |_, _| {
                calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(())
            })
            .is_err());
        assert_eq!(
            calls.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "no chunk may decode once the partition is rejected"
        );
    }

    #[test]
    fn chunk_errors_carry_their_index() {
        let mut slabs: Vec<Vec<u16>> = vec![vec![0; 50]; 2];
        let views: Vec<&mut [u16]> = slabs.iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut sink = SymbolSink::from_slabs(views, 50).unwrap();
        let stream = counts_stream(&[60, 40], 60);
        let err = sink
            .fill_chunks(&stream, 1, |ci, _| {
                if ci == 1 {
                    bail!("boom");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("chunk 1"), "{err:#}");
    }

    #[test]
    fn uneven_slabs_and_zero_len_rejected() {
        let mut a = vec![0u16; 10];
        let mut b = vec![0u16; 9];
        assert!(SymbolSink::from_slabs(vec![&mut a, &mut b], 10).is_err());
        let mut c = vec![0u16; 10];
        assert!(SymbolSink::from_slabs(vec![&mut c], 0).is_err());
        // zero slabs is a valid empty destination: an empty stream fills it
        let mut sink = SymbolSink::from_slabs(Vec::new(), 4).unwrap();
        assert!(sink.is_empty());
        sink.fill_chunks(&counts_stream(&[], 4), 2, |_, _| Ok(())).unwrap();
    }
}
