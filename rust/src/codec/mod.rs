//! Pluggable codec pipeline: the encoder half of Figure 1 as swappable
//! stages instead of a hard-wired Huffman path.
//!
//! A quant-code symbol stream can be turned into a framed byte stream by
//! any [`EncoderStage`] backend:
//!
//! * [`HuffmanStage`] — the paper's customized canonical Huffman coding
//!   (§3.2), extracted verbatim from the old monolithic compressor.
//! * [`FleStage`] — an FZ-GPU-style fixed-length encoder
//!   (arXiv:2304.12557): per-chunk max-magnitude bit width plus a bitplane
//!   shuffle, trading compression ratio for encode/decode throughput and
//!   leaving entropy removal to the archive's lossless tail stage.
//!
//! Which backend runs is the [`CodecSpec`] half of `CuszConfig`:
//! `Huffman` and `Fle` force a backend, `Auto` resolves per field from the
//! quant-code histogram ([`auto_select`]) — cuSZ+'s observation
//! (arXiv:2105.12912) that the best encoder depends on data smoothness.
//! The chosen backend is recorded in the archive header's encoder tag so
//! decompression is self-describing.

pub mod fle;
pub mod huffman_stage;

use anyhow::{bail, Result};

use crate::config::{CodewordRepr, LosslessStage};
use crate::huffman::deflate::DeflatedStream;

pub use fle::FleStage;
pub use huffman_stage::HuffmanStage;

/// Concrete encoder backends — the domain of the archive header's encoder
/// tag. Adding a backend means a new variant, a new tag value, and a new
/// arm in [`stage_for`]; unknown tags from future archives fail cleanly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EncoderKind {
    #[default]
    Huffman,
    Fle,
}

impl EncoderKind {
    pub const ALL: [EncoderKind; 2] = [EncoderKind::Huffman, EncoderKind::Fle];

    pub fn name(self) -> &'static str {
        match self {
            EncoderKind::Huffman => "huffman",
            EncoderKind::Fle => "fle",
        }
    }

    /// Wire value for the archive header.
    pub fn to_tag(self) -> u8 {
        match self {
            EncoderKind::Huffman => 0,
            EncoderKind::Fle => 1,
        }
    }

    pub fn from_tag(v: u8) -> Result<Self> {
        Ok(match v {
            0 => EncoderKind::Huffman,
            1 => EncoderKind::Fle,
            _ => bail!("unknown encoder tag {v} (archive written by a newer cusz?)"),
        })
    }
}

/// What the user asks for; `Auto` resolves to a concrete [`EncoderKind`]
/// per field once the quant-code histogram is known.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EncoderChoice {
    #[default]
    Huffman,
    Fle,
    Auto,
}

impl EncoderChoice {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "huffman" => EncoderChoice::Huffman,
            "fle" => EncoderChoice::Fle,
            "auto" => EncoderChoice::Auto,
            _ => bail!("unknown codec '{s}' (huffman|fle|auto)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EncoderChoice::Huffman => "huffman",
            EncoderChoice::Fle => "fle",
            EncoderChoice::Auto => "auto",
        }
    }
}

/// The codec half of the configuration: which symbol encoder plus which
/// lossless tail stage wraps the archive body.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CodecSpec {
    pub encoder: EncoderChoice,
    pub lossless: LosslessStage,
}

/// Encoder-stage inputs beyond the symbol stream itself.
pub struct EncodeContext<'a> {
    /// Quantization bins (symbol alphabet size; radius = dict_size/2).
    pub dict_size: usize,
    /// Symbols per framed chunk (the Table 6 knob; shared by backends so
    /// chunk-parallel decode keeps one geometry).
    pub chunk_symbols: usize,
    pub threads: usize,
    /// Huffman codeword representation preference (ignored by FLE).
    pub codeword_repr: CodewordRepr,
    /// Merged quant-code histogram, `len == dict_size` (already computed
    /// by the dual-quant phase; FLE ignores it).
    pub freq: &'a [u64],
}

/// An encoder's output: the chunked framed bitstream plus the sidecar
/// bytes its decoder needs (Huffman: per-symbol codebook lengths; FLE:
/// per-chunk bit widths).
pub struct EncodedSymbols {
    pub aux: Vec<u8>,
    pub stream: DeflatedStream,
    /// Representation width actually used, for stats (Huffman: packed
    /// codeword repr; FLE: widest chunk).
    pub repr_bits: u32,
    /// Time spent building per-symbol tables before streaming (Huffman
    /// tree + canonical codebook; zero for FLE) — reported separately so
    /// the Table 7 breakdown keeps its codebook row.
    pub codebook_time: std::time::Duration,
}

/// A symbol-stream encoder backend: quant codes in, framed chunked
/// bitstream + sidecar out, and the exact inverse.
pub trait EncoderStage: Send + Sync {
    fn kind(&self) -> EncoderKind;

    fn encode(&self, symbols: &[u16], ctx: &EncodeContext) -> Result<EncodedSymbols>;

    /// Inverse of [`EncoderStage::encode`]. `aux` and `stream` come from an
    /// untrusted archive: implementations must error (never panic) on
    /// inconsistent sidecar/stream combinations, and must reject streams
    /// claiming more than `max_symbols` total symbols *before* allocating
    /// for them (the caller knows the expected count from the header's
    /// geometry; a crafted stream must not turn symbol counts into
    /// unbounded allocations).
    fn decode(
        &self,
        aux: &[u8],
        stream: &DeflatedStream,
        dict_size: usize,
        threads: usize,
        max_symbols: usize,
    ) -> Result<Vec<u16>>;
}

/// Static backend registry: every [`EncoderKind`] maps to one stateless
/// stage instance.
pub fn stage_for(kind: EncoderKind) -> &'static dyn EncoderStage {
    static HUFFMAN: HuffmanStage = HuffmanStage;
    static FLE: FleStage = FleStage;
    match kind {
        EncoderKind::Huffman => &HUFFMAN,
        EncoderKind::Fle => &FLE,
    }
}

/// Shannon entropy of a histogram in bits/symbol — the floor any entropy
/// coder (Huffman) approaches.
pub fn entropy_bits(freq: &[u64]) -> f64 {
    let total: u64 = freq.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    freq.iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / t;
            -p * p.log2()
        })
        .sum()
}

/// Auto mode selection: FLE wins when the entropy coder would shave less
/// than this fraction off FLE's fixed width (its stream is then nearly
/// incompressible and FLE's flat, table-free hot loop is the better
/// trade); otherwise the histogram is skewed enough that Huffman's ratio
/// advantage dominates.
const AUTO_FLE_THRESHOLD: f64 = 0.8;

/// Resolve `Auto` for one field from its merged quant-code histogram
/// (`freq.len()` is the dict size).
pub fn auto_select(freq: &[u64]) -> EncoderKind {
    let width = fle::width_for_histogram(freq);
    if width == 0 {
        // degenerate stream (only outlier markers): FLE stores 0 bits/sym
        return EncoderKind::Fle;
    }
    if entropy_bits(freq) >= AUTO_FLE_THRESHOLD * width as f64 {
        EncoderKind::Fle
    } else {
        EncoderKind::Huffman
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip_and_unknown_rejected() {
        for k in EncoderKind::ALL {
            assert_eq!(EncoderKind::from_tag(k.to_tag()).unwrap(), k);
        }
        for bad in [2u8, 7, 255] {
            assert!(EncoderKind::from_tag(bad).is_err());
        }
    }

    #[test]
    fn choice_parses() {
        assert_eq!(EncoderChoice::parse("huffman").unwrap(), EncoderChoice::Huffman);
        assert_eq!(EncoderChoice::parse("fle").unwrap(), EncoderChoice::Fle);
        assert_eq!(EncoderChoice::parse("auto").unwrap(), EncoderChoice::Auto);
        assert!(EncoderChoice::parse("arith").is_err());
    }

    #[test]
    fn entropy_known_values() {
        // uniform over 4 symbols -> 2 bits
        assert!((entropy_bits(&[5, 5, 5, 5]) - 2.0).abs() < 1e-12);
        // single symbol -> 0 bits
        assert_eq!(entropy_bits(&[0, 42, 0]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
    }

    #[test]
    fn auto_picks_huffman_for_skewed_and_fle_for_flat() {
        let dict = 1024usize;
        let radius = dict / 2;
        // skewed: codes concentrated on radius +/- 1 -> low entropy
        let mut skewed = vec![0u64; dict];
        skewed[radius] = 1_000_000;
        skewed[radius + 1] = 1000;
        skewed[radius - 1] = 1000;
        assert_eq!(auto_select(&skewed), EncoderKind::Huffman);
        // flat: codes uniform over radius +/- 128 -> entropy ~ width
        let mut flat = vec![0u64; dict];
        for s in radius - 128..radius + 128 {
            flat[s] = 100;
        }
        assert_eq!(auto_select(&flat), EncoderKind::Fle);
        // degenerate: only outlier markers
        let mut outliers = vec![0u64; dict];
        outliers[0] = 777;
        assert_eq!(auto_select(&outliers), EncoderKind::Fle);
    }

    #[test]
    fn stages_report_their_kind() {
        for k in EncoderKind::ALL {
            assert_eq!(stage_for(k).kind(), k);
        }
    }
}
