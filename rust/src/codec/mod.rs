//! Pluggable codec pipeline: the encoder half of Figure 1 as swappable
//! stages instead of a hard-wired Huffman path.
//!
//! A quant-code symbol stream can be turned into a framed byte stream by
//! any [`EncoderStage`] backend:
//!
//! * [`HuffmanStage`] — the paper's customized canonical Huffman coding
//!   (§3.2), extracted verbatim from the old monolithic compressor.
//! * [`FleStage`] — an FZ-GPU-style fixed-length encoder
//!   (arXiv:2304.12557): per-chunk max-magnitude bit width plus a bitplane
//!   shuffle, trading compression ratio for encode/decode throughput and
//!   leaving entropy removal to the archive's lossless tail stage.
//! * [`RleStage`] — run-length coding over the radius-centered magnitude
//!   transform, for the zero/constant-dominated fields where both of the
//!   above waste bits on one endlessly repeated value.
//!
//! Which backend runs is the [`CodecSpec`] half of `CuszConfig`:
//! `Huffman`/`Fle`/`Rle` force a backend; `Auto` resolves from the
//! quant-code distribution — cuSZ+'s observation (arXiv:2105.12912) that
//! the best encoder depends on data smoothness — via the measured
//! [`cost::CostModel`]. At [`CodecGranularity::Field`] the whole stream
//! gets one backend ([`auto_select`]); at [`CodecGranularity::Chunk`]
//! every chunk is probed and tagged independently ([`chunked`]), which is
//! what makes `auto` win on fields that mix smoothness regimes. The
//! choice lands in the archive header's encoder tag (field granularity)
//! or the `CUSZA3` per-chunk tag table, so decompression is always
//! self-describing.

pub mod chunked;
pub mod cost;
pub mod fle;
pub mod huffman_stage;
pub mod rle;
pub mod sink;
pub mod source;

use anyhow::{bail, Result};

use crate::config::{CodewordRepr, LosslessStage};
use crate::huffman::deflate::DeflatedStream;

pub use cost::CostModel;
pub use fle::FleStage;
pub use huffman_stage::HuffmanStage;
pub use rle::RleStage;
pub use sink::SymbolSink;
pub use source::SymbolSource;

thread_local! {
    /// Whole-field symbol buffers materialized by this thread — the probe
    /// behind the "the fused decompress path never builds a monolithic
    /// `Vec<u16>`" regression test. Bumped by the materializing
    /// [`EncoderStage::decode`] adapter and by [`chunked::decode_chunked`];
    /// the `decode_into` sink paths never touch it. Thread-local so
    /// concurrent tests don't pollute each other's deltas.
    static SYMBOL_MATERIALIZATIONS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of whole-field symbol buffers this thread has materialized on
/// the decode side. Diagnostics / regression tests.
pub fn symbol_buffer_materializations() -> u64 {
    SYMBOL_MATERIALIZATIONS.with(|c| c.get())
}

/// Registry name of the process-wide materialization counter (the
/// thread-local probe above folded into [`crate::obs`] as a first-class
/// counter; the per-thread cell stays for delta-based regression tests).
pub const MATERIALIZATIONS_COUNTER: &str = "codec.symbol_materializations";

static MATERIALIZATIONS: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new(MATERIALIZATIONS_COUNTER);

pub(crate) fn note_symbol_materialization() {
    SYMBOL_MATERIALIZATIONS.with(|c| c.set(c.get() + 1));
    MATERIALIZATIONS.incr();
}

/// Per-backend telemetry counter names (`codec.<backend>.<metric>`).
/// `*_ns`/`*_symbols` pairs are what [`CostModel::from_registry`] turns
/// into measured throughput factors.
#[derive(Debug, Clone, Copy)]
pub struct CodecCounterKeys {
    pub encode_symbols: &'static str,
    pub encode_bytes: &'static str,
    pub encode_ns: &'static str,
    pub decode_symbols: &'static str,
    pub decode_bytes: &'static str,
    pub decode_ns: &'static str,
}

pub fn codec_counter_keys(kind: EncoderKind) -> CodecCounterKeys {
    match kind {
        EncoderKind::Huffman => CodecCounterKeys {
            encode_symbols: "codec.huffman.encode_symbols",
            encode_bytes: "codec.huffman.encode_bytes",
            encode_ns: "codec.huffman.encode_ns",
            decode_symbols: "codec.huffman.decode_symbols",
            decode_bytes: "codec.huffman.decode_bytes",
            decode_ns: "codec.huffman.decode_ns",
        },
        EncoderKind::Fle => CodecCounterKeys {
            encode_symbols: "codec.fle.encode_symbols",
            encode_bytes: "codec.fle.encode_bytes",
            encode_ns: "codec.fle.encode_ns",
            decode_symbols: "codec.fle.decode_symbols",
            decode_bytes: "codec.fle.decode_bytes",
            decode_ns: "codec.fle.decode_ns",
        },
        EncoderKind::Rle => CodecCounterKeys {
            encode_symbols: "codec.rle.encode_symbols",
            encode_bytes: "codec.rle.encode_bytes",
            encode_ns: "codec.rle.encode_ns",
            decode_symbols: "codec.rle.decode_symbols",
            decode_bytes: "codec.rle.decode_bytes",
            decode_ns: "codec.rle.decode_ns",
        },
    }
}

// Static-key fast path for the per-chunk paths: after the first bump each
// call is three relaxed sharded fetch_adds — no registry lock, no lookup.
// Rows indexed by `EncoderKind::to_tag()`; columns are
// [enc_symbols, enc_bytes, enc_ns, dec_symbols, dec_bytes, dec_ns].
use crate::obs::StaticCounter;
static CODEC_COUNTERS: [[StaticCounter; 6]; 3] = [
    [
        StaticCounter::new("codec.huffman.encode_symbols"),
        StaticCounter::new("codec.huffman.encode_bytes"),
        StaticCounter::new("codec.huffman.encode_ns"),
        StaticCounter::new("codec.huffman.decode_symbols"),
        StaticCounter::new("codec.huffman.decode_bytes"),
        StaticCounter::new("codec.huffman.decode_ns"),
    ],
    [
        StaticCounter::new("codec.fle.encode_symbols"),
        StaticCounter::new("codec.fle.encode_bytes"),
        StaticCounter::new("codec.fle.encode_ns"),
        StaticCounter::new("codec.fle.decode_symbols"),
        StaticCounter::new("codec.fle.decode_bytes"),
        StaticCounter::new("codec.fle.decode_ns"),
    ],
    [
        StaticCounter::new("codec.rle.encode_symbols"),
        StaticCounter::new("codec.rle.encode_bytes"),
        StaticCounter::new("codec.rle.encode_ns"),
        StaticCounter::new("codec.rle.decode_symbols"),
        StaticCounter::new("codec.rle.decode_bytes"),
        StaticCounter::new("codec.rle.decode_ns"),
    ],
];

/// Record one encode against `kind`'s registry counters. `symbols` is
/// the input symbol count, `bytes` the encoded output (stream + sidecar).
pub(crate) fn record_codec_encode(kind: EncoderKind, symbols: u64, bytes: u64, ns: u64) {
    let row = &CODEC_COUNTERS[kind.to_tag() as usize];
    row[0].add(symbols);
    row[1].add(bytes);
    row[2].add(ns);
}

/// Record one decode against `kind`'s registry counters. `bytes` is the
/// encoded input consumed (stream + sidecar).
pub(crate) fn record_codec_decode(kind: EncoderKind, symbols: u64, bytes: u64, ns: u64) {
    let row = &CODEC_COUNTERS[kind.to_tag() as usize];
    row[3].add(symbols);
    row[4].add(bytes);
    row[5].add(ns);
}

/// Concrete encoder backends — the domain of the archive header's encoder
/// tag and of the `CUSZA3` per-chunk tag table. Adding a backend means a
/// new variant, a new tag value, and a new arm in [`stage_for`]; unknown
/// tags from future archives fail cleanly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EncoderKind {
    #[default]
    Huffman,
    Fle,
    Rle,
}

impl EncoderKind {
    pub const ALL: [EncoderKind; 3] =
        [EncoderKind::Huffman, EncoderKind::Fle, EncoderKind::Rle];

    pub fn name(self) -> &'static str {
        match self {
            EncoderKind::Huffman => "huffman",
            EncoderKind::Fle => "fle",
            EncoderKind::Rle => "rle",
        }
    }

    /// Wire value for the archive header and the per-chunk tag table.
    pub fn to_tag(self) -> u8 {
        match self {
            EncoderKind::Huffman => 0,
            EncoderKind::Fle => 1,
            EncoderKind::Rle => 2,
        }
    }

    pub fn from_tag(v: u8) -> Result<Self> {
        Ok(match v {
            0 => EncoderKind::Huffman,
            1 => EncoderKind::Fle,
            2 => EncoderKind::Rle,
            _ => bail!("unknown encoder tag {v} (archive written by a newer cusz?)"),
        })
    }
}

/// What the user asks for; `Auto` resolves to a concrete [`EncoderKind`]
/// per field (or per chunk) once the quant codes are known.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EncoderChoice {
    #[default]
    Huffman,
    Fle,
    Rle,
    Auto,
}

impl EncoderChoice {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "huffman" => EncoderChoice::Huffman,
            "fle" => EncoderChoice::Fle,
            "rle" => EncoderChoice::Rle,
            "auto" => EncoderChoice::Auto,
            _ => bail!("unknown codec '{s}' (huffman|fle|rle|auto)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EncoderChoice::Huffman => "huffman",
            EncoderChoice::Fle => "fle",
            EncoderChoice::Rle => "rle",
            EncoderChoice::Auto => "auto",
        }
    }
}

/// At which grain `Auto` commits to a backend. Forced encoder choices
/// are uniform either way; granularity only changes how `Auto` resolves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodecGranularity {
    /// One backend for the whole field, picked from the merged histogram.
    #[default]
    Field,
    /// One backend per deflate chunk, picked from a measured per-chunk
    /// probe and recorded in the archive's chunk tag table.
    Chunk,
}

impl CodecGranularity {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "field" => CodecGranularity::Field,
            "chunk" => CodecGranularity::Chunk,
            _ => bail!("unknown codec granularity '{s}' (field|chunk)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecGranularity::Field => "field",
            CodecGranularity::Chunk => "chunk",
        }
    }

    pub fn to_u8(self) -> u8 {
        match self {
            CodecGranularity::Field => 0,
            CodecGranularity::Chunk => 1,
        }
    }

    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => CodecGranularity::Field,
            1 => CodecGranularity::Chunk,
            _ => bail!("unknown codec granularity tag {v}"),
        })
    }
}

/// The codec half of the configuration: which symbol encoder (at which
/// selection granularity) plus which lossless tail stage wraps the
/// archive body.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CodecSpec {
    pub encoder: EncoderChoice,
    pub lossless: LosslessStage,
    pub granularity: CodecGranularity,
}

/// Encoder-stage inputs beyond the symbol stream itself.
pub struct EncodeContext<'a> {
    /// Quantization bins (symbol alphabet size; radius = dict_size/2).
    pub dict_size: usize,
    /// Symbols per framed chunk (the Table 6 knob; shared by backends so
    /// chunk-parallel decode keeps one geometry).
    pub chunk_symbols: usize,
    pub threads: usize,
    /// Huffman codeword representation preference (ignored by FLE).
    pub codeword_repr: CodewordRepr,
    /// Merged quant-code histogram, `len == dict_size` (already computed
    /// by the dual-quant phase; FLE ignores it).
    pub freq: &'a [u64],
}

/// An encoder's output: the chunked framed bitstream plus the sidecar
/// bytes its decoder needs (Huffman: per-symbol codebook lengths; FLE:
/// per-chunk bit widths).
pub struct EncodedSymbols {
    pub aux: Vec<u8>,
    pub stream: DeflatedStream,
    /// Representation width actually used, for stats (Huffman: packed
    /// codeword repr; FLE: widest chunk).
    pub repr_bits: u32,
    /// Time spent building per-symbol tables before streaming (Huffman
    /// tree + canonical codebook; zero for FLE) — reported separately so
    /// the Table 7 breakdown keeps its codebook row.
    pub codebook_time: std::time::Duration,
}

/// A symbol-stream encoder backend: quant codes in, framed chunked
/// bitstream + sidecar out, and the exact inverse.
pub trait EncoderStage: Send + Sync {
    fn kind(&self) -> EncoderKind;

    /// Encode a (possibly multi-slab) symbol stream. Backends pull chunk
    /// windows straight out of the source — no field-wide flatten — and
    /// stitch boundary-straddling windows through an arena-loaned buffer.
    fn encode_source(&self, src: &SymbolSource<'_>, ctx: &EncodeContext)
        -> Result<EncodedSymbols>;

    /// Slice adapter for callers that already hold one contiguous
    /// buffer (tests, benches): identical output to
    /// [`EncoderStage::encode_source`] over `from_slice`.
    fn encode(&self, symbols: &[u16], ctx: &EncodeContext) -> Result<EncodedSymbols> {
        self.encode_source(&SymbolSource::from_slice(symbols), ctx)
    }

    /// Inverse of [`EncoderStage::encode_source`]: decode the stream
    /// directly into `sink`'s per-slab destination windows — no
    /// whole-field symbol buffer. `aux` and `stream` come from an
    /// untrusted archive: implementations must error (never panic) on
    /// inconsistent sidecar/stream combinations, and the sink's window
    /// partition rejects streams whose claimed symbol counts disagree
    /// with `sink.len()` *before* any chunk decodes, so a crafted count
    /// can neither overrun a window nor drive an allocation.
    fn decode_into(
        &self,
        aux: &[u8],
        stream: &DeflatedStream,
        dict_size: usize,
        threads: usize,
        sink: &mut SymbolSink<'_>,
    ) -> Result<()>;

    /// Materializing adapter over [`EncoderStage::decode_into`] for
    /// callers that want one contiguous buffer (tests, benches, the
    /// pre-fusion baseline). Rejects streams claiming more than
    /// `max_symbols` total symbols — or any chunk claiming more than the
    /// stream's chunk geometry — *before* allocating. Counts against the
    /// [`symbol_buffer_materializations`] probe; the hot decompress path
    /// never calls this.
    fn decode(
        &self,
        aux: &[u8],
        stream: &DeflatedStream,
        dict_size: usize,
        threads: usize,
        max_symbols: usize,
    ) -> Result<Vec<u16>> {
        let total = stream.total_symbols();
        if total > max_symbols as u64 {
            bail!("stream claims {total} symbols, caller expects at most {max_symbols}");
        }
        let cs = stream.chunk_symbols.max(1);
        for (ci, c) in stream.chunks.iter().enumerate() {
            if c.symbols as usize > cs {
                bail!(
                    "corrupt chunk {ci}: {} symbols exceeds chunk geometry {cs}",
                    c.symbols
                );
            }
        }
        note_symbol_materialization();
        let mut out = vec![0u16; total as usize];
        self.decode_into(aux, stream, dict_size, threads, &mut SymbolSink::from_slice(&mut out))?;
        Ok(out)
    }
}

/// Telemetry wrapper around a concrete backend: every `encode_source` /
/// `decode_into` that flows through [`stage_for`] records per-kind
/// symbols / bytes / nanoseconds into the registry — one `Instant` pair
/// and three sharded counter bumps per whole-field call, so the overhead
/// is unmeasurable next to the encode itself.
struct Instrumented<S>(S);

impl<S: EncoderStage> EncoderStage for Instrumented<S> {
    fn kind(&self) -> EncoderKind {
        self.0.kind()
    }

    fn encode_source(
        &self,
        src: &SymbolSource<'_>,
        ctx: &EncodeContext,
    ) -> Result<EncodedSymbols> {
        let t0 = std::time::Instant::now();
        let out = self.0.encode_source(src, ctx)?;
        record_codec_encode(
            self.kind(),
            src.len() as u64,
            (out.stream.payload_bytes() + out.aux.len()) as u64,
            t0.elapsed().as_nanos() as u64,
        );
        Ok(out)
    }

    fn decode_into(
        &self,
        aux: &[u8],
        stream: &DeflatedStream,
        dict_size: usize,
        threads: usize,
        sink: &mut SymbolSink<'_>,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        self.0.decode_into(aux, stream, dict_size, threads, sink)?;
        record_codec_decode(
            self.kind(),
            stream.total_symbols(),
            (stream.payload_bytes() + aux.len()) as u64,
            t0.elapsed().as_nanos() as u64,
        );
        Ok(())
    }
}

/// Static backend registry: every [`EncoderKind`] maps to one stateless
/// (telemetry-wrapped) stage instance.
pub fn stage_for(kind: EncoderKind) -> &'static dyn EncoderStage {
    static HUFFMAN: Instrumented<HuffmanStage> = Instrumented(HuffmanStage);
    static FLE: Instrumented<FleStage> = Instrumented(FleStage);
    static RLE: Instrumented<RleStage> = Instrumented(RleStage);
    match kind {
        EncoderKind::Huffman => &HUFFMAN,
        EncoderKind::Fle => &FLE,
        EncoderKind::Rle => &RLE,
    }
}

/// Shannon entropy of a histogram in bits/symbol — the floor any entropy
/// coder (Huffman) approaches.
pub fn entropy_bits(freq: &[u64]) -> f64 {
    let total: u64 = freq.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    freq.iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / t;
            -p * p.log2()
        })
        .sum()
}

/// Resolve `Auto` for one field from its merged quant-code histogram
/// (`freq.len()` is the dict size), via the measured [`CostModel`].
///
/// This replaces the old analytic rule `entropy ≥ 0.8 × width → FLE`,
/// which had two defects: it could never pick RLE, and — because the
/// entropy side averaged over the *full* histogram while the width side
/// never sees the outlier-marker bin (`transform(0) == 0`) — the marker
/// mass of rough fields under tight bounds deflated huffman's apparent
/// cost asymmetrically, biasing `auto` toward Huffman on exactly the
/// fields the throughput-first backends are for. The cost model prices
/// the marker bin consistently (see [`cost`]); the regression test below
/// locks the corrected behavior in.
pub fn auto_select(freq: &[u64]) -> EncoderKind {
    CostModel::MEASURED.select_field(freq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip_and_unknown_rejected() {
        for k in EncoderKind::ALL {
            assert_eq!(EncoderKind::from_tag(k.to_tag()).unwrap(), k);
        }
        for bad in [3u8, 7, 255] {
            assert!(EncoderKind::from_tag(bad).is_err());
        }
    }

    #[test]
    fn choice_and_granularity_parse() {
        assert_eq!(EncoderChoice::parse("huffman").unwrap(), EncoderChoice::Huffman);
        assert_eq!(EncoderChoice::parse("fle").unwrap(), EncoderChoice::Fle);
        assert_eq!(EncoderChoice::parse("rle").unwrap(), EncoderChoice::Rle);
        assert_eq!(EncoderChoice::parse("auto").unwrap(), EncoderChoice::Auto);
        assert!(EncoderChoice::parse("arith").is_err());
        assert_eq!(CodecGranularity::parse("field").unwrap(), CodecGranularity::Field);
        assert_eq!(CodecGranularity::parse("chunk").unwrap(), CodecGranularity::Chunk);
        assert!(CodecGranularity::parse("slab").is_err());
        for g in [CodecGranularity::Field, CodecGranularity::Chunk] {
            assert_eq!(CodecGranularity::from_u8(g.to_u8()).unwrap(), g);
        }
        assert!(CodecGranularity::from_u8(9).is_err());
    }

    #[test]
    fn entropy_known_values() {
        // uniform over 4 symbols -> 2 bits
        assert!((entropy_bits(&[5, 5, 5, 5]) - 2.0).abs() < 1e-12);
        // single symbol -> 0 bits
        assert_eq!(entropy_bits(&[0, 42, 0]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
    }

    #[test]
    fn auto_matches_distribution_shape() {
        let dict = 1024usize;
        let radius = dict / 2;
        // constant-dominated: one bin holds nearly everything -> runs
        // coalesce -> RLE (the old analytic rule could never pick it)
        let mut constant = vec![0u64; dict];
        constant[radius] = 1_000_000;
        constant[radius + 1] = 1000;
        constant[radius - 1] = 1000;
        assert_eq!(auto_select(&constant), EncoderKind::Rle);
        // gaussian-ish spread over a handful of bins: enough skew that
        // entropy coding pays, too many distinct values for runs
        let mut gaussian = vec![0u64; dict];
        for (off, count) in
            [(0i64, 38_000u64), (1, 24_000), (-1, 24_000), (2, 6_000), (-2, 6_000), (3, 1_000), (-3, 1_000)]
        {
            gaussian[(radius as i64 + off) as usize] = count;
        }
        assert_eq!(auto_select(&gaussian), EncoderKind::Huffman);
        // flat: codes uniform over radius +/- 128 -> entropy ~ width, no
        // runs -> FLE's table-free loop wins
        let mut flat = vec![0u64; dict];
        for s in radius - 128..radius + 128 {
            flat[s] = 100;
        }
        assert_eq!(auto_select(&flat), EncoderKind::Fle);
        // degenerate: only outlier markers
        let mut outliers = vec![0u64; dict];
        outliers[0] = 777;
        assert_eq!(auto_select(&outliers), EncoderKind::Fle);
    }

    /// Regression for the outlier-marker double-count (ISSUE 3 satellite):
    /// a rough field under a tight bound — 60% marker slots, the rest
    /// uniform over ±64 bins. The old analytic rule let the heavy marker
    /// bin drag the full-histogram entropy (~3.8 bits) under 0.8 × width
    /// (6.4 bits) and picked Huffman; over the non-marker population the
    /// stream is near-incompressible (conditional entropy ≈ width), the
    /// archive is outlier-channel-dominated either way, and the
    /// throughput-first fixed-length backend is the right call.
    #[test]
    fn auto_is_not_biased_by_the_outlier_marker_bin() {
        let dict = 1024usize;
        let radius = dict / 2;
        let mut spiky = vec![0u64; dict];
        spiky[0] = 600_000; // outlier markers
        for s in radius - 64..=radius + 64 {
            spiky[s] = 400_000 / 129;
        }
        let width = fle::width_for_histogram(&spiky) as f64;
        // document the old bias: full-histogram entropy sits well under
        // the old 0.8·width threshold, which would have forced Huffman
        assert!(entropy_bits(&spiky) < 0.8 * width);
        assert_eq!(auto_select(&spiky), EncoderKind::Fle);
        // the same distribution without the marker mass resolves the same
        // way — the marker bin no longer swings the decision
        let mut no_markers = spiky.clone();
        no_markers[0] = 0;
        assert_eq!(auto_select(&no_markers), auto_select(&spiky));
    }

    #[test]
    fn stages_report_their_kind() {
        for k in EncoderKind::ALL {
            assert_eq!(stage_for(k).kind(), k);
        }
    }

    /// Every backend must produce identical output whether it reads one
    /// contiguous buffer or pulls windows out of a multi-slab source —
    /// including chunk windows that straddle slab boundaries.
    #[test]
    fn slab_source_encode_matches_slice_encode_for_every_stage() {
        use crate::config::CodewordRepr;
        use crate::util::prng::Rng;
        let dict = 1024usize;
        let mut rng = Rng::new(31);
        let symbols: Vec<u16> = (0..12_000)
            .map(|i| {
                if i % 5 == 0 {
                    512 // runs for RLE
                } else {
                    ((rng.normal() * 20.0) as i32 + 512).clamp(0, dict as i32 - 1) as u16
                }
            })
            .collect();
        let mut freq = vec![0u64; dict];
        for &s in &symbols {
            freq[s as usize] += 1;
        }
        let slab_len = 3000; // 4 slabs; chunk 1300 straddles boundaries
        let slabs: Vec<&[u16]> = symbols.chunks(slab_len).collect();
        let src = SymbolSource::from_slabs(slabs, slab_len).unwrap();
        let ctx = EncodeContext {
            dict_size: dict,
            chunk_symbols: 1300,
            threads: 4,
            codeword_repr: CodewordRepr::Adaptive,
            freq: &freq,
        };
        for k in EncoderKind::ALL {
            let stage = stage_for(k);
            let a = stage.encode_source(&src, &ctx).unwrap();
            let b = stage.encode(&symbols, &ctx).unwrap();
            assert_eq!(a.aux, b.aux, "{}", k.name());
            assert_eq!(a.stream, b.stream, "{}", k.name());
            let out = stage.decode(&a.aux, &a.stream, dict, 4, symbols.len()).unwrap();
            assert_eq!(out, symbols, "{}", k.name());
        }
    }
}
