//! Measured cost model behind `--codec auto` — the replacement for the
//! old analytic `entropy ≥ 0.8 × width` threshold.
//!
//! Two granularities share one model:
//!
//! * **Per chunk** ([`probe_chunk`] + [`CostModel::select_chunk`]): a
//!   single pass over the chunk measures the *exact* encoded size each
//!   backend would produce — Huffman bits from the field codebook's
//!   length table, FLE bits from the chunk's magnitude width, RLE bits
//!   from the actual run structure — plus each backend's exact framing
//!   overhead (u64 word padding, sidecar bytes). Selection is a strict
//!   argmin, so per-chunk `auto` tracks the per-chunk oracle by
//!   construction (`benches/codec_compare.rs` verifies the fit and emits
//!   freshly measured constants).
//!
//! * **Per field** ([`CostModel::select_field`]): only the merged
//!   histogram exists, so RLE's run structure is estimated under an
//!   i.i.d. symbol model and the backends' measured decode-throughput
//!   gap enters as multipliers calibrated from `codec_compare` (Huffman's
//!   serial variable-length decode runs ~0.8× the FLE hot loop on this
//!   testbed — the old 0.8 threshold, relocated to the cost side).
//!
//! **Outlier-marker accounting.** The old analytic rule compared the
//! entropy of the *full* histogram against a width that — by construction
//! of the magnitude transform (`transform(0) == 0`) — never sees bin 0.
//! On rough fields under tight bounds, the heavy marker bin deflated the
//! huffman-side average while leaving the FLE side untouched, so the
//! marker mass was effectively counted in huffman's favor on both sides
//! of one comparison, and `auto` kept picking Huffman on exactly the
//! fields FLE is for. Markers carry no stream information — their 96-bit
//! payload lives in the outlier side channel whatever the encoder — so
//! the field-level estimates here price the huffman and FLE stream over
//! the *non-marker* population only (RLE still sees marker mass: it
//! genuinely coalesces marker runs). `codec::tests` locks the corrected
//! behavior in.

use super::fle::{self, transform};
use super::EncoderKind;
use crate::huffman;

/// Calibrated constants. `MEASURED` records the fit from
/// `benches/codec_compare.rs` on the dev testbed; the bench re-derives
/// and emits fresh values per run (CI archives them as an artifact).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Field-level multiplier on huffman stream bits: the measured
    /// decode-throughput gap vs the FLE hot loop (1/0.8 on this testbed).
    pub huffman_throughput_factor: f64,
    /// Field-level multiplier on the estimated RLE bits: run-structure
    /// estimation slack plus the serial per-chunk decode penalty.
    pub rle_throughput_factor: f64,
    /// Exact per-chunk sidecar cost in bits (FLE: one width byte).
    pub fle_sidecar_bits: u64,
    /// Exact per-chunk sidecar cost in bits (RLE: `[w, r]`).
    pub rle_sidecar_bits: u64,
}

impl CostModel {
    pub const MEASURED: CostModel = CostModel {
        huffman_throughput_factor: 1.25,
        rle_throughput_factor: 1.05,
        fle_sidecar_bits: 8,
        rle_sidecar_bits: 16,
    };

    /// Calibrate the field-level throughput factors from the telemetry
    /// registry: per-backend encode throughput (symbols/ns) recorded by
    /// the instrumented stages becomes the multiplier that prices
    /// slower-decoding backends' bits against the FLE hot loop —
    /// measured on *this* host and workload rather than the dev-testbed
    /// `MEASURED` constants. Backends with no recorded traffic fall back
    /// to the `MEASURED` value; factors are clamped to a sane band so a
    /// cold or skewed registry can never invert the selection logic. The
    /// exact per-chunk sidecar bits are physical constants of the wire
    /// format and are never recalibrated.
    pub fn from_registry(reg: &crate::obs::Registry) -> CostModel {
        let throughput = |kind: EncoderKind| -> Option<f64> {
            let keys = super::codec_counter_keys(kind);
            let ns = reg.counter_value(keys.encode_ns);
            let symbols = reg.counter_value(keys.encode_symbols);
            if ns == 0 || symbols == 0 {
                None
            } else {
                Some(symbols as f64 / ns as f64)
            }
        };
        let fle = throughput(EncoderKind::Fle);
        let factor = |kind: EncoderKind, fallback: f64, hi: f64| match (fle, throughput(kind)) {
            (Some(f), Some(t)) if t > 0.0 => (f / t).clamp(1.0, hi),
            _ => fallback,
        };
        CostModel {
            huffman_throughput_factor: factor(
                EncoderKind::Huffman,
                Self::MEASURED.huffman_throughput_factor,
                2.0,
            ),
            rle_throughput_factor: factor(
                EncoderKind::Rle,
                Self::MEASURED.rle_throughput_factor,
                1.5,
            ),
            fle_sidecar_bits: Self::MEASURED.fle_sidecar_bits,
            rle_sidecar_bits: Self::MEASURED.rle_sidecar_bits,
        }
    }

    /// Resolve `auto` for one field from its merged quant-code histogram.
    pub fn select_field(&self, freq: &[u64]) -> EncoderKind {
        self.select_field_within(freq, [true; 3])
    }

    /// [`CostModel::select_field`] restricted to the backends `allowed`
    /// leaves open (indexed by [`EncoderKind::to_tag`]) — the
    /// `--target-gbps` pruning hook. At least one entry must be true.
    pub fn select_field_within(&self, freq: &[u64], allowed: [bool; 3]) -> EncoderKind {
        let width = fle::width_for_histogram(freq);
        if width == 0 && allowed[EncoderKind::Fle.to_tag() as usize] {
            // degenerate stream (empty or only outlier markers): FLE
            // stores 0 bits/symbol
            return EncoderKind::Fle;
        }
        let e = self.estimate_field(freq, width);
        argmin_within(
            [
                (EncoderKind::Huffman, e.huffman_bits),
                (EncoderKind::Fle, e.fle_bits),
                (EncoderKind::Rle, e.rle_bits),
            ],
            allowed,
        )
    }

    /// Field-level stream-cost estimates in (throughput-weighted) bits.
    pub fn estimate_field(&self, freq: &[u64], width: u32) -> FieldEstimate {
        let n: u64 = freq.iter().sum();
        let markers = freq.first().copied().unwrap_or(0);
        let n_stream = n - markers;
        // exact huffman bits over the non-marker population, from the
        // same codebook the encoder would build
        let lengths = huffman::build_lengths(freq);
        let huffman_bits: u64 = freq
            .iter()
            .zip(&lengths)
            .skip(1)
            .map(|(&f, &l)| f * l as u64)
            .sum();
        // i.i.d. run estimate over the full stream (markers coalesce too):
        // expected runs = n·(1 − Σ p_s²) + 1, geometric-ish run lengths
        let nf = n as f64;
        let collision: f64 = freq
            .iter()
            .filter(|&&f| f > 0)
            .map(|&f| {
                let p = f as f64 / nf.max(1.0);
                p * p
            })
            .sum();
        let runs = (nf * (1.0 - collision) + 1.0).max(1.0);
        let mean_run = nf / runs;
        let run_width = (64 - ((2.0 * mean_run) as u64).max(1).leading_zeros()).clamp(1, 24);
        FieldEstimate {
            huffman_bits: huffman_bits as f64 * self.huffman_throughput_factor,
            fle_bits: (n_stream * width as u64) as f64,
            rle_bits: runs * (width + run_width) as f64 * self.rle_throughput_factor,
        }
    }

    /// Exact per-chunk archive cost (stream bits word-padded to the
    /// serialized u64 framing, plus sidecar bytes) for each backend.
    pub fn chunk_costs(&self, p: &ChunkProbe) -> [(EncoderKind, u64); 3] {
        let pad = |bits: u64| bits.div_ceil(64) * 64;
        [
            (EncoderKind::Huffman, pad(p.huffman_stream_bits)),
            (
                EncoderKind::Fle,
                pad(p.n as u64 * p.width as u64) + self.fle_sidecar_bits,
            ),
            (
                EncoderKind::Rle,
                pad(p.runs as u64 * (p.width + p.run_width) as u64) + self.rle_sidecar_bits,
            ),
        ]
    }

    /// Resolve `auto` for one chunk: strict argmin over the measured
    /// per-chunk costs (ties go to the earlier entry — Huffman shares the
    /// field codebook, so equal bytes favor no extra sidecar).
    pub fn select_chunk(&self, p: &ChunkProbe) -> EncoderKind {
        self.select_chunk_within(p, [true; 3])
    }

    /// [`CostModel::select_chunk`] restricted to the backends `allowed`
    /// leaves open (indexed by [`EncoderKind::to_tag`]) — the
    /// `--target-gbps` pruning hook. At least one entry must be true.
    pub fn select_chunk_within(&self, p: &ChunkProbe, allowed: [bool; 3]) -> EncoderKind {
        argmin_within(self.chunk_costs(p).map(|(k, b)| (k, b as f64)), allowed)
    }
}

fn argmin_within(costs: [(EncoderKind, f64); 3], allowed: [bool; 3]) -> EncoderKind {
    let mut best: Option<(EncoderKind, f64)> = None;
    for &(k, c) in &costs {
        if !allowed[k.to_tag() as usize] {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, b)) => c < b,
        };
        if better {
            best = Some((k, c));
        }
    }
    best.expect("allowed mask excludes every backend").0
}

/// Which backends meet a decode-throughput budget, from the telemetry
/// registry's measured decode rates (`codec.<k>.decode_symbols` symbols →
/// ×4 original bytes, over `codec.<k>.decode_ns`) — the `--target-gbps`
/// knob behind `auto`. Semantics chosen so the knob can only *prune*,
/// never strand: a non-positive target or a backend with no recorded
/// decode traffic passes (nothing measured, nothing to prune on), and if
/// every measured backend misses the budget the fastest one stays
/// allowed so selection always has somewhere to go.
pub fn allowed_for_target(reg: &crate::obs::Registry, target_gbps: f64) -> [bool; 3] {
    if !(target_gbps > 0.0) {
        return [true; 3];
    }
    let mut rate = [None::<f64>; 3];
    for kind in EncoderKind::ALL {
        let keys = super::codec_counter_keys(kind);
        let ns = reg.counter_value(keys.decode_ns);
        let symbols = reg.counter_value(keys.decode_symbols);
        if ns > 0 && symbols > 0 {
            // bytes/ns == GB/s against the original f32 payload
            rate[kind.to_tag() as usize] = Some(symbols as f64 * 4.0 / ns as f64);
        }
    }
    let mut allowed = [false; 3];
    for kind in EncoderKind::ALL {
        let i = kind.to_tag() as usize;
        allowed[i] = match rate[i] {
            Some(r) => r >= target_gbps,
            None => true,
        };
    }
    if allowed.iter().all(|&a| !a) {
        let fastest = EncoderKind::ALL
            .into_iter()
            .max_by(|a, b| {
                let ra = rate[a.to_tag() as usize].unwrap_or(0.0);
                let rb = rate[b.to_tag() as usize].unwrap_or(0.0);
                ra.total_cmp(&rb)
            })
            .expect("ALL is non-empty");
        allowed[fastest.to_tag() as usize] = true;
    }
    allowed
}

/// Field-level estimates (throughput-weighted bits; see [`CostModel`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FieldEstimate {
    pub huffman_bits: f64,
    pub fle_bits: f64,
    pub rle_bits: f64,
}

/// What one pass over a chunk measures: everything each backend's exact
/// encoded size depends on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkProbe {
    pub n: usize,
    /// Outlier-marker (code 0) slots in the chunk.
    pub markers: usize,
    /// Exact huffman stream bits under the field codebook (all symbols,
    /// markers included — that is what the encoder emits).
    pub huffman_stream_bits: u64,
    /// FLE / RLE magnitude width of the chunk.
    pub width: u32,
    /// Exact run count over transformed values.
    pub runs: usize,
    /// RLE run-length field width: bits of (longest run − 1).
    pub run_width: u32,
}

/// Measure one chunk in a single pass. `lengths` is the field codebook's
/// code-length table (one byte per symbol of the dict).
pub fn probe_chunk(symbols: &[u16], lengths: &[u8], radius: i32) -> ChunkProbe {
    let mut huffman_stream_bits = 0u64;
    let mut all = 0u32;
    let mut markers = 0usize;
    let mut runs = 0usize;
    let mut max_run = 1u32;
    let mut prev = u32::MAX; // transform never produces u32::MAX
    let mut cur_len = 0u32;
    for &s in symbols {
        if s == 0 {
            markers += 1;
        }
        huffman_stream_bits += lengths.get(s as usize).copied().unwrap_or(0) as u64;
        let v = transform(s, radius);
        all |= v;
        if v == prev {
            cur_len += 1;
            max_run = max_run.max(cur_len);
        } else {
            if cur_len > 0 {
                runs += 1;
            }
            prev = v;
            cur_len = 1;
        }
    }
    if cur_len > 0 {
        runs += 1;
    }
    let width = 32 - all.leading_zeros();
    let run_width = if max_run <= 1 { 0 } else { 32 - (max_run - 1).leading_zeros() };
    ChunkProbe { n: symbols.len(), markers, huffman_stream_bits, width, runs, run_width }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(symbols: &[u16], dict: usize) -> Vec<u64> {
        let mut freq = vec![0u64; dict];
        for &s in symbols {
            freq[s as usize] += 1;
        }
        freq
    }

    #[test]
    fn probe_measures_exact_backend_bits() {
        let symbols: Vec<u16> = (0..4096u32)
            .map(|i| match i % 10 {
                0..=6 => 512,           // dominant constant
                7 => 0,                 // marker
                _ => (510 + i % 5) as u16,
            })
            .collect();
        let freq = hist(&symbols, 1024);
        let lengths = huffman::build_lengths(&freq);
        let p = probe_chunk(&symbols, &lengths, 512);
        assert_eq!(p.n, 4096);
        assert_eq!(p.markers, symbols.iter().filter(|&&s| s == 0).count());

        // huffman: probe == actual deflate bits under the same codebook
        let book = crate::huffman::CanonicalCodebook::from_lengths(&lengths).unwrap();
        let direct = crate::huffman::deflate::deflate_one(&symbols, &book);
        assert_eq!(p.huffman_stream_bits, direct.bits);

        // fle: probe width == actual chunk width, bits == n·w
        let (w, fchunk) = super::super::fle::encode_chunk(&symbols, 512);
        assert_eq!(p.width, w as u32);
        assert_eq!(p.n as u64 * p.width as u64, fchunk.bits);

        // rle: probe runs/widths == actual run stream
        let (rec, rchunk) = super::super::rle::encode_chunk(&symbols, 512);
        assert_eq!(p.width, rec[0] as u32);
        assert_eq!(p.run_width, rec[1] as u32);
        assert_eq!(p.runs as u64 * (p.width + p.run_width) as u64, rchunk.bits);
    }

    #[test]
    fn chunk_selection_matches_oracle_by_construction() {
        let model = CostModel::MEASURED;
        let cases: [Vec<u16>; 3] = [
            vec![512; 4096],                                          // constant
            (0..4096).map(|i| (512 + (i % 9) - 4) as u16).collect(),  // cycling
            (0..4096).map(|i| (384 + (i * 7) % 257) as u16).collect(), // wide
        ];
        for symbols in &cases {
            let freq = hist(symbols, 1024);
            let lengths = huffman::build_lengths(&freq);
            let p = probe_chunk(symbols, &lengths, 512);
            let picked = model.select_chunk(&p);
            let min = model
                .chunk_costs(&p)
                .into_iter()
                .min_by_key(|&(_, b)| b)
                .unwrap();
            let picked_cost = model
                .chunk_costs(&p)
                .into_iter()
                .find(|&(k, _)| k == picked)
                .unwrap()
                .1;
            assert_eq!(picked_cost, min.1);
        }
    }

    #[test]
    fn from_registry_falls_back_and_clamps() {
        use crate::codec::codec_counter_keys;
        use crate::obs::Registry;
        // empty registry: every factor falls back to MEASURED
        let empty = Registry::new();
        assert_eq!(CostModel::from_registry(&empty), CostModel::MEASURED);

        // recorded throughputs: fle 2 sym/ns, huffman 0.5, rle 4
        let reg = Registry::new();
        let put = |kind: EncoderKind, symbols: u64, ns: u64| {
            let k = codec_counter_keys(kind);
            reg.add(k.encode_symbols, symbols);
            reg.add(k.encode_ns, ns);
        };
        put(EncoderKind::Fle, 2_000, 1_000);
        put(EncoderKind::Huffman, 500, 1_000);
        put(EncoderKind::Rle, 4_000, 1_000);
        let m = CostModel::from_registry(&reg);
        // huffman 4x slower would give 4.0 — clamped to the 2.0 ceiling
        assert_eq!(m.huffman_throughput_factor, 2.0);
        // rle faster than fle would give 0.5 — clamped up to 1.0
        assert_eq!(m.rle_throughput_factor, 1.0);
        // sidecar bits are wire-format constants, never recalibrated
        assert_eq!(m.fle_sidecar_bits, CostModel::MEASURED.fle_sidecar_bits);
        assert_eq!(m.rle_sidecar_bits, CostModel::MEASURED.rle_sidecar_bits);

        // per-chunk selection ignores throughput factors entirely, so a
        // calibrated model and MEASURED agree chunk-by-chunk (the bench's
        // oracle-tolerance acceptance rests on this)
        let symbols: Vec<u16> = (0..4096).map(|i| (384 + (i * 7) % 257) as u16).collect();
        let freq = hist(&symbols, 1024);
        let lengths = huffman::build_lengths(&freq);
        let p = probe_chunk(&symbols, &lengths, 512);
        assert_eq!(m.select_chunk(&p), CostModel::MEASURED.select_chunk(&p));
        assert_eq!(m.chunk_costs(&p), CostModel::MEASURED.chunk_costs(&p));
    }

    #[test]
    fn target_gbps_prunes_on_measured_decode_rates() {
        use crate::codec::codec_counter_keys;
        use crate::obs::Registry;
        // no target: everything allowed, even with telemetry present
        let reg = Registry::new();
        assert_eq!(allowed_for_target(&reg, 0.0), [true; 3]);
        assert_eq!(allowed_for_target(&reg, -1.0), [true; 3]);
        // empty registry: nothing measured, nothing pruned
        assert_eq!(allowed_for_target(&reg, 100.0), [true; 3]);

        // decode rates: huffman 1 GB/s, fle 8 GB/s, rle 2 GB/s
        // (symbols × 4 bytes over ns)
        let put = |kind: EncoderKind, symbols: u64, ns: u64| {
            let k = codec_counter_keys(kind);
            reg.add(k.decode_symbols, symbols);
            reg.add(k.decode_ns, ns);
        };
        put(EncoderKind::Huffman, 1_000, 4_000);
        put(EncoderKind::Fle, 8_000, 4_000);
        put(EncoderKind::Rle, 2_000, 4_000);
        // budget between huffman and rle: huffman pruned
        assert_eq!(allowed_for_target(&reg, 1.5), [false, true, true]);
        // budget between rle and fle: only fle survives
        assert_eq!(allowed_for_target(&reg, 4.0), [false, true, false]);
        // budget above everything: the fastest backend stays allowed
        assert_eq!(allowed_for_target(&reg, 100.0), [false, true, false]);
    }

    #[test]
    fn selection_within_respects_the_mask() {
        let model = CostModel::MEASURED;
        // constant field: unrestricted auto picks RLE
        let mut constant = vec![0u64; 1024];
        constant[512] = 1_000_000;
        constant[513] = 1000;
        constant[511] = 1000;
        assert_eq!(model.select_field(&constant), EncoderKind::Rle);
        // with RLE pruned the next-cheapest backend wins instead
        let mut no_rle = [true; 3];
        no_rle[EncoderKind::Rle.to_tag() as usize] = false;
        let picked = model.select_field_within(&constant, no_rle);
        assert_ne!(picked, EncoderKind::Rle);
        // per chunk: same contract against the exact probe
        let symbols = vec![512u16; 4096];
        let freq = hist(&symbols, 1024);
        let lengths = huffman::build_lengths(&freq);
        let p = probe_chunk(&symbols, &lengths, 512);
        assert_eq!(model.select_chunk(&p), EncoderKind::Rle);
        assert_ne!(model.select_chunk_within(&p, no_rle), EncoderKind::Rle);
        // a single-backend mask is honored verbatim
        let mut only_huffman = [false; 3];
        only_huffman[EncoderKind::Huffman.to_tag() as usize] = true;
        assert_eq!(model.select_chunk_within(&p, only_huffman), EncoderKind::Huffman);
    }

    #[test]
    fn empty_and_single_symbol_probes_are_sane() {
        let lengths = vec![4u8; 16];
        let p = probe_chunk(&[], &lengths, 8);
        assert_eq!((p.n, p.runs, p.width, p.run_width), (0, 0, 0, 0));
        let p = probe_chunk(&[8], &lengths, 8);
        assert_eq!((p.n, p.runs, p.run_width), (1, 1, 0));
    }
}
