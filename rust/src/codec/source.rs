//! [`SymbolSource`]: a zero-copy view over the per-slab quant-code
//! vectors, replacing the old phase-C flatten that copied every slab's
//! codes into one field-wide `Vec<u16>` before encoding.
//!
//! The encoder stages consume the symbol stream chunk by chunk, and the
//! stream is just the slab-major concatenation of the per-slab `codes`
//! vectors (every slab is padded to the same `slab_len`). So instead of
//! materializing that concatenation, the stages pull chunk windows
//! straight out of the slabs: a window that lies inside one slab is a
//! plain subslice (the common case — the default chunk size divides the
//! built-in slab lengths), and a window that straddles a slab boundary is
//! stitched into a small caller-provided buffer (loaned from the
//! thread-local [`crate::util::arena`] in the hot path). Either way each
//! symbol is read exactly once by the encoder instead of once for the
//! flatten plus once for the encode.

use anyhow::{bail, Result};

use crate::util::arena;
use crate::util::pool::parallel_map_range;

/// A borrowed, logically-contiguous u16 symbol stream backed by one or
/// more equal-length slab slices.
pub struct SymbolSource<'a> {
    slabs: Vec<&'a [u16]>,
    slab_len: usize,
    total: usize,
}

impl<'a> SymbolSource<'a> {
    /// View a single contiguous slice as a source (tests, benches, and
    /// the default [`super::EncoderStage::encode`] adapter).
    pub fn from_slice(symbols: &'a [u16]) -> SymbolSource<'a> {
        SymbolSource {
            total: symbols.len(),
            slab_len: symbols.len().max(1),
            slabs: vec![symbols],
        }
    }

    /// View the slab-major concatenation of `slabs`, each of which must
    /// be exactly `slab_len` symbols (the compressor pads every slab to
    /// the spec length).
    pub fn from_slabs(slabs: Vec<&'a [u16]>, slab_len: usize) -> Result<SymbolSource<'a>> {
        if slab_len == 0 {
            bail!("slab length must be positive");
        }
        for (i, s) in slabs.iter().enumerate() {
            if s.len() != slab_len {
                bail!("slab {i} has {} symbols, expected {slab_len}", s.len());
            }
        }
        Ok(SymbolSource { total: slab_len * slabs.len(), slab_len, slabs })
    }

    /// Total symbols in the stream.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Borrow the window `[lo, hi)` of the logical stream. Returns a
    /// direct subslice when the window lies within one slab; otherwise
    /// stitches the parts into `stitch` (cleared first) and returns it.
    /// The caller hands in the stitch buffer so hot loops can reuse one
    /// arena-loaned allocation across many chunks.
    pub fn chunk<'s>(&'s self, lo: usize, hi: usize, stitch: &'s mut Vec<u16>) -> &'s [u16] {
        assert!(lo <= hi && hi <= self.total, "window {lo}..{hi} outside 0..{}", self.total);
        if lo == hi {
            return &[];
        }
        let si = lo / self.slab_len;
        let off = lo - si * self.slab_len;
        if hi <= (si + 1) * self.slab_len {
            return &self.slabs[si][off..off + (hi - lo)];
        }
        stitch.clear();
        stitch.reserve(hi - lo);
        let mut pos = lo;
        while pos < hi {
            let si = pos / self.slab_len;
            let off = pos - si * self.slab_len;
            let take = (self.slab_len - off).min(hi - pos);
            stitch.extend_from_slice(&self.slabs[si][off..off + take]);
            pos += take;
        }
        stitch
    }

    /// Run `f(chunk_index, window)` over every `chunk_symbols`-sized
    /// window of the stream across `threads` workers, collecting results
    /// in chunk order. This is THE chunk-windowing idiom every encoder
    /// backend shares: windows inside one slab are zero-copy subslices,
    /// windows straddling a slab boundary stitch through an arena-loaned
    /// buffer reused across each worker's chunks.
    pub fn map_chunks<R, F>(&self, chunk_symbols: usize, threads: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &[u16]) -> R + Sync,
    {
        let cs = chunk_symbols.max(1);
        let nchunks = self.total.div_ceil(cs);
        parallel_map_range(threads, nchunks, |ci| {
            let lo = ci * cs;
            let hi = (lo + cs).min(self.total);
            arena::with_u16(|stitch| f(ci, self.chunk(lo, hi, stitch)))
        })
    }

    /// Materialize the whole stream (diagnostics / compatibility shims —
    /// the encode hot path never calls this).
    pub fn to_vec(&self) -> Vec<u16> {
        let mut out = Vec::with_capacity(self.total);
        for s in &self.slabs {
            out.extend_from_slice(s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slabs3() -> Vec<Vec<u16>> {
        (0..3u16)
            .map(|s| (0..100u16).map(|i| s * 1000 + i).collect())
            .collect()
    }

    #[test]
    fn from_slabs_matches_flat_reference_for_every_window() {
        let owned = slabs3();
        let src =
            SymbolSource::from_slabs(owned.iter().map(|v| v.as_slice()).collect(), 100).unwrap();
        let flat: Vec<u16> = owned.iter().flatten().copied().collect();
        assert_eq!(src.len(), 300);
        assert_eq!(src.to_vec(), flat);
        let mut stitch = Vec::new();
        // windows chosen to hit: inside-slab, exact-slab, straddling one
        // boundary, straddling both boundaries, empty, full
        for (lo, hi) in [
            (0, 0),
            (0, 100),
            (5, 37),
            (100, 200),
            (90, 110),
            (95, 205),
            (0, 300),
            (299, 300),
        ] {
            assert_eq!(src.chunk(lo, hi, &mut stitch), &flat[lo..hi], "{lo}..{hi}");
        }
    }

    #[test]
    fn aligned_windows_are_zero_copy() {
        let owned = slabs3();
        let src =
            SymbolSource::from_slabs(owned.iter().map(|v| v.as_slice()).collect(), 100).unwrap();
        let mut stitch = Vec::new();
        let w = src.chunk(100, 150, &mut stitch);
        // a within-slab window must alias the slab storage, not the stitch
        assert_eq!(w.as_ptr(), owned[1][0..].as_ptr());
        assert!(stitch.is_empty(), "aligned window must not touch the stitch buffer");
    }

    #[test]
    fn from_slice_covers_the_whole_slice() {
        let v: Vec<u16> = (0..257).collect();
        let src = SymbolSource::from_slice(&v);
        assert_eq!(src.len(), 257);
        let mut stitch = Vec::new();
        assert_eq!(src.chunk(13, 250, &mut stitch), &v[13..250]);
        let empty = SymbolSource::from_slice(&[]);
        assert_eq!(empty.len(), 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn map_chunks_matches_manual_windows() {
        let owned = slabs3();
        let src =
            SymbolSource::from_slabs(owned.iter().map(|v| v.as_slice()).collect(), 100).unwrap();
        let flat: Vec<u16> = owned.iter().flatten().copied().collect();
        // 70 does not divide 100: most windows straddle slab boundaries
        for threads in [1usize, 4] {
            let sums = src.map_chunks(70, threads, |ci, w| (ci, w.iter().map(|&x| x as u64).sum::<u64>()));
            let want: Vec<(usize, u64)> = flat
                .chunks(70)
                .enumerate()
                .map(|(ci, w)| (ci, w.iter().map(|&x| x as u64).sum::<u64>()))
                .collect();
            assert_eq!(sums, want, "threads={threads}");
        }
        // empty stream: no chunks, no calls
        assert!(SymbolSource::from_slice(&[]).map_chunks(70, 4, |_, _| ()).is_empty());
    }

    #[test]
    fn uneven_slabs_are_rejected() {
        let a = vec![1u16; 10];
        let b = vec![2u16; 9];
        assert!(SymbolSource::from_slabs(vec![&a, &b], 10).is_err());
        assert!(SymbolSource::from_slabs(vec![&a], 0).is_err());
        // zero slabs is a valid empty stream
        let none = SymbolSource::from_slabs(Vec::new(), 4).unwrap();
        assert_eq!(none.len(), 0);
    }
}
