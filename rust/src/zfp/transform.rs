//! ZFP's reversible integer lifting transform (near-orthogonal block
//! transform, Lindstrom'14) applied along each axis of a 4^d block, plus
//! the total-degree coefficient ordering.

/// Forward lift of 4 values (exact integer, reversible).
#[inline]
pub fn fwd_lift(p: &mut [i32; 4]) {
    let [mut x, mut y, mut z, mut w] = *p;
    // non-orthogonal transform: (4 4 4 4; 5 1 -1 -5; -4 4 4 -4; -2 6 -6 2)/16
    x = x.wrapping_add(w);
    x >>= 1;
    w = w.wrapping_sub(x);
    z = z.wrapping_add(y);
    z >>= 1;
    y = y.wrapping_sub(z);
    x = x.wrapping_add(z);
    x >>= 1;
    z = z.wrapping_sub(x);
    w = w.wrapping_add(y);
    w >>= 1;
    y = y.wrapping_sub(w);
    w = w.wrapping_add(y >> 1);
    y = y.wrapping_sub(w >> 1);
    *p = [x, y, z, w];
}

/// Inverse lift (exact inverse of `fwd_lift`).
#[inline]
pub fn inv_lift(p: &mut [i32; 4]) {
    let [mut x, mut y, mut z, mut w] = *p;
    y = y.wrapping_add(w >> 1);
    w = w.wrapping_sub(y >> 1);
    y = y.wrapping_add(w);
    w <<= 1;
    w = w.wrapping_sub(y);
    z = z.wrapping_add(x);
    x <<= 1;
    x = x.wrapping_sub(z);
    y = y.wrapping_add(z);
    z <<= 1;
    z = z.wrapping_sub(y);
    w = w.wrapping_add(x);
    x <<= 1;
    x = x.wrapping_sub(w);
    *p = [x, y, z, w];
}

/// Apply the lift along every axis of a 4^d block (row-major, side 4).
pub fn forward(block: &mut [i32], ndim: usize) {
    transform(block, ndim, fwd_lift, false)
}

pub fn inverse(block: &mut [i32], ndim: usize) {
    transform(block, ndim, inv_lift, true)
}

fn transform(block: &mut [i32], ndim: usize, lift: impl Fn(&mut [i32; 4]), rev: bool) {
    debug_assert_eq!(block.len(), 4usize.pow(ndim as u32));
    // axis strides in the row-major 4^d block
    let mut axes: Vec<usize> = (0..ndim).map(|ax| 4usize.pow((ndim - 1 - ax) as u32)).collect();
    if rev {
        axes.reverse();
    }
    let n = block.len();
    for &stride in &axes {
        // lines along this axis: all index combos with coordinate 0 on it
        let mut line = [0i32; 4];
        let mut idx = 0usize;
        while idx < n {
            // idx iterates over positions whose coordinate along axis == 0
            let coord = (idx / stride) % 4;
            if coord != 0 {
                idx += 1;
                continue;
            }
            for (k, l) in line.iter_mut().enumerate() {
                *l = block[idx + k * stride];
            }
            lift(&mut line);
            for (k, &l) in line.iter().enumerate() {
                block[idx + k * stride] = l;
            }
            idx += 1;
        }
    }
}

/// Coefficient ordering by total degree (sum of per-axis frequencies) —
/// zfp's sequency order, so low-frequency coefficients (big magnitudes)
/// are encoded first within each bit plane.
pub fn perm(ndim: usize) -> &'static [usize] {
    use std::sync::OnceLock;
    static P1: OnceLock<Vec<usize>> = OnceLock::new();
    static P2: OnceLock<Vec<usize>> = OnceLock::new();
    static P3: OnceLock<Vec<usize>> = OnceLock::new();
    match ndim {
        1 => P1.get_or_init(|| make_perm(1)),
        2 => P2.get_or_init(|| make_perm(2)),
        3 => P3.get_or_init(|| make_perm(3)),
        _ => panic!("ndim"),
    }
}

fn make_perm(ndim: usize) -> Vec<usize> {
    let n = 4usize.pow(ndim as u32);
    let mut idx: Vec<usize> = (0..n).collect();
    let degree = |i: usize| -> usize {
        let mut rem = i;
        let mut sum = 0;
        for _ in 0..ndim {
            sum += rem % 4;
            rem /= 4;
        }
        sum
    };
    idx.sort_by_key(|&i| (degree(i), i));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn lift_roundtrips_within_lsb_noise() {
        // zfp's lifting is fixed-point: each >>1 drops an LSB, so the
        // round trip is exact only up to a few low bits (the published
        // transform behaves identically). At scale 2^28 this noise is
        // ~2^-24 relative — invisible next to the bit-plane truncation.
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let orig: [i32; 4] =
                std::array::from_fn(|_| (rng.below(1 << 29) as i32) - (1 << 28));
            let mut p = orig;
            fwd_lift(&mut p);
            inv_lift(&mut p);
            for (a, b) in p.iter().zip(&orig) {
                assert!((a - b).abs() <= 8, "{p:?} vs {orig:?}");
            }
        }
    }

    #[test]
    fn block_transform_roundtrips_within_lsb_noise() {
        let mut rng = Rng::new(2);
        for ndim in 1..=3 {
            let n = 4usize.pow(ndim as u32);
            let orig: Vec<i32> =
                (0..n).map(|_| (rng.below(1 << 29) as i32) - (1 << 28)).collect();
            let mut b = orig.clone();
            forward(&mut b, ndim);
            inverse(&mut b, ndim);
            for (a, o) in b.iter().zip(&orig) {
                assert!((a - o).abs() <= 64, "ndim {ndim}");
            }
        }
    }

    #[test]
    fn constant_block_concentrates_energy() {
        // DC block: all energy in coefficient 0 after the transform.
        let mut b = vec![1 << 20; 64];
        forward(&mut b, 3);
        assert_ne!(b[0], 0);
        assert!(b[1..].iter().all(|&v| v == 0), "{:?}", &b[..8]);
    }

    #[test]
    fn perm_is_a_permutation_ordered_by_degree() {
        for ndim in 1..=3 {
            let p = perm(ndim);
            let n = 4usize.pow(ndim as u32);
            let mut seen = vec![false; n];
            for &i in p {
                assert!(!seen[i]);
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s));
            assert_eq!(p[0], 0, "DC first");
        }
    }
}
