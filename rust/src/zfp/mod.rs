//! A ZFP-style fixed-rate transform codec — the cuZFP comparison baseline
//! (paper §5.1, Figures 6-8, Table 5).
//!
//! Per 4^d block: exponent alignment → fixed-point i32 → reversible
//! integer lifting transform along each axis → total-degree coefficient
//! reordering → negabinary → embedded bit-plane coding with group testing,
//! truncated at the fixed per-block bit budget (`rate` bits/value). This
//! follows the published ZFP algorithm [Lindstrom'14]; like cuZFP's CUDA
//! version it supports only fixed-rate mode — exactly the limitation the
//! paper exploits in the rate-distortion comparison.

pub mod bitplane;
pub mod transform;

use anyhow::{bail, Result};

use crate::util::bitio::{BitReader, BitWriter};

/// Fixed-rate ZFP codec over an n-dimensional f32 field.
#[derive(Debug, Clone, Copy)]
pub struct Zfp {
    /// Bits per value (cuZFP's user-set bitrate, e.g. 6, 8, 12, 16).
    pub rate: f64,
}

#[derive(Debug, Clone)]
pub struct ZfpStream {
    pub words: Vec<u64>,
    pub bits: u64,
    pub dims: Vec<usize>,
    pub rate: f64,
}

impl ZfpStream {
    pub fn compressed_bytes(&self) -> usize {
        (self.bits as usize).div_ceil(8) + 16 // + tiny header
    }
}

impl Zfp {
    pub fn new(rate: f64) -> Self {
        Zfp { rate }
    }

    fn block_elems(ndim: usize) -> usize {
        4usize.pow(ndim as u32)
    }

    fn maxbits(&self, ndim: usize) -> usize {
        ((self.rate * Self::block_elems(ndim) as f64).round() as usize).max(10)
    }

    pub fn compress(&self, data: &[f32], dims: &[usize]) -> Result<ZfpStream> {
        let ndim = dims.len();
        if !(1..=3).contains(&ndim) {
            bail!("zfp supports 1..=3 dims (fold 4D first)");
        }
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("dims/data mismatch");
        }
        let maxbits = self.maxbits(ndim);
        let mut w = BitWriter::new();
        let mut block = vec![0f32; Self::block_elems(ndim)];
        for_each_block(dims, |origin| {
            gather_block(data, dims, origin, &mut block);
            encode_block(&block, ndim, maxbits, &mut w);
        });
        let (words, bits) = w.finish();
        Ok(ZfpStream { words, bits, dims: dims.to_vec(), rate: self.rate })
    }

    pub fn decompress(&self, stream: &ZfpStream) -> Result<Vec<f32>> {
        let dims = &stream.dims;
        let ndim = dims.len();
        let n: usize = dims.iter().product();
        let maxbits = self.maxbits(ndim);
        let mut out = vec![0f32; n];
        let mut r = BitReader::new(&stream.words, stream.words.len() as u64 * 64);
        let mut block = vec![0f32; Self::block_elems(ndim)];
        let mut ok = true;
        for_each_block(dims, |origin| {
            if !ok {
                return;
            }
            if decode_block(&mut r, ndim, maxbits, &mut block).is_err() {
                ok = false;
                return;
            }
            scatter_block(&mut out, dims, origin, &block);
        });
        if !ok {
            bail!("zfp stream truncated");
        }
        Ok(out)
    }
}

/// Visit every 4-aligned block origin (row-major order).
fn for_each_block(dims: &[usize], mut f: impl FnMut(&[usize])) {
    let counts: Vec<usize> = dims.iter().map(|d| d.div_ceil(4)).collect();
    let total: usize = counts.iter().product();
    let mut origin = vec![0usize; dims.len()];
    for flat in 0..total {
        let mut rem = flat;
        for ax in (0..dims.len()).rev() {
            origin[ax] = (rem % counts[ax]) * 4;
            rem /= counts[ax];
        }
        f(&origin);
    }
}

/// Gather a 4^d block with edge replication (zfp's partial-block handling).
fn gather_block(data: &[f32], dims: &[usize], origin: &[usize], block: &mut [f32]) {
    let nd = dims.len();
    let strides = strides_of(dims);
    let side = 4usize;
    let n = block.len();
    for bi in 0..n {
        let mut rem = bi;
        let mut off = 0usize;
        for ax in (0..nd).rev() {
            let c = rem % side;
            rem /= side;
            let pos = (origin[ax] + c).min(dims[ax] - 1); // replicate edge
            off += pos * strides[ax];
        }
        block[bi] = data[off];
    }
}

fn scatter_block(out: &mut [f32], dims: &[usize], origin: &[usize], block: &[f32]) {
    let nd = dims.len();
    let strides = strides_of(dims);
    let side = 4usize;
    for (bi, &v) in block.iter().enumerate() {
        let mut rem = bi;
        let mut off = 0usize;
        let mut in_range = true;
        for ax in (0..nd).rev() {
            let c = rem % side;
            rem /= side;
            let pos = origin[ax] + c;
            if pos >= dims[ax] {
                in_range = false;
                break;
            }
            off += pos * strides[ax];
        }
        if in_range {
            out[off] = v;
        }
    }
}

fn strides_of(dims: &[usize]) -> Vec<usize> {
    let nd = dims.len();
    let mut s = vec![1usize; nd];
    for ax in (0..nd.saturating_sub(1)).rev() {
        s[ax] = s[ax + 1] * dims[ax + 1];
    }
    s
}

/// Exponent of the block maximum (None for an all-zero block).
fn block_emax(block: &[f32]) -> Option<i32> {
    let m = block.iter().fold(0f32, |a, &b| a.max(b.abs()));
    if m == 0.0 || !m.is_finite() {
        return None;
    }
    Some(((m.to_bits() >> 23) & 0xff) as i32 - 127)
}

fn encode_block(block: &[f32], ndim: usize, maxbits: usize, w: &mut BitWriter) {
    let start = w.len_bits();
    match block_emax(block) {
        None => w.write_bit(false), // all-zero block: 1 bit
        Some(emax) => {
            w.write_bit(true);
            w.write((emax + 127) as u64, 8);
            // fixed point: scale so the max lands in [2^28, 2^29)
            let scale = exp2i(28 - emax);
            let mut q: Vec<i32> = block.iter().map(|&x| (x * scale) as i32).collect();
            transform::forward(&mut q, ndim);
            let perm = transform::perm(ndim);
            let nb: Vec<u32> = perm.iter().map(|&i| negabinary(q[i])).collect();
            let used = (w.len_bits() - start) as usize;
            bitplane::encode_ints(&nb, maxbits.saturating_sub(used), w);
        }
    }
    // pad to exactly maxbits (fixed rate => random access per block)
    let used = (w.len_bits() - start) as usize;
    debug_assert!(used <= maxbits);
    let mut pad = maxbits - used;
    while pad > 0 {
        let n = pad.min(57);
        w.write(0, n as u32);
        pad -= n;
    }
}

fn decode_block(r: &mut BitReader, ndim: usize, maxbits: usize, block: &mut [f32]) -> Result<()> {
    let start_rem = r.remaining();
    if (start_rem as usize) < maxbits {
        bail!("truncated");
    }
    let nonzero = r.read_bit().ok_or_else(|| anyhow::anyhow!("eof"))?;
    if !nonzero {
        block.fill(0.0);
    } else {
        let emax = r.read(8).ok_or_else(|| anyhow::anyhow!("eof"))? as i32 - 127;
        let used = (start_rem - r.remaining()) as usize;
        let mut nb = vec![0u32; block.len()];
        bitplane::decode_ints(&mut nb, maxbits.saturating_sub(used), r);
        let perm = transform::perm(ndim);
        let mut q = vec![0i32; block.len()];
        for (pi, &srci) in perm.iter().enumerate() {
            q[srci] = from_negabinary(nb[pi]);
        }
        transform::inverse(&mut q, ndim);
        let scale = exp2i(emax - 28);
        for (o, &v) in block.iter_mut().zip(&q) {
            *o = v as f32 * scale;
        }
    }
    // consume padding up to maxbits
    let used = (start_rem - r.remaining()) as usize;
    if used < maxbits {
        r.skip((maxbits - used) as u32);
    }
    Ok(())
}

/// 2^e as f32 (exact for |e| < 127).
fn exp2i(e: i32) -> f32 {
    f32::from_bits((((e + 127).clamp(1, 254)) as u32) << 23)
}

const NBMASK: u32 = 0xaaaa_aaaa;

#[inline]
fn negabinary(x: i32) -> u32 {
    ((x as u32).wrapping_add(NBMASK)) ^ NBMASK
}

#[inline]
fn from_negabinary(u: u32) -> i32 {
    ((u ^ NBMASK).wrapping_sub(NBMASK)) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::psnr;
    use crate::testkit::fields::{make, Regime};

    #[test]
    fn negabinary_roundtrip() {
        for x in [-5i32, -1, 0, 1, 7, i32::MAX / 2, i32::MIN / 2] {
            assert_eq!(from_negabinary(negabinary(x)), x);
        }
    }

    #[test]
    fn high_rate_is_near_lossless() {
        let data = make(Regime::Smooth, 64 * 64, 11);
        let z = Zfp::new(30.0);
        let s = z.compress(&data, &[64, 64]).unwrap();
        let out = z.decompress(&s).unwrap();
        let p = psnr(&data, &out);
        assert!(p > 90.0, "psnr {p}");
    }

    #[test]
    fn rate_controls_size_exactly() {
        let data = make(Regime::Noisy, 4096, 12);
        for rate in [4.0, 8.0, 16.0] {
            let z = Zfp::new(rate);
            let s = z.compress(&data, &[4096]).unwrap();
            let expect_bits = (4096 / 4) * z.maxbits(1);
            assert_eq!(s.bits as usize, expect_bits, "rate {rate}");
        }
    }

    #[test]
    fn quality_improves_with_rate() {
        let data = make(Regime::Smooth, 32 * 32 * 32, 13);
        let dims = [32usize, 32, 32];
        let mut last = 0.0;
        for rate in [2.0, 4.0, 8.0, 16.0] {
            let z = Zfp::new(rate);
            let out = z.decompress(&z.compress(&data, &dims).unwrap()).unwrap();
            let p = psnr(&data, &out);
            assert!(p > last, "rate {rate}: psnr {p} <= {last}");
            last = p;
        }
        assert!(last > 60.0, "16-bit rate should be high quality: {last}");
    }

    #[test]
    fn non_multiple_of_four_dims() {
        let data = make(Regime::Smooth, 33 * 35, 14);
        let z = Zfp::new(8.0);
        let s = z.compress(&data, &[33, 35]).unwrap();
        let out = z.decompress(&s).unwrap();
        assert_eq!(out.len(), data.len());
        let p = psnr(&data, &out);
        assert!(p > 25.0, "psnr {p}");
    }

    #[test]
    fn all_zero_blocks_cost_header_only_quality() {
        let data = vec![0f32; 4096];
        let z = Zfp::new(8.0);
        let out = z.decompress(&z.compress(&data, &[4096]).unwrap()).unwrap();
        assert_eq!(out, data);
    }
}
