//! ZFP's embedded bit-plane coder with group testing (encode_ints /
//! decode_ints from the reference implementation): planes are emitted MSB
//! to LSB; within a plane the first `n` already-significant coefficients
//! are emitted verbatim and the rest are unary run-length coded, with `n`
//! growing as coefficients become significant. Truncation at the bit
//! budget realizes the fixed rate.

use crate::util::bitio::{BitReader, BitWriter};

/// Encode `data` (negabinary, sequency-ordered) into at most `maxbits` bits.
pub fn encode_ints(data: &[u32], maxbits: usize, w: &mut BitWriter) {
    let size = data.len();
    debug_assert!(size <= 64);
    let mut bits = maxbits;
    let mut n = 0usize;
    let mut k = 32usize;
    while bits > 0 && k > 0 {
        k -= 1;
        // gather plane k
        let mut x: u64 = 0;
        for (i, &d) in data.iter().enumerate() {
            x |= (((d >> k) & 1) as u64) << i;
        }
        // step 2: first n bits verbatim
        let m = n.min(bits);
        w.write(x, m as u32);
        bits -= m;
        x = if m >= 64 { 0 } else { x >> m };
        // step 3: unary run-length encode the remainder
        while n < size && bits > 0 {
            bits -= 1;
            let any = x != 0;
            w.write_bit(any);
            if !any {
                break;
            }
            while n < size - 1 && bits > 0 {
                bits -= 1;
                let b = x & 1;
                w.write_bit(b != 0);
                if b != 0 {
                    break;
                }
                x >>= 1;
                n += 1;
            }
            x >>= 1;
            n += 1;
        }
    }
}

/// Decode into `data` (must be zeroed, same length as at encode time).
pub fn decode_ints(data: &mut [u32], maxbits: usize, r: &mut BitReader) {
    let size = data.len();
    data.fill(0);
    let mut bits = maxbits;
    let mut n = 0usize;
    let mut k = 32usize;
    while bits > 0 && k > 0 {
        k -= 1;
        let m = n.min(bits);
        let mut x = r.read(m as u32).unwrap_or(0);
        bits -= m;
        while n < size && bits > 0 {
            bits -= 1;
            let any = r.read_bit().unwrap_or(false);
            if !any {
                break;
            }
            while n < size - 1 && bits > 0 {
                bits -= 1;
                let b = r.read_bit().unwrap_or(false);
                if b {
                    break;
                }
                n += 1;
            }
            x += 1u64 << n;
            n += 1;
        }
        // deposit plane k
        let mut xx = x;
        let mut i = 0usize;
        while xx != 0 {
            data[i] += ((xx & 1) as u32) << k;
            xx >>= 1;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn roundtrip(data: &[u32], maxbits: usize) -> Vec<u32> {
        let mut w = BitWriter::new();
        encode_ints(data, maxbits, &mut w);
        let (words, bits) = w.finish();
        let mut r = BitReader::new(&words, bits.max(1));
        let mut out = vec![0u32; data.len()];
        decode_ints(&mut out, maxbits, &mut r);
        out
    }

    #[test]
    fn lossless_at_generous_budget() {
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let data: Vec<u32> = (0..16).map(|_| rng.next_u64() as u32).collect();
            let out = roundtrip(&data, 16 * 64);
            assert_eq!(out, data);
        }
    }

    #[test]
    fn truncation_preserves_high_planes() {
        let mut rng = Rng::new(6);
        let data: Vec<u32> = (0..64).map(|_| rng.next_u64() as u32).collect();
        let out = roundtrip(&data, 64 * 8);
        // truncated reconstruction must agree on the top bit planes that
        // were fully coded; check error is bounded by a low-plane mask
        for (a, b) in data.iter().zip(&out) {
            let diff = a ^ b;
            assert!(diff < 1 << 30, "top planes corrupted: {a:x} vs {b:x}");
        }
    }

    #[test]
    fn error_shrinks_with_budget() {
        let mut rng = Rng::new(7);
        let data: Vec<u32> = (0..64).map(|_| rng.next_u64() as u32).collect();
        let mut last_err = u64::MAX;
        for budget in [128usize, 512, 1024, 4096] {
            let out = roundtrip(&data, budget);
            let err: u64 = data
                .iter()
                .zip(&out)
                .map(|(&a, &b)| (a as i64 - b as i64).unsigned_abs())
                .sum();
            assert!(err <= last_err, "budget {budget}: {err} > {last_err}");
            last_err = err;
        }
        assert_eq!(last_err, 0);
    }

    #[test]
    fn sparse_data_codes_compactly() {
        // one significant coefficient: unary tests should terminate planes
        // quickly, so even a small budget reconstructs exactly
        let mut data = vec![0u32; 64];
        data[0] = 0x00f0_0000;
        let out = roundtrip(&data, 400);
        assert_eq!(out, data);
    }

    #[test]
    fn zero_block_zero_bits_needed() {
        let data = vec![0u32; 16];
        let mut w = BitWriter::new();
        encode_ints(&data, 1024, &mut w);
        let (_, bits) = w.finish();
        // 32 planes x 1 group-test bit
        assert_eq!(bits, 32);
    }
}
