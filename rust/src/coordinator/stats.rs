//! Per-run statistics: stage timings (Table 7 rows) and size accounting.

use crate::codec::{CodecGranularity, EncoderKind};
use crate::obs::RunTimings;

#[derive(Debug, Clone, Default)]
pub struct CompressStats {
    pub timer: RunTimings,
    pub original_bytes: usize,
    pub compressed_bytes: usize,
    pub n_slabs: usize,
    pub n_outliers: usize,
    pub n_verbatim: usize,
    /// Bits in the encoded symbol stream (pre-lossless), whichever
    /// encoder(s) produced it.
    pub encoded_bits: u64,
    pub repr_bits: u32,
    /// Which encoder backend compressed this field (the resolved choice
    /// when the config said `auto`; the majority backend at chunk
    /// granularity — `chunk_counts` has the full tally).
    pub encoder: EncoderKind,
    /// Selection granularity this field was encoded at.
    pub granularity: CodecGranularity,
    /// Chunks encoded per backend, indexed by [`EncoderKind::to_tag`].
    /// Uniform archives tally every chunk under the one encoder; at chunk
    /// granularity this is the measured cost model's per-chunk verdict.
    pub chunk_counts: [usize; EncoderKind::ALL.len()],
    pub abs_eb: f32,
    /// Decode-throughput budget (`--target-gbps`) this field was
    /// compressed under; 0 when the knob was off.
    pub target_gbps: f64,
    /// Backends the budget pruned before `auto`'s selection argmin,
    /// indexed by [`EncoderKind::to_tag`]; all-false when nothing was
    /// pruned (knob off, forced encoder, or every backend met the budget).
    pub pruned: [bool; EncoderKind::ALL.len()],
}

impl CompressStats {
    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    pub fn bitrate(&self) -> f64 {
        32.0 / self.compression_ratio()
    }

    /// Chunks this field encoded with `kind`.
    pub fn chunks_for(&self, kind: EncoderKind) -> usize {
        self.chunk_counts[kind.to_tag() as usize]
    }

    /// Compact per-backend chunk tally, e.g. `huffman:3 fle:2 rle:7`
    /// (backends with zero chunks are omitted).
    pub fn chunk_report(&self) -> String {
        let parts: Vec<String> = EncoderKind::ALL
            .into_iter()
            .filter(|&k| self.chunks_for(k) > 0)
            .map(|k| format!("{}:{}", k.name(), self.chunks_for(k)))
            .collect();
        if parts.is_empty() { "-".to_string() } else { parts.join(" ") }
    }

    /// Backends the `--target-gbps` budget pruned, e.g. `huffman rle`;
    /// `-` when nothing was pruned.
    pub fn pruned_report(&self) -> String {
        let parts: Vec<&str> = EncoderKind::ALL
            .into_iter()
            .filter(|&k| self.pruned[k.to_tag() as usize])
            .map(|k| k.name())
            .collect();
        if parts.is_empty() { "-".to_string() } else { parts.join(" ") }
    }

    pub fn report(&self) -> String {
        let target = if self.target_gbps > 0.0 {
            format!(", target {:.1} GB/s pruned {}", self.target_gbps, self.pruned_report())
        } else {
            String::new()
        };
        format!(
            "original {:.2} MB -> compressed {:.2} MB  CR {:.2}x  bitrate {:.2} b/v  \
             (encoder {} [{} granularity, chunks {}], outliers {}, verbatim {}, repr u{}{})\n{}",
            self.original_bytes as f64 / 1e6,
            self.compressed_bytes as f64 / 1e6,
            self.compression_ratio(),
            self.bitrate(),
            self.encoder.name(),
            self.granularity.name(),
            self.chunk_report(),
            self.n_outliers,
            self.n_verbatim,
            self.repr_bits,
            target,
            self.timer.report(self.original_bytes)
        )
    }
}

#[derive(Debug, Clone, Default)]
pub struct DecompressStats {
    pub timer: RunTimings,
    pub original_bytes: usize,
    /// Worker threads the decode + fused slab pass actually ran with
    /// (the CLI/serve budget after the 0 = all-cores fallback).
    pub threads: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_math() {
        let s = CompressStats {
            original_bytes: 4_000_000,
            compressed_bytes: 400_000,
            ..Default::default()
        };
        assert!((s.compression_ratio() - 10.0).abs() < 1e-12);
        assert!((s.bitrate() - 3.2).abs() < 1e-12);
    }

    #[test]
    fn chunk_report_tallies_backends() {
        let mut s = CompressStats::default();
        assert_eq!(s.chunk_report(), "-");
        s.chunk_counts[EncoderKind::Huffman.to_tag() as usize] = 3;
        s.chunk_counts[EncoderKind::Rle.to_tag() as usize] = 7;
        assert_eq!(s.chunks_for(EncoderKind::Huffman), 3);
        assert_eq!(s.chunks_for(EncoderKind::Fle), 0);
        assert_eq!(s.chunk_report(), "huffman:3 rle:7");
        assert!(s.report().contains("huffman:3 rle:7"));
    }
}
