//! Per-run statistics: stage timings (Table 7 rows) and size accounting.

use crate::codec::EncoderKind;
use crate::metrics::StageTimer;

#[derive(Debug, Clone, Default)]
pub struct CompressStats {
    pub timer: StageTimer,
    pub original_bytes: usize,
    pub compressed_bytes: usize,
    pub n_slabs: usize,
    pub n_outliers: usize,
    pub n_verbatim: usize,
    /// Bits in the encoded symbol stream (pre-lossless), whichever
    /// encoder produced it.
    pub encoded_bits: u64,
    pub repr_bits: u32,
    /// Which encoder backend compressed this field (the resolved choice
    /// when the config said `auto`).
    pub encoder: EncoderKind,
    pub abs_eb: f32,
}

impl CompressStats {
    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    pub fn bitrate(&self) -> f64 {
        32.0 / self.compression_ratio()
    }

    pub fn report(&self) -> String {
        format!(
            "original {:.2} MB -> compressed {:.2} MB  CR {:.2}x  bitrate {:.2} b/v  \
             (encoder {}, outliers {}, verbatim {}, repr u{})\n{}",
            self.original_bytes as f64 / 1e6,
            self.compressed_bytes as f64 / 1e6,
            self.compression_ratio(),
            self.bitrate(),
            self.encoder.name(),
            self.n_outliers,
            self.n_verbatim,
            self.repr_bits,
            self.timer.report(self.original_bytes)
        )
    }
}

#[derive(Debug, Clone, Default)]
pub struct DecompressStats {
    pub timer: StageTimer,
    pub original_bytes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_math() {
        let s = CompressStats {
            original_bytes: 4_000_000,
            compressed_bytes: 400_000,
            ..Default::default()
        };
        assert!((s.compression_ratio() - 10.0).abs() < 1e-12);
        assert!((s.bitrate() - 3.2).abs() < 1e-12);
    }
}
