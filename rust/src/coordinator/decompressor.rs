//! Per-field decompression orchestration (Figure 1, bottom path):
//! decode via the header-tagged encoder stage → rebuild deltas (patch
//! outliers) → inverse Lorenzo (engine) → scatter slabs → verbatim
//! overwrite.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::{Coordinator, DecompressStats};
use crate::codec;
use crate::container::Archive;
use crate::field::Field;
use crate::metrics::StageTimer;
use crate::sz::blocks::{scatter_slab, tile_grid};
use crate::util::pool::parallel_map;

pub fn decompress(coord: &Coordinator, archive: &Archive) -> Result<(Field, DecompressStats)> {
    let cfg = &coord.cfg;
    let mut timer = StageTimer::new();
    let t_total = Instant::now();
    let h = &archive.header;
    let abs_eb = h.abs_eb;
    let radius = (h.dict_size / 2) as i32;

    // geometry must reproduce compression exactly
    let logical_dims = h.dims.clone();
    let kernel_dims = if logical_dims.len() == 4 {
        vec![logical_dims[0], logical_dims[1], logical_dims[2] * logical_dims[3]]
    } else {
        logical_dims.clone()
    };
    let spec = coord
        .spec_for(&kernel_dims)
        .with_context(|| format!("variant {} unavailable", h.variant))?
        .clone();
    if spec.name != h.variant {
        bail!("archive variant {} != resolved {}", h.variant, spec.name);
    }
    let grid = tile_grid(&kernel_dims, &spec);
    if grid.len() != h.n_slabs {
        bail!("slab count mismatch: {} vs {}", grid.len(), h.n_slabs);
    }

    // ---- decode the symbol stream --------------------------------------
    // the stage is picked by the archive's tags, not the config: a
    // Huffman coordinator decodes FLE/RLE archives and vice versa, and a
    // mixed-granularity archive dispatches per chunk from its tag table
    let t0 = Instant::now();
    let threads = cfg.effective_threads();
    let slab_len = spec.len();
    let expected_symbols = slab_len * grid.len();
    let symbols = if !archive.chunk_tags.is_empty() {
        codec::chunked::decode_chunked(
            &archive.chunk_tags,
            &archive.encoder_aux,
            &archive.chunk_aux,
            &archive.stream,
            h.dict_size,
            threads,
            expected_symbols,
        )?
    } else {
        codec::stage_for(h.encoder).decode(
            &archive.encoder_aux,
            &archive.stream,
            h.dict_size,
            threads,
            expected_symbols,
        )?
    };
    if symbols.len() != expected_symbols {
        bail!("symbol count {} != {expected_symbols}", symbols.len());
    }
    timer.add("1.decode", t0.elapsed());

    // ---- rebuild per-slab deltas (patch prediction outliers) -----------
    let t0 = Instant::now();
    // outliers are stored sorted by global (slab-major) position; split
    // them per slab so each worker patches its own range
    for w in archive.outliers.windows(2) {
        if w[0].0 >= w[1].0 {
            bail!("outlier positions not strictly increasing");
        }
    }
    if let Some(&(last, _)) = archive.outliers.last() {
        if last as usize >= slab_len * grid.len() {
            bail!("outlier position {last} out of range");
        }
    }
    let mut slab_deltas: Vec<Vec<i32>> = Vec::with_capacity(grid.len());
    let mut oi = 0usize;
    for si in 0..grid.len() {
        let syms = &symbols[si * slab_len..(si + 1) * slab_len];
        let mut delta: Vec<i32> =
            syms.iter().map(|&c| if c == 0 { 0 } else { c as i32 - radius }).collect();
        let base = (si * slab_len) as u64;
        let end = base + slab_len as u64;
        while oi < archive.outliers.len() && archive.outliers[oi].0 < end {
            let (pos, d) = archive.outliers[oi];
            delta[(pos - base) as usize] = d;
            oi += 1;
        }
        slab_deltas.push(delta);
    }
    timer.add("2.patch-outliers", t0.elapsed());

    // ---- inverse Lorenzo per slab, scatter into the field ---------------
    let t0 = Instant::now();
    let n: usize = kernel_dims.iter().product();
    let deltas_cell: Vec<std::sync::Mutex<Vec<i32>>> =
        slab_deltas.into_iter().map(std::sync::Mutex::new).collect();
    let slabs: Vec<Result<Vec<f32>>> = parallel_map(threads, &deltas_cell, |_, cell| {
        let delta = std::mem::take(&mut *cell.lock().unwrap());
        coord.engine().decompress_slab_owned(&spec, delta, abs_eb)
    });
    let mut out = vec![0f32; n];
    for (si, (slab, idx)) in slabs.into_iter().zip(&grid).enumerate() {
        let slab = slab.with_context(|| format!("slab {si}"))?;
        scatter_slab(&mut out, &kernel_dims, &spec, idx, &slab);
    }
    timer.add("3.reverse-predict-quant", t0.elapsed());

    // ---- verbatim overwrites -------------------------------------------
    let t0 = Instant::now();
    for &(pos, val) in &archive.verbatim {
        // verbatim positions are slab-stream positions: map back to field
        let pos = pos as usize;
        let si = pos / slab_len;
        let within = pos % slab_len;
        if si >= grid.len() {
            bail!("verbatim slab {si} out of range");
        }
        if let Some(field_off) = slab_to_field_offset(&kernel_dims, &spec, &grid[si], within) {
            out[field_off] = val;
        }
    }
    timer.add("4.verbatim", t0.elapsed());
    timer.add("total", t_total.elapsed());

    let field = Field::new(h.field_name.clone(), logical_dims, out)?;
    let stats = DecompressStats { timer, original_bytes: field.size_bytes() };
    Ok((field, stats))
}

/// Map an in-slab row-major offset to the field offset (None if padding).
fn slab_to_field_offset(
    dims: &[usize],
    spec: &crate::sz::blocks::SlabSpec,
    idx: &crate::sz::blocks::SlabIndex,
    within: usize,
) -> Option<usize> {
    let nd = dims.len();
    let mut rem = within;
    let mut coord = vec![0usize; nd];
    for ax in (0..nd).rev() {
        coord[ax] = rem % spec.shape[ax];
        rem /= spec.shape[ax];
    }
    let mut off = 0usize;
    let mut stride = 1usize;
    for ax in (0..nd).rev() {
        if coord[ax] >= idx.valid[ax] {
            return None; // padding region
        }
        off += (idx.origin[ax] + coord[ax]) * stride;
        stride *= dims[ax];
    }
    Some(off)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sz::blocks::SlabSpec;

    #[test]
    fn slab_offset_mapping_2d() {
        let dims = [5usize, 7];
        let spec = SlabSpec::new("t", &[4, 4], &[2, 2]);
        let grid = tile_grid(&dims, &spec);
        // slab (1,1): origin (4,4), valid (1,3)
        let idx = &grid[3];
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 0), Some(4 * 7 + 4));
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 2), Some(4 * 7 + 6));
        // row 0, col 3 is padding (valid cols = 3)
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 3), None);
        // row 1 entirely padding (valid rows = 1)
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 4), None);
    }
}
