//! Per-field decompression orchestration (Figure 1, bottom path),
//! mirroring the zero-copy encode path: decode via the header-tagged
//! encoder stage straight into per-slab symbol buffers (a
//! [`codec::SymbolSink`], no whole-field `Vec<u16>`), then one
//! slab-parallel fused pass — patch prediction outliers, inverse
//! Lorenzo, verbatim overwrites, scatter — over arena-loaned scratch
//! into a partitioned output view.
//!
//! The outlier and verbatim side channels are stored sorted by global
//! (slab-major) position, so each worker locates its slab's entries with
//! `partition_point` instead of the old whole-channel validation scan +
//! shared sequential cursor; hostile inputs (out-of-range or unsorted
//! positions) still fail cleanly, now inside the owning slab's worker.
//!
//! The pre-fusion materializing path is kept as
//! [`decompress_materializing`]: `cusz bench` prices the fused pipeline
//! against it, and the acceptance tests assert both produce bit-identical
//! fields.

use std::io::Write;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::{Coordinator, DecompressStats};
use crate::codec::{self, SymbolSink};
use crate::container::Archive;
use crate::field::{self, Field};
use crate::obs::{self, keys, RunTimings};
use crate::sz::blocks::{
    band_local, band_plan, scatter_slab, tile_grid, PartitionedField, SlabIndex, SlabSpec,
};
use crate::util::arena;
use crate::util::pool::{parallel_map, parallel_map_range};

pub fn decompress(coord: &Coordinator, archive: &Archive) -> Result<(Field, DecompressStats)> {
    decompress_with_threads(coord, archive, coord.cfg.effective_threads())
}

/// Geometry shared by the fused and baseline paths: must reproduce
/// compression exactly.
struct Geometry {
    logical_dims: Vec<usize>,
    kernel_dims: Vec<usize>,
    spec: SlabSpec,
    grid: Vec<SlabIndex>,
    abs_eb: f32,
    radius: i32,
}

fn resolve_geometry(coord: &Coordinator, archive: &Archive) -> Result<Geometry> {
    let h = &archive.header;
    let logical_dims = h.dims.clone();
    let kernel_dims = if logical_dims.len() == 4 {
        vec![logical_dims[0], logical_dims[1], logical_dims[2] * logical_dims[3]]
    } else {
        logical_dims.clone()
    };
    let spec = coord
        .spec_for(&kernel_dims)
        .with_context(|| format!("variant {} unavailable", h.variant))?
        .clone();
    if spec.name != h.variant {
        bail!("archive variant {} != resolved {}", h.variant, spec.name);
    }
    let grid = tile_grid(&kernel_dims, &spec);
    if grid.len() != h.n_slabs {
        bail!("slab count mismatch: {} vs {}", grid.len(), h.n_slabs);
    }
    Ok(Geometry {
        logical_dims,
        kernel_dims,
        spec,
        grid,
        abs_eb: h.abs_eb,
        radius: (h.dict_size / 2) as i32,
    })
}

/// Split a sorted global-position side channel into per-slab index
/// ranges via `partition_point` (O(S log N) instead of the old O(N)
/// whole-channel pre-scan). Returns `n_slabs` half-open `[lo, hi)`
/// ranges tiling the channel. On a sorted channel the ranges are exact;
/// an unsorted channel still yields ranges that tile `[0, len)`, so
/// every entry lands in *some* slab's range and the per-slab in-range /
/// ordering checks catch the corruption there. The only case those
/// checks cannot see — entries past the final boundary — is rejected
/// here.
fn split_channel_ranges<T>(
    entries: &[T],
    pos: impl Fn(&T) -> u64,
    slab_len: usize,
    n_slabs: usize,
    what: &str,
) -> Result<Vec<(usize, usize)>> {
    let mut bounds = Vec::with_capacity(n_slabs + 1);
    bounds.push(0usize);
    for si in 1..=n_slabs {
        let limit = (si * slab_len) as u64;
        bounds.push(entries.partition_point(|e| pos(e) < limit));
    }
    let covered = *bounds.last().expect("bounds non-empty");
    if covered != entries.len() {
        bail!("{what} position {} out of range", pos(&entries[covered]));
    }
    Ok(bounds.windows(2).map(|w| (w[0], w[1])).collect())
}

/// The fused zero-copy decompress path. `threads` is the worker budget
/// for every stage (the segmented-tail decode upstream takes its own
/// budget at parse time); batch pipelines pass their per-job share.
pub fn decompress_with_threads(
    coord: &Coordinator,
    archive: &Archive,
    threads: usize,
) -> Result<(Field, DecompressStats)> {
    let threads = threads.max(1);
    let mut timer = RunTimings::new();
    let t_total = Instant::now();
    let h = &archive.header;
    let geo = resolve_geometry(coord, archive)?;
    let (spec, grid) = (&geo.spec, &geo.grid);
    let slab_len = spec.len();
    // original (reconstructed) bytes, the paper's throughput denominator
    let field_bytes = (slab_len * grid.len() * 4) as u64;

    // ---- stage 1: decode chunk-parallel into per-slab code buffers ----
    let t0 = Instant::now();
    let slab_codes = decode_slab_codes(archive, slab_len, grid.len(), threads)?;
    timer.add_recorded("1.decode", keys::DECOMPRESS_DECODE, t0.elapsed(), field_bytes);

    // ---- stage 2: fused per-slab patch → inverse Lorenzo → verbatim →
    // scatter, one slab-parallel pass over arena-loaned scratch ----------
    let t0 = Instant::now();
    let outlier_ranges =
        split_channel_ranges(&archive.outliers, |o| o.0, slab_len, grid.len(), "outlier")?;
    let verbatim_ranges =
        split_channel_ranges(&archive.verbatim, |v| v.0, slab_len, grid.len(), "verbatim")?;
    let n: usize = geo.kernel_dims.iter().product();
    let mut out = vec![0f32; n];
    // one worker per slab: build deltas in arena-loaned i32 scratch,
    // patch this slab's outlier range, reconstruct in place into
    // arena-loaned f32 scratch, apply this slab's verbatim range, and
    // scatter into the slab's disjoint region of the output view
    let results: Vec<Result<()>> = {
        let view = PartitionedField::new(&mut out);
        parallel_map_range(threads, grid.len(), |si| {
            fuse_slab_into(
                coord,
                archive,
                &geo,
                &slab_codes,
                &outlier_ranges,
                &verbatim_ranges,
                si,
                &view,
                &geo.kernel_dims,
                &grid[si],
            )
        })
    };
    for (si, r) in results.into_iter().enumerate() {
        r.with_context(|| format!("slab {si}"))?;
    }
    timer.add_recorded(
        "2.patch-reverse-scatter",
        keys::DECOMPRESS_FUSED_RECONSTRUCT,
        t0.elapsed(),
        field_bytes,
    );
    timer.add_recorded("total", keys::DECOMPRESS_TOTAL, t_total.elapsed(), field_bytes);
    obs::global().add("decompress.fields", 1);

    let field = Field::new(h.field_name.clone(), geo.logical_dims, out)?;
    let stats = DecompressStats { timer, original_bytes: field.size_bytes(), threads };
    Ok((field, stats))
}

/// Streaming decompress: the fused slab pass feeds straight into a
/// `Write` sink, one *band* at a time (see [`band_plan`]), so the whole
/// reconstructed f32 field is never resident.
///
/// Stage 1 (chunk-parallel decode into per-slab code buffers) is shared
/// with [`decompress_with_threads`] — the codec layer validates the
/// chunk partition over the whole symbol stream, and the codes cost only
/// 2 B/elem. Stage 2 fuses each band's slabs in parallel into a reusable
/// band buffer, streams the band's rows out as little-endian f32 bytes
/// (layout identical in kernel and logical space — the 4D fold only
/// merges trailing axes), and retires the band's code buffers, so peak
/// working set falls from field + codes to codes + one band. The bytes
/// written are exactly `Field::write_f32_into` of the in-memory result.
/// The caller owns buffering and flushing of `sink`.
pub fn decompress_stream_into(
    coord: &Coordinator,
    archive: &Archive,
    threads: usize,
    sink: &mut dyn Write,
) -> Result<DecompressStats> {
    let threads = threads.max(1);
    let mut timer = RunTimings::new();
    let t_total = Instant::now();
    let geo = resolve_geometry(coord, archive)?;
    let (spec, grid) = (&geo.spec, &geo.grid);
    let slab_len = spec.len();
    let field_bytes = (slab_len * grid.len() * 4) as u64;

    // ---- stage 1: decode chunk-parallel into per-slab code buffers ----
    let t0 = Instant::now();
    let mut slab_codes = decode_slab_codes(archive, slab_len, grid.len(), threads)?;
    timer.add_recorded("1.decode", keys::DECOMPRESS_DECODE, t0.elapsed(), field_bytes);

    // ---- stage 2: band-streamed fuse → sink ---------------------------
    let t0 = Instant::now();
    let outlier_ranges =
        split_channel_ranges(&archive.outliers, |o| o.0, slab_len, grid.len(), "outlier")?;
    let verbatim_ranges =
        split_channel_ranges(&archive.verbatim, |v| v.0, slab_len, grid.len(), "verbatim")?;
    let bands = band_plan(&geo.kernel_dims, spec, grid);
    let row_elems: usize = geo.kernel_dims[1..].iter().product();
    let mut band_buf = vec![0f32; spec.shape[0] * row_elems];
    for band in &bands {
        let elems = band.field_elems(&geo.kernel_dims);
        band_buf.truncate(elems); // only the tail band shrinks
        let mut band_dims = geo.kernel_dims.clone();
        band_dims[0] = band.rows;
        // the band's valid slab regions tile the band buffer exactly, so
        // every element is written before the band is streamed out
        let results: Vec<Result<()>> = {
            let view = PartitionedField::new(&mut band_buf[..elems]);
            parallel_map_range(threads, band.slab_hi - band.slab_lo, |bi| {
                let si = band.slab_lo + bi;
                fuse_slab_into(
                    coord,
                    archive,
                    &geo,
                    &slab_codes,
                    &outlier_ranges,
                    &verbatim_ranges,
                    si,
                    &view,
                    &band_dims,
                    &band_local(&grid[si], band),
                )
            })
        };
        for (bi, r) in results.into_iter().enumerate() {
            r.with_context(|| format!("slab {}", band.slab_lo + bi))?;
        }
        field::write_f32_into(&band_buf[..elems], sink)?;
        // retire this band's code buffers: working set shrinks as we go
        for codes in &mut slab_codes[band.slab_lo..band.slab_hi] {
            *codes = Vec::new();
        }
    }
    timer.add_recorded(
        "2.patch-reverse-scatter",
        keys::DECOMPRESS_FUSED_RECONSTRUCT,
        t0.elapsed(),
        field_bytes,
    );
    timer.add_recorded("total", keys::DECOMPRESS_TOTAL, t_total.elapsed(), field_bytes);
    obs::global().add("decompress.fields", 1);

    let n: usize = geo.kernel_dims.iter().product();
    Ok(DecompressStats { timer, original_bytes: n * 4, threads })
}

/// Stage 1 of the fused and streaming paths: decode the symbol stream
/// chunk-parallel into per-slab code buffers. The stage is picked by the
/// archive's tags, not the config: a Huffman coordinator decodes FLE/RLE
/// archives and vice versa, and a mixed-granularity archive dispatches
/// per chunk from its tag table. Decoded chunk windows land directly in
/// the slab buffers (straddles stitch through the arena) — the
/// whole-field symbol buffer of the materializing path never exists.
fn decode_slab_codes(
    archive: &Archive,
    slab_len: usize,
    n_slabs: usize,
    threads: usize,
) -> Result<Vec<Vec<u16>>> {
    let h = &archive.header;
    let mut slab_codes: Vec<Vec<u16>> = (0..n_slabs).map(|_| vec![0u16; slab_len]).collect();
    {
        let views: Vec<&mut [u16]> = slab_codes.iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut sink = SymbolSink::from_slabs(views, slab_len.max(1))?;
        if !archive.chunk_tags.is_empty() {
            codec::chunked::decode_chunked_into_with_gaps(
                &archive.chunk_tags,
                &archive.encoder_aux,
                &archive.chunk_aux,
                &archive.stream,
                &archive.gap_tables,
                h.dict_size,
                threads,
                &mut sink,
            )?;
        } else if h.encoder == codec::EncoderKind::Huffman && !archive.gap_tables.is_empty() {
            // gap-tabled Huffman archive: chunks fan out across workers
            // and each large chunk splits further across its subchunks,
            // so even a single-chunk field saturates the thread budget
            codec::huffman_stage::decode_into_gap(
                &archive.encoder_aux,
                &archive.stream,
                &archive.gap_tables,
                h.dict_size,
                threads,
                &mut sink,
            )?;
        } else {
            codec::stage_for(h.encoder).decode_into(
                &archive.encoder_aux,
                &archive.stream,
                h.dict_size,
                threads,
                &mut sink,
            )?;
        }
    }
    Ok(slab_codes)
}

/// The fused per-slab reconstruction: build deltas in arena-loaned i32
/// scratch, patch this slab's outlier range, inverse-Lorenzo into
/// arena-loaned f32 scratch, apply this slab's verbatim range, scatter
/// into `view`. `scatter_dims`/`scatter_idx` address the view: the whole
/// field (`kernel_dims` + the grid index) for the in-memory path, or a
/// band buffer (band dims + the band-local index) for the streaming one.
#[allow(clippy::too_many_arguments)]
fn fuse_slab_into(
    coord: &Coordinator,
    archive: &Archive,
    geo: &Geometry,
    slab_codes: &[Vec<u16>],
    outlier_ranges: &[(usize, usize)],
    verbatim_ranges: &[(usize, usize)],
    si: usize,
    view: &PartitionedField<'_>,
    scatter_dims: &[usize],
    scatter_idx: &SlabIndex,
) -> Result<()> {
    let spec = &geo.spec;
    let slab_len = spec.len();
    let base = (si * slab_len) as u64;
    let end = base + slab_len as u64;
    let codes = &slab_codes[si];
    arena::with_i32(|delta| -> Result<()> {
        delta.clear();
        delta.extend(codes.iter().map(|&c| if c == 0 { 0 } else { c as i32 - geo.radius }));
        // patch prediction outliers: this slab's sorted range, found
        // by partition_point — hostile-input checks stay per slab
        let (lo, hi) = outlier_ranges[si];
        let mut prev: Option<u64> = None;
        for &(pos, d) in &archive.outliers[lo..hi] {
            if pos < base || pos >= end {
                bail!("outlier position {pos} outside slab {si} (channel not sorted?)");
            }
            if prev.is_some_and(|p| pos <= p) {
                bail!("outlier positions not strictly increasing");
            }
            prev = Some(pos);
            delta[(pos - base) as usize] = d;
        }
        arena::with_f32(|slab| -> Result<()> {
            slab.clear();
            slab.resize(slab_len, 0.0);
            coord.engine().decompress_slab_into(spec, delta, geo.abs_eb, slab)?;
            // verbatim overwrites in slab coordinates (padding slots
            // are dropped by the valid-region scatter below, exactly
            // as the old field-offset mapping dropped them)
            let (lo, hi) = verbatim_ranges[si];
            for &(pos, val) in &archive.verbatim[lo..hi] {
                if pos < base || pos >= end {
                    bail!("verbatim position {pos} outside slab {si} (channel not sorted?)");
                }
                slab[(pos - base) as usize] = val;
            }
            view.scatter(scatter_dims, spec, scatter_idx, slab);
            Ok(())
        })
    })
}

/// The pre-fusion decompress path: decode to one whole-field symbol
/// buffer, rebuild per-slab deltas sequentially behind a shared cursor,
/// inverse-Lorenzo behind `Mutex` cells, scatter and patch verbatim
/// serially. Kept (not emulated) so `cusz bench` prices the fused
/// pipeline against the real thing and tests can assert bit-identical
/// output; not wired to any production entry point.
pub fn decompress_materializing(
    coord: &Coordinator,
    archive: &Archive,
) -> Result<(Field, DecompressStats)> {
    // local-only timings: the baseline must not pollute the global
    // registry's production stage aggregates it is benchmarked against
    let mut timer = RunTimings::new();
    let t_total = Instant::now();
    let h = &archive.header;
    let geo = resolve_geometry(coord, archive)?;
    let (spec, grid) = (&geo.spec, &geo.grid);
    let slab_len = spec.len();
    let threads = coord.cfg.effective_threads();

    // ---- decode the symbol stream (whole-field materialization) --------
    let t0 = Instant::now();
    let expected_symbols = slab_len * grid.len();
    let symbols = if !archive.chunk_tags.is_empty() {
        codec::chunked::decode_chunked(
            &archive.chunk_tags,
            &archive.encoder_aux,
            &archive.chunk_aux,
            &archive.stream,
            h.dict_size,
            threads,
            expected_symbols,
        )?
    } else {
        codec::stage_for(h.encoder).decode(
            &archive.encoder_aux,
            &archive.stream,
            h.dict_size,
            threads,
            expected_symbols,
        )?
    };
    if symbols.len() != expected_symbols {
        bail!("symbol count {} != {expected_symbols}", symbols.len());
    }
    timer.add("1.decode", t0.elapsed());

    // ---- rebuild per-slab deltas (patch prediction outliers) -----------
    let t0 = Instant::now();
    for w in archive.outliers.windows(2) {
        if w[0].0 >= w[1].0 {
            bail!("outlier positions not strictly increasing");
        }
    }
    if let Some(&(last, _)) = archive.outliers.last() {
        if last as usize >= slab_len * grid.len() {
            bail!("outlier position {last} out of range");
        }
    }
    let mut slab_deltas: Vec<Vec<i32>> = Vec::with_capacity(grid.len());
    let mut oi = 0usize;
    for si in 0..grid.len() {
        let syms = &symbols[si * slab_len..(si + 1) * slab_len];
        let mut delta: Vec<i32> =
            syms.iter().map(|&c| if c == 0 { 0 } else { c as i32 - geo.radius }).collect();
        let base = (si * slab_len) as u64;
        let end = base + slab_len as u64;
        while oi < archive.outliers.len() && archive.outliers[oi].0 < end {
            let (pos, d) = archive.outliers[oi];
            delta[(pos - base) as usize] = d;
            oi += 1;
        }
        slab_deltas.push(delta);
    }
    timer.add("2.patch-outliers", t0.elapsed());

    // ---- inverse Lorenzo per slab, scatter into the field ---------------
    let t0 = Instant::now();
    let n: usize = geo.kernel_dims.iter().product();
    let deltas_cell: Vec<std::sync::Mutex<Vec<i32>>> =
        slab_deltas.into_iter().map(std::sync::Mutex::new).collect();
    let slabs: Vec<Result<Vec<f32>>> = parallel_map(threads, &deltas_cell, |_, cell| {
        let delta = std::mem::take(&mut *cell.lock().unwrap());
        coord.engine().decompress_slab_owned(spec, delta, geo.abs_eb)
    });
    let mut out = vec![0f32; n];
    for (si, (slab, idx)) in slabs.into_iter().zip(grid).enumerate() {
        let slab = slab.with_context(|| format!("slab {si}"))?;
        scatter_slab(&mut out, &geo.kernel_dims, spec, idx, &slab);
    }
    timer.add("3.reverse-predict-quant", t0.elapsed());

    // ---- verbatim overwrites -------------------------------------------
    let t0 = Instant::now();
    for &(pos, val) in &archive.verbatim {
        // verbatim positions are slab-stream positions: map back to field
        let pos = pos as usize;
        let si = pos / slab_len;
        let within = pos % slab_len;
        if si >= grid.len() {
            bail!("verbatim slab {si} out of range");
        }
        if let Some(field_off) = slab_to_field_offset(&geo.kernel_dims, spec, &grid[si], within) {
            out[field_off] = val;
        }
    }
    timer.add("4.verbatim", t0.elapsed());
    timer.add("total", t_total.elapsed());

    let field = Field::new(h.field_name.clone(), geo.logical_dims, out)?;
    let stats = DecompressStats { timer, original_bytes: field.size_bytes(), threads };
    Ok((field, stats))
}

/// Map an in-slab row-major offset to the field offset (None if padding).
fn slab_to_field_offset(
    dims: &[usize],
    spec: &SlabSpec,
    idx: &SlabIndex,
    within: usize,
) -> Option<usize> {
    let nd = dims.len();
    let mut rem = within;
    let mut coord = vec![0usize; nd];
    for ax in (0..nd).rev() {
        coord[ax] = rem % spec.shape[ax];
        rem /= spec.shape[ax];
    }
    let mut off = 0usize;
    let mut stride = 1usize;
    for ax in (0..nd).rev() {
        if coord[ax] >= idx.valid[ax] {
            return None; // padding region
        }
        off += (idx.origin[ax] + coord[ax]) * stride;
        stride *= dims[ax];
    }
    Some(off)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sz::blocks::SlabSpec;

    #[test]
    fn slab_offset_mapping_2d() {
        let dims = [5usize, 7];
        let spec = SlabSpec::new("t", &[4, 4], &[2, 2]);
        let grid = tile_grid(&dims, &spec);
        // slab (1,1): origin (4,4), valid (1,3)
        let idx = &grid[3];
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 0), Some(4 * 7 + 4));
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 2), Some(4 * 7 + 6));
        // row 0, col 3 is padding (valid cols = 3)
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 3), None);
        // row 1 entirely padding (valid rows = 1)
        assert_eq!(slab_to_field_offset(&dims, &spec, idx, 4), None);
    }

    #[test]
    fn channel_ranges_tile_a_sorted_channel() {
        let entries: Vec<(u64, i32)> = vec![(0, 1), (5, 2), (9, 3), (10, 4), (25, 5)];
        let ranges = split_channel_ranges(&entries, |e| e.0, 10, 3, "outlier").unwrap();
        assert_eq!(ranges, vec![(0, 3), (3, 4), (4, 5)]);
        // empty channel: every slab gets an empty range
        let none: Vec<(u64, i32)> = Vec::new();
        assert_eq!(
            split_channel_ranges(&none, |e| e.0, 10, 2, "outlier").unwrap(),
            vec![(0, 0), (0, 0)]
        );
    }

    #[test]
    fn channel_ranges_reject_out_of_range_positions() {
        // a position at/past the stream end is the one corruption the
        // per-slab checks cannot see — it must be rejected at the split
        let entries: Vec<(u64, i32)> = vec![(3, 1), (30, 2)];
        let err = split_channel_ranges(&entries, |e| e.0, 10, 3, "outlier").unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err:#}");
        // even when no slab exists at all
        assert!(split_channel_ranges(&entries, |e| e.0, 10, 0, "outlier").is_err());
    }
}
