//! Streaming multi-field pipeline — the data-pipeline face of the
//! coordinator: a bounded-queue three-stage flow (produce → compress →
//! sink) with backpressure, for workloads like "compress every field of a
//! simulation snapshot as it is produced" (the paper's LCLS-II / HACC
//! motivation, §1).

use std::sync::mpsc::sync_channel;
use std::time::Instant;

use anyhow::Result;

use super::{CompressStats, Coordinator};
use crate::container::Archive;
use crate::field::Field;
use crate::obs::{self, keys};

/// Aggregate results of a streaming run.
#[derive(Debug, Default)]
pub struct PipelineReport {
    pub fields: usize,
    pub original_bytes: usize,
    pub compressed_bytes: usize,
    pub wall_seconds: f64,
    pub per_field: Vec<(String, CompressStats)>,
}

impl PipelineReport {
    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    pub fn throughput_gbps(&self) -> f64 {
        self.original_bytes as f64 / self.wall_seconds.max(1e-12) / 1e9
    }
}

/// Run the pipeline: `producer` yields fields (runs on its own thread,
/// throttled by the bounded queue), the calling thread compresses, and
/// `sink` consumes each archive (e.g. writes it to storage).
pub fn run<P, S>(coord: &Coordinator, producer: P, mut sink: S) -> Result<PipelineReport>
where
    P: FnOnce(&dyn Fn(Field) -> bool) + Send + 'static,
    S: FnMut(&str, Archive) -> Result<()>,
{
    let depth = coord.cfg.queue_depth.max(1);
    let (tx, rx) = sync_channel::<Field>(depth);
    let producer_handle = std::thread::Builder::new()
        .name("field-producer".into())
        .spawn(move || {
            let push = |f: Field| tx.send(f).is_ok();
            producer(&push);
        })?;

    let t0 = Instant::now();
    let mut report = PipelineReport::default();
    for field in rx {
        let name = field.name.clone();
        // spans, not a mutable timer: each iteration records wall time +
        // bytes into the shared registry without any &mut aliasing
        let span = obs::span(keys::PIPELINE_COMPRESS).with_bytes(field.size_bytes() as u64);
        let (archive, stats) = coord.compress_with_stats(&field)?;
        drop(span);
        report.fields += 1;
        report.original_bytes += stats.original_bytes;
        report.compressed_bytes += stats.compressed_bytes;
        let sink_span = obs::span(keys::PIPELINE_SINK).with_bytes(stats.compressed_bytes as u64);
        sink(&name, archive)?;
        drop(sink_span);
        report.per_field.push((name, stats));
    }
    report.wall_seconds = t0.elapsed().as_secs_f64();
    producer_handle.join().ok();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, CuszConfig, ErrorBound};
    use crate::metrics;
    use crate::testkit::fields::{make, Regime};

    #[test]
    fn streams_fields_with_backpressure() {
        // eb large enough that even the Noisy regime (sigma=10) stays
        // in-cap and compresses
        let eb = 0.05f32;
        let cfg = CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(eb as f64),
            queue_depth: 2,
            ..Default::default()
        };
        let coord = Coordinator::new(cfg).unwrap();
        let originals: Vec<Field> = (0..6)
            .map(|i| {
                Field::new(
                    format!("f{i}"),
                    vec![256, 256],
                    make(Regime::ALL[i % 3], 256 * 256, i as u64),
                )
                .unwrap()
            })
            .collect();
        let to_send = originals.clone();
        let mut archives = Vec::new();
        let report = run(
            &coord,
            move |push| {
                for f in to_send {
                    if !push(f) {
                        break;
                    }
                }
            },
            |name, archive| {
                archives.push((name.to_string(), archive));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(report.fields, 6);
        assert_eq!(archives.len(), 6);
        assert!(report.compression_ratio() > 1.0);
        // decompress everything and verify bounds
        for ((_, archive), orig) in archives.iter().zip(&originals) {
            let out = coord.decompress(archive).unwrap();
            assert_eq!(metrics::verify_error_bound(&orig.data, &out.data, eb), None);
        }
    }

    #[test]
    fn sink_error_aborts_cleanly() {
        let cfg = CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(1e-2),
            ..Default::default()
        };
        let coord = Coordinator::new(cfg).unwrap();
        let result = run(
            &coord,
            |push| {
                for i in 0..100 {
                    let f = Field::new(
                        format!("f{i}"),
                        vec![4096],
                        make(Regime::Smooth, 4096, i),
                    )
                    .unwrap();
                    if !push(f) {
                        break; // backpressure released on abort
                    }
                }
            },
            |_, _| anyhow::bail!("disk full"),
        );
        assert!(result.is_err());
    }
}
