//! The cuSZ coordinator (L3): orchestrates the full compression /
//! decompression flow of Figure 1 over the quantization engine (PJRT AOT
//! executables or the CPU mirror), the Huffman substrate, and the archive
//! container.
//!
//! Field → slab tiling (§3.1.1) → DUAL-QUANT + histogram (L1/L2 kernels)
//! → outlier extraction → Huffman tree + canonical codebook (§3.2.2-3.2.3)
//! → chunked encode+deflate (§3.2.4) → `.cusza` archive, and the reverse.

pub mod compressor;
pub mod decompressor;
pub mod pipeline;
pub mod stats;

use anyhow::{Context, Result};

use crate::config::{BackendKind, CuszConfig};
use crate::container::Archive;
use crate::field::Field;
use crate::runtime::{self, QuantEngine};
use crate::sz::blocks::{builtin_variants, select_spec, SlabSpec};

pub use compressor::StreamHint;
pub use stats::{CompressStats, DecompressStats};

/// A compressed field together with its one-and-only serialization.
///
/// The compressor serializes exactly once (`bytes` is what the CLI
/// writes, the store appends, and the serve sink consumes) and the stats
/// are priced off that same pass — no consumer ever re-serializes, so a
/// gzip/zstd lossless tail is encoded exactly once per field.
pub struct CompressedField {
    pub archive: Archive,
    pub bytes: Vec<u8>,
    pub stats: CompressStats,
}

pub struct Coordinator {
    pub cfg: CuszConfig,
    engine: Box<dyn QuantEngine>,
    specs: Vec<SlabSpec>,
}

impl Coordinator {
    /// Build from config; `Pjrt` backend requires `make artifacts`.
    pub fn new(cfg: CuszConfig) -> Result<Self> {
        let engine = runtime::build_engine(&cfg).context("building quant engine")?;
        let specs = match cfg.backend {
            BackendKind::Pjrt => {
                let manifest = runtime::ArtifactManifest::load(&cfg.artifacts_dir)?;
                manifest
                    .executables
                    .iter()
                    .filter(|e| e.op == "compress")
                    .map(|e| e.slab_spec())
                    .collect()
            }
            BackendKind::Cpu => builtin_variants(),
        };
        Ok(Coordinator { cfg, engine, specs })
    }

    /// Like `new` but falls back to the CPU engine when the PJRT path is
    /// unavailable (used by examples, benches, and the CLI). Builds the
    /// coordinator once: a successful PJRT construction is returned
    /// directly instead of being probed, discarded, and rebuilt.
    pub fn new_with_fallback(mut cfg: CuszConfig) -> Result<Self> {
        if cfg.backend == BackendKind::Pjrt {
            match Coordinator::new(cfg.clone()) {
                Ok(coord) => return Ok(coord),
                Err(e) => {
                    eprintln!("[cusz] PJRT unavailable ({e:#}); falling back to CPU backend");
                    cfg.backend = BackendKind::Cpu;
                }
            }
        }
        Coordinator::new(cfg)
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    pub(crate) fn engine(&self) -> &dyn QuantEngine {
        self.engine.as_ref()
    }

    /// Resolve the slab spec for a field.
    pub fn spec_for(&self, kernel_dims: &[usize]) -> Result<&SlabSpec> {
        select_spec(&self.specs, kernel_dims)
            .with_context(|| format!("no slab variant for {}D fields", kernel_dims.len()))
    }

    pub fn compress(&self, field: &Field) -> Result<Archive> {
        Ok(self.compress_with_stats(field)?.0)
    }

    pub fn compress_with_stats(&self, field: &Field) -> Result<(Archive, CompressStats)> {
        let c = self.compress_encoded(field)?;
        Ok((c.archive, c.stats))
    }

    /// Compress and serialize in one pass: the returned
    /// [`CompressedField`] carries the archive, its bytes, and stats
    /// priced off those bytes. The hot paths (CLI, store, serve) use
    /// this so the lossless tail is encoded exactly once per field.
    pub fn compress_encoded(&self, field: &Field) -> Result<CompressedField> {
        compressor::compress(self, field)
    }

    /// Streaming compress: pull `dims.product() * 4` little-endian f32
    /// bytes off `src` one slab band at a time, never holding the whole
    /// field. `hint` (a one-pass value-range summary) is required for
    /// `valrel` error bounds and optional for absolute ones — see
    /// [`compressor::StreamHint`]. With an equivalent hint the archive
    /// bytes are identical to [`Coordinator::compress_encoded`].
    pub fn compress_stream(
        &self,
        name: &str,
        dims: &[usize],
        src: &mut dyn std::io::Read,
        hint: Option<compressor::StreamHint>,
    ) -> Result<CompressedField> {
        compressor::compress_stream(self, name, dims, src, hint)
    }

    /// Streaming decompress: the fused slab pass writes straight into
    /// `sink` one band at a time, never holding the reconstructed field.
    /// The bytes written equal `Field::write_f32_into` of
    /// [`Coordinator::decompress_with_threads`]'s result. The caller owns
    /// buffering/flushing of `sink`.
    pub fn decompress_stream_into(
        &self,
        archive: &Archive,
        threads: usize,
        sink: &mut dyn std::io::Write,
    ) -> Result<DecompressStats> {
        decompressor::decompress_stream_into(self, archive, threads, sink)
    }

    pub fn decompress(&self, archive: &Archive) -> Result<Field> {
        Ok(self.decompress_with_stats(archive)?.0)
    }

    pub fn decompress_with_stats(&self, archive: &Archive) -> Result<(Field, DecompressStats)> {
        decompressor::decompress(self, archive)
    }

    /// Decompress with an explicit worker budget for the chunk-parallel
    /// decode and the fused slab pass. Batch pipelines that already fan
    /// out across fields pass their per-job share instead of the
    /// config-wide count, mirroring the segmented-tail decode budget.
    pub fn decompress_with_threads(
        &self,
        archive: &Archive,
        threads: usize,
    ) -> Result<(Field, DecompressStats)> {
        decompressor::decompress_with_threads(self, archive, threads)
    }

    /// The pre-fusion materializing decompress path — the baseline
    /// `cusz bench` prices the fused pipeline against (and the
    /// bit-identical-output oracle in the acceptance tests). Not a
    /// production entry point.
    pub fn decompress_materializing(
        &self,
        archive: &Archive,
    ) -> Result<(Field, DecompressStats)> {
        decompressor::decompress_materializing(self, archive)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{CodecSpec, EncoderChoice, EncoderKind};
    use crate::config::{ErrorBound, LosslessStage};
    use crate::metrics;
    use crate::testkit::fields::{make, Regime};

    fn cpu_coordinator(eb: ErrorBound) -> Coordinator {
        let cfg = CuszConfig { backend: BackendKind::Cpu, eb, ..Default::default() };
        Coordinator::new(cfg).unwrap()
    }

    fn cpu_coordinator_codec(eb: ErrorBound, codec: CodecSpec) -> Coordinator {
        let cfg = CuszConfig { backend: BackendKind::Cpu, eb, codec, ..Default::default() };
        Coordinator::new(cfg).unwrap()
    }

    #[test]
    fn fle_codec_roundtrips_all_regimes() {
        let codec = CodecSpec { encoder: EncoderChoice::Fle, lossless: LosslessStage::None, ..Default::default() };
        for regime in Regime::ALL {
            let data = make(regime, 40_000, 11);
            let field = Field::new("t", vec![40_000], data).unwrap();
            let coord = cpu_coordinator_codec(ErrorBound::Abs(1e-3), codec);
            let (archive, stats) = coord.compress_with_stats(&field).unwrap();
            assert_eq!(archive.header.encoder, EncoderKind::Fle);
            assert_eq!(stats.encoder, EncoderKind::Fle);
            let out = coord.decompress(&archive).unwrap();
            assert_eq!(
                metrics::verify_error_bound(&field.data, &out.data, 1e-3),
                None,
                "{regime:?}"
            );
        }
    }

    #[test]
    fn decode_follows_archive_tag_not_config() {
        // compress with FLE, decompress with a default (Huffman) config —
        // the archive's encoder tag, not the coordinator, picks the stage
        let data = make(Regime::Smooth, 20_000, 4);
        let field = Field::new("x", vec![20_000], data).unwrap();
        let fle = cpu_coordinator_codec(
            ErrorBound::Abs(1e-3),
            CodecSpec { encoder: EncoderChoice::Fle, lossless: LosslessStage::None, ..Default::default() },
        );
        let archive = fle.compress(&field).unwrap();
        let huff = cpu_coordinator(ErrorBound::Abs(1e-3));
        let out = huff.decompress(&archive).unwrap();
        assert_eq!(metrics::verify_error_bound(&field.data, &out.data, 1e-3), None);
    }

    #[test]
    fn auto_codec_resolves_and_roundtrips() {
        let codec = CodecSpec { encoder: EncoderChoice::Auto, lossless: LosslessStage::None, ..Default::default() };
        for regime in Regime::ALL {
            let data = make(regime, 30_000, 6);
            let field = Field::new("a", vec![30_000], data).unwrap();
            let coord = cpu_coordinator_codec(ErrorBound::Abs(1e-2), codec);
            let (archive, stats) = coord.compress_with_stats(&field).unwrap();
            // auto must resolve to a concrete backend and record it
            assert_eq!(stats.encoder, archive.header.encoder);
            let out = coord.decompress(&archive).unwrap();
            assert_eq!(
                metrics::verify_error_bound(&field.data, &out.data, 1e-2),
                None,
                "{regime:?}"
            );
        }
    }

    #[test]
    fn v0_archive_bytes_still_decompress() {
        // simulate a pre-refactor archive: Huffman payload reserialized
        // under the legacy magic with a version-0 header
        let data = make(Regime::Smooth, 8192, 3);
        let field = Field::new("v0", vec![8192], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
        let mut archive = coord.compress(&field).unwrap();
        archive.header.version = 0;
        let bytes = archive.to_bytes();
        let restored = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(restored.header.version, 0);
        assert_eq!(restored.header.encoder, EncoderKind::Huffman);
        let out = coord.decompress(&restored).unwrap();
        assert_eq!(metrics::verify_error_bound(&field.data, &out.data, 1e-3), None);
    }

    #[test]
    fn roundtrip_all_regimes_all_ndims() {
        for regime in Regime::ALL {
            for dims in [vec![50_000usize], vec![300, 300], vec![40, 50, 60]] {
                let n: usize = dims.iter().product();
                let data = make(regime, n, 3);
                let field = Field::new("t", dims.clone(), data).unwrap();
                let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
                let archive = coord.compress(&field).unwrap();
                let out = coord.decompress(&archive).unwrap();
                assert_eq!(out.dims, field.dims);
                assert_eq!(
                    metrics::verify_error_bound(&field.data, &out.data, 1e-3),
                    None,
                    "{regime:?} {dims:?}"
                );
            }
        }
    }

    #[test]
    fn valrel_bound_resolves_per_field() {
        let data = make(Regime::Noisy, 65536, 9);
        let field = Field::new("t", vec![65536], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::ValRel(1e-3));
        let (archive, _) = coord.compress_with_stats(&field).unwrap();
        let (lo, hi) = field.value_range();
        let expect = 1e-3 * (hi - lo) as f64;
        assert!((archive.header.abs_eb as f64 - expect).abs() / expect < 1e-5);
        let out = coord.decompress(&archive).unwrap();
        assert_eq!(
            metrics::verify_error_bound(&field.data, &out.data, archive.header.abs_eb),
            None
        );
    }

    #[test]
    fn four_d_field_roundtrips_via_fold() {
        let data = make(Regime::Smooth, 8 * 10 * 12 * 14, 5);
        let field = Field::new("q4", vec![8, 10, 12, 14], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-2));
        let out = coord.decompress(&coord.compress(&field).unwrap()).unwrap();
        assert_eq!(out.dims, vec![8, 10, 12, 14]);
        assert_eq!(metrics::verify_error_bound(&field.data, &out.data, 1e-2), None);
    }

    #[test]
    fn nonfinite_values_roundtrip_verbatim() {
        let mut data = make(Regime::Smooth, 4096, 6);
        data[10] = f32::NAN;
        data[20] = f32::INFINITY;
        data[30] = f32::NEG_INFINITY;
        let field = Field::new("nan", vec![4096], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
        let out = coord.decompress(&coord.compress(&field).unwrap()).unwrap();
        assert!(out.data[10].is_nan());
        assert_eq!(out.data[20], f32::INFINITY);
        assert_eq!(out.data[30], f32::NEG_INFINITY);
    }

    #[test]
    fn huge_values_roundtrip_via_range_outliers() {
        let mut data = make(Regime::Smooth, 4096, 7);
        data[100] = 3.4e38;
        data[200] = -3.4e38;
        let field = Field::new("huge", vec![4096], data.clone()).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-6));
        let out = coord.decompress(&coord.compress(&field).unwrap()).unwrap();
        assert_eq!(out.data[100], 3.4e38);
        assert_eq!(out.data[200], -3.4e38);
        // the huge values must not corrupt their neighbors
        assert_eq!(metrics::verify_error_bound(&data, &out.data, 1e-6), None);
    }

    #[test]
    fn smooth_data_compresses_well() {
        let data = make(Regime::Smooth, 1 << 18, 8);
        let field = Field::new("s", vec![1 << 18], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::ValRel(1e-3));
        let (archive, stats) = coord.compress_with_stats(&field).unwrap();
        let cr = field.size_bytes() as f64 / archive.compressed_bytes() as f64;
        assert!(cr > 4.0, "compression ratio {cr}");
        assert_eq!(stats.original_bytes, field.size_bytes());
    }

    fn field_le_bytes(data: &[f32]) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes
    }

    #[test]
    fn compress_stream_bytes_match_in_memory_compress() {
        use crate::coordinator::compressor::StreamHint;
        for dims in [vec![50_000usize], vec![300, 300], vec![40, 50, 60], vec![6, 8, 10, 12]] {
            let n: usize = dims.iter().product();
            let data = make(Regime::Smooth, n, 21);
            let field = Field::new("s", dims.clone(), data).unwrap();
            let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
            let whole = coord.compress_encoded(&field).unwrap();
            // with a range hint the range-safe decision matches exactly
            let hint = StreamHint::scan(&field.data);
            let mut src = std::io::Cursor::new(field_le_bytes(&field.data));
            let streamed = coord.compress_stream("s", &dims, &mut src, Some(hint)).unwrap();
            assert_eq!(streamed.bytes, whole.bytes, "hinted stream differs for {dims:?}");
            // without a hint (abs bound): conservative per-slab scans find
            // nothing on finite in-range data — bytes still identical
            let mut src = std::io::Cursor::new(field_le_bytes(&field.data));
            let blind = coord.compress_stream("s", &dims, &mut src, None).unwrap();
            assert_eq!(blind.bytes, whole.bytes, "blind stream differs for {dims:?}");
            assert_eq!(streamed.stats.original_bytes, field.size_bytes());
        }
    }

    #[test]
    fn compress_stream_valrel_matches_and_requires_hint() {
        use crate::coordinator::compressor::StreamHint;
        let dims = vec![200usize, 300];
        let data = make(Regime::Noisy, 200 * 300, 13);
        let field = Field::new("r", dims.clone(), data).unwrap();
        let coord = cpu_coordinator(ErrorBound::ValRel(1e-3));
        let whole = coord.compress_encoded(&field).unwrap();
        let hint = StreamHint::scan(&field.data);
        let mut src = std::io::Cursor::new(field_le_bytes(&field.data));
        let streamed = coord.compress_stream("r", &dims, &mut src, Some(hint)).unwrap();
        assert_eq!(streamed.bytes, whole.bytes);
        // valrel cannot resolve without a range
        let mut src = std::io::Cursor::new(field_le_bytes(&field.data));
        assert!(coord.compress_stream("r", &dims, &mut src, None).is_err());
    }

    #[test]
    fn compress_stream_handles_nonfinite_without_hint() {
        let mut data = make(Regime::Smooth, 4096, 6);
        data[10] = f32::NAN;
        data[20] = f32::INFINITY;
        let dims = vec![4096usize];
        let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
        let mut src = std::io::Cursor::new(field_le_bytes(&data));
        let c = coord.compress_stream("nan", &dims, &mut src, None).unwrap();
        let out = coord.decompress(&c.archive).unwrap();
        assert!(out.data[10].is_nan());
        assert_eq!(out.data[20], f32::INFINITY);
    }

    #[test]
    fn compress_stream_rejects_short_source() {
        let data = make(Regime::Smooth, 1000, 2);
        let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
        let bytes = field_le_bytes(&data);
        let mut short = std::io::Cursor::new(&bytes[..bytes.len() - 4]);
        assert!(coord.compress_stream("s", &[1000], &mut short, None).is_err());
    }

    #[test]
    fn decompress_stream_into_matches_materialized_bytes() {
        for dims in [vec![50_000usize], vec![300, 300], vec![40, 50, 60], vec![6, 8, 10, 12]] {
            let n: usize = dims.iter().product();
            let data = make(Regime::Noisy, n, 17);
            let field = Field::new("d", dims.clone(), data).unwrap();
            let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
            let archive = coord.compress(&field).unwrap();
            let (whole, _) = coord.decompress_with_threads(&archive, 4).unwrap();
            let mut streamed = Vec::new();
            let stats = coord.decompress_stream_into(&archive, 4, &mut streamed).unwrap();
            assert_eq!(streamed, field_le_bytes(&whole.data), "stream differs for {dims:?}");
            assert_eq!(stats.original_bytes, field.size_bytes());
        }
    }

    #[test]
    fn decompress_stream_into_carries_outliers_and_verbatim() {
        // spiky data with non-finite and huge values exercises both side
        // channels through the band-streamed fused pass
        let mut data = make(Regime::Zeros, 70_000, 9);
        data[123] = f32::NAN;
        data[4567] = 3.4e38;
        let field = Field::new("v", vec![70_000], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-5));
        let archive = coord.compress(&field).unwrap();
        let (whole, _) = coord.decompress_with_threads(&archive, 3).unwrap();
        let mut streamed = Vec::new();
        coord.decompress_stream_into(&archive, 3, &mut streamed).unwrap();
        assert_eq!(streamed, field_le_bytes(&whole.data));
    }

    #[test]
    fn stream_roundtrip_stays_error_bounded() {
        use crate::coordinator::compressor::StreamHint;
        let dims = vec![120usize, 250];
        let data = make(Regime::Noisy, 120 * 250, 29);
        let field = Field::new("rt", dims.clone(), data).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-3));
        let hint = StreamHint::scan(&field.data);
        let mut src = std::io::Cursor::new(field_le_bytes(&field.data));
        let c = coord.compress_stream("rt", &dims, &mut src, Some(hint)).unwrap();
        let restored = Archive::from_bytes(&c.bytes).unwrap();
        let mut out_bytes = Vec::new();
        coord.decompress_stream_into(&restored, 2, &mut out_bytes).unwrap();
        let out: Vec<f32> = out_bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        assert_eq!(metrics::verify_error_bound(&field.data, &out, 1e-3), None);
    }

    #[test]
    fn archive_bytes_roundtrip_through_container() {
        let data = make(Regime::Zeros, 128 * 128, 10);
        let field = Field::new("z", vec![128, 128], data).unwrap();
        let coord = cpu_coordinator(ErrorBound::Abs(1e-4));
        let archive = coord.compress(&field).unwrap();
        let bytes = archive.to_bytes();
        let restored = Archive::from_bytes(&bytes).unwrap();
        let out = coord.decompress(&restored).unwrap();
        assert_eq!(metrics::verify_error_bound(&field.data, &out.data, 1e-4), None);
    }
}
