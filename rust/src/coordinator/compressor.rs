//! Per-field compression orchestration (Figure 1, top path).

use std::io::Read;
use std::time::Instant;

use anyhow::Result;

use super::{CompressStats, CompressedField, Coordinator};
use crate::codec::{
    self, chunked, cost, CodecGranularity, CostModel, EncodeContext, EncoderChoice, EncoderKind,
    SymbolSource,
};
use crate::container::{self, Archive, Header, LosslessTag, FORMAT_VERSION, MAX_CHUNK_SYMBOLS};
use crate::field::{self, Field};
use crate::huffman;
use crate::obs::{self, keys, RunTimings};

use crate::sz::blocks::{self, tile_grid, SlabSpec};
use crate::sz::dual_quant;
use crate::util::arena;
use crate::util::pool::parallel_map;

/// Output of the quant phase for one slab.
struct SlabQuant {
    codes: Vec<u16>,
    /// (in-slab position, exact delta) for code==0 slots.
    outliers: Vec<(u32, i32)>,
    /// (in-slab position, verbatim f32) for cap/non-finite values.
    verbatim: Vec<(u32, f32)>,
    hist: Vec<u32>,
}

/// Value-range summary a [`compress_stream`] caller supplies when it has
/// one (a CLI pre-scan of a seekable file, the daemon's pass over an
/// already-buffered PUT body). Required for relative (`valrel`) error
/// bounds — the bound cannot be resolved without the range — and optional
/// for absolute bounds, where it only unlocks the fast range-safe path.
/// With no hint the stream path conservatively runs the per-slab
/// range-outlier scan, which finds nothing on finite in-range data, so
/// the archive bytes still match the in-memory path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamHint {
    /// Minimum over finite values.
    pub lo: f32,
    /// Maximum over finite values.
    pub hi: f32,
    /// True iff every value in the stream is finite.
    pub all_finite: bool,
}

impl StreamHint {
    /// Summarize a slice of values (one pass): finite min/max + finiteness.
    pub fn scan(data: &[f32]) -> StreamHint {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        let mut all_finite = true;
        for &v in data {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            } else {
                all_finite = false;
            }
        }
        if lo > hi {
            (lo, hi) = (0.0, 0.0);
        }
        StreamHint { lo, hi, all_finite }
    }

    /// Summarize a raw little-endian f32 byte image (daemon PUT bodies).
    /// Trailing bytes short of a full value are ignored.
    pub fn scan_le_bytes(bytes: &[u8]) -> StreamHint {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        let mut all_finite = true;
        for b in bytes.chunks_exact(4) {
            let v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            } else {
                all_finite = false;
            }
        }
        if lo > hi {
            (lo, hi) = (0.0, 0.0);
        }
        StreamHint { lo, hi, all_finite }
    }
}

pub fn compress(coord: &Coordinator, field: &Field) -> Result<CompressedField> {
    let cfg = &coord.cfg;
    // refuse to produce an archive the parser would reject as corrupt
    if cfg.chunk_symbols == 0 || cfg.chunk_symbols > MAX_CHUNK_SYMBOLS {
        anyhow::bail!(
            "chunk_symbols {} outside the supported range 1..={MAX_CHUNK_SYMBOLS}",
            cfg.chunk_symbols
        );
    }
    let mut timer = RunTimings::new();
    let t_total = Instant::now();
    // stage spans carry the original field bytes so registry-level GB/s
    // follows the paper's convention (footnote 4: throughput against
    // original data size)
    let field_bytes = field.size_bytes() as u64;

    // ---- resolve error bound & geometry ------------------------------
    let (lo, hi) = field.value_range();
    let abs_eb = cfg.eb.resolve((hi - lo) as f64);
    let kernel_dims = field.kernel_dims();
    let spec = coord.spec_for(&kernel_dims)?.clone();
    let grid = tile_grid(&kernel_dims, &spec);
    let dict = cfg.dict_size;
    let max_abs = lo.abs().max(hi.abs());
    let range_safe = dual_quant::range_safe(max_abs, abs_eb)
        && field.data.iter().all(|v| v.is_finite());

    // ---- phase A: per-slab gather + DUAL-QUANT + code extraction -----
    // The engine call runs on the PJRT engine thread (serialized, like a
    // CUDA stream) or truly in parallel on the CPU backend.
    let t0 = Instant::now();
    let threads = cfg.effective_threads();
    let slabs: Vec<Result<SlabQuant>> = parallel_map(threads, &grid, |_, idx| {
        // per-worker gather buffer loaned from the thread-local arena,
        // reused across slabs — and, on long-lived batch workers, across
        // whole fields (page-fault avoidance, EXPERIMENTS.md §Perf
        // iteration 3)
        arena::with_f32(|buf| {
            if buf.len() != spec.len() {
                buf.clear();
                buf.resize(spec.len(), 0.0);
            }
            // gather into the reused buffer (pad regions zeroed below only
            // where the previous slab left residue)
            if idx.valid != spec.shape {
                buf.fill(0.0);
            }
            crate::sz::blocks::gather_slab_into(&field.data, &kernel_dims, &spec, idx, buf);
            let data: &[f32] = buf;
            let full = coord.engine().compress_slab_full(&spec, data, abs_eb, dict)?;
            let verbatim = if range_safe {
                Vec::new()
            } else {
                dual_quant::find_range_outliers(data, abs_eb)
            };
            Ok(SlabQuant {
                codes: full.codes,
                outliers: full.outliers,
                verbatim,
                hist: full.hist,
            })
        })
    });
    let mut quants = Vec::with_capacity(slabs.len());
    for s in slabs {
        quants.push(s?);
    }
    timer.add_recorded("1.predict-quant", keys::COMPRESS_PREDICT_QUANT, t0.elapsed(), field_bytes);

    finish_compress(coord, &field.name, &field.dims, &spec, quants, abs_eb, field_bytes, timer, t_total)
}

/// Streaming compress: pull the field off `src` one *band* at a time
/// (see [`blocks::band_plan`]) so the whole f32 field is never resident.
///
/// `src` must yield exactly `dims.product() * 4` little-endian f32 bytes.
/// The window buffer holds `spec.shape[0]` rows; the per-slab u16 quant
/// codes (2 B/elem) are kept in memory — they are the encoder's input —
/// so peak working set is ~half the field plus one band, instead of the
/// in-memory path's field + codes. Phases B–D are shared with
/// [`compress`], so given the same effective `range_safe` decision (see
/// [`StreamHint`]) the archive bytes are identical to the in-memory path.
pub fn compress_stream(
    coord: &Coordinator,
    name: &str,
    dims: &[usize],
    src: &mut dyn Read,
    hint: Option<StreamHint>,
) -> Result<CompressedField> {
    let cfg = &coord.cfg;
    if cfg.chunk_symbols == 0 || cfg.chunk_symbols > MAX_CHUNK_SYMBOLS {
        anyhow::bail!(
            "chunk_symbols {} outside the supported range 1..={MAX_CHUNK_SYMBOLS}",
            cfg.chunk_symbols
        );
    }
    if dims.is_empty() || dims.len() > 4 {
        anyhow::bail!("field must have 1..=4 dims, got {}", dims.len());
    }
    let mut timer = RunTimings::new();
    let t_total = Instant::now();
    let n: usize = dims.iter().product();
    let field_bytes = (n * 4) as u64;

    // ---- resolve error bound & geometry ------------------------------
    let abs_eb = match cfg.eb {
        crate::config::ErrorBound::Abs(_) => cfg.eb.resolve(0.0),
        crate::config::ErrorBound::ValRel(_) => {
            let h = hint.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "valrel error bounds need a value-range hint to stream; \
                     pre-scan the source (StreamHint) or use an absolute bound"
                )
            })?;
            cfg.eb.resolve((h.hi - h.lo) as f64)
        }
    };
    let kernel_dims = field::kernel_dims_of(dims);
    let spec = coord.spec_for(&kernel_dims)?.clone();
    let grid = tile_grid(&kernel_dims, &spec);
    let dict = cfg.dict_size;
    // without finiteness knowledge, stay conservative: the per-slab
    // range-outlier scan stays on, and it finds nothing on finite
    // in-range data — so the archive bytes still match `compress`
    let range_safe = hint
        .as_ref()
        .is_some_and(|h| h.all_finite && dual_quant::range_safe(h.lo.abs().max(h.hi.abs()), abs_eb));

    // ---- phase A: banded read + per-slab DUAL-QUANT ------------------
    let t0 = Instant::now();
    let threads = cfg.effective_threads();
    let bands = blocks::band_plan(&kernel_dims, &spec, &grid);
    let row_elems: usize = kernel_dims[1..].iter().product();
    let mut band_buf = vec![0f32; spec.shape[0] * row_elems];
    let mut quants: Vec<SlabQuant> = Vec::with_capacity(grid.len());
    for band in &bands {
        // a band is one contiguous run of the raw byte stream...
        let elems = band.field_elems(&kernel_dims);
        band_buf.truncate(elems); // only the tail band shrinks
        field::read_f32_into(src, &mut band_buf[..elems])?;
        // ...and one contiguous run of grid order, gathered band-locally
        let mut band_dims = kernel_dims.clone();
        band_dims[0] = band.rows;
        let idxs = &grid[band.slab_lo..band.slab_hi];
        let slabs: Vec<Result<SlabQuant>> = parallel_map(threads, idxs, |_, idx| {
            let local = blocks::band_local(idx, band);
            arena::with_f32(|buf| {
                if buf.len() != spec.len() {
                    buf.clear();
                    buf.resize(spec.len(), 0.0);
                }
                if local.valid != spec.shape {
                    buf.fill(0.0);
                }
                blocks::gather_slab_into(&band_buf, &band_dims, &spec, &local, buf);
                let data: &[f32] = buf;
                let full = coord.engine().compress_slab_full(&spec, data, abs_eb, dict)?;
                let verbatim = if range_safe {
                    Vec::new()
                } else {
                    dual_quant::find_range_outliers(data, abs_eb)
                };
                Ok(SlabQuant {
                    codes: full.codes,
                    outliers: full.outliers,
                    verbatim,
                    hist: full.hist,
                })
            })
        });
        for s in slabs {
            quants.push(s?);
        }
    }
    timer.add_recorded("1.predict-quant", keys::COMPRESS_PREDICT_QUANT, t0.elapsed(), field_bytes);

    finish_compress(coord, name, dims, &spec, quants, abs_eb, field_bytes, timer, t_total)
}

/// Phases B–D + container assembly + the single serialize pass — shared
/// verbatim by [`compress`] and [`compress_stream`], which is what makes
/// the streamed archive bit-identical to the in-memory one: by the time
/// either path reaches this point, all that remains of the field is the
/// per-slab quant output.
#[allow(clippy::too_many_arguments)]
fn finish_compress(
    coord: &Coordinator,
    field_name: &str,
    dims: &[usize],
    spec: &SlabSpec,
    quants: Vec<SlabQuant>,
    abs_eb: f32,
    field_bytes: u64,
    mut timer: RunTimings,
    t_total: Instant,
) -> Result<CompressedField> {
    let cfg = &coord.cfg;
    let dict = cfg.dict_size;
    let threads = cfg.effective_threads();

    // ---- phase B: histogram merge ------------------------------------
    let t0 = Instant::now();
    let mut freq = vec![0u64; dict];
    for q in &quants {
        huffman::histogram::merge_into(&mut freq, &q.hist);
    }
    timer.add_recorded("2.histogram", keys::COMPRESS_HISTOGRAM, t0.elapsed(), field_bytes);

    // ---- phase C: view the slab codes in place, gather outliers --------
    // No field-wide flatten: the codec stages pull chunk windows straight
    // out of the per-slab `codes` vectors through a `SymbolSource`
    // (boundary-straddling windows stitch through the thread-local
    // arena), so each symbol is touched once — by its encoder.
    let t0 = Instant::now();
    let slab_len = spec.len();
    let symbols = SymbolSource::from_slabs(
        quants.iter().map(|q| q.codes.as_slice()).collect(),
        slab_len,
    )?;
    let mut outliers = Vec::new();
    let mut verbatim = Vec::new();
    for (si, q) in quants.iter().enumerate() {
        let base = (si * slab_len) as u64;
        outliers.extend(q.outliers.iter().map(|&(p, d)| (base + p as u64, d)));
        verbatim.extend(q.verbatim.iter().map(|&(p, v)| (base + p as u64, v)));
    }
    timer.add_recorded("4.gather-outliers", keys::COMPRESS_GATHER_OUTLIERS, t0.elapsed(), field_bytes);

    // ---- phase D: resolve the codec, run the encoder stage(s) ----------
    // `auto` adapts to smoothness (cuSZ+-style): at field granularity it
    // picks one backend from the merged histogram; at chunk granularity
    // every chunk is probed against the measured cost model and tagged
    // independently. Forced choices are uniform at either granularity.
    let t0 = Instant::now();
    let ctx = EncodeContext {
        dict_size: dict,
        chunk_symbols: cfg.chunk_symbols,
        threads,
        codeword_repr: cfg.codeword_repr,
        freq: &freq,
    };
    let is_auto = cfg.codec.encoder == EncoderChoice::Auto;
    let per_chunk_auto = is_auto && cfg.codec.granularity == CodecGranularity::Chunk;
    // `--target-gbps`: prune backends whose measured decode rate misses
    // the budget before `auto`'s size argmin (forced choices are never
    // overridden — the knob only narrows what `auto` may pick)
    let allowed = if is_auto {
        cost::allowed_for_target(obs::global(), cfg.target_gbps)
    } else {
        [true; 3]
    };
    let (encoder_kind, granularity, encoder_aux, chunk_tags, chunk_aux, stream, repr_bits, codebook_time, chunk_counts, gap_tables);
    if per_chunk_auto {
        let enc = chunked::encode_chunked_within(&symbols, &ctx, &CostModel::MEASURED, allowed)?;
        // the header's field-level tag records the majority backend (an
        // `ls`-level summary; decode follows the per-chunk tag table)
        encoder_kind = EncoderKind::ALL
            .into_iter()
            .max_by_key(|k| enc.counts[k.to_tag() as usize])
            .unwrap_or_default();
        // a degenerate empty stream has no chunks to tag: stay at field
        // granularity so the header and (empty) tag table agree
        granularity = if enc.tags.is_empty() {
            CodecGranularity::Field
        } else {
            CodecGranularity::Chunk
        };
        encoder_aux = enc.shared_aux;
        chunk_tags = enc.tags;
        chunk_aux = enc.chunk_aux;
        stream = enc.stream;
        repr_bits = enc.repr_bits;
        codebook_time = enc.codebook_time;
        chunk_counts = enc.counts;
        gap_tables = enc.gaps;
    } else {
        let kind = match cfg.codec.encoder {
            EncoderChoice::Huffman => EncoderKind::Huffman,
            EncoderChoice::Fle => EncoderKind::Fle,
            EncoderChoice::Rle => EncoderKind::Rle,
            EncoderChoice::Auto => CostModel::MEASURED.select_field_within(&freq, allowed),
        };
        // Huffman goes through the gap-recording path so any chunk larger
        // than the subchunk granularity carries its parallel-decode index
        // (bitstream unchanged; only the sidecar table is new)
        let (enc, gaps) = if kind == EncoderKind::Huffman {
            codec::huffman_stage::encode_source_with_gaps(&symbols, &ctx)?
        } else {
            (codec::stage_for(kind).encode_source(&symbols, &ctx)?, Vec::new())
        };
        let mut counts = [0usize; EncoderKind::ALL.len()];
        counts[kind.to_tag() as usize] = enc.stream.chunks.len();
        encoder_kind = kind;
        granularity = CodecGranularity::Field;
        encoder_aux = enc.aux;
        chunk_tags = Vec::new();
        chunk_aux = Vec::new();
        stream = enc.stream;
        repr_bits = enc.repr_bits;
        codebook_time = enc.codebook_time;
        chunk_counts = counts;
        gap_tables = gaps;
    }
    // keep the Table 7 breakdown rows: table/codebook construction is
    // reported apart from the streaming encode it precedes
    timer.add_recorded("3.codebook", keys::COMPRESS_CODEBOOK, codebook_time, field_bytes);
    timer.add_recorded(
        "5.encode-deflate",
        keys::COMPRESS_ENCODE,
        t0.elapsed().saturating_sub(codebook_time),
        field_bytes,
    );

    // ---- assemble ------------------------------------------------------
    let t0 = Instant::now();
    let lossless = match cfg.codec.lossless {
        crate::config::LosslessStage::None => LosslessTag::None,
        crate::config::LosslessStage::Gzip => LosslessTag::Gzip,
        crate::config::LosslessStage::Zstd => LosslessTag::Zstd,
    };
    let encoded_bits = stream.total_bits();
    let archive = Archive {
        header: Header {
            version: FORMAT_VERSION,
            encoder: encoder_kind,
            granularity,
            field_name: field_name.to_string(),
            dims: dims.to_vec(),
            variant: spec.name.clone(),
            eb: cfg.eb,
            abs_eb,
            dict_size: dict,
            chunk_symbols: cfg.chunk_symbols,
            repr_bits,
            lossless,
            n_slabs: quants.len(),
        },
        encoder_aux,
        chunk_tags,
        chunk_aux,
        stream,
        outliers,
        verbatim,
        // all-empty tables carry no information: write a bare zero count
        // instead of nchunks empty frames
        gap_tables: if gap_tables.iter().all(|g| g.is_empty()) {
            Vec::new()
        } else {
            gap_tables
        },
    };

    // ---- serialize: the one and only pass -------------------------------
    // One streaming write produces the bytes every consumer (CLI file,
    // store shard, serve sink) uses, and its length is the stats' size —
    // the old `compressed_bytes()` re-serialization (a second lossless-
    // tail encode per field) is gone, regression-locked by
    // `tests/zero_copy.rs`.
    let mut bytes = Vec::with_capacity(archive.serialized_len_hint());
    archive
        .write_into_with(&mut bytes, threads, container::TAIL_SEGMENT_BYTES)
        .expect("writing to a Vec cannot fail");
    timer.add_recorded("6.container", keys::COMPRESS_CONTAINER, t0.elapsed(), field_bytes);
    timer.add_recorded("total", keys::COMPRESS_TOTAL, t_total.elapsed(), field_bytes);
    obs::global().add("compress.fields", 1);

    let stats = CompressStats {
        original_bytes: field_bytes as usize,
        compressed_bytes: bytes.len(),
        n_slabs: archive.header.n_slabs,
        n_outliers: archive.outliers.len(),
        n_verbatim: archive.verbatim.len(),
        encoded_bits,
        repr_bits,
        encoder: encoder_kind,
        granularity,
        chunk_counts,
        abs_eb,
        target_gbps: cfg.target_gbps,
        pruned: {
            let mut p = [false; 3];
            for (i, &a) in allowed.iter().enumerate() {
                p[i] = !a;
            }
            p
        },
        timer,
    };
    Ok(CompressedField { archive, bytes, stats })
}
