//! Framework configuration: error-bound modes, backend selection, tuning
//! knobs that the paper's evaluation sweeps (chunk size, dict size,
//! codeword representation).

use std::path::PathBuf;

pub use crate::codec::{CodecGranularity, CodecSpec, EncoderChoice};
pub use crate::store::Durability;

/// Error-bound mode. The paper evaluates with the value-range-based
/// relative bound (`valrel`, footnote 2): `abs_eb = valrel * (max - min)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Absolute bound: |d - d*| <= eb.
    Abs(f64),
    /// Value-range relative bound: |d - d*| <= eb * (max(d) - min(d)).
    ValRel(f64),
}

impl ErrorBound {
    /// Resolve to an absolute bound given the field's value range.
    pub fn resolve(&self, range: f64) -> f32 {
        match *self {
            ErrorBound::Abs(eb) => eb as f32,
            ErrorBound::ValRel(rel) => {
                // Degenerate constant fields still need a positive bound.
                let r = if range > 0.0 { range } else { 1.0 };
                (rel * r) as f32
            }
        }
    }
}

/// Which engine executes the quantization kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO executables on the PJRT CPU client (the production path;
    /// stands in for the paper's CUDA kernels — see DESIGN.md §4).
    Pjrt,
    /// Pure-Rust dual-quant (bit-exact with the PJRT path); used as the
    /// multicore baseline and as a fallback when artifacts are absent.
    Cpu,
}

/// Huffman codeword representation (paper §3.2.2, Table 4). `Adaptive`
/// selects U32 when the longest codeword fits in 24 bits, else U64.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodewordRepr {
    U32,
    U64,
    Adaptive,
}

/// Optional lossless stage over the deflated bitstream (paper step 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LosslessStage {
    #[default]
    None,
    Gzip,
    Zstd,
}

#[derive(Debug, Clone)]
pub struct CuszConfig {
    pub eb: ErrorBound,
    pub backend: BackendKind,
    /// Number of quantization bins (Huffman symbols). Paper default 1024.
    /// The AOT artifacts are compiled for 1024; the CPU backend accepts
    /// any power of two in [128, 65536] (Table 3 sweeps this).
    pub dict_size: usize,
    /// Symbols per deflate chunk (paper §3.2.4, Table 6). 4096 is the
    /// measured optimum on this testbed; `cusz bench-chunk-size` re-derives.
    pub chunk_symbols: usize,
    pub codeword_repr: CodewordRepr,
    /// Which symbol encoder backend + lossless tail stage (the pluggable
    /// codec pipeline; `Auto` resolves per field from the histogram).
    pub codec: CodecSpec,
    /// Decode-throughput budget in GB/s for `auto` codec selection: when
    /// positive, backends whose measured decode rate (telemetry registry,
    /// original bytes over decode time) misses the budget are pruned
    /// before the cost model's size argmin — trading compression ratio
    /// for decompression speed. 0 (default) disables pruning; backends
    /// with no recorded decode traffic are never pruned.
    pub target_gbps: f64,
    /// Worker threads for coarse-grained (chunk) parallelism. 0 = all cores.
    pub threads: usize,
    /// Directory holding `manifest.tsv` + HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Bounded queue depth between pipeline stages (backpressure).
    pub queue_depth: usize,
    /// How hard store mutations are pushed to stable storage before the
    /// operation (and any PUT ack built on it) completes.
    pub durability: Durability,
}

impl Default for CuszConfig {
    fn default() -> Self {
        CuszConfig {
            eb: ErrorBound::ValRel(1e-4),
            backend: BackendKind::Pjrt,
            dict_size: 1024,
            chunk_symbols: 4096,
            codeword_repr: CodewordRepr::Adaptive,
            codec: CodecSpec::default(),
            target_gbps: 0.0,
            threads: 0,
            artifacts_dir: PathBuf::from("artifacts"),
            queue_depth: 4,
            durability: Durability::default(),
        }
    }
}

impl CuszConfig {
    pub fn radius(&self) -> i32 {
        (self.dict_size / 2) as i32
    }

    pub fn effective_threads(&self) -> usize {
        crate::util::pool::effective_threads(self.threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valrel_resolves_against_range() {
        let eb = ErrorBound::ValRel(1e-3);
        assert!((eb.resolve(100.0) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn abs_ignores_range() {
        let eb = ErrorBound::Abs(0.5);
        assert_eq!(eb.resolve(123.0), 0.5);
    }

    #[test]
    fn degenerate_range_stays_positive() {
        let eb = ErrorBound::ValRel(1e-3);
        assert!(eb.resolve(0.0) > 0.0);
    }

    #[test]
    fn default_config_matches_paper() {
        let c = CuszConfig::default();
        assert_eq!(c.dict_size, 1024);
        assert_eq!(c.radius(), 512);
    }

    #[test]
    fn default_codec_is_huffman_without_lossless() {
        let c = CuszConfig::default();
        assert_eq!(c.codec.encoder, EncoderChoice::Huffman);
        assert_eq!(c.codec.lossless, LosslessStage::None);
        assert_eq!(c.codec.granularity, CodecGranularity::Field);
    }
}
