//! Named crashpoints inside every store mutation, for crash-recovery
//! testing. Setting `CUSZ_CRASHPOINT=<name>` in the environment makes the
//! process `abort()` the moment execution reaches that point, simulating
//! a kill -9 at the most inconvenient instant of an append, index
//! publish, compaction swap, remove, or quarantine move. The harness in
//! `tests/crash_recovery.rs` runs each point in a child process, lets it
//! die, then asserts that reopen + fsck restore a consistent store with
//! every durably-acked write intact.
//!
//! The registry is always compiled (it is a single cached env read and a
//! string compare per point — nanoseconds on the hot path, and zero
//! branches once the `OnceLock` resolves to `None` in production where
//! the variable is unset).

use std::sync::OnceLock;

/// Environment variable naming the crashpoint to arm.
pub const ENV: &str = "CUSZ_CRASHPOINT";

/// Append: payload streamed into the shard's userspace buffer, nothing
/// flushed or synced yet, index untouched.
pub const APPEND_WRITTEN: &str = "append.written";
/// Append: payload flushed to the OS, not yet synced, index untouched.
pub const APPEND_FLUSHED: &str = "append.flushed";
/// Append: payload durable (`sync_data` done under `Durability::Sync`),
/// index commit not yet started — the classic orphan-bytes window.
pub const APPEND_SYNCED: &str = "append.synced";
/// Index publish: tmp file fully written, not yet synced or renamed.
pub const INDEX_TMP_WRITTEN: &str = "index.tmp_written";
/// Index publish: tmp renamed over the live index, parent directory not
/// yet fsynced.
pub const INDEX_RENAMED: &str = "index.renamed";
/// Remove: entry dropped from the in-memory index, on-disk index not yet
/// rewritten.
pub const REMOVE_UNCOMMITTED: &str = "remove.uncommitted";
/// Compaction: staging bundle fully built, swap-intent marker not yet
/// written.
pub const COMPACT_STAGED: &str = "compact.staged";
/// Compaction: swap-intent marker durable, first rename not yet issued.
pub const COMPACT_INTENT: &str = "compact.intent";
/// Compaction: old bundle renamed aside to the graveyard, compacted
/// staging not yet installed — the window the marker exists to cover.
pub const COMPACT_OLD_ASIDE: &str = "compact.old_aside";
/// Compaction: compacted bundle installed, graveyard and marker still on
/// disk.
pub const COMPACT_INSTALLED: &str = "compact.installed";
/// Quarantine: payload copied into `quarantine/`, manifest not yet
/// updated, entry still live.
pub const QUARANTINE_COPIED: &str = "quarantine.copied";
/// Quarantine: manifest updated, index entry not yet dropped.
pub const QUARANTINE_MANIFESTED: &str = "quarantine.manifested";

/// Every registered crashpoint; the harness iterates this list, so a new
/// point added here is automatically exercised.
pub const ALL: &[&str] = &[
    APPEND_WRITTEN,
    APPEND_FLUSHED,
    APPEND_SYNCED,
    INDEX_TMP_WRITTEN,
    INDEX_RENAMED,
    REMOVE_UNCOMMITTED,
    COMPACT_STAGED,
    COMPACT_INTENT,
    COMPACT_OLD_ASIDE,
    COMPACT_INSTALLED,
    QUARANTINE_COPIED,
    QUARANTINE_MANIFESTED,
];

fn armed() -> Option<&'static str> {
    static ARMED: OnceLock<Option<String>> = OnceLock::new();
    ARMED
        .get_or_init(|| std::env::var(ENV).ok().filter(|s| !s.is_empty()))
        .as_deref()
}

/// Abort the process if `point` is the armed crashpoint. No-op (one
/// pointer load + branch) when `CUSZ_CRASHPOINT` is unset.
#[inline]
pub fn fire(point: &str) {
    if let Some(target) = armed() {
        if target == point {
            // stderr so the harness can confirm the point actually fired
            eprintln!("[cusz] crashpoint '{point}' armed: aborting");
            std::process::abort();
        }
    }
}
