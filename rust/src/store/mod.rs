//! The `.cuszb` multi-field archive store: a sharded bundle of
//! concatenated `.cusza` payloads plus a footer index, giving a compressed
//! simulation snapshot (dozens of fields) one on-disk home with random
//! access per field.
//!
//! Layout — a bundle is a directory:
//!
//! ```text
//! snapshot.cuszb/
//!   index.cuszi        footer index: name → (shard, offset, len,
//!                      payload CRC32, header digest, dims); CRC-framed,
//!                      rewritten atomically (tmp + rename) on add/remove
//!   shard-0000.cuszs   8-byte shard magic, then concatenated .cusza
//!   shard-0001.cuszs   payloads, append-only
//!   ...
//! ```
//!
//! Placement is least-loaded-shard, so parallel readers of different
//! fields tend to hit different files. `get` seeks straight to one
//! payload and never touches sibling payloads; integrity is checked at
//! three levels (payload CRC from the index, per-section CRCs inside the
//! payload, header digest against the index entry). `remove` drops the
//! index entry and leaves the payload bytes as dead space — reclaim by
//! rebuilding the bundle ([`Store::compact_into`]).
//!
//! Concurrency contract: one writer OR many readers per bundle. Writers
//! are arbitrated by an advisory lock file beside the footer index
//! ([`lock::StoreLock`]): the first mutating call acquires it, a second
//! writer process fails fast instead of interleaving shard appends.
//! Readers ([`Store::open`]) never take the lock.
//!
//! Crash consistency: mutations honor a [`Durability`] level (userspace
//! flush / index fsync / full shard + directory sync), and
//! [`Store::open_writable`] runs a recovery pass — truncating torn or
//! orphaned shard tails past the last index-referenced byte, finishing or
//! rolling back an interrupted compaction swap from its durable intent
//! marker, and sweeping stale machinery files — so a crashed writer's
//! bundle always reopens into a consistent state. Deeper damage (bit rot,
//! index/shard disagreement) is the [`fsck`] scrubber's job; fields it
//! can't salvage move to a `quarantine/` subdir instead of failing the
//! bundle. [`crashpoints`] provides the injection hooks the recovery
//! test harness aborts at.

pub mod crashpoints;
pub mod fsck;
pub mod index;
pub mod lock;

use std::collections::BTreeSet;
use std::fs::{self, File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::container::bytes::{crc32, Crc32};
use crate::container::Archive;

pub use fsck::{FsckOptions, FsckReport};
pub use index::{StoreEntry, StoreIndex};
pub use lock::StoreLock;

pub const SHARD_MAGIC: &[u8; 8] = b"CUSZS1\0\0";
pub(crate) const INDEX_FILE: &str = "index.cuszi";
/// Bounded buffer size for streamed payload reads ([`Store::get_into`],
/// compaction): the working set of a shard→sink copy, independent of
/// payload size.
pub const READ_CHUNK_BYTES: usize = 1 << 20;
/// Subdirectory (inside the bundle) holding payload copies of fields
/// pulled from service, plus the manifest naming them.
pub const QUARANTINE_DIR: &str = "quarantine";
pub const QUARANTINE_MANIFEST: &str = "MANIFEST";

/// How hard mutations are pushed toward stable storage before they are
/// declared done — the ack-vs-durability contract for callers (the serve
/// daemon acks a PUT only after [`Store::put_bytes`] returns, i.e. after
/// this level's sync point).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Durability {
    /// Userspace flush only: fastest; a crashed *process* loses nothing
    /// it was told was stored, but a crashed *machine* may.
    None,
    /// `None` plus the index tmp file is fsynced before its rename, so a
    /// published index is never torn (the default).
    #[default]
    Flush,
    /// Full discipline: shard `sync_data` before the index references the
    /// new bytes, index tmp fsync, and a directory fsync after every
    /// rename (index publish, compaction swap) — an acked write survives
    /// power loss.
    Sync,
}

impl Durability {
    pub fn parse(s: &str) -> Result<Durability> {
        match s {
            "none" => Ok(Durability::None),
            "flush" => Ok(Durability::Flush),
            "sync" => Ok(Durability::Sync),
            _ => bail!("unknown durability level '{s}' (expected none|flush|sync)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Durability::None => "none",
            Durability::Flush => "flush",
            Durability::Sync => "sync",
        }
    }
}

/// fsync a directory so a rename inside it is durable. No-op off unix,
/// where directory handles can't be synced portably.
pub(crate) fn fsync_dir(dir: &Path) -> Result<()> {
    if cfg!(unix) {
        File::open(dir)
            .and_then(|f| f.sync_all())
            .with_context(|| format!("fsyncing directory {}", dir.display()))?;
    }
    Ok(())
}

/// Optional mmap fast path for shard payload reads (`store-mmap` cargo
/// feature, unix only): map the entry's region read-only and copy it
/// straight out of the page cache instead of `read(2)`-ing through a
/// buffer. The bindings are declared in-tree (the same approach as the
/// serve daemon's `signal` binding) — no new dependencies. Off by
/// default: a concurrently truncated shard turns a mapped read into a
/// fault, where the buffered path gets a clean short-read error.
#[cfg(all(feature = "store-mmap", unix))]
mod mmap {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;
    use std::path::Path;

    use anyhow::{Context, Result};

    /// mmap offsets must be page-aligned; aligning down to 64 KiB keeps
    /// the offset aligned on any common page size (4 KiB x86, 16 KiB
    /// arm64) without a `sysconf` binding.
    const ALIGN: u64 = 64 * 1024;

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut std::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut std::ffi::c_void;
        fn munmap(addr: *mut std::ffi::c_void, len: usize) -> i32;
    }

    /// A read-only mapping of one shard region; unmapped on drop.
    pub struct MappedRegion {
        base: *mut std::ffi::c_void,
        map_len: usize,
        skip: usize,
        len: usize,
    }

    impl MappedRegion {
        /// Map `len` bytes at `offset` of `path`. Returns `None` for an
        /// empty region (a zero-length mmap is an error by spec).
        pub fn map(path: &Path, offset: u64, len: u64) -> Result<Option<MappedRegion>> {
            if len == 0 {
                return Ok(None);
            }
            let f = File::open(path)
                .with_context(|| format!("opening shard {}", path.display()))?;
            let aligned = offset & !(ALIGN - 1);
            let skip = (offset - aligned) as usize;
            let map_len = skip + len as usize;
            // SAFETY: private read-only mapping of a regular file we just
            // opened; the region [aligned, offset + len) lies within the
            // file because the index entry does. Closing the fd after
            // mmap is fine — the mapping keeps the file referenced.
            let base = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    map_len,
                    PROT_READ,
                    MAP_PRIVATE,
                    f.as_raw_fd(),
                    aligned as i64,
                )
            };
            if base as isize == -1 {
                anyhow::bail!(
                    "mmap of {} failed: {}",
                    path.display(),
                    std::io::Error::last_os_error()
                );
            }
            Ok(Some(MappedRegion { base, map_len, skip, len: len as usize }))
        }

        pub fn bytes(&self) -> &[u8] {
            // SAFETY: the mapping covers `skip + len` readable bytes.
            unsafe {
                std::slice::from_raw_parts((self.base as *const u8).add(self.skip), self.len)
            }
        }
    }

    impl Drop for MappedRegion {
        fn drop(&mut self) {
            // SAFETY: base/map_len came from a successful mmap.
            unsafe { munmap(self.base, self.map_len) };
        }
    }
}

fn hex_encode(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn hex_nibble(c: u8) -> Option<u8> {
    match c {
        b'0'..=b'9' => Some(c - b'0'),
        b'a'..=b'f' => Some(c - b'a' + 10),
        b'A'..=b'F' => Some(c - b'A' + 10),
        _ => None,
    }
}

fn hex_decode(s: &str) -> Option<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return None;
    }
    b.chunks(2)
        .map(|p| Some((hex_nibble(p[0])? << 4) | hex_nibble(p[1])?))
        .collect()
}

/// Parse `quarantine/MANIFEST` into `(field name, payload file)` rows.
/// Tolerant: damaged or unknown lines are skipped, so a half-written
/// manifest from a crashed quarantine move can never fail an open.
/// Field names are hex-encoded on disk (they are arbitrary UTF-8 and may
/// contain the manifest's own separators).
pub(crate) fn read_quarantine_manifest(dir: &Path) -> Vec<(String, String)> {
    let path = dir.join(QUARANTINE_DIR).join(QUARANTINE_MANIFEST);
    let Ok(raw) = fs::read_to_string(&path) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for line in raw.lines() {
        let mut parts = line.splitn(4, ' ');
        if parts.next() != Some("q1") {
            continue;
        }
        let (Some(hexname), Some(file)) = (parts.next(), parts.next()) else {
            continue;
        };
        let Some(name) = hex_decode(hexname).and_then(|b| String::from_utf8(b).ok()) else {
            continue;
        };
        out.push((name, file.to_string()));
    }
    out
}

/// Append one quarantine record; `sync` forces it to stable storage.
pub(crate) fn append_quarantine_manifest(
    dir: &Path,
    name: &str,
    file: &str,
    reason: &str,
    sync: bool,
) -> Result<()> {
    let qdir = dir.join(QUARANTINE_DIR);
    fs::create_dir_all(&qdir)
        .with_context(|| format!("creating {}", qdir.display()))?;
    let path = qdir.join(QUARANTINE_MANIFEST);
    let mut f = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .with_context(|| format!("opening {}", path.display()))?;
    let reason: String =
        reason.chars().map(|c| if c == '\n' || c == '\r' { ' ' } else { c }).collect();
    writeln!(f, "q1 {} {} {}", hex_encode(name.as_bytes()), file, reason)
        .with_context(|| format!("appending to {}", path.display()))?;
    f.flush()?;
    if sync {
        f.sync_data()
            .with_context(|| format!("syncing {}", path.display()))?;
    }
    Ok(())
}

/// Stale machinery files inside a bundle: a leftover `index.cuszi.tmp`
/// from a crashed publish, lock-breaker captures / staged lock tmps whose
/// owner died, and unmanifested `quarantine/` payload copies from a
/// crashed quarantine move. Returns one description per artifact found;
/// removes them when `remove` is set.
pub(crate) fn sweep_stale_artifacts(dir: &Path, remove: bool) -> Result<Vec<String>> {
    let mut found = Vec::new();
    let tmp = dir.join(format!("{INDEX_FILE}.tmp"));
    if tmp.exists() {
        found.push(format!("half-published index {}", tmp.display()));
        if remove {
            fs::remove_file(&tmp)
                .with_context(|| format!("removing {}", tmp.display()))?;
        }
    }
    for entry in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(pid) = lock::artifact_pid(&name) {
            if !lock::process_alive(pid) {
                found.push(format!("stale lock artifact {name} (pid {pid} is dead)"));
                if remove {
                    let _ = fs::remove_file(entry.path());
                }
            }
        }
    }
    let qdir = dir.join(QUARANTINE_DIR);
    if qdir.is_dir() {
        let manifested: std::collections::HashSet<String> =
            read_quarantine_manifest(dir).into_iter().map(|(_, f)| f).collect();
        for entry in fs::read_dir(&qdir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name != QUARANTINE_MANIFEST && !manifested.contains(&name) {
                found.push(format!("unmanifested quarantine copy {name}"));
                if remove {
                    let _ = fs::remove_file(entry.path());
                }
            }
        }
    }
    Ok(found)
}

// Store I/O telemetry (static-key fast path into the obs registry).
static WRITE_BYTES: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.write_bytes");
static READ_BYTES: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.read_bytes");
static CRC_CHECKS: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.crc_checks");
static COMPACTIONS: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.compactions");
static COMPACTED_BYTES: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.compacted_bytes");
static QUARANTINED: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.quarantined");
static RECOVER_TRUNCATED: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.recover.truncated_bytes");
static RECOVER_ARTIFACTS: crate::obs::StaticCounter =
    crate::obs::StaticCounter::new("store.recover.artifacts");

/// An open `.cuszb` bundle.
pub struct Store {
    dir: PathBuf,
    index: StoreIndex,
    /// Current byte length of each shard file (append cursor).
    shard_sizes: Vec<u64>,
    /// When true, `add`/`remove` skip the per-call index rewrite; the
    /// index commits once when deferral ends (batch ingestion path).
    defer_index: bool,
    /// Held writer lock (None for read-only opens until a mutating call
    /// acquires it lazily).
    lock: Option<StoreLock>,
    /// Durability level mutations honor (see [`Durability`]).
    durability: Durability,
    /// Names pulled from service into `quarantine/` (the manifest minus
    /// live index entries). GETs of these get a distinct "quarantined"
    /// classification instead of a generic miss.
    quarantined: BTreeSet<String>,
}

pub(crate) fn shard_file_name(i: u32) -> String {
    format!("shard-{i:04}.cuszs")
}

/// Description of compaction-swap leftovers at `dir` that recovery should
/// act on, or `None` when there is nothing to do — including when the
/// leftovers belong to a *live* process (the swap-intent marker names the
/// compacting pid, and the bundle lock names a live writer), which must
/// be left alone.
pub(crate) fn swap_leftovers(dir: &Path) -> Option<String> {
    let paths = SwapPaths::of(dir);
    let mut present: Vec<&str> = Vec::new();
    if paths.marker.exists() {
        present.push("swap-intent marker");
    }
    if paths.staging.exists() {
        present.push("staging dir");
    }
    if paths.graveyard.exists() {
        present.push("graveyard dir");
    }
    if present.is_empty() {
        return None;
    }
    if let Ok(raw) = fs::read_to_string(&paths.marker) {
        if let Some(pid) = raw.lines().nth(1).and_then(|l| l.trim().parse::<u32>().ok()) {
            if lock::process_alive(pid) {
                return None; // swap in flight, owner alive
            }
        }
    }
    if dir.join(INDEX_FILE).exists() && lock::holder_alive(dir) {
        return None; // a live writer owns the bundle and its leftovers
    }
    Some(format!(
        "interrupted compaction swap of {} ({} left behind)",
        dir.display(),
        present.join(" + ")
    ))
}

/// Digests everything written through it, so a streamed shard append can
/// record the payload CRC without ever buffering the payload.
struct CrcWriter<W: Write> {
    inner: W,
    crc: Crc32,
}

impl<W: Write> CrcWriter<W> {
    fn new(inner: W) -> Self {
        CrcWriter { inner, crc: Crc32::new() }
    }

    fn crc(&self) -> u32 {
        self.crc.finish()
    }

    fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

impl Store {
    /// Create a new empty bundle with `n_shards` payload shards. The
    /// directory may exist (and be empty); an existing index is refused.
    pub fn create(dir: impl AsRef<Path>, n_shards: usize) -> Result<Store> {
        let dir = dir.as_ref().to_path_buf();
        if !(1..=4096).contains(&n_shards) {
            bail!("shard count must be in 1..=4096, got {n_shards}");
        }
        if dir.join(INDEX_FILE).exists() {
            bail!("store already exists at {}", dir.display());
        }
        // A shard file without an index means a damaged bundle whose
        // payloads may still be salvageable — refuse to truncate them.
        if dir.is_dir() {
            for entry in fs::read_dir(&dir)? {
                let name = entry?.file_name();
                if name.to_string_lossy().ends_with(".cuszs") {
                    bail!(
                        "{} contains shard files but no index (damaged bundle?); \
                         refusing to overwrite — move them away first",
                        dir.display()
                    );
                }
            }
        }
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        // a new bundle is born with its writer lock held
        let lock = StoreLock::acquire(&dir)?;
        for i in 0..n_shards as u32 {
            let path = dir.join(shard_file_name(i));
            let mut f = File::create(&path)
                .with_context(|| format!("creating shard {}", path.display()))?;
            f.write_all(SHARD_MAGIC)?;
        }
        let store = Store {
            dir,
            index: StoreIndex { n_shards: n_shards as u32, entries: Vec::new() },
            shard_sizes: vec![SHARD_MAGIC.len() as u64; n_shards],
            defer_index: false,
            lock: Some(lock),
            durability: Durability::default(),
            quarantined: BTreeSet::new(),
        };
        store.write_index()?;
        Ok(store)
    }

    /// Whether a bundle (its index file) exists at `dir`.
    pub fn exists(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join(INDEX_FILE).exists()
    }

    /// Open the bundle at `dir` as a writer (lock held up front), or
    /// create it with `n_shards` shards if no index exists yet.
    pub fn open_or_create(dir: impl AsRef<Path>, n_shards: usize) -> Result<Store> {
        if Store::exists(&dir) {
            Store::open_writable(dir)
        } else {
            Store::create(dir, n_shards)
        }
    }

    /// Open an existing bundle and acquire the writer lock immediately
    /// (instead of lazily on the first mutating call), so lock conflicts
    /// surface before any work is done.
    ///
    /// This is also the crash-recovery entry point: before the strict
    /// open it finishes or rolls back an interrupted compaction swap
    /// (from the durable swap-intent marker), and once the lock is held
    /// it reconciles the bundle — stale machinery files are swept and
    /// torn or orphaned shard tails past the last index-referenced byte
    /// are truncated away. Bytes removed this way were never committed to
    /// the index, so they were never acked to any caller.
    pub fn open_writable(dir: impl AsRef<Path>) -> Result<Store> {
        let dir = dir.as_ref();
        Store::recover_interrupted_swap(dir)?;
        let mut store = Store::open(dir).map_err(|e| {
            e.context("opening for write (if the bundle is damaged, run `cusz store fsck --repair`)")
        })?;
        store.ensure_writer_lock()?;
        store.reconcile()?;
        Ok(store)
    }

    /// Open an existing bundle, verifying the index and shard framing:
    /// index magic/version/CRC, shard files present with the right magic,
    /// every entry within its shard's bounds, names unique.
    pub fn open(dir: impl AsRef<Path>) -> Result<Store> {
        let dir = dir.as_ref().to_path_buf();
        let raw = fs::read(dir.join(INDEX_FILE))
            .with_context(|| format!("reading store index in {}", dir.display()))?;
        let index = StoreIndex::from_bytes(&raw)
            .with_context(|| format!("parsing store index in {}", dir.display()))?;

        let mut shard_sizes = Vec::with_capacity(index.n_shards as usize);
        for i in 0..index.n_shards {
            let path = dir.join(shard_file_name(i));
            let mut f = File::open(&path)
                .with_context(|| format!("opening shard {}", path.display()))?;
            let size = f
                .metadata()
                .with_context(|| format!("stat {}", path.display()))?
                .len();
            if size < SHARD_MAGIC.len() as u64 {
                bail!("{} is truncated (no shard magic)", path.display());
            }
            let mut magic = [0u8; 8];
            f.read_exact(&mut magic)?;
            if &magic != SHARD_MAGIC {
                bail!("{} is not a cuszb shard (bad magic)", path.display());
            }
            shard_sizes.push(size);
        }

        let mut seen = std::collections::HashSet::new();
        for e in &index.entries {
            if e.offset < SHARD_MAGIC.len() as u64 {
                bail!("entry '{}' offset {} inside shard magic", e.name, e.offset);
            }
            let end = e
                .offset
                .checked_add(e.len)
                .with_context(|| format!("entry '{}' offset overflow", e.name))?;
            if end > shard_sizes[e.shard as usize] {
                bail!(
                    "entry '{}' overruns shard {} ({} > {} bytes)",
                    e.name,
                    e.shard,
                    end,
                    shard_sizes[e.shard as usize]
                );
            }
            if !seen.insert(e.name.as_str()) {
                bail!("duplicate entry '{}' in index", e.name);
            }
        }
        // quarantined = manifest minus live entries: a field re-put after
        // quarantine (or a manifest line from a half-finished move whose
        // index commit never landed) is live again, manifest notwithstanding
        let mut quarantined: BTreeSet<String> =
            read_quarantine_manifest(&dir).into_iter().map(|(name, _)| name).collect();
        for e in &index.entries {
            quarantined.remove(&e.name);
        }
        Ok(Store {
            dir,
            index,
            shard_sizes,
            defer_index: false,
            lock: None,
            durability: Durability::default(),
            quarantined,
        })
    }

    /// Set the durability level honored by subsequent mutations.
    pub fn set_durability(&mut self, durability: Durability) {
        self.durability = durability;
    }

    pub fn durability(&self) -> Durability {
        self.durability
    }

    /// Lazily acquire the writer lock; every mutating entry point calls
    /// this so read-only opens stay lock-free. Once held, the lock file is
    /// revalidated per call (one tiny read) so a writer whose lock was
    /// voided by a racing stale-lock breaker fails fast instead of
    /// appending unguarded.
    fn ensure_writer_lock(&mut self) -> Result<()> {
        match &self.lock {
            Some(lock) => lock.verify_held(),
            None => {
                self.lock = Some(StoreLock::acquire(&self.dir)?);
                Ok(())
            }
        }
    }

    /// Toggle deferred index commits. While deferred, `add`/`remove`
    /// mutate only the in-memory index (payload appends still hit disk);
    /// turning deferral off commits the index once. Batch ingestion over
    /// N fields thus does one index write instead of N. A crash while
    /// deferred loses only index entries — appended payloads become dead
    /// space, never corruption.
    pub fn set_deferred_index(&mut self, deferred: bool) -> Result<()> {
        self.ensure_writer_lock()?;
        self.defer_index = deferred;
        if !deferred {
            self.write_index()?;
        }
        Ok(())
    }

    /// Compress-side entry point: append one archive under its header's
    /// field name, streaming the serialization straight into the shard
    /// file — the payload is never materialized in memory, and the CRC
    /// the index records is digested as the bytes flow past. Fails on
    /// duplicate names (remove first). A write error mid-stream leaves
    /// unindexed partial bytes in the shard (dead space, reclaimed by
    /// compaction), never a corrupt index entry.
    pub fn add(&mut self, archive: &Archive) -> Result<StoreEntry> {
        let name = archive.header.field_name.clone();
        self.append_streamed(
            &name,
            archive.header_digest(),
            archive.header.dims.clone(),
            // `&mut w`: write_into is generic over a sized writer, so it
            // takes a &mut to the trait-object reference itself
            |mut w| archive.write_into(&mut w).map_err(anyhow::Error::from),
        )
    }

    /// Append a pre-serialized `.cusza` payload under `name`. Validates
    /// the payload's framing (magic + header section) before committing.
    pub fn add_bytes(&mut self, name: &str, payload: &[u8]) -> Result<StoreEntry> {
        let header = Archive::peek_header(payload)
            .with_context(|| format!("payload for '{name}' is not a valid .cusza archive"))?;
        self.append_streamed(name, crc32(&header.to_bytes()), header.dims, |w| {
            w.write_all(payload)?;
            Ok(payload.len() as u64)
        })
    }

    /// Whether `name` has a live index entry.
    pub fn contains(&self, name: &str) -> bool {
        self.find(name).is_some()
    }

    /// Upsert a pre-serialized `.cusza` payload under `name`: the daemon's
    /// PUT path, where re-sending a field replaces the stored archive
    /// instead of failing the duplicate-name check. The old entry (if any)
    /// is dropped from the in-memory index and the new payload appended in
    /// one index commit; the superseded payload becomes dead space for
    /// compaction. On append failure the old entry is already gone — same
    /// crash contract as `remove` followed by `add_bytes`.
    pub fn put_bytes(&mut self, name: &str, payload: &[u8]) -> Result<StoreEntry> {
        self.ensure_writer_lock()?;
        if self.find(name).is_some() {
            // in-memory retain only: add_bytes commits the index, so the
            // upsert costs one index write, not two
            self.index.entries.retain(|e| e.name != name);
        }
        let entry = self.add_bytes(name, payload)?;
        // a fresh payload supersedes any quarantine verdict on the name
        self.quarantined.remove(name);
        Ok(entry)
    }

    /// The one append path both entry points share: duplicate-name
    /// check, least-loaded shard choice, CRC-digesting streamed write,
    /// index-entry commit. `write` streams the payload into the provided
    /// sink and returns its byte length.
    fn append_streamed(
        &mut self,
        name: &str,
        header_digest: u32,
        dims: Vec<usize>,
        write: impl FnOnce(&mut dyn Write) -> Result<u64>,
    ) -> Result<StoreEntry> {
        self.ensure_writer_lock()?;
        if self.find(name).is_some() {
            bail!("field '{name}' already in store (remove it first)");
        }

        // least-loaded shard keeps payloads spread for parallel readers
        let shard = self
            .shard_sizes
            .iter()
            .enumerate()
            .min_by_key(|&(_, &s)| s)
            .map(|(i, _)| i as u32)
            .expect("store has at least one shard");
        let path = self.shard_path(shard);
        let mut f = OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        let offset = f.seek(SeekFrom::End(0))?;
        let mut w = CrcWriter::new(BufWriter::new(&mut f));
        let len = write(&mut w)
            .with_context(|| format!("appending '{name}' to shard {}", path.display()))?;
        let payload_crc = w.crc();
        crashpoints::fire(crashpoints::APPEND_WRITTEN);
        w.into_inner()
            .flush()
            .with_context(|| format!("flushing shard {}", path.display()))?;
        f.flush()?;
        crashpoints::fire(crashpoints::APPEND_FLUSHED);
        // the payload must be durable before the index can reference it:
        // an index entry pointing at unsynced bytes would turn power loss
        // into a torn read of an acked write
        if self.durability == Durability::Sync {
            f.sync_data()
                .with_context(|| format!("syncing shard {}", path.display()))?;
        }
        crashpoints::fire(crashpoints::APPEND_SYNCED);

        WRITE_BYTES.add(len);

        let entry = StoreEntry {
            name: name.to_string(),
            shard,
            offset,
            len,
            payload_crc,
            header_digest,
            dims,
        };
        self.index.entries.push(entry.clone());
        self.shard_sizes[shard as usize] = offset + len;
        if !self.defer_index {
            self.write_index()?;
        }
        Ok(entry)
    }

    /// Seek + read + CRC-check one entry's payload from its shard.
    fn read_entry(&self, e: &StoreEntry) -> Result<Vec<u8>> {
        let path = self.shard_path(e.shard);
        let mut f = File::open(&path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        f.seek(SeekFrom::Start(e.offset))?;
        let mut buf = vec![0u8; e.len as usize];
        f.read_exact(&mut buf)
            .with_context(|| format!("reading '{}' from {}", e.name, path.display()))?;
        CRC_CHECKS.incr();
        if crc32(&buf) != e.payload_crc {
            bail!("field '{}': payload CRC mismatch (corrupt shard)", e.name);
        }
        READ_BYTES.add(e.len);
        Ok(buf)
    }

    /// Stream one entry's payload into `w` through a bounded buffer
    /// ([`READ_CHUNK_BYTES`]), digesting the payload CRC as the bytes
    /// flow — the payload is never resident as one `Vec`. With the
    /// `store-mmap` feature on unix the shard region is mapped instead
    /// and copied straight out of the page cache.
    ///
    /// Caveat of streaming verification: bytes reach `w` *before* the
    /// final CRC verdict; on a mismatch the call errors after the fact
    /// (and a transactional consumer like [`Store::append_streamed`]
    /// discards the partial write). Consumers that must never expose
    /// unverified bytes should use [`Store::get_bytes`].
    fn read_entry_into(&self, e: &StoreEntry, w: &mut dyn Write) -> Result<()> {
        let path = self.shard_path(e.shard);
        #[cfg(all(feature = "store-mmap", unix))]
        {
            if let Some(mapped) = mmap::MappedRegion::map(&path, e.offset, e.len)? {
                CRC_CHECKS.incr();
                if crc32(mapped.bytes()) != e.payload_crc {
                    bail!("field '{}': payload CRC mismatch (corrupt shard)", e.name);
                }
                w.write_all(mapped.bytes())
                    .with_context(|| format!("streaming '{}' from {}", e.name, path.display()))?;
                READ_BYTES.add(e.len);
                return Ok(());
            }
            // fall through to the buffered path (e.g. empty payload)
        }
        let mut f = File::open(&path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        f.seek(SeekFrom::Start(e.offset))?;
        let mut crc = Crc32::new();
        crate::util::arena::with_u8(|buf| -> Result<()> {
            buf.clear();
            buf.resize(READ_CHUNK_BYTES.min(e.len.max(1) as usize), 0);
            let mut remaining = e.len;
            while remaining > 0 {
                let take = (buf.len() as u64).min(remaining) as usize;
                f.read_exact(&mut buf[..take])
                    .with_context(|| format!("reading '{}' from {}", e.name, path.display()))?;
                crc.update(&buf[..take]);
                w.write_all(&buf[..take])
                    .with_context(|| format!("streaming '{}'", e.name))?;
                remaining -= take as u64;
            }
            Ok(())
        })?;
        CRC_CHECKS.incr();
        if crc.finish() != e.payload_crc {
            bail!("field '{}': payload CRC mismatch (corrupt shard)", e.name);
        }
        READ_BYTES.add(e.len);
        Ok(())
    }

    /// Stream one field's raw payload into `w` through a bounded buffer —
    /// the chunked sibling of [`Store::get_bytes`]. Returns the payload
    /// length. See [`Store::read_entry_into`] for the CRC-after-stream
    /// caveat.
    pub fn get_into(&self, name: &str, w: &mut dyn Write) -> Result<u64> {
        let e = self
            .find(name)
            .with_context(|| format!("field '{name}' not in store"))?;
        self.read_entry_into(e, w)?;
        Ok(e.len)
    }

    /// Random-access read of one field's raw payload: one seek + one read
    /// in one shard; sibling payloads are never touched. Verifies the
    /// payload CRC recorded at add time.
    pub fn get_bytes(&self, name: &str) -> Result<Vec<u8>> {
        let e = self
            .find(name)
            .with_context(|| format!("field '{name}' not in store"))?;
        self.read_entry(e)
    }

    /// Like [`Store::get_bytes`] but with the header digest cross-checked
    /// against the index entry too (the same guarantee [`Store::get`]
    /// gives), without decoding the payload body — the batch-drain read
    /// path.
    pub fn get_bytes_checked(&self, name: &str) -> Result<Vec<u8>> {
        let e = self
            .find(name)
            .with_context(|| format!("field '{name}' not in store"))?;
        let bytes = self.read_entry(e)?;
        let header = Archive::peek_header(&bytes)
            .with_context(|| format!("field '{name}': payload framing"))?;
        CRC_CHECKS.incr();
        if crc32(&header.to_bytes()) != e.header_digest {
            bail!("field '{name}': header digest mismatch (payload rewritten since indexing?)");
        }
        Ok(bytes)
    }

    /// Random-access read + decode of one field, with the header digest
    /// cross-checked against the index entry (via the shared checked read
    /// path, so single-field and batch-drain reads enforce one contract).
    pub fn get(&self, name: &str) -> Result<Archive> {
        let bytes = self.get_bytes_checked(name)?;
        Archive::from_bytes(&bytes).with_context(|| format!("decoding field '{name}'"))
    }

    /// Drop a field from the index. Its payload bytes become dead space in
    /// the shard until the bundle is compacted.
    pub fn remove(&mut self, name: &str) -> Result<()> {
        self.ensure_writer_lock()?;
        let before = self.index.entries.len();
        self.index.entries.retain(|e| e.name != name);
        if self.index.entries.len() == before {
            bail!("field '{name}' not in store");
        }
        crashpoints::fire(crashpoints::REMOVE_UNCOMMITTED);
        if self.defer_index {
            return Ok(());
        }
        self.write_index()
    }

    /// Rebuild the bundle at `dest` with only live entries (reclaims the
    /// dead space `remove` leaves behind). Each payload streams shard to
    /// shard through the bounded [`Store::read_entry_into`] buffer — the
    /// source entry's CRC is re-verified in flight, its header digest and
    /// dims are carried over from the index, and a CRC mismatch aborts
    /// before the destination entry is committed — so compacting a bundle
    /// bigger than RAM holds ~1 MiB, not the largest payload.
    pub fn compact_into(&self, dest: impl AsRef<Path>) -> Result<Store> {
        let mut out = Store::create(dest, self.index.n_shards as usize)?;
        out.durability = self.durability;
        for e in &self.index.entries {
            out.append_streamed(&e.name, e.header_digest, e.dims.clone(), |w| {
                self.read_entry_into(e, w)?;
                Ok(e.len)
            })?;
        }
        Ok(out)
    }

    /// Compact the bundle in place: rebuild into a sibling temp directory,
    /// then swap it over this bundle's path (rename + rename, with a
    /// rollback if the install rename fails). Returns the number of dead
    /// bytes reclaimed.
    ///
    /// A durable swap-intent marker (`<name>.swap-intent`, written before
    /// the first rename, removed after cleanup) closes the crash window
    /// between the two renames: [`Store::open_writable`] and `fsck` use
    /// the marker plus whichever of the staging/graveyard directories
    /// survive to finish or roll back a half-done swap deterministically.
    /// Reader handles opened *before* the swap become invalid: `Store`
    /// reopens shard files by path on every read, so a stale handle's
    /// offsets no longer match the compacted shards and its reads fail
    /// cleanly with CRC mismatches — reopen after compaction. New opens
    /// see the compacted bundle.
    pub fn compact_in_place(&mut self) -> Result<u64> {
        self.ensure_writer_lock()?;
        let reclaimed = self.dead_bytes();
        if reclaimed == 0 {
            return Ok(0);
        }
        let paths = SwapPaths::of(&self.dir);
        for leftover in [&paths.staging, &paths.graveyard] {
            if leftover.exists() {
                fs::remove_dir_all(leftover)
                    .with_context(|| format!("clearing stale {}", leftover.display()))?;
            }
        }
        let _ = fs::remove_file(&paths.marker);
        let mut fresh = self.compact_into(&paths.staging)?;
        crashpoints::fire(crashpoints::COMPACT_STAGED);
        // Publish the swap intent durably before touching the live bundle:
        // recovery keys off this marker (which names the compacting pid,
        // so a concurrent opener can tell a crash from a swap in flight).
        {
            let mut mf = File::create(&paths.marker)
                .with_context(|| format!("writing {}", paths.marker.display()))?;
            write!(mf, "cuszb swap-intent v1\n{}\n", std::process::id())?;
            mf.sync_all()
                .with_context(|| format!("syncing {}", paths.marker.display()))?;
        }
        fsync_dir(&paths.parent)?;
        crashpoints::fire(crashpoints::COMPACT_INTENT);
        // Swap. Our own (still armed) lock file travels with the renames;
        // it is only disarmed once the new bundle is fully installed, so
        // any failure path below leaves this handle locked and usable.
        fs::rename(&self.dir, &paths.graveyard)
            .with_context(|| format!("moving old bundle to {}", paths.graveyard.display()))?;
        crashpoints::fire(crashpoints::COMPACT_OLD_ASIDE);
        if let Err(e) = fs::rename(&paths.staging, &self.dir) {
            // roll the old bundle back into place (its lock file included)
            let rollback = fs::rename(&paths.graveyard, &self.dir);
            if rollback.is_ok() {
                let _ = fs::remove_file(&paths.marker);
            }
            return Err(anyhow::Error::new(e).context(match rollback {
                Ok(()) => format!(
                    "installing compacted bundle at {} (old bundle restored)",
                    self.dir.display()
                ),
                Err(r) => format!(
                    "installing compacted bundle at {} (rollback also failed: {r}; \
                     old bundle is at {})",
                    self.dir.display(),
                    paths.graveyard.display()
                ),
            }));
        }
        if self.durability == Durability::Sync {
            fsync_dir(&paths.parent)?;
        }
        crashpoints::fire(crashpoints::COMPACT_INSTALLED);
        // The swap is complete: `fresh`'s lock file now sits at
        // dir/writer.lock, and our old lock file is inside the graveyard.
        // Disarm the old lock so its Drop doesn't delete the new one.
        if let Some(old_lock) = self.lock.take() {
            old_lock.disarm();
        }
        if let Some(l) = fresh.lock.as_mut() {
            l.retarget(&self.dir);
        }
        self.index = fresh.index;
        self.shard_sizes = fresh.shard_sizes;
        self.defer_index = false;
        self.lock = fresh.lock.take();
        // the compaction itself has fully succeeded at this point; failing
        // to clear the graveyard is not worth failing the operation over —
        // recovery-on-open (or the next compaction) clears stale leftovers.
        // The marker outlives the graveyard so recovery knows a surviving
        // graveyard belongs to a *finished* swap.
        match fs::remove_dir_all(&paths.graveyard) {
            Ok(()) => {
                let _ = fs::remove_file(&paths.marker);
                if self.durability == Durability::Sync {
                    let _ = fsync_dir(&paths.parent);
                }
            }
            Err(e) => eprintln!(
                "[cusz] warning: compacted bundle installed, but removing the old \
                 bundle at {} failed ({e}); it will be cleared on the next open",
                paths.graveyard.display()
            ),
        }
        COMPACTIONS.incr();
        COMPACTED_BYTES.add(reclaimed);
        Ok(reclaimed)
    }

    /// Full integrity scan: every payload read back and CRC-verified.
    pub fn verify(&self) -> Result<()> {
        for e in &self.index.entries {
            self.read_entry(e)
                .with_context(|| format!("verifying '{}'", e.name))?;
        }
        Ok(())
    }

    pub fn find(&self, name: &str) -> Option<&StoreEntry> {
        self.index.entries.iter().find(|e| e.name == name)
    }

    /// Entries in insertion order.
    pub fn list(&self) -> &[StoreEntry] {
        &self.index.entries
    }

    pub fn len(&self) -> usize {
        self.index.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.entries.is_empty()
    }

    pub fn n_shards(&self) -> u32 {
        self.index.n_shards
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Total bytes of live payloads.
    pub fn live_bytes(&self) -> u64 {
        self.index.entries.iter().map(|e| e.len).sum()
    }

    /// Bytes held by removed (unreachable) payloads. Saturating: a
    /// crafted index with overlapping entries can make live > stored
    /// without failing `open`'s per-entry bounds checks.
    pub fn dead_bytes(&self) -> u64 {
        let shard_data: u64 = self
            .shard_sizes
            .iter()
            .map(|&s| s.saturating_sub(SHARD_MAGIC.len() as u64))
            .sum();
        shard_data.saturating_sub(self.live_bytes())
    }

    fn shard_path(&self, shard: u32) -> PathBuf {
        self.dir.join(shard_file_name(shard))
    }

    fn write_index(&self) -> Result<()> {
        let tmp = self.dir.join(format!("{INDEX_FILE}.tmp"));
        let final_path = self.dir.join(INDEX_FILE);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.write_all(&self.index.to_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            crashpoints::fire(crashpoints::INDEX_TMP_WRITTEN);
            // the tmp must be durable before the rename publishes it, or a
            // power cut can leave a torn index at the final path
            if self.durability >= Durability::Flush {
                f.sync_data()
                    .with_context(|| format!("syncing {}", tmp.display()))?;
            }
        }
        fs::rename(&tmp, &final_path)
            .with_context(|| format!("committing {}", final_path.display()))?;
        crashpoints::fire(crashpoints::INDEX_RENAMED);
        if self.durability == Durability::Sync {
            fsync_dir(&self.dir)?;
        }
        Ok(())
    }

    /// Pull a field from service into `quarantine/`: its payload bytes are
    /// copied aside (unverified — the field is being quarantined precisely
    /// because they are suspect), recorded in the quarantine manifest, and
    /// the index entry dropped. The name then reads back as *quarantined*
    /// rather than missing ([`Store::is_quarantined`]), until a fresh
    /// `put_bytes` under the same name supersedes the verdict.
    pub fn quarantine(&mut self, name: &str, reason: &str) -> Result<()> {
        self.ensure_writer_lock()?;
        let e = self
            .find(name)
            .with_context(|| format!("field '{name}' not in store"))?
            .clone();
        let path = self.shard_path(e.shard);
        let mut f = File::open(&path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        f.seek(SeekFrom::Start(e.offset))?;
        let mut buf = vec![0u8; e.len as usize];
        f.read_exact(&mut buf)
            .with_context(|| format!("reading '{name}' from {}", path.display()))?;
        let qdir = self.dir.join(QUARANTINE_DIR);
        fs::create_dir_all(&qdir)
            .with_context(|| format!("creating {}", qdir.display()))?;
        let file = quarantine_file_name(e.shard, e.offset);
        let qpath = qdir.join(&file);
        let mut qf = File::create(&qpath)
            .with_context(|| format!("writing {}", qpath.display()))?;
        qf.write_all(&buf)?;
        if self.durability == Durability::Sync {
            qf.sync_all()?;
            fsync_dir(&qdir)?;
        }
        crashpoints::fire(crashpoints::QUARANTINE_COPIED);
        append_quarantine_manifest(
            &self.dir,
            name,
            &file,
            reason,
            self.durability == Durability::Sync,
        )?;
        crashpoints::fire(crashpoints::QUARANTINE_MANIFESTED);
        self.index.entries.retain(|x| x.name != name);
        if !self.defer_index {
            self.write_index()?;
        }
        self.quarantined.insert(name.to_string());
        QUARANTINED.incr();
        Ok(())
    }

    /// Whether `name` sits in quarantine (manifested, no live entry).
    pub fn is_quarantined(&self, name: &str) -> bool {
        self.quarantined.contains(name)
    }

    /// Quarantined names, sorted.
    pub fn quarantined_names(&self) -> Vec<&str> {
        self.quarantined.iter().map(String::as_str).collect()
    }

    /// Finish or roll back a compaction swap that crashed mid-flight,
    /// using the swap-intent marker plus whichever of the staging and
    /// graveyard directories survive. Also sweeps marker-less stale
    /// staging/graveyard leftovers. Safe against a live compactor: the
    /// marker names the compacting pid and the bundle lock names a live
    /// writer, and both are left alone while their owner is alive.
    pub(crate) fn recover_interrupted_swap(dir: &Path) -> Result<()> {
        if swap_leftovers(dir).is_none() {
            return Ok(());
        }
        let paths = SwapPaths::of(dir);
        let dir_live = dir.join(INDEX_FILE).exists();
        if paths.marker.exists() {
            if dir_live {
                // swap never started (staging still aside) or fully
                // completed with cleanup interrupted — either way the
                // bundle at `dir` is authoritative; discard the side dirs
                remove_stale_dir(&paths.staging)?;
                remove_stale_dir(&paths.graveyard)?;
            } else if Store::open(&paths.staging).is_ok() {
                // old bundle renamed aside, install crashed: finish the swap
                fs::rename(&paths.staging, dir).with_context(|| {
                    format!("installing staged bundle at {}", dir.display())
                })?;
                fsync_dir(&paths.parent)?;
                remove_stale_dir(&paths.graveyard)?;
            } else if paths.graveyard.join(INDEX_FILE).exists() {
                // staging missing or invalid: roll the old bundle back
                fs::rename(&paths.graveyard, dir).with_context(|| {
                    format!("rolling old bundle back to {}", dir.display())
                })?;
                fsync_dir(&paths.parent)?;
                remove_stale_dir(&paths.staging)?;
            } else {
                bail!(
                    "interrupted compaction of {}: neither the staging nor the \
                     graveyard directory holds a usable bundle",
                    dir.display()
                );
            }
            let _ = fs::remove_file(&paths.marker);
            let _ = fsync_dir(&paths.parent);
            RECOVER_ARTIFACTS.incr();
        } else {
            // no marker: a stale staging dir is always discardable, and a
            // graveyard shadowing a missing bundle is a pre-marker-era
            // crash between the two renames — roll it back
            if !dir_live && paths.graveyard.join(INDEX_FILE).exists() {
                fs::rename(&paths.graveyard, dir).with_context(|| {
                    format!("rolling old bundle back to {}", dir.display())
                })?;
                fsync_dir(&paths.parent)?;
            }
            remove_stale_dir(&paths.staging)?;
            remove_stale_dir(&paths.graveyard)?;
        }
        Ok(())
    }

    /// Post-lock reconciliation: sweep stale machinery files and truncate
    /// every shard back to its last index-referenced byte, reclaiming
    /// torn tails from crashed appends and orphaned (never-indexed, never-
    /// acked) payload bytes.
    fn reconcile(&mut self) -> Result<()> {
        let swept = sweep_stale_artifacts(&self.dir, true)?;
        RECOVER_ARTIFACTS.add(swept.len() as u64);
        for shard in 0..self.index.n_shards {
            let live_end = self
                .index
                .entries
                .iter()
                .filter(|e| e.shard == shard)
                .map(|e| e.offset + e.len)
                .max()
                .unwrap_or(0)
                .max(SHARD_MAGIC.len() as u64);
            let actual = self.shard_sizes[shard as usize];
            if actual > live_end {
                let path = self.shard_path(shard);
                let f = OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .with_context(|| format!("opening shard {}", path.display()))?;
                f.set_len(live_end)
                    .with_context(|| format!("truncating {}", path.display()))?;
                if self.durability == Durability::Sync {
                    f.sync_all()?;
                }
                self.shard_sizes[shard as usize] = live_end;
                RECOVER_TRUNCATED.add(actual - live_end);
            }
        }
        Ok(())
    }
}

/// The sibling paths a compaction swap runs through.
pub(crate) struct SwapPaths {
    pub(crate) parent: PathBuf,
    pub(crate) staging: PathBuf,
    pub(crate) graveyard: PathBuf,
    pub(crate) marker: PathBuf,
}

impl SwapPaths {
    pub(crate) fn of(dir: &Path) -> SwapPaths {
        let file_name = dir
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "store".into());
        let parent = dir
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from("."));
        SwapPaths {
            staging: parent.join(format!("{file_name}.compact-tmp")),
            graveyard: parent.join(format!("{file_name}.old-tmp")),
            marker: parent.join(format!("{file_name}.swap-intent")),
            parent,
        }
    }
}

pub(crate) fn quarantine_file_name(shard: u32, offset: u64) -> String {
    format!("q-{shard:04}-{offset:012}.bin")
}

fn remove_stale_dir(path: &Path) -> Result<()> {
    if path.exists() {
        fs::remove_dir_all(path)
            .with_context(|| format!("clearing stale {}", path.display()))?;
        RECOVER_ARTIFACTS.incr();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, CuszConfig, ErrorBound};
    use crate::coordinator::Coordinator;
    use crate::field::Field;
    use crate::metrics;
    use crate::testkit::fields::{make, Regime};
    use crate::testkit::tmp_dir;

    fn coordinator() -> Coordinator {
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(1e-3),
            ..Default::default()
        })
        .unwrap()
    }

    fn sample_field(i: u64) -> Field {
        let regime = Regime::ALL[(i % 3) as usize];
        Field::new(format!("field-{i}"), vec![64, 64], make(regime, 64 * 64, i)).unwrap()
    }

    #[test]
    fn create_add_get_roundtrip() {
        let dir = tmp_dir("store-roundtrip");
        let coord = coordinator();
        let mut store = Store::create(&dir, 2).unwrap();
        let fields: Vec<Field> = (0..5).map(sample_field).collect();
        for f in &fields {
            let archive = coord.compress(f).unwrap();
            let entry = store.add(&archive).unwrap();
            assert_eq!(entry.dims, vec![64, 64]);
        }
        assert_eq!(store.len(), 5);
        // random access in arbitrary order, bounds verified
        for f in fields.iter().rev() {
            let archive = store.get(&f.name).unwrap();
            let out = coord.decompress(&archive).unwrap();
            assert_eq!(
                metrics::verify_error_bound(&f.data, &out.data, 1e-3),
                None,
                "{}",
                f.name
            );
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn get_into_streams_bytes_identical_to_get_bytes() {
        let dir = tmp_dir("store-get-into");
        let coord = coordinator();
        let mut store = Store::create(&dir, 2).unwrap();
        for i in 0..4 {
            store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
        }
        for i in 0..4 {
            let name = format!("field-{i}");
            let whole = store.get_bytes(&name).unwrap();
            let mut streamed = Vec::new();
            let len = store.get_into(&name, &mut streamed).unwrap();
            assert_eq!(len as usize, whole.len());
            assert_eq!(streamed, whole, "{name}");
        }
        let mut sink = Vec::new();
        assert!(store.get_into("absent", &mut sink).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn get_into_detects_corruption_after_streaming() {
        let dir = tmp_dir("store-get-into-corrupt");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        let entry = store.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        // flip one payload byte mid-entry on disk
        let path = dir.join(format!("shard-{:04}.cuszs", entry.shard));
        let mut bytes = fs::read(&path).unwrap();
        let victim = entry.offset as usize + entry.len as usize / 2;
        bytes[victim] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let mut sink = Vec::new();
        let err = store.get_into("field-0", &mut sink).unwrap_err();
        assert!(err.to_string().contains("CRC mismatch"), "{err:#}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_preserves_entries_and_verifies() {
        let dir = tmp_dir("store-reopen");
        let coord = coordinator();
        {
            let mut store = Store::create(&dir, 3).unwrap();
            for i in 0..4 {
                store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
            }
        }
        let store = Store::open(&dir).unwrap();
        assert_eq!(store.len(), 4);
        assert_eq!(store.n_shards(), 3);
        store.verify().unwrap();
        // payloads really are spread across shards
        let mut shards: Vec<u32> = store.list().iter().map(|e| e.shard).collect();
        shards.sort_unstable();
        shards.dedup();
        assert!(shards.len() > 1, "expected multi-shard placement");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_and_missing_names_error() {
        let dir = tmp_dir("store-dup");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        let archive = coord.compress(&sample_field(0)).unwrap();
        store.add(&archive).unwrap();
        assert!(store.add(&archive).is_err());
        assert!(store.get("nope").is_err());
        assert!(store.remove("nope").is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn put_bytes_upserts_latest_payload() {
        let dir = tmp_dir("store-upsert");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        let a = coord.compress(&sample_field(0)).unwrap();
        let name = a.header.field_name.clone();
        store.put_bytes(&name, &a.to_bytes()).unwrap();
        assert!(store.contains(&name));
        assert!(!store.contains("nope"));
        // re-put a different payload under the same name: one live
        // entry, old bytes become dead space, latest payload wins
        let mut other = sample_field(0);
        other.data[0] += 1.0;
        other.name = name.clone();
        let b = coord.compress(&other).unwrap();
        store.put_bytes(&name, &b.to_bytes()).unwrap();
        assert_eq!(store.len(), 1);
        assert!(store.dead_bytes() > 0);
        let restored = coord.decompress(&store.get(&name).unwrap()).unwrap();
        assert!((restored.data[0] - other.data[0]).abs() <= 1e-3 as f32);
        store.verify().unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn remove_then_readd_and_compact() {
        let dir = tmp_dir("store-rm");
        let coord = coordinator();
        let mut store = Store::create(&dir, 2).unwrap();
        for i in 0..4 {
            store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
        }
        store.remove("field-1").unwrap();
        assert_eq!(store.len(), 3);
        assert!(store.get("field-1").is_err());
        assert!(store.dead_bytes() > 0);
        // same name can come back
        store.add(&coord.compress(&sample_field(1)).unwrap()).unwrap();
        assert_eq!(store.len(), 4);

        store.remove("field-2").unwrap();
        let cdir = tmp_dir("store-compact");
        let compacted = store.compact_into(&cdir).unwrap();
        assert_eq!(compacted.len(), 3);
        assert_eq!(compacted.dead_bytes(), 0);
        compacted.verify().unwrap();
        fs::remove_dir_all(&dir).unwrap();
        fs::remove_dir_all(&cdir).unwrap();
    }

    #[test]
    fn corrupt_shard_detected_on_get() {
        let dir = tmp_dir("store-corrupt");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        let entry = store.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        // flip one payload byte in the middle of the entry
        let path = dir.join(shard_file_name(0));
        let mut bytes = fs::read(&path).unwrap();
        let mid = (entry.offset + entry.len / 2) as usize;
        bytes[mid] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let store = Store::open(&dir).unwrap();
        assert!(store.get("field-0").is_err());
        assert!(store.verify().is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_shard_detected_on_open() {
        let dir = tmp_dir("store-trunc");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        store.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        let path = dir.join(shard_file_name(0));
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Store::open(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_refuses_existing_store() {
        let dir = tmp_dir("store-exists");
        Store::create(&dir, 1).unwrap();
        assert!(Store::create(&dir, 1).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn deferred_index_commits_once() {
        let dir = tmp_dir("store-defer");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        store.set_deferred_index(true).unwrap();
        for i in 0..3 {
            store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
        }
        // on-disk index untouched so far: a concurrent open sees an empty
        // bundle with the appended payloads as (harmless) dead space —
        // the crash-mid-batch picture
        let snapshot = Store::open(&dir).unwrap();
        assert_eq!(snapshot.len(), 0);
        assert!(snapshot.dead_bytes() > 0);
        drop(snapshot);
        store.set_deferred_index(false).unwrap(); // single commit
        drop(store);
        let store = Store::open(&dir).unwrap();
        assert_eq!(store.len(), 3);
        store.verify().unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_refuses_orphan_shards() {
        let dir = tmp_dir("store-orphan");
        Store::create(&dir, 1).unwrap();
        // losing just the index must not let create() truncate payloads
        fs::remove_file(dir.join("index.cuszi")).unwrap();
        assert!(Store::create(&dir, 1).is_err());
        assert!(Store::open_or_create(&dir, 1).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_or_create_is_idempotent() {
        let dir = tmp_dir("store-ooc");
        assert!(!Store::exists(&dir));
        let coord = coordinator();
        let mut store = Store::open_or_create(&dir, 2).unwrap();
        store.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        drop(store);
        assert!(Store::exists(&dir));
        // second call opens (shard count preserved), does not recreate
        let store = Store::open_or_create(&dir, 5).unwrap();
        assert_eq!(store.n_shards(), 2);
        assert_eq!(store.len(), 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn second_writer_is_locked_out() {
        let dir = tmp_dir("store-lock");
        let coord = coordinator();
        let mut writer = Store::create(&dir, 1).unwrap();
        writer.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        // a second writer handle (same dir) must fail fast...
        let err = Store::open_writable(&dir).unwrap_err();
        assert!(err.to_string().contains("locked"), "{err:#}");
        // ...and a lazily-locking mutation through a read handle too
        let mut reader = Store::open(&dir).unwrap();
        assert!(reader.remove("field-0").is_err());
        // read-only access stays lock-free
        let ro = Store::open(&dir).unwrap();
        assert_eq!(ro.len(), 1);
        ro.verify().unwrap();
        drop(writer);
        // lock released on drop: writing works again
        let mut w2 = Store::open_writable(&dir).unwrap();
        w2.add(&coord.compress(&sample_field(1)).unwrap()).unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compact_in_place_reclaims_and_swaps_atomically() {
        let dir = tmp_dir("store-cip");
        let coord = coordinator();
        let mut store = Store::create(&dir, 2).unwrap();
        for i in 0..5 {
            store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
        }
        store.remove("field-1").unwrap();
        store.remove("field-3").unwrap();
        let dead = store.dead_bytes();
        assert!(dead > 0);
        let reclaimed = store.compact_in_place().unwrap();
        assert_eq!(reclaimed, dead);
        assert_eq!(store.dead_bytes(), 0);
        assert_eq!(store.len(), 3);
        // same handle keeps working: read, verify, and write again
        store.verify().unwrap();
        let out = coord.decompress(&store.get("field-2").unwrap()).unwrap();
        assert_eq!(out.dims, vec![64, 64]);
        store.add(&coord.compress(&sample_field(9)).unwrap()).unwrap();
        // no temp dirs left behind, lock still held by this handle
        assert!(!dir.with_file_name(format!(
            "{}.compact-tmp",
            dir.file_name().unwrap().to_string_lossy()
        )).exists());
        assert!(Store::open_writable(&dir).is_err());
        // a fresh reader sees the compacted bundle
        let ro = Store::open(&dir).unwrap();
        assert_eq!(ro.len(), 4);
        ro.verify().unwrap();
        drop(store);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compact_in_place_noop_without_dead_bytes() {
        let dir = tmp_dir("store-cip-noop");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        store.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        assert_eq!(store.compact_in_place().unwrap(), 0);
        store.verify().unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn add_bytes_rejects_garbage_payload() {
        let dir = tmp_dir("store-garbage");
        let mut store = Store::create(&dir, 1).unwrap();
        assert!(store.add_bytes("junk", b"definitely not an archive").is_err());
        assert!(store.is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn durability_parses_and_orders() {
        assert_eq!(Durability::parse("none").unwrap(), Durability::None);
        assert_eq!(Durability::parse("flush").unwrap(), Durability::Flush);
        assert_eq!(Durability::parse("sync").unwrap(), Durability::Sync);
        assert!(Durability::parse("paranoid").is_err());
        assert!(Durability::None < Durability::Flush);
        assert!(Durability::Flush < Durability::Sync);
        assert_eq!(Durability::default(), Durability::Flush);
        assert_eq!(Durability::Sync.name(), "sync");
    }

    #[test]
    fn sync_durability_exercises_every_mutation() {
        let dir = tmp_dir("store-sync");
        let coord = coordinator();
        let mut store = Store::create(&dir, 2).unwrap();
        store.set_durability(Durability::Sync);
        assert_eq!(store.durability(), Durability::Sync);
        for i in 0..4 {
            store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
        }
        store.remove("field-1").unwrap();
        assert!(store.compact_in_place().unwrap() > 0);
        assert_eq!(store.durability(), Durability::Sync, "survives the swap");
        store.verify().unwrap();
        drop(store);
        let store = Store::open(&dir).unwrap();
        assert_eq!(store.len(), 3);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_truncated_on_writable_open() {
        let dir = tmp_dir("store-torn-tail");
        let coord = coordinator();
        {
            let mut store = Store::create(&dir, 1).unwrap();
            for i in 0..2 {
                store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
            }
        }
        // a crashed append leaves unindexed garbage at the shard tail
        let path = dir.join(shard_file_name(0));
        let clean_len = fs::metadata(&path).unwrap().len();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0x5A; 1234]).unwrap();
        drop(f);
        // a read-only open keeps the strict view (tail is dead space)…
        assert!(Store::open(&dir).unwrap().dead_bytes() >= 1234);
        // …and a writable open reclaims it
        let store = Store::open_writable(&dir).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(fs::metadata(&path).unwrap().len(), clean_len);
        store.verify().unwrap();
        drop(store);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stale_artifacts_swept_on_writable_open() {
        let dir = tmp_dir("store-stale");
        let coord = coordinator();
        {
            let mut store = Store::create(&dir, 1).unwrap();
            store.add(&coord.compress(&sample_field(0)).unwrap()).unwrap();
        }
        // crashed index publish + dead writer's lock machinery
        fs::write(dir.join("index.cuszi.tmp"), b"half-written index").unwrap();
        fs::write(dir.join(".writer.lock.4000000000.tmp"), b"4000000000").unwrap();
        fs::write(dir.join(".writer.lock.broken.4000000001.0"), b"junk").unwrap();
        let store = Store::open_writable(&dir).unwrap();
        store.verify().unwrap();
        drop(store);
        let leftovers: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp") || n.contains("broken"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn interrupted_swap_rolls_back_from_graveyard() {
        let dir = tmp_dir("store-swap-rb");
        let coord = coordinator();
        {
            let mut store = Store::create(&dir, 1).unwrap();
            for i in 0..3 {
                store.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
            }
        }
        // crash window: old bundle renamed aside, install never happened
        let paths = SwapPaths::of(&dir);
        fs::rename(&dir, &paths.graveyard).unwrap();
        fs::write(&paths.marker, "cuszb swap-intent v1\n4000000000\n").unwrap();
        let store = Store::open_writable(&dir).unwrap();
        assert_eq!(store.len(), 3);
        store.verify().unwrap();
        assert!(!paths.marker.exists());
        assert!(!paths.graveyard.exists());
        drop(store);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn interrupted_swap_completes_from_staging() {
        let dir = tmp_dir("store-swap-fwd");
        let coord = coordinator();
        let paths = SwapPaths::of(&dir);
        {
            let mut staged = Store::create(&paths.staging, 1).unwrap();
            for i in 0..2 {
                staged.add(&coord.compress(&sample_field(i)).unwrap()).unwrap();
            }
        }
        // crash window: intent durable, old bundle renamed aside, install
        // of the staged bundle never happened
        fs::write(&paths.marker, "cuszb swap-intent v1\n4000000000\n").unwrap();
        fs::remove_dir_all(&dir).unwrap(); // tmp_dir pre-created it empty
        let store = Store::open_writable(&dir).unwrap();
        assert_eq!(store.len(), 2);
        store.verify().unwrap();
        assert!(!paths.marker.exists());
        assert!(!paths.staging.exists());
        drop(store);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn quarantine_pulls_field_and_reopen_remembers() {
        let dir = tmp_dir("store-quarantine");
        let coord = coordinator();
        let mut store = Store::create(&dir, 1).unwrap();
        let a = coord.compress(&sample_field(0)).unwrap();
        let b = coord.compress(&sample_field(1)).unwrap();
        store.add(&a).unwrap();
        store.add(&b).unwrap();
        store.quarantine("field-0", "test: simulated bit rot").unwrap();
        assert!(store.is_quarantined("field-0"));
        assert!(!store.contains("field-0"));
        assert!(store.get("field-0").is_err());
        assert!(store.contains("field-1"));
        assert_eq!(store.quarantined_names(), vec!["field-0"]);
        // the payload copy and manifest are on disk
        assert!(dir.join(QUARANTINE_DIR).join(QUARANTINE_MANIFEST).exists());
        drop(store);
        // reopen remembers the verdict
        let mut store = Store::open_writable(&dir).unwrap();
        assert!(store.is_quarantined("field-0"));
        // a fresh put under the same name supersedes it
        store.put_bytes("field-0", &a.to_bytes()).unwrap();
        assert!(!store.is_quarantined("field-0"));
        assert!(store.contains("field-0"));
        drop(store);
        let store = Store::open(&dir).unwrap();
        assert!(!store.is_quarantined("field-0"));
        fs::remove_dir_all(&dir).unwrap();
    }
}
