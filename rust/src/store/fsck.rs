//! `fsck` for `.cuszb` bundles: a full scrub that classifies every kind
//! of damage a crash or bit rot can leave behind, and (optionally)
//! repairs it in place.
//!
//! The scrub is deliberately tolerant where [`super::Store::open`] is
//! strict: a damaged bundle must still *scan* so the damage can be
//! classified and repaired, so fsck reads the index and shards itself
//! with bounded buffers (payloads are CRC-verified in 1 MiB chunks —
//! a hostile or huge index entry never drives an unbounded allocation).
//!
//! Findings and their repairs:
//!
//! | finding            | meaning                                   | repair |
//! |--------------------|-------------------------------------------|--------|
//! | interrupted-swap   | compaction swap crashed mid-rename        | finish or roll back from the intent marker |
//! | stale-artifact     | leftover index tmp / dead-pid lock file / unmanifested quarantine copy | remove |
//! | missing-shard      | index names a shard file that is gone     | drop its entries, recreate the (empty) shard |
//! | bad-shard-magic    | shard exists but its 8-byte magic is wrong| rewrite the magic in place |
//! | torn-entry         | entry overruns shard EOF (torn append) or sits inside the magic | drop the entry |
//! | duplicate-entry    | two index entries share a name            | keep the first, drop the rest |
//! | corrupt-payload    | payload bytes fail the indexed CRC        | quarantine (with `--quarantine`) or drop |
//! | header-mismatch    | payload CRC is fine but the archive header disagrees with the index | quarantine or drop |
//! | orphan-tail        | shard bytes past the last indexed byte (crashed append or dead space) | truncate (repair mode only — in scan mode tail bytes are reported as reclaimable, not flagged, since unindexed bytes were never acked) |
//!
//! Exit-code contract (`FsckReport::exit_code`, used by
//! `cusz store fsck` and CI): **0** clean — or, with `--repair`, every
//! finding repaired; **1** findings remain unrepaired; **2** fatal (index
//! unreadable, store locked by a live writer, I/O failure).

use std::collections::HashSet;
use std::fs::{self, File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::container::bytes::{crc32, Crc32};
use crate::container::Archive;

use super::index::{StoreEntry, StoreIndex};
use super::lock::StoreLock;
use super::{
    append_quarantine_manifest, fsync_dir, quarantine_file_name, shard_file_name,
    sweep_stale_artifacts, Store, INDEX_FILE, QUARANTINE_DIR, SHARD_MAGIC,
};

/// Payloads are CRC-verified through a buffer of this size.
const CHUNK: usize = 1 << 20;
/// Archive headers are tiny; this prefix is plenty to re-peek one.
const PREFIX_CAP: usize = 64 << 10;

#[derive(Debug, Clone, Copy, Default)]
pub struct FsckOptions {
    /// Repair what can be repaired (implies taking the writer lock).
    pub repair: bool,
    /// With `repair`: move unreadable payloads into `quarantine/` instead
    /// of discarding them outright.
    pub quarantine: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FindingKind {
    InterruptedSwap,
    StaleArtifact,
    MissingShard,
    BadShardMagic,
    TornEntry,
    DuplicateEntry,
    CorruptPayload,
    HeaderMismatch,
    OrphanTail,
}

impl FindingKind {
    pub fn label(self) -> &'static str {
        match self {
            FindingKind::InterruptedSwap => "interrupted-swap",
            FindingKind::StaleArtifact => "stale-artifact",
            FindingKind::MissingShard => "missing-shard",
            FindingKind::BadShardMagic => "bad-shard-magic",
            FindingKind::TornEntry => "torn-entry",
            FindingKind::DuplicateEntry => "duplicate-entry",
            FindingKind::CorruptPayload => "corrupt-payload",
            FindingKind::HeaderMismatch => "header-mismatch",
            FindingKind::OrphanTail => "orphan-tail",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Finding {
    pub kind: FindingKind,
    pub detail: String,
    pub repaired: bool,
}

#[derive(Debug, Default)]
pub struct FsckReport {
    pub findings: Vec<Finding>,
    /// Entries whose payloads were fully CRC-verified.
    pub entries_checked: usize,
    pub bytes_checked: u64,
    /// Names moved into `quarantine/` by this run.
    pub quarantined: Vec<String>,
    /// Unindexed bytes at shard tails (crashed appends, dead space after
    /// an upsert). Informational in scan mode; truncated under repair.
    pub tail_bytes: u64,
    /// Scrub could not proceed at all (unreadable index, locked store).
    pub fatal: Option<String>,
}

impl FsckReport {
    pub fn clean(&self) -> bool {
        self.fatal.is_none() && self.findings.is_empty()
    }

    pub fn unrepaired(&self) -> usize {
        self.findings.iter().filter(|f| !f.repaired).count()
    }

    /// 0 clean / fully repaired · 1 findings remain · 2 fatal.
    pub fn exit_code(&self) -> i32 {
        if self.fatal.is_some() {
            2
        } else if self.unrepaired() > 0 {
            1
        } else {
            0
        }
    }

    fn push(&mut self, kind: FindingKind, detail: impl Into<String>, repaired: bool) {
        self.findings.push(Finding { kind, detail: detail.into(), repaired });
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(fatal) = &self.fatal {
            out.push_str(&format!("fatal: {fatal}\n"));
        }
        for f in &self.findings {
            let mark = if f.repaired { "repaired" } else { "unrepaired" };
            out.push_str(&format!("  [{}] {} ({mark})\n", f.kind.label(), f.detail));
        }
        for name in &self.quarantined {
            out.push_str(&format!("  quarantined '{name}'\n"));
        }
        out.push_str(&format!(
            "checked {} entr{} ({} payload bytes); {} reclaimable tail byte(s)\n",
            self.entries_checked,
            if self.entries_checked == 1 { "y" } else { "ies" },
            self.bytes_checked,
            self.tail_bytes,
        ));
        out.push_str(&format!(
            "status: {} ({} finding(s), {} unrepaired) → exit {}\n",
            if self.clean() { "clean" } else { "damaged" },
            self.findings.len(),
            self.unrepaired(),
            self.exit_code()
        ));
        out
    }
}

/// Scrub (and with [`FsckOptions::repair`], heal) the bundle at `dir`.
/// Never panics on hostile input: unreadable structures become findings
/// or a `fatal` classification, and `Err` is reserved for environmental
/// I/O failure. A repair pass is convergent — a second scan of a
/// repaired bundle is clean.
pub fn fsck(dir: impl AsRef<Path>, opts: &FsckOptions) -> Result<FsckReport> {
    let dir = dir.as_ref();
    let mut report = FsckReport::default();

    // interrupted compaction swap: recover first so the index we scrub is
    // the installed (or rolled-back) one
    if let Some(detail) = super::swap_leftovers(dir) {
        if opts.repair {
            match Store::recover_interrupted_swap(dir) {
                Ok(()) => report.push(FindingKind::InterruptedSwap, detail, true),
                Err(e) => {
                    report.fatal = Some(format!("recovering interrupted swap: {e:#}"));
                    return Ok(report);
                }
            }
        } else {
            report.push(FindingKind::InterruptedSwap, detail, false);
        }
    }

    // repair mutates: hold the writer lock so we can't race a live writer
    let _lock = if opts.repair {
        match StoreLock::acquire(dir) {
            Ok(l) => Some(l),
            Err(e) => {
                report.fatal = Some(format!("cannot lock store for repair: {e:#}"));
                return Ok(report);
            }
        }
    } else {
        None
    };

    let raw = match fs::read(dir.join(INDEX_FILE)) {
        Ok(raw) => raw,
        Err(e) => {
            report.fatal = Some(format!(
                "reading store index in {}: {e} (an unreadable index is not repairable \
                 in place — restore it from a replica)",
                dir.display()
            ));
            return Ok(report);
        }
    };
    let index = match StoreIndex::from_bytes(&raw) {
        Ok(index) => index,
        Err(e) => {
            report.fatal = Some(format!(
                "parsing store index in {}: {e:#} (an unreadable index is not \
                 repairable in place — restore it from a replica)",
                dir.display()
            ));
            return Ok(report);
        }
    };

    for detail in sweep_stale_artifacts(dir, opts.repair)? {
        report.push(FindingKind::StaleArtifact, detail, opts.repair);
    }

    // shard framing: presence, length, magic
    let mut shard_len: Vec<Option<u64>> = Vec::with_capacity(index.n_shards as usize);
    let mut bad_magic: Vec<u32> = Vec::new();
    for i in 0..index.n_shards {
        let path = dir.join(shard_file_name(i));
        match fs::metadata(&path) {
            Err(_) => {
                shard_len.push(None);
                report.push(
                    FindingKind::MissingShard,
                    format!("shard file {} is missing", path.display()),
                    opts.repair, // recreated (empty) below, entries dropped
                );
            }
            Ok(md) => {
                let len = md.len();
                let magic_ok = len >= SHARD_MAGIC.len() as u64 && {
                    let mut m = [0u8; 8];
                    File::open(&path)
                        .and_then(|mut f| f.read_exact(&mut m))
                        .map(|()| &m == SHARD_MAGIC)
                        .unwrap_or(false)
                };
                if !magic_ok {
                    bad_magic.push(i);
                    report.push(
                        FindingKind::BadShardMagic,
                        format!("{} has a damaged shard magic", path.display()),
                        opts.repair,
                    );
                }
                shard_len.push(Some(len.max(SHARD_MAGIC.len() as u64)));
            }
        }
    }

    // entry-by-entry: bounds against the real files, then payload CRC and
    // header digest
    let mut keep: Vec<StoreEntry> = Vec::with_capacity(index.entries.len());
    let mut seen: HashSet<&str> = HashSet::new();
    let mut dropped = false;
    for e in &index.entries {
        if !seen.insert(e.name.as_str()) {
            report.push(
                FindingKind::DuplicateEntry,
                format!("duplicate entry '{}' (keeping the first)", e.name),
                opts.repair,
            );
            dropped = true;
            continue;
        }
        let Some(Some(len)) = shard_len.get(e.shard as usize).copied() else {
            report.push(
                FindingKind::TornEntry,
                format!("entry '{}' references missing shard {}", e.name, e.shard),
                opts.repair,
            );
            dropped = true;
            continue;
        };
        let end = e.offset.checked_add(e.len);
        if e.offset < SHARD_MAGIC.len() as u64 || end.is_none() || end.unwrap() > len {
            report.push(
                FindingKind::TornEntry,
                format!(
                    "entry '{}' overruns shard {} (offset {} + len {} vs {} bytes) — torn tail",
                    e.name, e.shard, e.offset, e.len, len
                ),
                opts.repair,
            );
            dropped = true;
            continue;
        }
        let path = dir.join(shard_file_name(e.shard));
        let verdict = match verify_payload(&path, e) {
            Err(err) => Some((
                FindingKind::CorruptPayload,
                format!("entry '{}': payload unreadable ({err})", e.name),
            )),
            Ok(check) => {
                report.entries_checked += 1;
                report.bytes_checked += e.len;
                if check.crc != e.payload_crc {
                    Some((
                        FindingKind::CorruptPayload,
                        format!("entry '{}': payload CRC mismatch (bit rot?)", e.name),
                    ))
                } else {
                    match Archive::peek_header(&check.prefix) {
                        Ok(h) if crc32(&h.to_bytes()) == e.header_digest => None,
                        Ok(_) => Some((
                            FindingKind::HeaderMismatch,
                            format!("entry '{}': header digest disagrees with index", e.name),
                        )),
                        Err(err) => Some((
                            FindingKind::HeaderMismatch,
                            format!("entry '{}': payload framing unreadable ({err:#})", e.name),
                        )),
                    }
                }
            }
        };
        match verdict {
            None => keep.push(e.clone()),
            Some((kind, detail)) => {
                if opts.repair && opts.quarantine {
                    let file = quarantine_file_name(e.shard, e.offset);
                    let qdir = dir.join(QUARANTINE_DIR);
                    fs::create_dir_all(&qdir)
                        .with_context(|| format!("creating {}", qdir.display()))?;
                    copy_range(&path, e.offset, e.len, &qdir.join(&file))
                        .with_context(|| format!("quarantining '{}'", e.name))?;
                    append_quarantine_manifest(
                        dir,
                        &e.name,
                        &file,
                        &format!("fsck: {}", kind.label()),
                        true,
                    )?;
                    report.quarantined.push(e.name.clone());
                    report.push(kind, format!("{detail} — moved to quarantine/"), true);
                } else if opts.repair {
                    report.push(
                        kind,
                        format!("{detail} — entry dropped (bytes remain as dead space)"),
                        true,
                    );
                } else {
                    report.push(kind, detail, false);
                }
                dropped = true;
            }
        }
    }

    // orphaned / torn tail bytes past the last indexed byte of each shard
    let live: &[StoreEntry] = if opts.repair { &keep } else { &index.entries };
    for i in 0..index.n_shards {
        let Some(Some(len)) = shard_len.get(i as usize).copied() else { continue };
        let live_end = live
            .iter()
            .filter(|e| e.shard == i)
            .filter_map(|e| e.offset.checked_add(e.len))
            .max()
            .unwrap_or(0)
            .max(SHARD_MAGIC.len() as u64);
        if len > live_end {
            report.tail_bytes += len - live_end;
            if opts.repair {
                let path = dir.join(shard_file_name(i));
                let f = OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .with_context(|| format!("opening {}", path.display()))?;
                f.set_len(live_end)
                    .with_context(|| format!("truncating {}", path.display()))?;
                f.sync_all().ok();
                report.push(
                    FindingKind::OrphanTail,
                    format!(
                        "shard {i}: {} unindexed tail byte(s) truncated",
                        len - live_end
                    ),
                    true,
                );
            }
        }
    }

    if opts.repair {
        // heal shard framing now that doomed entries are dropped
        for i in bad_magic {
            let path = dir.join(shard_file_name(i));
            let mut f = OpenOptions::new()
                .write(true)
                .open(&path)
                .with_context(|| format!("opening {}", path.display()))?;
            f.write_all(SHARD_MAGIC)
                .with_context(|| format!("rewriting magic in {}", path.display()))?;
            f.sync_all().ok();
        }
        for (i, len) in shard_len.iter().enumerate() {
            if len.is_none() {
                let path = dir.join(shard_file_name(i as u32));
                let mut f = File::create(&path)
                    .with_context(|| format!("recreating {}", path.display()))?;
                f.write_all(SHARD_MAGIC)?;
                f.sync_all().ok();
            }
        }
        if dropped {
            let healed = StoreIndex { n_shards: index.n_shards, entries: keep };
            publish_index(dir, &healed)?;
        }
    }

    Ok(report)
}

struct PayloadCheck {
    crc: u32,
    /// First `min(len, PREFIX_CAP)` bytes, for re-peeking the header.
    prefix: Vec<u8>,
}

/// Chunked CRC over one entry's byte range — bounded memory no matter
/// what the index claims the length is (the range was already validated
/// against the real file size).
fn verify_payload(path: &Path, e: &StoreEntry) -> std::io::Result<PayloadCheck> {
    let mut f = File::open(path)?;
    f.seek(SeekFrom::Start(e.offset))?;
    let mut crc = Crc32::new();
    let mut prefix = Vec::with_capacity(PREFIX_CAP.min(e.len as usize));
    let mut buf = vec![0u8; CHUNK.min((e.len as usize).max(1))];
    let mut remaining = e.len;
    while remaining > 0 {
        let n = buf.len().min(remaining as usize);
        f.read_exact(&mut buf[..n])?;
        crc.update(&buf[..n]);
        if prefix.len() < PREFIX_CAP {
            let take = n.min(PREFIX_CAP - prefix.len());
            prefix.extend_from_slice(&buf[..take]);
        }
        remaining -= n as u64;
    }
    Ok(PayloadCheck { crc: crc.finish(), prefix })
}

fn copy_range(src: &Path, offset: u64, len: u64, dest: &Path) -> std::io::Result<()> {
    let mut f = File::open(src)?;
    f.seek(SeekFrom::Start(offset))?;
    let mut out = File::create(dest)?;
    let mut buf = vec![0u8; CHUNK.min((len as usize).max(1))];
    let mut remaining = len;
    while remaining > 0 {
        let n = buf.len().min(remaining as usize);
        f.read_exact(&mut buf[..n])?;
        out.write_all(&buf[..n])?;
        remaining -= n as u64;
    }
    out.sync_all()
}

/// Atomically publish a repaired index with the full durability
/// discipline (tmp fsync, rename, directory fsync) — a repair must never
/// introduce the very torn state it exists to remove.
fn publish_index(dir: &Path, index: &StoreIndex) -> Result<()> {
    let tmp = dir.join(format!("{INDEX_FILE}.tmp"));
    {
        let mut f =
            File::create(&tmp).with_context(|| format!("writing {}", tmp.display()))?;
        f.write_all(&index.to_bytes())?;
        f.sync_data()
            .with_context(|| format!("syncing {}", tmp.display()))?;
    }
    let final_path = dir.join(INDEX_FILE);
    fs::rename(&tmp, &final_path)
        .with_context(|| format!("committing {}", final_path.display()))?;
    fsync_dir(dir)?;
    Ok(())
}

/// Convenience for tests and callers that already hold a path: scrub
/// without repairing.
pub fn scan(dir: impl AsRef<Path>) -> Result<FsckReport> {
    fsck(dir, &FsckOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, CuszConfig, ErrorBound};
    use crate::coordinator::Coordinator;
    use crate::field::Field;
    use crate::testkit::fields::{make, Regime};
    use crate::testkit::tmp_dir;

    fn coordinator() -> Coordinator {
        Coordinator::new(CuszConfig {
            backend: BackendKind::Cpu,
            eb: ErrorBound::Abs(1e-3),
            ..Default::default()
        })
        .unwrap()
    }

    fn seeded_store(tag: &str, n_fields: u64, n_shards: usize) -> std::path::PathBuf {
        let dir = tmp_dir(tag);
        let coord = coordinator();
        let mut store = Store::create(&dir, n_shards).unwrap();
        for i in 0..n_fields {
            let f = Field::new(
                format!("field-{i}"),
                vec![32, 32],
                make(Regime::ALL[(i % 3) as usize], 32 * 32, i),
            )
            .unwrap();
            store.add(&coord.compress(&f).unwrap()).unwrap();
        }
        dir
    }

    #[test]
    fn clean_store_scans_clean() {
        let dir = seeded_store("fsck-clean", 3, 2);
        let report = scan(&dir).unwrap();
        assert!(report.clean(), "{}", report.render());
        assert_eq!(report.exit_code(), 0);
        assert_eq!(report.entries_checked, 3);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_is_classified_and_quarantined() {
        let dir = seeded_store("fsck-flip", 2, 1);
        // flip a byte in the middle of the first entry's payload
        let store = Store::open(&dir).unwrap();
        let e = store.list()[0].clone();
        drop(store);
        let path = dir.join(shard_file_name(0));
        let mut bytes = fs::read(&path).unwrap();
        bytes[(e.offset + e.len / 2) as usize] ^= 0xff;
        fs::write(&path, &bytes).unwrap();

        let report = scan(&dir).unwrap();
        assert_eq!(report.exit_code(), 1);
        assert!(report
            .findings
            .iter()
            .any(|f| f.kind == FindingKind::CorruptPayload && !f.repaired));

        let report =
            fsck(&dir, &FsckOptions { repair: true, quarantine: true }).unwrap();
        assert_eq!(report.exit_code(), 0, "{}", report.render());
        assert_eq!(report.quarantined, vec![e.name.clone()]);

        // convergent: second pass clean; the store opens and remembers
        let report = scan(&dir).unwrap();
        assert!(report.clean(), "{}", report.render());
        let store = Store::open_writable(&dir).unwrap();
        assert!(store.is_quarantined(&e.name));
        assert!(!store.contains(&e.name));
        store.verify().unwrap();
        drop(store);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_and_overrun_entry_repair() {
        let dir = seeded_store("fsck-torn", 2, 1);
        let path = dir.join(shard_file_name(0));
        // torn append: unindexed garbage at the tail
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0xAB; 257]).unwrap();
        drop(f);
        let report = scan(&dir).unwrap();
        assert!(report.clean(), "unindexed tail bytes are not a defect");
        assert_eq!(report.tail_bytes, 257);

        // index claiming bytes past EOF: a torn acked write
        let raw = fs::read(dir.join(INDEX_FILE)).unwrap();
        let mut index = StoreIndex::from_bytes(&raw).unwrap();
        index.entries[0].len += 10_000;
        fs::write(dir.join(INDEX_FILE), index.to_bytes()).unwrap();
        let report = scan(&dir).unwrap();
        assert_eq!(report.exit_code(), 1);
        assert!(report.findings.iter().any(|f| f.kind == FindingKind::TornEntry));

        let report = fsck(&dir, &FsckOptions { repair: true, quarantine: false }).unwrap();
        assert_eq!(report.exit_code(), 0, "{}", report.render());
        let report = scan(&dir).unwrap();
        assert!(report.clean(), "{}", report.render());
        // the torn entry is gone, the intact one still reads
        let store = Store::open(&dir).unwrap();
        assert_eq!(store.len(), 1);
        store.verify().unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_shard_and_unreadable_index() {
        let dir = seeded_store("fsck-missing", 2, 2);
        fs::remove_file(dir.join(shard_file_name(1))).unwrap();
        let report = scan(&dir).unwrap();
        assert_eq!(report.exit_code(), 1);
        assert!(report.findings.iter().any(|f| f.kind == FindingKind::MissingShard));
        let report = fsck(&dir, &FsckOptions { repair: true, quarantine: false }).unwrap();
        assert_eq!(report.exit_code(), 0, "{}", report.render());
        assert!(scan(&dir).unwrap().clean());
        Store::open(&dir).unwrap().verify().unwrap();

        // a trashed index is fatal (exit 2), never a panic
        fs::write(dir.join(INDEX_FILE), b"not an index at all").unwrap();
        let report = scan(&dir).unwrap();
        assert_eq!(report.exit_code(), 2);
        assert!(report.fatal.is_some());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repair_is_locked_out_by_live_writer() {
        let dir = seeded_store("fsck-lock", 1, 1);
        let store = Store::open_writable(&dir).unwrap();
        let report = fsck(&dir, &FsckOptions { repair: true, quarantine: false }).unwrap();
        assert_eq!(report.exit_code(), 2);
        drop(store);
        assert_eq!(fsck(&dir, &FsckOptions { repair: true, quarantine: false })
            .unwrap()
            .exit_code(), 0);
        fs::remove_dir_all(&dir).unwrap();
    }
}
