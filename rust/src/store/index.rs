//! `.cuszb` footer index: the name → (shard, offset, length, digests) map
//! written as a small CRC-framed file next to the shard payloads. The
//! index is the only mutable piece of a bundle — payload shards are
//! append-only — so updates are a single atomic tmp-file rename.

use anyhow::{bail, Context, Result};

use crate::container::bytes::{ByteReader, ByteWriter};

pub const INDEX_MAGIC: &[u8; 8] = b"CUSZB1\0\0";
pub const INDEX_VERSION: u32 = 1;

/// Smallest possible serialized entry (empty name, 1 dim), used to bound
/// untrusted entry counts before allocating.
const MIN_ENTRY_BYTES: usize = 4 + 4 + 8 + 8 + 4 + 4 + 4 + 8;

/// One field's location and integrity metadata inside a bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreEntry {
    /// Field name (the lookup key; unique within a bundle).
    pub name: String,
    /// Which shard file holds the payload.
    pub shard: u32,
    /// Byte offset of the serialized `.cusza` payload within the shard.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// CRC32 of the whole payload (verified on every random access).
    pub payload_crc: u32,
    /// CRC32 of the payload's serialized header ([`crate::container::Archive::header_digest`]);
    /// detects a payload swapped or rewritten since indexing.
    pub header_digest: u32,
    /// Logical field dims, for `ls`-style listings without shard reads.
    pub dims: Vec<usize>,
}

impl StoreEntry {
    pub fn n_elements(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product()
    }

    /// Original (uncompressed) field size in bytes.
    pub fn original_bytes(&self) -> u64 {
        self.n_elements() * 4
    }

    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes() as f64 / (self.len.max(1)) as f64
    }
}

/// The in-memory index of a `.cuszb` bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreIndex {
    pub n_shards: u32,
    pub entries: Vec<StoreEntry>,
}

impl StoreIndex {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.bytes(INDEX_MAGIC);
        w.u32(INDEX_VERSION);
        let mut body = ByteWriter::new();
        body.u32(self.n_shards);
        body.u64(self.entries.len() as u64);
        for e in &self.entries {
            body.str(&e.name);
            body.u32(e.shard);
            body.u64(e.offset);
            body.u64(e.len);
            body.u32(e.payload_crc);
            body.u32(e.header_digest);
            body.u32(e.dims.len() as u32);
            for &d in &e.dims {
                body.u64(d as u64);
            }
        }
        w.section(&body.finish());
        w.finish()
    }

    pub fn from_bytes(data: &[u8]) -> Result<StoreIndex> {
        let mut r = ByteReader::new(data);
        let magic = r.take(8)?;
        if magic != INDEX_MAGIC {
            bail!("not a cuszb index (bad magic)");
        }
        let version = r.u32()?;
        if version != INDEX_VERSION {
            bail!("unsupported cuszb index version {version}");
        }
        let body = r.section().context("index body section")?;
        let mut b = ByteReader::new(&body);
        let n_shards = b.u32()?;
        if n_shards == 0 || n_shards > 4096 {
            bail!("implausible shard count {n_shards}");
        }
        let n = b.u64()? as usize;
        if n > b.remaining() / MIN_ENTRY_BYTES {
            bail!("corrupt index: {n} entries exceeds payload");
        }
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let name = b.str()?;
            let shard = b.u32()?;
            let offset = b.u64()?;
            let len = b.u64()?;
            let payload_crc = b.u32()?;
            let header_digest = b.u32()?;
            let nd = b.u32()? as usize;
            if nd == 0 || nd > 4 {
                bail!("index entry '{name}': bad ndim {nd}");
            }
            if shard >= n_shards {
                bail!("index entry '{name}': shard {shard} out of range");
            }
            let mut dims = Vec::with_capacity(nd);
            let mut product: u64 = 1;
            for _ in 0..nd {
                let d = b.u64()?;
                // keep n_elements()/original_bytes() overflow-free on
                // crafted indexes: per-axis and total element bounds
                if d == 0 || d > 1 << 40 {
                    bail!("index entry '{name}': implausible dim {d}");
                }
                product = product
                    .checked_mul(d)
                    .filter(|&p| p <= 1 << 48)
                    .with_context(|| format!("index entry '{name}': dims overflow"))?;
                dims.push(d as usize);
            }
            entries.push(StoreEntry { name, shard, offset, len, payload_crc, header_digest, dims });
        }
        Ok(StoreIndex { n_shards, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StoreIndex {
        StoreIndex {
            n_shards: 4,
            entries: vec![
                StoreEntry {
                    name: "NYX/baryon_density".into(),
                    shard: 2,
                    offset: 8,
                    len: 120_000,
                    payload_crc: 0xdeadbeef,
                    header_digest: 0x1234_5678,
                    dims: vec![128, 128, 128],
                },
                StoreEntry {
                    name: "vx".into(),
                    shard: 0,
                    offset: 8,
                    len: 99,
                    payload_crc: 1,
                    header_digest: 2,
                    dims: vec![1 << 21],
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let idx = sample();
        let back = StoreIndex::from_bytes(&idx.to_bytes()).unwrap();
        assert_eq!(idx, back);
    }

    #[test]
    fn empty_index_roundtrips() {
        let idx = StoreIndex { n_shards: 1, entries: vec![] };
        assert_eq!(StoreIndex::from_bytes(&idx.to_bytes()).unwrap(), idx);
    }

    #[test]
    fn entry_math() {
        let e = &sample().entries[0];
        assert_eq!(e.n_elements(), 128 * 128 * 128);
        assert_eq!(e.original_bytes(), 128 * 128 * 128 * 4);
        assert!(e.compression_ratio() > 60.0);
    }

    #[test]
    fn corruption_is_rejected() {
        let bytes = sample().to_bytes();
        // bad magic
        let mut b = bytes.clone();
        b[0] = b'X';
        assert!(StoreIndex::from_bytes(&b).is_err());
        // truncations at every prefix length must error, never panic
        for cut in 0..bytes.len() {
            assert!(StoreIndex::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // flipped byte in the body breaks the section CRC
        let mut b = bytes.clone();
        let n = b.len();
        b[n - 2] ^= 0x40;
        assert!(StoreIndex::from_bytes(&b).is_err());
    }

    #[test]
    fn implausible_dims_rejected() {
        let mut idx = sample();
        idx.entries[0].dims = vec![usize::MAX, 2];
        assert!(StoreIndex::from_bytes(&idx.to_bytes()).is_err());
        idx.entries[0].dims = vec![0];
        assert!(StoreIndex::from_bytes(&idx.to_bytes()).is_err());
    }

    #[test]
    fn out_of_range_shard_rejected() {
        let mut idx = sample();
        idx.entries[0].shard = 7; // n_shards is 4
        assert!(StoreIndex::from_bytes(&idx.to_bytes()).is_err());
    }
}
