//! Advisory writer lock for a `.cuszb` bundle: a lock file beside the
//! footer index so two writer processes (`cusz store add`, `cusz serve`)
//! can't interleave shard appends. Readers never take it — the bundle's
//! contract stays one-writer-or-many-readers.
//!
//! Implementation is a PID lock file with no `flock` dependency, built so
//! the file is never observable half-written: the PID is written to a
//! unique temp file first and published with `hard_link` (atomic,
//! fails-if-exists), so any visible lock file always carries its holder's
//! PID. A lock whose holder is no longer alive (crashed writer) is
//! detected via `/proc/<pid>` and broken by atomically renaming it aside
//! — the rename succeeds for exactly one breaker — then re-verifying the
//! captured file really belonged to the dead holder before discarding it
//! (if a live writer re-acquired in the window, its file is restored).
//! On non-Linux targets liveness can't be probed, so stale locks must be
//! removed by hand.

use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Lock file name, next to `index.cuszi` inside the bundle directory.
pub const LOCK_FILE: &str = "writer.lock";

/// A held writer lock; the lock file is removed on drop.
#[derive(Debug)]
pub struct StoreLock {
    path: PathBuf,
    /// Disarmed locks skip removal on drop (used when the bundle
    /// directory is atomically swapped out from under the lock).
    armed: bool,
}

impl StoreLock {
    /// Acquire the writer lock in `dir`. Errors if another live process
    /// holds it; a stale lock (holder dead) is broken and re-acquired.
    pub fn acquire(dir: &Path) -> Result<StoreLock> {
        let path = dir.join(LOCK_FILE);
        let me = std::process::id();
        // stage the fully-written pid file once; hard_link publishes it
        let staged = dir.join(format!(".writer.lock.{me}.tmp"));
        {
            let mut f = OpenOptions::new()
                .write(true)
                .create(true)
                .truncate(true)
                .open(&staged)
                .with_context(|| format!("staging lock file {}", staged.display()))?;
            write!(f, "{me}").with_context(|| format!("writing {}", staged.display()))?;
            f.flush()?;
        }
        let result = Self::acquire_staged(dir, &path, &staged);
        let _ = fs::remove_file(&staged);
        result
    }

    fn acquire_staged(dir: &Path, path: &Path, staged: &Path) -> Result<StoreLock> {
        for attempt in 0..2 {
            match fs::hard_link(staged, path) {
                Ok(()) => return Ok(StoreLock { path: path.to_path_buf(), armed: true }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let holder = fs::read_to_string(path).unwrap_or_default();
                    let pid: Option<u32> = holder.trim().parse().ok();
                    let stale = match pid {
                        Some(p) => !process_alive(p),
                        None => true, // unreadable/empty: holder vanished mid-crash
                    };
                    if attempt == 0 && stale {
                        Self::break_stale(dir, path, &holder)?;
                        continue;
                    }
                    bail!(
                        "store {} is locked by another writer (pid {}); \
                         a second writer would interleave shard appends",
                        dir.display(),
                        holder.trim()
                    );
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating lock file {}", path.display()))
                }
            }
        }
        unreachable!("lock acquisition resolves within two attempts");
    }

    /// Atomically capture a stale lock file and discard it — but only
    /// after confirming (post-rename, when we exclusively own the file)
    /// that it still belongs to the dead holder we judged stale. Exactly
    /// one of several concurrent breakers wins the rename; losers simply
    /// retry acquisition. If the capture turns out to have grabbed a
    /// *live* lock (a writer re-acquired in the window), it is restored
    /// with `rename`, which also displaces any lock that sneaked into the
    /// brief gap — that displaced writer is then stopped by its next
    /// [`StoreLock::verify_held`] check. The gap between capture and
    /// restore is the residual race of lockfile-based advisory locking
    /// (closing it fully needs `flock`); `verify_held` on every mutating
    /// call bounds the damage to at most one in-flight operation.
    fn break_stale(dir: &Path, path: &Path, judged: &str) -> Result<()> {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let captured = dir.join(format!(
            ".writer.lock.broken.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        // last-moment recheck narrows the judge-then-capture window: if
        // the content changed since we judged it stale, a live writer
        // owns it now — leave it alone
        if fs::read_to_string(path).unwrap_or_default().trim() != judged.trim() {
            return Ok(());
        }
        if fs::rename(path, &captured).is_err() {
            // someone else broke (or released) it first; just retry create
            return Ok(());
        }
        let now = fs::read_to_string(&captured).unwrap_or_default();
        if now.trim() != judged.trim() {
            // a live writer re-acquired between the recheck and the
            // rename: put its lock back unconditionally (rename replaces
            // any newcomer, whose own verify_held will stop it)
            if fs::rename(&captured, path).is_err() {
                let _ = fs::remove_file(&captured);
                bail!(
                    "store writer-lock contention while breaking a stale lock \
                     (a live lock was captured and could not be restored); retry"
                );
            }
            return Ok(());
        }
        let _ = fs::remove_file(&captured);
        Ok(())
    }

    /// Cheap revalidation that the lock file still names this process —
    /// detects the (rare) case where a racing stale-lock breaker voided
    /// our lock, so a writer fails fast instead of appending unguarded.
    pub(crate) fn verify_held(&self) -> Result<()> {
        let holder = fs::read_to_string(&self.path).unwrap_or_default();
        if holder.trim() != std::process::id().to_string() {
            bail!(
                "writer lock at {} no longer names this process (holder: '{}'); \
                 it was broken or stolen — reopen the store",
                self.path.display(),
                holder.trim()
            );
        }
        Ok(())
    }

    /// Re-point the lock at a new bundle directory after the directory
    /// holding the (still-open, still-owned) lock file was renamed.
    pub(crate) fn retarget(&mut self, dir: &Path) {
        self.path = dir.join(LOCK_FILE);
    }

    /// Forget the lock file without removing it (its directory is being
    /// discarded wholesale, or another lock now owns the path).
    pub(crate) fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // only remove the file if it still names this process: a lock
        // that was broken/displaced by a racing stale-breaker may have
        // been replaced by another writer's live lock, which must survive
        let holder = fs::read_to_string(&self.path).unwrap_or_default();
        if holder.trim() == std::process::id().to_string() {
            let _ = fs::remove_file(&self.path);
        }
    }
}

#[cfg(target_os = "linux")]
pub(crate) fn process_alive(pid: u32) -> bool {
    pid == std::process::id() || Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn process_alive(_pid: u32) -> bool {
    // no portable liveness probe without extra deps: never break locks
    true
}

/// Pid embedded in a lock-machinery artifact file name — a staged
/// `.writer.lock.<pid>.tmp` or a captured `.writer.lock.broken.<pid>.<seq>`
/// — if `name` is one. Store recovery sweeps artifacts whose owner died
/// mid-acquire or mid-break, which would otherwise accumulate forever.
pub(crate) fn artifact_pid(name: &str) -> Option<u32> {
    let rest = name.strip_prefix(".writer.lock.")?;
    if let Some(rest) = rest.strip_prefix("broken.") {
        let (pid, _seq) = rest.split_once('.')?;
        return pid.parse().ok();
    }
    rest.strip_suffix(".tmp")?.parse().ok()
}

/// Whether the bundle's writer lock file exists and names a live process.
pub(crate) fn holder_alive(dir: &Path) -> bool {
    let Ok(holder) = fs::read_to_string(dir.join(LOCK_FILE)) else {
        return false;
    };
    holder.trim().parse::<u32>().map(process_alive).unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::tmp_dir;

    #[test]
    fn second_acquire_fails_while_held() {
        let dir = tmp_dir("lock-held");
        let lock = StoreLock::acquire(&dir).unwrap();
        let err = StoreLock::acquire(&dir).unwrap_err();
        assert!(err.to_string().contains("locked by another writer"), "{err:#}");
        drop(lock);
        // released on drop: acquirable again
        let _again = StoreLock::acquire(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn stale_lock_is_broken() {
        let dir = tmp_dir("lock-stale");
        // a pid far above any real pid_max: the holder is definitely gone
        std::fs::write(dir.join(LOCK_FILE), "4000000000").unwrap();
        let _lock = StoreLock::acquire(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn published_lock_always_carries_a_pid() {
        let dir = tmp_dir("lock-pid");
        let _lock = StoreLock::acquire(&dir).unwrap();
        let content = std::fs::read_to_string(dir.join(LOCK_FILE)).unwrap();
        assert_eq!(content.trim(), std::process::id().to_string());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_removes_the_file() {
        let dir = tmp_dir("lock-drop");
        let path = dir.join(LOCK_FILE);
        {
            let _lock = StoreLock::acquire(&dir).unwrap();
            assert!(path.exists());
        }
        assert!(!path.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn artifact_pid_parses_machinery_names() {
        assert_eq!(artifact_pid(".writer.lock.1234.tmp"), Some(1234));
        assert_eq!(artifact_pid(".writer.lock.broken.99.7"), Some(99));
        assert_eq!(artifact_pid("writer.lock"), None);
        assert_eq!(artifact_pid("index.cuszi"), None);
        assert_eq!(artifact_pid(".writer.lock.notapid.tmp"), None);
    }

    #[test]
    fn no_temp_files_left_behind() {
        let dir = tmp_dir("lock-tmp");
        {
            let _lock = StoreLock::acquire(&dir).unwrap();
        }
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
