//! Multi-octave value noise — the shared substrate for the synthetic
//! SDRBench-like fields. Each octave places random values on a coarse
//! lattice and multilinearly interpolates; summing octaves with geometric
//! persistence gives fields whose smoothness (hence Lorenzo
//! predictability) is tunable to match each dataset's character.

use crate::util::prng::Rng;

/// Smooth field over `dims` (1..=3 axes): octave sum, values roughly in
/// [-1, 1]. `base_cell` is the coarsest lattice spacing in grid units.
pub fn smooth(dims: &[usize], base_cell: usize, octaves: usize, persistence: f32, rng: &mut Rng) -> Vec<f32> {
    let n: usize = dims.iter().product();
    let mut out = vec![0f32; n];
    let mut amp = 1.0f32;
    let mut cell = base_cell.max(2);
    let mut total_amp = 0.0f32;
    for _ in 0..octaves {
        add_octave(&mut out, dims, cell, amp, rng);
        total_amp += amp;
        amp *= persistence;
        cell = (cell / 2).max(2);
    }
    let inv = 1.0 / total_amp.max(1e-9);
    for v in &mut out {
        *v *= inv;
    }
    out
}

fn add_octave(out: &mut [f32], dims: &[usize], cell: usize, amp: f32, rng: &mut Rng) {
    // lattice sizes (+1 for the right edge)
    let lat: Vec<usize> = dims.iter().map(|d| d / cell + 2).collect();
    let ln: usize = lat.iter().product();
    let lattice: Vec<f32> = (0..ln).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    match dims.len() {
        1 => {
            for i in 0..dims[0] {
                let x = i as f32 / cell as f32;
                out[i] += amp * lerp1(&lattice, x);
            }
        }
        2 => {
            let cols = dims[1];
            let lcols = lat[1];
            for r in 0..dims[0] {
                let y = r as f32 / cell as f32;
                for c in 0..cols {
                    let x = c as f32 / cell as f32;
                    out[r * cols + c] += amp * lerp2(&lattice, lcols, x, y);
                }
            }
        }
        3 => {
            let (d1, d2) = (dims[1], dims[2]);
            let (l1, l2) = (lat[1], lat[2]);
            for i in 0..dims[0] {
                let z = i as f32 / cell as f32;
                for j in 0..d1 {
                    let y = j as f32 / cell as f32;
                    for k in 0..d2 {
                        let x = k as f32 / cell as f32;
                        out[(i * d1 + j) * d2 + k] += amp * lerp3(&lattice, l1, l2, x, y, z);
                    }
                }
            }
        }
        _ => panic!("noise supports 1..=3 dims"),
    }
}

#[inline]
fn sfade(t: f32) -> f32 {
    t * t * (3.0 - 2.0 * t)
}

#[inline]
fn lerp1(lat: &[f32], x: f32) -> f32 {
    let x0 = x as usize;
    let t = sfade(x - x0 as f32);
    lat[x0] * (1.0 - t) + lat[x0 + 1] * t
}

#[inline]
fn lerp2(lat: &[f32], lcols: usize, x: f32, y: f32) -> f32 {
    let (x0, y0) = (x as usize, y as usize);
    let (tx, ty) = (sfade(x - x0 as f32), sfade(y - y0 as f32));
    let at = |r: usize, c: usize| lat[r * lcols + c];
    let top = at(y0, x0) * (1.0 - tx) + at(y0, x0 + 1) * tx;
    let bot = at(y0 + 1, x0) * (1.0 - tx) + at(y0 + 1, x0 + 1) * tx;
    top * (1.0 - ty) + bot * ty
}

#[inline]
fn lerp3(lat: &[f32], l1: usize, l2: usize, x: f32, y: f32, z: f32) -> f32 {
    let (x0, y0, z0) = (x as usize, y as usize, z as usize);
    let (tx, ty, tz) = (sfade(x - x0 as f32), sfade(y - y0 as f32), sfade(z - z0 as f32));
    let at = |i: usize, j: usize, k: usize| lat[(i * l1 + j) * l2 + k];
    let mut corners = [0f32; 2];
    for (dz, corner) in corners.iter_mut().enumerate() {
        let top = at(z0 + dz, y0, x0) * (1.0 - tx) + at(z0 + dz, y0, x0 + 1) * tx;
        let bot = at(z0 + dz, y0 + 1, x0) * (1.0 - tx) + at(z0 + dz, y0 + 1, x0 + 1) * tx;
        *corner = top * (1.0 - ty) + bot * ty;
    }
    corners[0] * (1.0 - tz) + corners[1] * tz
}

/// Zero-dominate: keep only the upper `1 - frac` tail above a threshold,
/// shifted to zero — models cloud/moisture fields where most of the domain
/// is exactly 0 (Table 9: CLOUDf48 is ~89% within eb of 0).
pub fn zero_dominate(field: &mut [f32], zero_frac: f32) {
    let mut sorted: Vec<f32> = field.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f32 * zero_frac) as usize).min(sorted.len() - 1);
    let thresh = sorted[idx];
    for v in field.iter_mut() {
        *v = (*v - thresh).max(0.0);
    }
}

/// Exponentiate a smooth field into a heavy-tailed positive one (Nyx
/// baryon_density: range ~1e5, yet 99.5% of values within one eb of the
/// minimum — Table 9).
pub fn lognormalize(field: &mut [f32], sigma: f32, floor: f32) {
    for v in field.iter_mut() {
        *v = floor + (*v * sigma).exp();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smooth_is_bounded_and_deterministic() {
        let mut a = Rng::new(5);
        let fa = smooth(&[64, 64], 16, 3, 0.5, &mut a);
        let mut b = Rng::new(5);
        let fb = smooth(&[64, 64], 16, 3, 0.5, &mut b);
        assert_eq!(fa, fb);
        for &v in &fa {
            assert!(v.abs() <= 1.5, "{v}");
        }
    }

    #[test]
    fn smooth_has_small_local_differences() {
        let mut rng = Rng::new(6);
        let f = smooth(&[4096], 64, 4, 0.5, &mut rng);
        let max_diff = f.windows(2).map(|w| (w[1] - w[0]).abs()).fold(0f32, f32::max);
        let range = f.iter().fold(0f32, |a, &b| a.max(b.abs())) * 2.0;
        assert!(max_diff < range * 0.15, "diff {max_diff} range {range}");
    }

    #[test]
    fn zero_dominate_fraction() {
        let mut rng = Rng::new(7);
        let mut f = smooth(&[128, 128], 16, 3, 0.5, &mut rng);
        zero_dominate(&mut f, 0.8);
        let zeros = f.iter().filter(|&&v| v == 0.0).count();
        let frac = zeros as f32 / f.len() as f32;
        assert!(frac > 0.7 && frac < 0.95, "{frac}");
        assert!(f.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn lognormalize_heavy_tail() {
        let mut rng = Rng::new(8);
        let mut f = smooth(&[64, 64, 64], 16, 3, 0.5, &mut rng);
        lognormalize(&mut f, 6.0, 0.05);
        let max = f.iter().fold(0f32, |a, &b| a.max(b));
        let median = {
            let mut s = f.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[s.len() / 2]
        };
        assert!(max / median > 50.0, "max {max} median {median}");
        assert!(f.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn works_in_all_dims() {
        let mut rng = Rng::new(9);
        assert_eq!(smooth(&[100], 8, 2, 0.5, &mut rng).len(), 100);
        assert_eq!(smooth(&[10, 20], 4, 2, 0.5, &mut rng).len(), 200);
        assert_eq!(smooth(&[5, 6, 7], 4, 2, 0.5, &mut rng).len(), 210);
    }
}
