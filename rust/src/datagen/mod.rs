//! Synthetic stand-ins for the five SDRBench datasets (Table 2).
//!
//! The real datasets are not redistributable inside this environment
//! (repro band 0), so each field is generated to match the statistical
//! character that drives compression behaviour (DESIGN.md §4): smoothness
//! class (Lorenzo predictability), zero-domination (Table 9), value range
//! and tail shape. Dimensions are scaled down from production size by the
//! `scale` knob (default keeps every field a few MB so the whole benchmark
//! suite runs in minutes; `--scale 2` per-axis-doubles 2D/3D fields).

pub mod noise;
pub mod profiles;

use anyhow::{bail, Result};

use crate::field::Field;
use crate::util::prng::Rng;

/// The five evaluated datasets (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// 1D cosmology particles (HACC): positions + velocities.
    Hacc,
    /// 2D climate (CESM-ATM).
    CesmAtm,
    /// 3D climate (Hurricane ISABEL).
    Hurricane,
    /// 3D cosmology (Nyx).
    Nyx,
    /// 4D quantum Monte Carlo (QMCPACK einspline), folds to 3D.
    Qmcpack,
}

impl Dataset {
    pub const ALL: [Dataset; 5] =
        [Dataset::Hacc, Dataset::CesmAtm, Dataset::Hurricane, Dataset::Nyx, Dataset::Qmcpack];

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Hacc => "HACC",
            Dataset::CesmAtm => "CESM-ATM",
            Dataset::Hurricane => "HURRICANE",
            Dataset::Nyx => "NYX",
            Dataset::Qmcpack => "QMCPACK",
        }
    }

    pub fn parse(s: &str) -> Result<Dataset> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "hacc" => Dataset::Hacc,
            "cesm" | "cesm-atm" => Dataset::CesmAtm,
            "hurricane" | "isabel" => Dataset::Hurricane,
            "nyx" => Dataset::Nyx,
            "qmcpack" => Dataset::Qmcpack,
            _ => bail!("unknown dataset {s} (hacc|cesm|hurricane|nyx|qmcpack)"),
        })
    }

    /// Scaled-down dims (scale=1). Production dims are in Table 2.
    pub fn dims(&self, scale: usize) -> Vec<usize> {
        let s = scale.max(1);
        match self {
            Dataset::Hacc => vec![(1 << 21) * s],
            Dataset::CesmAtm => vec![450 * s, 900 * s],
            Dataset::Hurricane => vec![25 * s, 125 * s, 125 * s],
            Dataset::Nyx => vec![128 * s, 128 * s, 128 * s],
            Dataset::Qmcpack => vec![72 * s, 29 * s, 35 * s, 35 * s],
        }
    }

    /// Representative field names (the ones the paper's tables use).
    pub fn field_names(&self) -> Vec<&'static str> {
        match self {
            Dataset::Hacc => vec!["x", "vx"],
            Dataset::CesmAtm => vec!["CLDHGH", "PS"],
            Dataset::Hurricane => profiles::HURRICANE_FIELDS.to_vec(),
            Dataset::Nyx => profiles::NYX_FIELDS.to_vec(),
            Dataset::Qmcpack => vec!["einspline"],
        }
    }
}

/// Generate one named field of a dataset at default scale.
pub fn generate(dataset: Dataset, field: &str, seed: u64) -> Field {
    generate_scaled(dataset, field, seed, 1)
}

/// Generate with an axis scale multiplier.
pub fn generate_scaled(dataset: Dataset, field: &str, seed: u64, scale: usize) -> Field {
    let dims = dataset.dims(scale);
    let mut rng = Rng::new(seed ^ hash_name(dataset.name()) ^ hash_name(field));
    let data = profiles::synthesize(dataset, field, &dims, &mut rng);
    Field::new(format!("{}/{}", dataset.name(), field), dims, data).expect("datagen shape")
}

fn hash_name(s: &str) -> u64 {
    let mut h = 1469598103934665603u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(1099511628211);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_dataset_generates_every_field() {
        for ds in Dataset::ALL {
            for f in ds.field_names() {
                let field = generate(ds, f, 1);
                assert_eq!(field.len(), ds.dims(1).iter().product::<usize>());
                assert!(field.data.iter().all(|v| v.is_finite()), "{}/{}", ds.name(), f);
            }
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = generate(Dataset::Nyx, "baryon_density", 9);
        let b = generate(Dataset::Nyx, "baryon_density", 9);
        assert_eq!(a.data, b.data);
        let c = generate(Dataset::Nyx, "baryon_density", 10);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn fields_differ_from_each_other() {
        let a = generate(Dataset::Hurricane, "CLOUDf48", 1);
        let b = generate(Dataset::Hurricane, "Pf48", 1);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Dataset::parse("NYX").unwrap(), Dataset::Nyx);
        assert!(Dataset::parse("bogus").is_err());
    }
}
