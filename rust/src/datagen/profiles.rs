//! Per-field statistical profiles, calibrated to the paper's description:
//!
//! * Hurricane Q* moisture fields and Nyx baryon_density are zero- or
//!   min-dominated with heavy upper tails (Table 9: 89-99% of values
//!   within one eb of the minimum) — these are the fields where cuSZ's
//!   zero-padded blocks beat SZ-1.4 in PSNR (Table 8).
//! * Pressure/temperature/velocity fields are smooth with moderate range —
//!   cuSZ and SZ-1.4 tie at the valrel-implied PSNR (~84.79 dB).
//! * `.log10` variants are the paper's logarithmic-transformed twins.
//! * HACC positions are locally-sorted particle coordinates; velocities
//!   are multi-stream Gaussian mixtures (moderately predictable).

use super::noise::{lognormalize, smooth, zero_dominate};
use super::Dataset;
use crate::util::prng::Rng;

pub const HURRICANE_FIELDS: [&str; 20] = [
    "CLOUDf48",
    "CLOUDf48.log10",
    "Pf48",
    "PRECIPf48",
    "PRECIPf48.log10",
    "QCLOUDf48",
    "QCLOUDf48.log10",
    "QGRAUPf48",
    "QGRAUPf48.log10",
    "QICEf48",
    "QICEf48.log10",
    "QRAINf48",
    "QRAINf48.log10",
    "QSNOWf48",
    "QSNOWf48.log10",
    "QVAPORf48",
    "TCf48",
    "Uf48",
    "Vf48",
    "Wf48",
];

pub const NYX_FIELDS: [&str; 6] = [
    "baryon_density",
    "dark_matter_density",
    "temperature",
    "velocity_x",
    "velocity_y",
    "velocity_z",
];

/// Synthesize `field` of `dataset` over `dims` (logical dims, 1..=4).
pub fn synthesize(dataset: Dataset, field: &str, dims: &[usize], rng: &mut Rng) -> Vec<f32> {
    match dataset {
        Dataset::Hacc => hacc(field, dims[0], rng),
        Dataset::CesmAtm => cesm(field, dims, rng),
        Dataset::Hurricane => hurricane(field, dims, rng),
        Dataset::Nyx => nyx(field, dims, rng),
        Dataset::Qmcpack => qmcpack(dims, rng),
    }
}

fn hacc(field: &str, n: usize, rng: &mut Rng) -> Vec<f32> {
    match field {
        // Particle x-positions: particles are laid out rank-by-rank, so
        // coordinates ramp within segments (locally smooth) with jitter.
        "x" => {
            let box_size = 256.0f32;
            let seg = 4096usize;
            let mut out = Vec::with_capacity(n);
            for s in 0..n.div_ceil(seg) {
                let lo = rng.range_f32(0.0, box_size * 0.75);
                let hi = lo + box_size * 0.25;
                let m = seg.min(n - s * seg);
                for i in 0..m {
                    let t = i as f32 / m as f32;
                    out.push(lo + (hi - lo) * t + rng.normal() * 0.003);
                }
            }
            out
        }
        // Velocities: multi-stream Gaussian mixture with bulk flows.
        _ => {
            let seg = 8192usize;
            let mut out = Vec::with_capacity(n);
            // bulk flow varies smoothly along the stream; thermal jitter is
            // small relative to the bulk scale (velocity-dispersion ratio
            // matched so valrel 1e-4 keeps residuals within a few bins)
            let mut bulk = rng.normal() * 300.0;
            for s in 0..n.div_ceil(seg) {
                let target = rng.normal() * 300.0;
                let disp = 5.0 + rng.f32() * 15.0;
                let m = seg.min(n - s * seg);
                for i in 0..m {
                    let t = i as f32 / m as f32;
                    let b = bulk + (target - bulk) * t;
                    out.push(b + rng.normal() * disp);
                }
                bulk = target;
            }
            out
        }
    }
}

fn cesm(field: &str, dims: &[usize], rng: &mut Rng) -> Vec<f32> {
    match field {
        // High-cloud fraction in [0,1], ~60% exactly 0 with smooth patches.
        "CLDHGH" => {
            let mut f = smooth(dims, 64, 4, 0.55, rng);
            zero_dominate(&mut f, 0.6);
            let max = f.iter().fold(0f32, |a, &b| a.max(b)).max(1e-6);
            for v in f.iter_mut() {
                *v = (*v / max).min(1.0);
            }
            f
        }
        // Surface pressure: smooth, ~[50kPa, 103kPa].
        _ => {
            let mut f = smooth(dims, 96, 3, 0.35, rng);
            for v in f.iter_mut() {
                *v = 95_000.0 + *v * 8_000.0;
            }
            f
        }
    }
}

fn hurricane(field: &str, dims: &[usize], rng: &mut Rng) -> Vec<f32> {
    if let Some(base) = field.strip_suffix(".log10") {
        let mut f = hurricane(base, dims, rng);
        // the paper's log10 preprocessing for pointwise-relative fields
        for v in f.iter_mut() {
            *v = (v.max(1e-12)).log10();
        }
        return f;
    }
    match field {
        // Moisture mixing ratios: overwhelmingly zero, heavy positive tail.
        "CLOUDf48" | "QCLOUDf48" | "QICEf48" | "QSNOWf48" | "QGRAUPf48" | "QRAINf48" => {
            let zero_frac = match field {
                "CLOUDf48" => 0.89,
                "QCLOUDf48" => 0.92,
                "QICEf48" => 0.85,
                _ => 0.80,
            };
            let mut f = smooth(dims, 24, 4, 0.55, rng);
            zero_dominate(&mut f, zero_frac);
            // cube the tail: concentrates mass near 0, max ~2e-3 like Table 9
            let max = f.iter().fold(0f32, |a, &b| a.max(b)).max(1e-6);
            for v in f.iter_mut() {
                let t = *v / max;
                *v = t * t * t * 2.05e-3;
            }
            f
        }
        // Precipitation: zero-dominated but shallower tail.
        "PRECIPf48" => {
            let mut f = smooth(dims, 24, 4, 0.5, rng);
            zero_dominate(&mut f, 0.75);
            let max = f.iter().fold(0f32, |a, &b| a.max(b)).max(1e-6);
            for v in f.iter_mut() {
                *v = (*v / max) * (*v / max) * 1e-2;
            }
            f
        }
        // Vapor: positive, smooth, no zero plateau.
        "QVAPORf48" => {
            let mut f = smooth(dims, 48, 3, 0.35, rng);
            for v in f.iter_mut() {
                *v = (0.5 + 0.5 * *v).max(0.0) * 0.02;
            }
            f
        }
        // Pressure: very smooth, large values.
        "Pf48" => {
            let mut f = smooth(dims, 64, 3, 0.45, rng);
            for v in f.iter_mut() {
                *v = 85_000.0 + *v * 15_000.0;
            }
            f
        }
        // Temperature (C): smooth.
        "TCf48" => {
            let mut f = smooth(dims, 64, 3, 0.35, rng);
            for v in f.iter_mut() {
                *v = 10.0 + *v * 40.0;
            }
            f
        }
        // Wind components: smooth with vortex-like swirl energy.
        _ => {
            let mut f = smooth(dims, 48, 3, 0.4, rng);
            for v in f.iter_mut() {
                *v *= 75.0;
            }
            f
        }
    }
}

fn nyx(field: &str, dims: &[usize], rng: &mut Rng) -> Vec<f32> {
    match field {
        // Densities: lognormal — min ~0.058, max ~1.16e5 (Table 9), with
        // 99.5% of the mass within one eb of the minimum at valrel 1e-4.
        "baryon_density" | "dark_matter_density" => {
            let sigma = if field == "baryon_density" { 11.5 } else { 9.5 };
            let mut f = smooth(dims, 16, 5, 0.6, rng);
            // normalize to max |v| = 1, then sharpen peaks (cosmic web
            // filaments): cubing concentrates mass near the floor
            let max = f.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1e-9);
            for v in f.iter_mut() {
                let t = *v / max;
                *v = t * t * t;
            }
            lognormalize(&mut f, sigma, 0.058);
            f
        }
        // Temperature: lognormal-ish but tamer.
        "temperature" => {
            let mut f = smooth(dims, 32, 3, 0.4, rng);
            for v in f.iter_mut() {
                *v = 1e4 * (1.2 * *v).exp();
            }
            f
        }
        // Velocities: smooth turbulence, range ~±1e7 cm/s.
        _ => {
            let mut f = smooth(dims, 48, 3, 0.4, rng);
            for v in f.iter_mut() {
                *v *= 5e6;
            }
            f
        }
    }
}

fn qmcpack(dims: &[usize], rng: &mut Rng) -> Vec<f32> {
    // einspline orbital coefficients on a 4D (orbital, x, y, z) grid:
    // per-orbital smooth oscillatory 3D fields with varying frequency.
    assert_eq!(dims.len(), 4);
    let orbital_dims = &dims[1..];
    let per: usize = orbital_dims.iter().product();
    let mut out = Vec::with_capacity(dims[0] * per);
    // Adjacent orbitals are strongly correlated (einspline coefficients
    // vary smoothly with the orbital index), so the 3D kernel's axis-0
    // prediction still helps after the 4D->3D fold.
    let base = smooth(orbital_dims, 12, 3, 0.4, rng);
    let mut drift = smooth(orbital_dims, 16, 2, 0.4, rng);
    for orb in 0..dims[0] {
        let amp = 1.0 + 0.002 * orb as f32;
        for (b, d) in base.iter().zip(&drift) {
            out.push(amp * (b + 0.03 * d));
        }
        // slow random walk of the drift field between orbitals
        if orb % 16 == 15 {
            let fresh = smooth(orbital_dims, 16, 2, 0.4, rng);
            for (d, f) in drift.iter_mut().zip(&fresh) {
                *d = 0.9 * *d + 0.1 * f;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(f: &[f32]) -> (f32, f32, f32) {
        let mut s = f.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (s[0], s[s.len() / 2], s[s.len() - 1])
    }

    #[test]
    fn cloud_field_matches_table9_shape() {
        let mut rng = Rng::new(1);
        let f = synthesize(Dataset::Hurricane, "CLOUDf48", &[25, 125, 125], &mut rng);
        let (min, med, max) = stats(&f);
        assert_eq!(min, 0.0);
        assert_eq!(med, 0.0, "median must be exactly 0 (Table 9: 75% are 0)");
        assert!(max > 1e-3 && max < 1e-2, "max {max}");
        // >= 80% of values within eb=2.05e-7 of zero
        let eb = 2.05e-7f32;
        let frac = f.iter().filter(|&&v| v.abs() <= eb).count() as f32 / f.len() as f32;
        assert!(frac > 0.8, "near-zero fraction {frac}");
    }

    #[test]
    fn baryon_density_heavy_tail() {
        let mut rng = Rng::new(2);
        let f = synthesize(Dataset::Nyx, "baryon_density", &[64, 64, 64], &mut rng);
        let (min, med, max) = stats(&f);
        assert!(min >= 0.05, "min {min}");
        assert!(med < 5.0, "median {med}");
        assert!(max / med > 1e3, "tail ratio {}", max / med);
        // Table 9: at eb = 1e-4 * range, ~99.5% within [min, min+eb]
        let eb = 1e-4 * (max - min);
        let frac = f.iter().filter(|&&v| v - min <= eb).count() as f32 / f.len() as f32;
        assert!(frac > 0.9, "min-hugging fraction {frac}");
    }

    #[test]
    fn pressure_is_smooth() {
        let mut rng = Rng::new(3);
        let dims = [25usize, 125, 125];
        let f = synthesize(Dataset::Hurricane, "Pf48", &dims, &mut rng);
        // neighbor diffs along the fastest axis are small vs range
        let (min, _, max) = stats(&f);
        let range = max - min;
        let mut max_diff = 0f32;
        for row in f.chunks(dims[2]) {
            for w in row.windows(2) {
                max_diff = max_diff.max((w[1] - w[0]).abs());
            }
        }
        assert!(max_diff < 0.1 * range, "diff {max_diff} range {range}");
    }

    #[test]
    fn log10_variant_is_log_of_base() {
        let mut ra = Rng::new(4);
        let a = synthesize(Dataset::Hurricane, "QICEf48", &[10, 50, 50], &mut ra);
        let mut rb = Rng::new(4);
        let b = synthesize(Dataset::Hurricane, "QICEf48.log10", &[10, 50, 50], &mut rb);
        for (x, y) in a.iter().zip(&b) {
            assert!((x.max(1e-12).log10() - y).abs() < 1e-5);
        }
    }

    #[test]
    fn hacc_positions_locally_monotone() {
        let mut rng = Rng::new(5);
        let f = hacc("x", 100_000, &mut rng);
        // within a segment, mostly increasing
        let inc = f.windows(2).filter(|w| w[1] >= w[0]).count();
        assert!(inc as f32 / f.len() as f32 > 0.8);
    }
}
