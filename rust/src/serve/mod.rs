//! `serve`: the batched streaming compression front end — the shape the
//! paper's I/O-reduction story takes when many fields arrive faster than
//! one compressor loop can drain them (LCLS-II / HACC campaigns, §1).
//!
//! A [`BatchCompressor`] accepts a stream of [`Field`]s and fans whole-job
//! compression across a bounded [`FanStage`] worker pipeline with
//! backpressure: one producer thread feeds a bounded queue, `workers`
//! threads share a single [`Coordinator`] (one engine, one codebook/config
//! universe — the paper's single-device discipline), and the calling
//! thread is the sink, writing archives into a [`Store`] and folding
//! per-job [`CompressStats`] into service-level [`ServiceStats`].
//!
//! Inside each job the coordinator already parallelizes slab quantization
//! and per-chunk deflate; the batch layer adds job-level concurrency on
//! top. When both are unbounded the core count is oversubscribed, so batch
//! deployments set `CuszConfig::threads` to a small number and let
//! `BatchConfig::workers` cover the cores (see `examples/batch_service.rs`).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::container::Archive;
use crate::coordinator::{CompressStats, CompressedField, Coordinator, DecompressStats};
use crate::field::Field;
use crate::obs::{self, keys, RunTimings};
use crate::store::Store;
use crate::util::pool::{bounded, FanStage};

pub mod daemon;
pub mod loadgen;
pub mod wire;

pub use daemon::{install_signal_drain, Daemon, DaemonConfig, DaemonHandle, DaemonStats};
pub use loadgen::{ArrivalPattern, LoadReport, LoadgenConfig};
pub use wire::{Client, GetOutcome, Limits, PutOutcome};

/// Run `f`, converting a panic into an ordinary error instead of letting
/// it unwind through a worker pool. Batch and daemon workers wrap every
/// job in this so one poisoned job surfaces as a per-job error entry (or
/// a per-request `SERVER_ERROR` frame) while the pool keeps draining —
/// the service must outlive any single bad field.
pub fn contain_panic<T>(label: &str, f: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(result) => result,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(anyhow::anyhow!("{label} panicked: {msg}"))
        }
    }
}

/// Exact percentile (linear interpolation) over *sorted* nanosecond
/// samples, reported in milliseconds. The service keeps every job's
/// latency, so percentiles here are oracle-exact; the registry's
/// log2-bucketed histograms carry the streaming approximation.
fn percentile_ms(sorted_ns: &[u64], q: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let rank = q.clamp(0.0, 1.0) * (sorted_ns.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    (sorted_ns[lo] as f64 * (1.0 - frac) + sorted_ns[hi] as f64 * frac) / 1e6
}

/// Tuning for the batch front end.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Concurrent compression jobs (whole fields in flight).
    /// 0 = one per available core.
    pub workers: usize,
    /// Bounded queue depth between stages (backpressure: at most
    /// `queue_depth` fields buffered ahead of the workers, and
    /// `queue_depth` archives ahead of the sink).
    pub queue_depth: usize,
    /// Auto-compaction trigger for [`BatchCompressor::run_into_store`]:
    /// after a batch drain, if the store's dead bytes exceed this
    /// fraction of its live payload bytes, the bundle is compacted in
    /// place. 0.0 disables (compaction stays manual).
    pub compact_threshold: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { workers: 0, queue_depth: 4, compact_threshold: 0.0 }
    }
}

impl BatchConfig {
    pub fn effective_workers(&self) -> usize {
        crate::util::pool::effective_threads(self.workers)
    }
}

/// Service-level aggregate over every job of a batch run.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub jobs: usize,
    pub failed: usize,
    pub original_bytes: usize,
    pub compressed_bytes: usize,
    pub n_outliers: usize,
    pub n_verbatim: usize,
    pub encoded_bits: u64,
    pub wall_seconds: f64,
    /// Worker threads the batch ran with (for utilization).
    pub workers: usize,
    /// Per-job wall nanoseconds, completion order (successful jobs only).
    /// Mirrored into the `serve.compress.job_ns` registry histogram.
    pub job_ns: Vec<u64>,
    /// Dead bytes reclaimed by auto-compaction after the drain (0 when
    /// the threshold was not crossed or auto-compaction is disabled).
    pub compacted_bytes: u64,
    /// Per-job stats in completion order (not submission order). Each
    /// job's `CompressStats::encoder` records the backend that `auto`
    /// resolved to for that field.
    pub per_job: Vec<(String, CompressStats)>,
    /// (field name, error) for jobs whose compression failed.
    pub errors: Vec<(String, String)>,
}

impl ServiceStats {
    pub fn absorb(&mut self, name: &str, stats: &CompressStats) {
        self.jobs += 1;
        self.original_bytes += stats.original_bytes;
        self.compressed_bytes += stats.compressed_bytes;
        self.n_outliers += stats.n_outliers;
        self.n_verbatim += stats.n_verbatim;
        self.encoded_bits += stats.encoded_bits;
        self.per_job.push((name.to_string(), stats.clone()));
    }

    /// Per-encoder job tallies (the auto-mode choice report): how many
    /// fields each backend ended up compressing (majority backend for
    /// chunk-granularity jobs; see [`ServiceStats::chunk_encoder_counts`]
    /// for the chunk-level tally).
    pub fn encoder_counts(&self) -> Vec<(&'static str, usize)> {
        let mut counts: Vec<(&'static str, usize)> = Vec::new();
        for (_, s) in &self.per_job {
            let name = s.encoder.name();
            match counts.iter_mut().find(|(n, _)| *n == name) {
                Some((_, c)) => *c += 1,
                None => counts.push((name, 1)),
            }
        }
        counts
    }

    /// Per-encoder *chunk* tallies across every job, indexed by
    /// [`crate::codec::EncoderKind::to_tag`] — the service-level view of
    /// per-chunk adaptive selection (uniform jobs tally all their chunks
    /// under the one backend).
    pub fn chunk_encoder_counts(&self) -> [usize; crate::codec::EncoderKind::ALL.len()] {
        let mut counts = [0usize; crate::codec::EncoderKind::ALL.len()];
        for (_, s) in &self.per_job {
            for (slot, &c) in counts.iter_mut().zip(&s.chunk_counts) {
                *slot += c;
            }
        }
        counts
    }

    /// Per-encoder *compressed byte* totals across every job (field-level
    /// resolution: a chunk-granularity job's bytes tally under its
    /// majority backend, same attribution as [`ServiceStats::encoder_counts`]).
    pub fn encoder_bytes(&self) -> Vec<(&'static str, usize)> {
        let mut totals: Vec<(&'static str, usize)> = Vec::new();
        for (_, s) in &self.per_job {
            let name = s.encoder.name();
            match totals.iter_mut().find(|(n, _)| *n == name) {
                Some((_, b)) => *b += s.compressed_bytes,
                None => totals.push((name, s.compressed_bytes)),
            }
        }
        totals
    }

    /// Job latency (p50, p95, p99) in milliseconds, exact over the
    /// recorded per-job samples. `None` until a job completes.
    pub fn latency_percentiles(&self) -> Option<(f64, f64, f64)> {
        if self.job_ns.is_empty() {
            return None;
        }
        let mut v = self.job_ns.clone();
        v.sort_unstable();
        Some((percentile_ms(&v, 0.50), percentile_ms(&v, 0.95), percentile_ms(&v, 0.99)))
    }

    /// Fraction of worker wall time spent inside jobs: sum of job
    /// nanoseconds over `workers x wall`. 1.0 means the pool never idled.
    pub fn worker_utilization(&self) -> f64 {
        let budget_ns = self.wall_seconds * 1e9 * self.workers.max(1) as f64;
        if budget_ns <= 0.0 {
            return 0.0;
        }
        let busy: u64 = self.job_ns.iter().sum();
        (busy as f64 / budget_ns).min(1.0)
    }

    /// Stage timings merged across every job — feeds the per-stage GB/s
    /// rows of [`ServiceStats::report`] (against original bytes, paper
    /// footnote 4 convention).
    pub fn stage_timings(&self) -> RunTimings {
        let mut t = RunTimings::new();
        for (_, s) in &self.per_job {
            t.merge(&s.timer);
        }
        t
    }

    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    /// End-to-end service throughput against original bytes (paper
    /// footnote 4 convention), including queueing and store writes.
    pub fn throughput_gbps(&self) -> f64 {
        self.original_bytes as f64 / self.wall_seconds.max(1e-12) / 1e9
    }

    pub fn report(&self) -> String {
        let encoders = self
            .encoder_counts()
            .iter()
            .map(|(n, c)| format!("{n}:{c}"))
            .collect::<Vec<_>>()
            .join(" ");
        let chunk_counts = self.chunk_encoder_counts();
        let chunks = crate::codec::EncoderKind::ALL
            .into_iter()
            .filter(|&k| chunk_counts[k.to_tag() as usize] > 0)
            .map(|k| format!("{}:{}", k.name(), chunk_counts[k.to_tag() as usize]))
            .collect::<Vec<_>>()
            .join(" ");
        let mut s = format!(
            "jobs {} ok / {} failed  {:.2} MB -> {:.2} MB  CR {:.2}x  \
             {:.3} GB/s end-to-end  (encoders {}, chunks {}, outliers {}, verbatim {}, wall {:.3}s)",
            self.jobs,
            self.failed,
            self.original_bytes as f64 / 1e6,
            self.compressed_bytes as f64 / 1e6,
            self.compression_ratio(),
            self.throughput_gbps(),
            if encoders.is_empty() { "-".to_string() } else { encoders },
            if chunks.is_empty() { "-".to_string() } else { chunks },
            self.n_outliers,
            self.n_verbatim,
            self.wall_seconds,
        );
        if self.compacted_bytes > 0 {
            s.push_str(&format!(
                "  [auto-compacted {:.2} MB dead space]",
                self.compacted_bytes as f64 / 1e6
            ));
        }
        if let Some((p50, p95, p99)) = self.latency_percentiles() {
            s.push_str(&format!(
                "\n  job latency ms  p50 {p50:.3}  p95 {p95:.3}  p99 {p99:.3}  \
                 (workers {}, utilization {:.0}%)",
                self.workers,
                self.worker_utilization() * 100.0,
            ));
        }
        let enc_bytes = self.encoder_bytes();
        if !enc_bytes.is_empty() {
            let cols = enc_bytes
                .iter()
                .map(|(n, b)| format!("{n}:{:.2} MB", *b as f64 / 1e6))
                .collect::<Vec<_>>()
                .join("  ");
            s.push_str(&format!("\n  encoder bytes   {cols}"));
        }
        let timings = self.stage_timings();
        let stage_rows = timings.report(self.original_bytes);
        if !stage_rows.is_empty() {
            s.push('\n');
            s.push_str(stage_rows.trim_end_matches('\n'));
        }
        s
    }
}

/// Batched streaming compressor: one shared engine, many jobs in flight.
pub struct BatchCompressor {
    coord: Arc<Coordinator>,
    cfg: BatchConfig,
}

impl BatchCompressor {
    pub fn new(coord: Arc<Coordinator>, cfg: BatchConfig) -> Self {
        BatchCompressor { coord, cfg }
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Stream `fields` through the worker pipeline, handing each finished
    /// [`CompressedField`] (archive + its single serialization + stats)
    /// to `sink` on the calling thread. Workers serialize inside
    /// `compress_encoded`, so sinks write `bytes` as-is and never
    /// re-serialize. A sink error aborts the run (producer and workers
    /// unwind via channel hang-up); per-job compression errors are
    /// collected, not fatal.
    pub fn run<I, S>(&self, fields: I, mut sink: S) -> Result<ServiceStats>
    where
        I: IntoIterator<Item = Field>,
        I::IntoIter: Send + 'static,
        S: FnMut(&str, CompressedField) -> Result<()>,
    {
        let workers = self.cfg.effective_workers();
        let depth = self.cfg.queue_depth.max(1);

        let (tx, rx) = bounded::<Field>(depth);
        let coord = Arc::clone(&self.coord);
        let fan = FanStage::try_spawn(rx, workers, depth, "compress", move |field: Field| {
            obs::global().add(keys::SERVE_QUEUE_DEQUEUED, 1);
            let name = field.name.clone();
            let span = obs::span(keys::SERVE_COMPRESS_JOB)
                .with_bytes(field.size_bytes() as u64)
                .with_histogram(obs::global().histogram(keys::HIST_COMPRESS_JOB_NS));
            let result = contain_panic("compress job", || coord.compress_encoded(&field));
            let ns = span.finish().as_nanos() as u64;
            // fall this worker's scratch pools back to the watermark so
            // one outsized field doesn't pin its buffers for the run
            crate::util::arena::trim_to_watermark(crate::util::arena::DEFAULT_TRIM_WATERMARK);
            (name, result, ns)
        })
        .context("spawning compress workers")?;
        let fields = fields.into_iter();
        let producer = std::thread::Builder::new()
            .name("field-producer".into())
            .spawn(move || {
                for f in fields {
                    if tx.send(f).is_err() {
                        break; // pipeline shut down early
                    }
                    obs::global().add(keys::SERVE_QUEUE_ENQUEUED, 1);
                }
            })
            .context("spawning field producer")?;

        let t0 = Instant::now();
        let mut stats = ServiceStats { workers, ..Default::default() };
        let mut sink_err = None;
        for (name, result, job_ns) in fan.rx.iter() {
            match result {
                Ok(compressed) => {
                    let job_stats = compressed.stats.clone();
                    if let Err(e) = sink(&name, compressed) {
                        sink_err = Some(e.context(format!("sink failed on '{name}'")));
                        break;
                    }
                    stats.absorb(&name, &job_stats);
                    stats.job_ns.push(job_ns);
                }
                Err(e) => {
                    stats.failed += 1;
                    stats.errors.push((name, format!("{e:#}")));
                }
            }
        }
        stats.wall_seconds = t0.elapsed().as_secs_f64();
        // Dropping fan.rx (join) unblocks workers; workers dropping the
        // shared input receiver unblocks the producer.
        fan.join();
        let producer_panicked = producer.join().is_err();
        match sink_err {
            Some(e) => Err(e),
            None if producer_panicked => Err(anyhow::anyhow!(
                "field producer panicked; results incomplete ({} jobs finished)",
                stats.jobs
            )),
            None => Ok(stats),
        }
    }

    /// Convenience: run the batch and write every archive into `store`
    /// under its field name. Each worker's single serialization is
    /// appended as-is (`Store::add_bytes`) — the store never re-encodes.
    /// The store's index is committed once at the end of the run (payload
    /// appends are still immediate), so ingesting N fields costs one
    /// index rewrite instead of N. After the drain, if
    /// `BatchConfig::compact_threshold` is set and the store's dead bytes
    /// exceed that fraction of its live bytes, the bundle is compacted in
    /// place (atomic directory swap) and the reclaimed bytes recorded.
    pub fn run_into_store<I>(&self, fields: I, store: &mut Store) -> Result<ServiceStats>
    where
        I: IntoIterator<Item = Field>,
        I::IntoIter: Send + 'static,
    {
        store.set_deferred_index(true)?;
        let result = self.run(fields, |_name, c| {
            store
                .add_bytes(&c.archive.header.field_name, &c.bytes)
                .map(|_| ())
        });
        // commit whatever landed, even if the run errored mid-stream
        let commit = store.set_deferred_index(false);
        let mut stats = result?;
        commit?;
        let threshold = self.cfg.compact_threshold;
        if threshold > 0.0 {
            let dead = store.dead_bytes();
            if dead > 0 && dead as f64 >= threshold * store.live_bytes().max(1) as f64 {
                stats.compacted_bytes = store
                    .compact_in_place()
                    .context("auto-compaction after batch drain")?;
            }
        }
        Ok(stats)
    }
}

/// Aggregate results of draining a bundle back to fields.
#[derive(Debug, Clone, Default)]
pub struct DrainStats {
    pub jobs: usize,
    pub failed: usize,
    /// Total bytes of restored (uncompressed) field data.
    pub original_bytes: usize,
    pub wall_seconds: f64,
    /// Worker threads the drain ran with (for utilization).
    pub workers: usize,
    /// Per-job wall nanoseconds, completion order (successful jobs only).
    /// Mirrored into the `serve.decompress.job_ns` registry histogram.
    pub job_ns: Vec<u64>,
    /// Stage timings merged across every drained job (decode, fused
    /// reconstruct, total) — the decompress mirror of
    /// [`ServiceStats::stage_timings`].
    pub timer: RunTimings,
    /// (field name, error) for entries that failed to read or decode.
    pub errors: Vec<(String, String)>,
}

impl DrainStats {
    /// Decompression throughput against restored bytes.
    pub fn throughput_gbps(&self) -> f64 {
        self.original_bytes as f64 / self.wall_seconds.max(1e-12) / 1e9
    }

    /// Job latency (p50, p95, p99) in milliseconds, exact over the
    /// recorded per-job samples. `None` until a job completes.
    pub fn latency_percentiles(&self) -> Option<(f64, f64, f64)> {
        if self.job_ns.is_empty() {
            return None;
        }
        let mut v = self.job_ns.clone();
        v.sort_unstable();
        Some((percentile_ms(&v, 0.50), percentile_ms(&v, 0.95), percentile_ms(&v, 0.99)))
    }

    /// Fraction of worker wall time spent inside jobs.
    pub fn worker_utilization(&self) -> f64 {
        let budget_ns = self.wall_seconds * 1e9 * self.workers.max(1) as f64;
        if budget_ns <= 0.0 {
            return 0.0;
        }
        let busy: u64 = self.job_ns.iter().sum();
        (busy as f64 / budget_ns).min(1.0)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "drained {} ok / {} failed  {:.2} MB restored  {:.3} GB/s  (wall {:.3}s)",
            self.jobs,
            self.failed,
            self.original_bytes as f64 / 1e6,
            self.throughput_gbps(),
            self.wall_seconds,
        );
        if let Some((p50, p95, p99)) = self.latency_percentiles() {
            s.push_str(&format!(
                "\n  job latency ms  p50 {p50:.3}  p95 {p95:.3}  p99 {p99:.3}  \
                 (workers {}, utilization {:.0}%)",
                self.workers,
                self.worker_utilization() * 100.0,
            ));
        }
        let stage_rows = self.timer.report(self.original_bytes);
        if !stage_rows.is_empty() {
            s.push('\n');
            s.push_str(stage_rows.trim_end_matches('\n'));
        }
        s
    }
}

/// Decompression-side batching: drain a `.cuszb` bundle back to fields
/// in parallel — the mirror of [`BatchCompressor`] over the same
/// [`FanStage`] pipeline. A producer thread streams raw payloads out of
/// the store (one seek+read each, throttled by the bounded queue),
/// `workers` threads decode + decompress against one shared
/// [`Coordinator`], and the calling thread sinks restored fields.
pub struct BatchDecompressor {
    coord: Arc<Coordinator>,
    cfg: BatchConfig,
}

impl BatchDecompressor {
    pub fn new(coord: Arc<Coordinator>, cfg: BatchConfig) -> Self {
        BatchDecompressor { coord, cfg }
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Decompress every field in `store`, handing each restored [`Field`]
    /// to `sink` on the calling thread (completion order), together with
    /// the *store entry name* it was read under — which can differ from
    /// `Field::name` when the entry was added under an overridden name.
    /// Per-entry read or decode failures are collected in the stats, not
    /// fatal; a sink error aborts the drain.
    pub fn drain<S>(&self, store: &Store, mut sink: S) -> Result<DrainStats>
    where
        S: FnMut(&str, Field, &DecompressStats) -> Result<()>,
    {
        let workers = self.cfg.effective_workers();
        let depth = self.cfg.queue_depth.max(1);
        let (tx, rx) = bounded::<(String, Vec<u8>)>(depth);
        let coord = Arc::clone(&self.coord);
        // the drain pool already fans out across fields: split the
        // machine-wide thread budget across the workers so a drain does
        // not multiply the segmented-tail decode — or the fused
        // decode→inverse-Lorenzo→scatter pass — by the worker count.
        // Workers are long-lived, so the fused pass's arena-loaned slab
        // scratch (delta/reconstruction buffers, chunk stitch windows)
        // is allocated once per worker thread and reused across every
        // job of the drain.
        let job_threads = (self.coord.cfg.effective_threads() / workers).max(1);
        let fan = FanStage::try_spawn(rx, workers, depth, "decompress", move |job: (String, Vec<u8>)| {
            obs::global().add(keys::SERVE_QUEUE_DEQUEUED, 1);
            let (name, bytes) = job;
            let mut span = obs::span(keys::SERVE_DECOMPRESS_JOB)
                .with_histogram(obs::global().histogram(keys::HIST_DECOMPRESS_JOB_NS));
            let result = contain_panic("decompress job", || {
                Archive::from_bytes_with_threads(&bytes, job_threads)
                    .and_then(|archive| coord.decompress_with_threads(&archive, job_threads))
            });
            if let Ok((field, _)) = &result {
                // restored bytes — the paper's decompression denominator
                span.add_bytes(field.size_bytes() as u64);
            }
            let ns = span.finish().as_nanos() as u64;
            crate::util::arena::trim_to_watermark(crate::util::arena::DEFAULT_TRIM_WATERMARK);
            (name, result, ns)
        })
        .context("spawning decompress workers")?;
        let names: Vec<String> = store.list().iter().map(|e| e.name.clone()).collect();

        let t0 = Instant::now();
        let mut stats = DrainStats { workers, ..Default::default() };
        let mut sink_err = None;
        let mut producer_panicked = false;
        // the producer borrows `store`, so it runs under a scope; the fan
        // workers own their inputs and need no scoping
        std::thread::scope(|scope| {
            let producer = scope.spawn(move || {
                let mut read_errors: Vec<(String, String)> = Vec::new();
                for name in names {
                    // checked read: payload CRC + header digest, the same
                    // integrity bar as the single-field Store::get path
                    match store.get_bytes_checked(&name) {
                        Ok(bytes) => {
                            if tx.send((name, bytes)).is_err() {
                                break; // pipeline shut down early
                            }
                            obs::global().add(keys::SERVE_QUEUE_ENQUEUED, 1);
                        }
                        Err(e) => read_errors.push((name, format!("{e:#}"))),
                    }
                }
                read_errors
            });
            for (name, result, job_ns) in fan.rx.iter() {
                match result {
                    Ok((field, job_stats)) => {
                        stats.original_bytes += field.size_bytes();
                        stats.timer.merge(&job_stats.timer);
                        if let Err(e) = sink(&name, field, &job_stats) {
                            sink_err = Some(e.context(format!("sink failed on '{name}'")));
                            break;
                        }
                        stats.jobs += 1;
                        stats.job_ns.push(job_ns);
                    }
                    Err(e) => {
                        stats.failed += 1;
                        stats.errors.push((name, format!("{e:#}")));
                    }
                }
            }
            // dropping fan.rx unblocks workers; workers exiting drops the
            // shared input receiver, which unblocks the producer
            fan.join();
            match producer.join() {
                Ok(read_errors) => {
                    for (name, err) in read_errors {
                        stats.failed += 1;
                        stats.errors.push((name, err));
                    }
                }
                Err(_) => producer_panicked = true,
            }
        });
        stats.wall_seconds = t0.elapsed().as_secs_f64();
        match sink_err {
            Some(e) => Err(e),
            None if producer_panicked => Err(anyhow::anyhow!(
                "store reader panicked; results incomplete ({} fields drained)",
                stats.jobs
            )),
            None => Ok(stats),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, CuszConfig, ErrorBound};
    use crate::metrics;
    use crate::testkit::fields::{make, Regime};
    use crate::testkit::tmp_dir;

    const EB: f32 = 1e-2;

    fn coordinator() -> Arc<Coordinator> {
        Arc::new(
            Coordinator::new(CuszConfig {
                backend: BackendKind::Cpu,
                eb: ErrorBound::Abs(EB as f64),
                threads: 1, // job-level parallelism comes from the batch layer
                ..Default::default()
            })
            .unwrap(),
        )
    }

    fn fields(n: usize) -> Vec<Field> {
        (0..n)
            .map(|i| {
                Field::new(
                    format!("f{i:02}"),
                    vec![96, 96],
                    make(Regime::ALL[i % 3], 96 * 96, i as u64),
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn batch_into_store_roundtrips_every_field() {
        let dir = tmp_dir("serve-batch");
        let mut store = Store::create(&dir, 2).unwrap();
        let batch = BatchCompressor::new(
            coordinator(),
            BatchConfig { workers: 3, queue_depth: 2, ..Default::default() },
        );
        let originals = fields(10);
        let stats = batch.run_into_store(originals.clone(), &mut store).unwrap();
        assert_eq!(stats.jobs, 10);
        assert_eq!(stats.failed, 0);
        assert_eq!(store.len(), 10);
        assert!(stats.compression_ratio() > 1.0);
        assert!(stats.wall_seconds > 0.0);
        let coord = batch.coordinator();
        for f in &originals {
            let out = coord.decompress(&store.get(&f.name).unwrap()).unwrap();
            assert_eq!(metrics::verify_error_bound(&f.data, &out.data, EB), None, "{}", f.name);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn service_stats_record_latency_utilization_and_encoder_bytes() {
        let dir = tmp_dir("serve-latency");
        let mut store = Store::create(&dir, 1).unwrap();
        let batch = BatchCompressor::new(
            coordinator(),
            BatchConfig { workers: 2, queue_depth: 2, ..Default::default() },
        );
        let stats = batch.run_into_store(fields(5), &mut store).unwrap();
        assert_eq!(stats.workers, 2);
        assert_eq!(stats.job_ns.len(), 5);
        let (p50, p95, p99) = stats.latency_percentiles().unwrap();
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99);
        let util = stats.worker_utilization();
        assert!(util > 0.0 && util <= 1.0, "utilization {util}");
        let enc_total: usize = stats.encoder_bytes().iter().map(|(_, b)| b).sum();
        assert_eq!(enc_total, stats.compressed_bytes);
        // per-stage rows merged across jobs must cover the compress stages
        let timings = stats.stage_timings();
        assert!(timings.total("total").as_nanos() > 0);
        let report = stats.report();
        assert!(report.contains("p50"), "{report}");
        assert!(report.contains("encoder bytes"), "{report}");
        assert!(report.contains("GB/s"), "{report}");
        // the registry's streaming histogram saw every job too
        let snap = crate::obs::global().snapshot();
        let hist = snap.histogram(crate::obs::keys::HIST_COMPRESS_JOB_NS).unwrap();
        assert!(hist.count >= 5);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn queue_depth_counters_balance_after_a_run() {
        let reg = crate::obs::global();
        let dir = tmp_dir("serve-queue");
        let mut store = Store::create(&dir, 1).unwrap();
        let enq0 = reg.counter_value(keys::SERVE_QUEUE_ENQUEUED);
        let deq0 = reg.counter_value(keys::SERVE_QUEUE_DEQUEUED);
        let batch = BatchCompressor::new(
            coordinator(),
            BatchConfig { workers: 2, queue_depth: 2, ..Default::default() },
        );
        batch.run_into_store(fields(6), &mut store).unwrap();
        // other tests share the global registry, so assert on deltas:
        // this run enqueued >= 6 and, once drained, dequeues match.
        assert!(reg.counter_value(keys::SERVE_QUEUE_ENQUEUED) >= enq0 + 6);
        assert!(reg.counter_value(keys::SERVE_QUEUE_DEQUEUED) >= deq0 + 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sink_error_aborts_without_deadlock() {
        let batch = BatchCompressor::new(
            coordinator(),
            BatchConfig { workers: 2, queue_depth: 1, ..Default::default() },
        );
        let mut seen = 0usize;
        let result = batch.run(fields(50), |_, _| {
            seen += 1;
            if seen >= 3 {
                anyhow::bail!("store full");
            }
            Ok(())
        });
        assert!(result.is_err());
    }

    #[test]
    fn sink_receives_the_single_serialization() {
        // the bytes handed to the sink must be exactly what the archive
        // serializes to — the sink never needs (and never triggers) a
        // second serialization pass
        let batch = BatchCompressor::new(coordinator(), BatchConfig::default());
        let mut checked = 0usize;
        batch
            .run(fields(3), |name, c| {
                assert_eq!(c.archive.header.field_name, name);
                assert_eq!(c.bytes.len(), c.stats.compressed_bytes);
                let reparsed = Archive::from_bytes(&c.bytes).unwrap();
                assert_eq!(reparsed, c.archive);
                checked += 1;
                Ok(())
            })
            .unwrap();
        assert_eq!(checked, 3);
    }

    #[test]
    fn duplicate_names_surface_as_sink_error() {
        let dir = tmp_dir("serve-dup");
        let mut store = Store::create(&dir, 1).unwrap();
        let batch = BatchCompressor::new(coordinator(), BatchConfig::default());
        let mut twice = fields(2);
        twice[1].name = twice[0].name.clone();
        assert!(batch.run_into_store(twice, &mut store).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stats_aggregate_matches_job_sum() {
        let dir = tmp_dir("serve-stats");
        let mut store = Store::create(&dir, 1).unwrap();
        let batch = BatchCompressor::new(coordinator(), BatchConfig { workers: 2, queue_depth: 2, ..Default::default() });
        let stats = batch.run_into_store(fields(6), &mut store).unwrap();
        let sum_orig: usize = stats.per_job.iter().map(|(_, s)| s.original_bytes).sum();
        let sum_comp: usize = stats.per_job.iter().map(|(_, s)| s.compressed_bytes).sum();
        assert_eq!(stats.original_bytes, sum_orig);
        assert_eq!(stats.compressed_bytes, sum_comp);
        assert_eq!(stats.per_job.len(), 6);
        assert!(!stats.report().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn auto_codec_records_per_field_choices() {
        use crate::codec::{CodecSpec, EncoderChoice};
        let dir = tmp_dir("serve-auto");
        let mut store = Store::create(&dir, 2).unwrap();
        let coord = Arc::new(
            Coordinator::new(CuszConfig {
                backend: BackendKind::Cpu,
                eb: ErrorBound::Abs(EB as f64),
                threads: 1,
                codec: CodecSpec { encoder: EncoderChoice::Auto, ..Default::default() },
                ..Default::default()
            })
            .unwrap(),
        );
        let batch = BatchCompressor::new(
            Arc::clone(&coord),
            BatchConfig { workers: 2, queue_depth: 2, ..Default::default() },
        );
        let originals = fields(6);
        let stats = batch.run_into_store(originals.clone(), &mut store).unwrap();
        assert_eq!(stats.jobs, 6);
        // every job's resolved encoder is recorded and tallied
        let counts = stats.encoder_counts();
        let total: usize = counts.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 6);
        for (name, job) in &stats.per_job {
            let archive = store.get(name).unwrap();
            assert_eq!(archive.header.encoder, job.encoder, "{name}");
        }
        assert!(stats.report().contains("encoders"));
        // and the archives still roundtrip
        for f in &originals {
            let out = coord.decompress(&store.get(&f.name).unwrap()).unwrap();
            assert_eq!(metrics::verify_error_bound(&f.data, &out.data, EB), None, "{}", f.name);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn per_chunk_auto_service_tallies_chunk_choices() {
        use crate::codec::{CodecGranularity, CodecSpec, EncoderChoice};
        let dir = tmp_dir("serve-chunk-auto");
        let mut store = Store::create(&dir, 2).unwrap();
        let coord = Arc::new(
            Coordinator::new(CuszConfig {
                backend: BackendKind::Cpu,
                eb: ErrorBound::Abs(EB as f64),
                threads: 1,
                codec: CodecSpec {
                    encoder: EncoderChoice::Auto,
                    granularity: CodecGranularity::Chunk,
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap(),
        );
        let batch = BatchCompressor::new(
            Arc::clone(&coord),
            BatchConfig { workers: 2, queue_depth: 2, ..Default::default() },
        );
        let originals = fields(6);
        let stats = batch.run_into_store(originals.clone(), &mut store).unwrap();
        assert_eq!(stats.jobs, 6);
        // chunk tallies aggregate across jobs and match the per-job sums
        let chunk_counts = stats.chunk_encoder_counts();
        let total: usize = chunk_counts.iter().sum();
        let expected: usize = stats
            .per_job
            .iter()
            .map(|(_, s)| s.chunk_counts.iter().sum::<usize>())
            .sum();
        assert!(total > 0);
        assert_eq!(total, expected);
        assert!(stats.report().contains("chunks"));
        // mixed archives written through the store still roundtrip
        for f in &originals {
            let out = coord.decompress(&store.get(&f.name).unwrap()).unwrap();
            assert_eq!(metrics::verify_error_bound(&f.data, &out.data, EB), None, "{}", f.name);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn auto_compaction_runs_after_drain() {
        let dir = tmp_dir("serve-compact");
        let mut store = Store::create(&dir, 2).unwrap();
        // seed the bundle with dead space before the batch run
        let coord = coordinator();
        let pre = fields(4);
        for f in &pre {
            store.add(&coord.compress(f).unwrap()).unwrap();
        }
        for f in pre.iter().take(3) {
            store.remove(&f.name).unwrap();
        }
        assert!(store.dead_bytes() > 0);

        let batch = BatchCompressor::new(
            Arc::clone(&coord),
            BatchConfig { workers: 2, queue_depth: 2, compact_threshold: 0.1 },
        );
        // fresh names so the batch doesn't collide with the survivor
        let extra: Vec<Field> = fields(4)
            .into_iter()
            .map(|mut f| {
                f.name = format!("new-{}", f.name);
                f
            })
            .collect();
        let stats = batch.run_into_store(extra.clone(), &mut store).unwrap();
        assert!(stats.compacted_bytes > 0, "threshold crossed -> compaction");
        assert_eq!(store.dead_bytes(), 0);
        assert_eq!(store.len(), 5); // 1 survivor + 4 new
        store.verify().unwrap();
        for f in &extra {
            let out = coord.decompress(&store.get(&f.name).unwrap()).unwrap();
            assert_eq!(metrics::verify_error_bound(&f.data, &out.data, EB), None, "{}", f.name);
        }
        assert!(stats.report().contains("auto-compacted"));
        // disabled threshold leaves dead space alone
        let mut store2 = Store::create(tmp_dir("serve-nocompact"), 1).unwrap();
        store2.add(&coord.compress(&fields(1)[0]).unwrap()).unwrap();
        store2.remove("f00").unwrap();
        let batch2 = BatchCompressor::new(coord, BatchConfig::default());
        let one: Vec<Field> = fields(2).into_iter().skip(1).collect();
        let stats2 = batch2.run_into_store(one, &mut store2).unwrap();
        assert_eq!(stats2.compacted_bytes, 0);
        assert!(store2.dead_bytes() > 0);
        let dir2 = store2.dir().to_path_buf();
        drop(store2);
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&dir2).unwrap();
    }

    #[test]
    fn batch_drain_restores_every_field() {
        let dir = tmp_dir("serve-drain");
        let mut store = Store::create(&dir, 2).unwrap();
        let coord = coordinator();
        let batch = BatchCompressor::new(
            Arc::clone(&coord),
            BatchConfig { workers: 3, queue_depth: 2, ..Default::default() },
        );
        let originals = fields(9);
        batch.run_into_store(originals.clone(), &mut store).unwrap();

        let drainer = BatchDecompressor::new(
            Arc::clone(&coord),
            BatchConfig { workers: 3, queue_depth: 2, ..Default::default() },
        );
        let mut restored: Vec<(String, Field)> = Vec::new();
        let stats = drainer
            .drain(&store, |entry_name, field, _| {
                restored.push((entry_name.to_string(), field));
                Ok(())
            })
            .unwrap();
        assert_eq!(stats.jobs, 9);
        assert_eq!(stats.failed, 0);
        assert!(stats.original_bytes > 0);
        assert_eq!(restored.len(), 9);
        // drain-side telemetry: per-job latency, merged stage rows
        assert_eq!(stats.job_ns.len(), 9);
        assert_eq!(stats.workers, 3);
        let (p50, _, p99) = stats.latency_percentiles().unwrap();
        assert!(p50 > 0.0 && p50 <= p99);
        assert!(stats.timer.total("total").as_nanos() > 0);
        assert!(stats.report().contains("p50"));
        for orig in &originals {
            let (entry_name, out) =
                restored.iter().find(|(_, f)| f.name == orig.name).unwrap();
            assert_eq!(entry_name, &orig.name); // entry name matches header name here
            assert_eq!(out.dims, orig.dims);
            assert_eq!(
                metrics::verify_error_bound(&orig.data, &out.data, EB),
                None,
                "{}",
                orig.name
            );
        }
        assert!(!stats.report().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drain_sink_error_aborts_without_deadlock() {
        let dir = tmp_dir("serve-drain-abort");
        let mut store = Store::create(&dir, 1).unwrap();
        let coord = coordinator();
        let batch = BatchCompressor::new(Arc::clone(&coord), BatchConfig::default());
        batch.run_into_store(fields(12), &mut store).unwrap();
        let drainer = BatchDecompressor::new(
            coord,
            BatchConfig { workers: 2, queue_depth: 1, ..Default::default() },
        );
        let mut seen = 0usize;
        let result = drainer.drain(&store, |_, _, _| {
            seen += 1;
            if seen >= 2 {
                anyhow::bail!("out of disk");
            }
            Ok(())
        });
        assert!(result.is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn contain_panic_converts_panics_to_errors() {
        assert_eq!(contain_panic("job", || Ok(7)).unwrap(), 7);
        let err = contain_panic("job", || -> Result<()> { panic!("boom {}", 3) });
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("job panicked"), "{msg}");
        assert!(msg.contains("boom 3"), "{msg}");
        let err = contain_panic("job", || -> Result<()> { panic!("static payload") });
        assert!(format!("{:#}", err.unwrap_err()).contains("static payload"));
    }

    #[test]
    fn poisoned_job_does_not_take_down_the_pool() {
        // regression-lock for the unwrap audit: one panicking job must
        // surface as a per-job error while the fan stage keeps draining
        // the jobs behind it
        let (tx, rx) = bounded::<usize>(2);
        let fan = FanStage::try_spawn(rx, 2, 2, "poison", move |i: usize| {
            contain_panic("poison job", || {
                if i == 3 {
                    panic!("job {i} is poisoned");
                }
                Ok(i * 2)
            })
        })
        .unwrap();
        let feeder = std::thread::spawn(move || {
            for i in 0..8 {
                if tx.send(i).is_err() {
                    break;
                }
            }
        });
        let (mut ok, mut failed) = (0usize, 0usize);
        for result in fan.rx.iter() {
            match result {
                Ok(_) => ok += 1,
                Err(e) => {
                    failed += 1;
                    assert!(format!("{e:#}").contains("poisoned"));
                }
            }
        }
        fan.join();
        feeder.join().unwrap();
        assert_eq!(ok, 7);
        assert_eq!(failed, 1);
    }
}
