//! `serve`: the batched streaming compression front end — the shape the
//! paper's I/O-reduction story takes when many fields arrive faster than
//! one compressor loop can drain them (LCLS-II / HACC campaigns, §1).
//!
//! A [`BatchCompressor`] accepts a stream of [`Field`]s and fans whole-job
//! compression across a bounded [`FanStage`] worker pipeline with
//! backpressure: one producer thread feeds a bounded queue, `workers`
//! threads share a single [`Coordinator`] (one engine, one codebook/config
//! universe — the paper's single-device discipline), and the calling
//! thread is the sink, writing archives into a [`Store`] and folding
//! per-job [`CompressStats`] into service-level [`ServiceStats`].
//!
//! Inside each job the coordinator already parallelizes slab quantization
//! and per-chunk deflate; the batch layer adds job-level concurrency on
//! top. When both are unbounded the core count is oversubscribed, so batch
//! deployments set `CuszConfig::threads` to a small number and let
//! `BatchConfig::workers` cover the cores (see `examples/batch_service.rs`).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::container::Archive;
use crate::coordinator::{CompressStats, Coordinator};
use crate::field::Field;
use crate::store::Store;
use crate::util::pool::{bounded, FanStage};

/// Tuning for the batch front end.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Concurrent compression jobs (whole fields in flight).
    /// 0 = one per available core.
    pub workers: usize,
    /// Bounded queue depth between stages (backpressure: at most
    /// `queue_depth` fields buffered ahead of the workers, and
    /// `queue_depth` archives ahead of the sink).
    pub queue_depth: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { workers: 0, queue_depth: 4 }
    }
}

impl BatchConfig {
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }
}

/// Service-level aggregate over every job of a batch run.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub jobs: usize,
    pub failed: usize,
    pub original_bytes: usize,
    pub compressed_bytes: usize,
    pub n_outliers: usize,
    pub n_verbatim: usize,
    pub huffman_bits: u64,
    pub wall_seconds: f64,
    /// Per-job stats in completion order (not submission order).
    pub per_job: Vec<(String, CompressStats)>,
    /// (field name, error) for jobs whose compression failed.
    pub errors: Vec<(String, String)>,
}

impl ServiceStats {
    pub fn absorb(&mut self, name: &str, stats: &CompressStats) {
        self.jobs += 1;
        self.original_bytes += stats.original_bytes;
        self.compressed_bytes += stats.compressed_bytes;
        self.n_outliers += stats.n_outliers;
        self.n_verbatim += stats.n_verbatim;
        self.huffman_bits += stats.huffman_bits;
        self.per_job.push((name.to_string(), stats.clone()));
    }

    pub fn compression_ratio(&self) -> f64 {
        self.original_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    /// End-to-end service throughput against original bytes (paper
    /// footnote 4 convention), including queueing and store writes.
    pub fn throughput_gbps(&self) -> f64 {
        self.original_bytes as f64 / self.wall_seconds.max(1e-12) / 1e9
    }

    pub fn report(&self) -> String {
        format!(
            "jobs {} ok / {} failed  {:.2} MB -> {:.2} MB  CR {:.2}x  \
             {:.3} GB/s end-to-end  (outliers {}, verbatim {}, wall {:.3}s)",
            self.jobs,
            self.failed,
            self.original_bytes as f64 / 1e6,
            self.compressed_bytes as f64 / 1e6,
            self.compression_ratio(),
            self.throughput_gbps(),
            self.n_outliers,
            self.n_verbatim,
            self.wall_seconds,
        )
    }
}

/// Batched streaming compressor: one shared engine, many jobs in flight.
pub struct BatchCompressor {
    coord: Arc<Coordinator>,
    cfg: BatchConfig,
}

impl BatchCompressor {
    pub fn new(coord: Arc<Coordinator>, cfg: BatchConfig) -> Self {
        BatchCompressor { coord, cfg }
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Stream `fields` through the worker pipeline, handing each finished
    /// archive (with its stats) to `sink` on the calling thread. A sink
    /// error aborts the run (producer and workers unwind via channel
    /// hang-up); per-job compression errors are collected, not fatal.
    pub fn run<I, S>(&self, fields: I, mut sink: S) -> Result<ServiceStats>
    where
        I: IntoIterator<Item = Field>,
        I::IntoIter: Send + 'static,
        S: FnMut(&str, Archive, &CompressStats) -> Result<()>,
    {
        let workers = self.cfg.effective_workers();
        let depth = self.cfg.queue_depth.max(1);

        let (tx, rx) = bounded::<Field>(depth);
        let coord = Arc::clone(&self.coord);
        let fan = FanStage::spawn(rx, workers, depth, "compress", move |field: Field| {
            let name = field.name.clone();
            (name, coord.compress_with_stats(&field))
        });
        let fields = fields.into_iter();
        let producer = std::thread::Builder::new()
            .name("field-producer".into())
            .spawn(move || {
                for f in fields {
                    if tx.send(f).is_err() {
                        break; // pipeline shut down early
                    }
                }
            })
            .context("spawning field producer")?;

        let t0 = Instant::now();
        let mut stats = ServiceStats::default();
        let mut sink_err = None;
        for (name, result) in fan.rx.iter() {
            match result {
                Ok((archive, job_stats)) => {
                    if let Err(e) = sink(&name, archive, &job_stats) {
                        sink_err = Some(e.context(format!("sink failed on '{name}'")));
                        break;
                    }
                    stats.absorb(&name, &job_stats);
                }
                Err(e) => {
                    stats.failed += 1;
                    stats.errors.push((name, format!("{e:#}")));
                }
            }
        }
        stats.wall_seconds = t0.elapsed().as_secs_f64();
        // Dropping fan.rx (join) unblocks workers; workers dropping the
        // shared input receiver unblocks the producer.
        fan.join();
        let producer_panicked = producer.join().is_err();
        match sink_err {
            Some(e) => Err(e),
            None if producer_panicked => Err(anyhow::anyhow!(
                "field producer panicked; results incomplete ({} jobs finished)",
                stats.jobs
            )),
            None => Ok(stats),
        }
    }

    /// Convenience: run the batch and write every archive into `store`
    /// under its field name. The store's index is committed once at the
    /// end of the run (payload appends are still immediate), so ingesting
    /// N fields costs one index rewrite instead of N.
    pub fn run_into_store<I>(&self, fields: I, store: &mut Store) -> Result<ServiceStats>
    where
        I: IntoIterator<Item = Field>,
        I::IntoIter: Send + 'static,
    {
        store.set_deferred_index(true)?;
        let result = self.run(fields, |_name, archive, _stats| store.add(&archive).map(|_| ()));
        // commit whatever landed, even if the run errored mid-stream
        let commit = store.set_deferred_index(false);
        let stats = result?;
        commit?;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, CuszConfig, ErrorBound};
    use crate::metrics;
    use crate::testkit::fields::{make, Regime};
    use crate::testkit::tmp_dir;

    const EB: f32 = 1e-2;

    fn coordinator() -> Arc<Coordinator> {
        Arc::new(
            Coordinator::new(CuszConfig {
                backend: BackendKind::Cpu,
                eb: ErrorBound::Abs(EB as f64),
                threads: 1, // job-level parallelism comes from the batch layer
                ..Default::default()
            })
            .unwrap(),
        )
    }

    fn fields(n: usize) -> Vec<Field> {
        (0..n)
            .map(|i| {
                Field::new(
                    format!("f{i:02}"),
                    vec![96, 96],
                    make(Regime::ALL[i % 3], 96 * 96, i as u64),
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn batch_into_store_roundtrips_every_field() {
        let dir = tmp_dir("serve-batch");
        let mut store = Store::create(&dir, 2).unwrap();
        let batch = BatchCompressor::new(
            coordinator(),
            BatchConfig { workers: 3, queue_depth: 2 },
        );
        let originals = fields(10);
        let stats = batch.run_into_store(originals.clone(), &mut store).unwrap();
        assert_eq!(stats.jobs, 10);
        assert_eq!(stats.failed, 0);
        assert_eq!(store.len(), 10);
        assert!(stats.compression_ratio() > 1.0);
        assert!(stats.wall_seconds > 0.0);
        let coord = batch.coordinator();
        for f in &originals {
            let out = coord.decompress(&store.get(&f.name).unwrap()).unwrap();
            assert_eq!(metrics::verify_error_bound(&f.data, &out.data, EB), None, "{}", f.name);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sink_error_aborts_without_deadlock() {
        let batch = BatchCompressor::new(
            coordinator(),
            BatchConfig { workers: 2, queue_depth: 1 },
        );
        let mut seen = 0usize;
        let result = batch.run(fields(50), |_, _, _| {
            seen += 1;
            if seen >= 3 {
                anyhow::bail!("store full");
            }
            Ok(())
        });
        assert!(result.is_err());
    }

    #[test]
    fn duplicate_names_surface_as_sink_error() {
        let dir = tmp_dir("serve-dup");
        let mut store = Store::create(&dir, 1).unwrap();
        let batch = BatchCompressor::new(coordinator(), BatchConfig::default());
        let mut twice = fields(2);
        twice[1].name = twice[0].name.clone();
        assert!(batch.run_into_store(twice, &mut store).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stats_aggregate_matches_job_sum() {
        let dir = tmp_dir("serve-stats");
        let mut store = Store::create(&dir, 1).unwrap();
        let batch = BatchCompressor::new(coordinator(), BatchConfig { workers: 2, queue_depth: 2 });
        let stats = batch.run_into_store(fields(6), &mut store).unwrap();
        let sum_orig: usize = stats.per_job.iter().map(|(_, s)| s.original_bytes).sum();
        let sum_comp: usize = stats.per_job.iter().map(|(_, s)| s.compressed_bytes).sum();
        assert_eq!(stats.original_bytes, sum_orig);
        assert_eq!(stats.compressed_bytes, sum_comp);
        assert_eq!(stats.per_job.len(), 6);
        assert!(!stats.report().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
