//! `cusz loadgen`: the traffic generator for the serve daemon — N
//! simulated clients over persistent connections driving a mixed
//! put/get workload with steady, bursty, or diurnal arrival patterns,
//! reporting latency percentiles and throughput as a
//! `cusz-bench-serve/v1` JSON artifact (`BENCH_serve.json`, validated
//! in CI like `BENCH_pipeline.json`).
//!
//! Semantics worth knowing when reading the numbers:
//!
//! * Each client keeps one connection and reconnects on transport
//!   errors (counted in `reconnects`); a `BUSY` shed is retried with
//!   exponential backoff up to `busy_retries` times and counted per
//!   attempt, so the `busy` column measures how often admission control
//!   fired, while `failed` measures work that never landed.
//! * Latency samples (`p50/p95/p99`) are the round-trip of the
//!   *successful* attempt only — shed-and-retried time shows up in
//!   throughput, not in the percentile columns.
//! * PUTs are upserts of fields the client generates locally
//!   (`testkit::fields` regimes, deterministic from `seed`); GETs pick
//!   uniformly among names that client has already stored, so every GET
//!   has a well-defined expected answer.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::field::Field;
use crate::testkit::fields::{make, Regime};
use crate::util::prng::Rng;

use super::wire::{Client, GetOutcome, PutOutcome};

/// Inter-arrival shaping for the simulated clients.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Jittered constant rate.
    Steady,
    /// Back-to-back bursts separated by long gaps (mean rate ~= steady).
    Bursty,
    /// One sinusoidal "day" across the run: rate swings 0..2x the base.
    Diurnal,
}

impl ArrivalPattern {
    pub fn parse(s: &str) -> Result<ArrivalPattern> {
        match s {
            "steady" => Ok(ArrivalPattern::Steady),
            "bursty" => Ok(ArrivalPattern::Bursty),
            "diurnal" => Ok(ArrivalPattern::Diurnal),
            other => bail!("unknown arrival pattern '{other}' (steady|bursty|diurnal)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ArrivalPattern::Steady => "steady",
            ArrivalPattern::Bursty => "bursty",
            ArrivalPattern::Diurnal => "diurnal",
        }
    }
}

/// Load-generator tuning; the `cusz loadgen` CLI maps onto every field.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Daemon address, e.g. `127.0.0.1:9599`.
    pub addr: String,
    /// Simulated clients (threads, one persistent connection each).
    pub clients: usize,
    /// Total requests across all clients.
    pub requests: usize,
    /// Fraction of requests that are PUTs (a client's first request is
    /// always a PUT so its GETs have something to read).
    pub put_ratio: f64,
    pub pattern: ArrivalPattern,
    /// Elements per generated 1-D field (4 bytes each).
    pub elems: usize,
    /// Base inter-arrival delay per client (0 = closed-loop, as fast as
    /// the daemon answers).
    pub pace: Duration,
    pub seed: u64,
    /// BUSY-shed retries per request before counting it failed.
    pub busy_retries: usize,
    /// Connect attempts (50 ms apart) — absorbs daemon start-up races.
    pub connect_retries: usize,
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    /// Client-side wire body cap, mirroring the daemon's `--max-payload`:
    /// responses declaring a larger body are rejected before allocation.
    /// Keep this at least the daemon's limit or large GETs will fail
    /// client-side.
    pub max_body_bytes: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:9599".into(),
            clients: 8,
            requests: 256,
            put_ratio: 0.5,
            pattern: ArrivalPattern::Steady,
            elems: 1 << 16,
            pace: Duration::ZERO,
            seed: 42,
            busy_retries: 8,
            connect_retries: 40,
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            max_body_bytes: super::wire::Limits::default().max_body_bytes,
        }
    }
}

/// Per-operation tally (one for PUT, one for GET).
#[derive(Debug, Clone, Default)]
pub struct OpStats {
    /// Wire round-trips attempted (includes BUSY retries).
    pub attempts: usize,
    pub ok: usize,
    /// BUSY responses observed (each is one shed admission).
    pub busy: usize,
    pub not_found: usize,
    /// Requests that never succeeded (error response, retries exhausted,
    /// or transport loss).
    pub failed: usize,
    /// Requests abandoned because the daemon reported it was draining.
    pub shutdown: usize,
    /// Field payload bytes moved by successful operations.
    pub bytes: u64,
    /// Wall nanoseconds of each successful round-trip.
    pub ns: Vec<u64>,
}

impl OpStats {
    fn merge(&mut self, other: &OpStats) {
        self.attempts += other.attempts;
        self.ok += other.ok;
        self.busy += other.busy;
        self.not_found += other.not_found;
        self.failed += other.failed;
        self.shutdown += other.shutdown;
        self.bytes += other.bytes;
        self.ns.extend_from_slice(&other.ns);
    }

    /// (p50, p95, p99) in milliseconds over successful round-trips.
    pub fn latency_percentiles(&self) -> Option<(f64, f64, f64)> {
        if self.ns.is_empty() {
            return None;
        }
        let mut v = self.ns.clone();
        v.sort_unstable();
        Some((
            super::percentile_ms(&v, 0.50),
            super::percentile_ms(&v, 0.95),
            super::percentile_ms(&v, 0.99),
        ))
    }

    pub fn mean_ms(&self) -> f64 {
        if self.ns.is_empty() {
            return 0.0;
        }
        self.ns.iter().sum::<u64>() as f64 / self.ns.len() as f64 / 1e6
    }
}

/// Aggregate result of a load run; serializes to `cusz-bench-serve/v1`.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub addr: String,
    pub clients: usize,
    pub requests: usize,
    pub put_ratio: f64,
    pub pattern: &'static str,
    pub elems: usize,
    pub put: OpStats,
    pub get: OpStats,
    pub reconnects: usize,
    pub wall_seconds: f64,
    /// Every name whose PUT the daemon acked (`Stored`), across all
    /// clients. A durability check after a daemon crash asserts exactly
    /// these names survive; not serialized into the JSON report.
    pub acked_names: Vec<String>,
}

impl LoadReport {
    pub const SCHEMA: &'static str = "cusz-bench-serve/v1";

    /// Successful operations per second of wall time.
    pub fn throughput_rps(&self) -> f64 {
        (self.put.ok + self.get.ok) as f64 / self.wall_seconds.max(1e-12)
    }

    pub fn to_json(&self) -> String {
        fn num(v: f64) -> String {
            if v.is_finite() { format!("{v:.4}") } else { "0".into() }
        }
        fn clean(v: &str) -> String {
            v.chars()
                .filter(|c| c.is_ascii_alphanumeric() || ".:-_[]".contains(*c))
                .collect()
        }
        fn op_json(op: &OpStats, extra: &str) -> String {
            let (p50, p95, p99) = op.latency_percentiles().unwrap_or((0.0, 0.0, 0.0));
            format!(
                "{{\"attempts\": {}, \"ok\": {}, \"busy\": {}, \"failed\": {}, \
                 \"shutdown\": {}{extra}, \"mb\": {}, \"mean_ms\": {}, \
                 \"p50_ms\": {}, \"p95_ms\": {}, \"p99_ms\": {}}}",
                op.attempts,
                op.ok,
                op.busy,
                op.failed,
                op.shutdown,
                num(op.bytes as f64 / 1e6),
                num(op.mean_ms()),
                num(p50),
                num(p95),
                num(p99),
            )
        }
        let host = std::env::var("HOSTNAME").map(|v| clean(&v)).unwrap_or_default();
        let commit = std::env::var("GITHUB_SHA").map(|v| clean(&v)).unwrap_or_default();
        format!(
            "{{\n  \"schema\": \"{}\",\n  \"generated_by\": {{\"host\": \"{}\", \
             \"commit\": \"{}\", \"placeholder\": false}},\n  \
             \"addr\": \"{}\",\n  \"clients\": {},\n  \"requests\": {},\n  \
             \"put_ratio\": {},\n  \"pattern\": \"{}\",\n  \"elems\": {},\n  \
             \"wall_seconds\": {},\n  \"throughput_rps\": {},\n  \
             \"reconnects\": {},\n  \"put\": {},\n  \"get\": {}\n}}\n",
            Self::SCHEMA,
            if host.is_empty() { "unknown".into() } else { host },
            if commit.is_empty() { "unknown".into() } else { commit },
            clean(&self.addr),
            self.clients,
            self.requests,
            num(self.put_ratio),
            self.pattern,
            self.elems,
            num(self.wall_seconds),
            num(self.throughput_rps()),
            self.reconnects,
            op_json(&self.put, ""),
            op_json(&self.get, &format!(", \"not_found\": {}", self.get.not_found)),
        )
    }

    pub fn report(&self) -> String {
        let fmt_op = |label: &str, op: &OpStats| {
            let (p50, p95, p99) = op.latency_percentiles().unwrap_or((0.0, 0.0, 0.0));
            format!(
                "{label}: {} ok / {} busy / {} failed  {:.2} MB  \
                 latency ms  p50 {p50:.3}  p95 {p95:.3}  p99 {p99:.3}",
                op.ok,
                op.busy,
                op.failed,
                op.bytes as f64 / 1e6,
            )
        };
        format!(
            "loadgen: {} clients x {} requests ({} pattern, {:.0}% puts)  \
             {:.1} req/s over {:.3}s, {} reconnects\n{}\n{}",
            self.clients,
            self.requests,
            self.pattern,
            self.put_ratio * 100.0,
            self.throughput_rps(),
            self.wall_seconds,
            self.reconnects,
            fmt_op("puts", &self.put),
            fmt_op("gets", &self.get),
        )
    }
}

#[derive(Debug, Default)]
struct Tally {
    put: OpStats,
    get: OpStats,
    reconnects: usize,
    /// Names whose PUT ack this client saw (drives the GET mix and the
    /// post-crash durability audit).
    acked: Vec<String>,
}

enum Step {
    Continue,
    Stop,
}

/// Run the load against a live daemon and aggregate every client tally.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.clients == 0 {
        bail!("loadgen needs at least one client");
    }
    if cfg.elems == 0 {
        bail!("loadgen needs at least one element per field");
    }
    if !(0.0..=1.0).contains(&cfg.put_ratio) {
        bail!("put ratio must be in [0, 1], got {}", cfg.put_ratio);
    }
    let mut report = LoadReport {
        addr: cfg.addr.clone(),
        clients: cfg.clients,
        requests: cfg.requests,
        put_ratio: cfg.put_ratio,
        pattern: cfg.pattern.name(),
        elems: cfg.elems,
        ..Default::default()
    };
    if cfg.requests == 0 {
        // connectivity check only (used by readiness probes)
        let mut client = connect_with_retry(cfg)?;
        client.ping().context("pinging daemon")?;
        return Ok(report);
    }
    let t0 = Instant::now();
    let tallies: Vec<Tally> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|i| scope.spawn(move || client_loop(cfg, i)))
            .collect();
        // a panicking client thread forfeits its tally but must not sink
        // the whole run
        handles.into_iter().map(|h| h.join().unwrap_or_default()).collect()
    });
    report.wall_seconds = t0.elapsed().as_secs_f64();
    for t in tallies {
        report.put.merge(&t.put);
        report.get.merge(&t.get);
        report.reconnects += t.reconnects;
        report.acked_names.extend(t.acked);
    }
    Ok(report)
}

fn connect_with_retry(cfg: &LoadgenConfig) -> Result<Client> {
    let mut last_err = None;
    let limits = super::wire::Limits {
        max_body_bytes: cfg.max_body_bytes,
        ..super::wire::Limits::default()
    };
    for _ in 0..cfg.connect_retries.max(1) {
        match Client::connect(&cfg.addr, cfg.read_timeout, cfg.write_timeout) {
            Ok(c) => return Ok(c.with_limits(limits.clone())),
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    Err(last_err
        .unwrap_or_else(|| anyhow::anyhow!("no connect attempts made"))
        .context(format!("connecting to daemon at {}", cfg.addr)))
}

/// Inter-arrival delay for request `progress` (0..1) of a client's run.
fn pace_delay(pattern: ArrivalPattern, progress: f64, base: Duration, rng: &mut Rng) -> Duration {
    if base.is_zero() {
        return Duration::ZERO;
    }
    let jitter = 0.5 + rng.f64(); // 0.5..1.5
    let scale = match pattern {
        ArrivalPattern::Steady => 1.0,
        // ~1 arrival in 8 pays an 8x gap; the rest are back-to-back
        ArrivalPattern::Bursty => {
            if rng.f64() < 0.125 {
                8.0
            } else {
                0.0
            }
        }
        ArrivalPattern::Diurnal => 1.0 + (progress * std::f64::consts::TAU).sin(),
    };
    Duration::from_secs_f64((base.as_secs_f64() * scale * jitter).max(0.0))
}

fn client_loop(cfg: &LoadgenConfig, client_idx: usize) -> Tally {
    let mut tally = Tally::default();
    let mut rng =
        Rng::new(cfg.seed ^ 0x9e3779b97f4a7c15u64.wrapping_mul(client_idx as u64 + 1));
    let mut client = match connect_with_retry(cfg) {
        Ok(c) => c,
        Err(_) => {
            // daemon unreachable: every planned request of this client
            // counts as failed so the report shows the outage
            tally.put.failed += per_client_requests(cfg, client_idx);
            return tally;
        }
    };
    let n = per_client_requests(cfg, client_idx);
    let mut names: Vec<String> = Vec::new();
    for k in 0..n {
        let delay = pace_delay(cfg.pattern, k as f64 / n.max(1) as f64, cfg.pace, &mut rng);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        let is_put = names.is_empty() || (rng.f64() < cfg.put_ratio);
        let step = if is_put {
            let name = format!("lg-{client_idx}-{}", names.len());
            let regime = Regime::ALL[(client_idx + names.len()) % Regime::ALL.len()];
            let data = make(regime, cfg.elems, cfg.seed + (client_idx * 7919 + k) as u64);
            // dims/data lengths agree by construction
            let field = Field { name: name.clone(), dims: vec![cfg.elems], data };
            do_put(cfg, &mut client, &field, &mut tally, &mut names)
        } else {
            let name = names[rng.below(names.len() as u64) as usize].clone();
            do_get(cfg, &mut client, &name, &mut tally)
        };
        if matches!(step, Step::Stop) {
            break;
        }
    }
    tally.acked = names;
    tally
}

fn per_client_requests(cfg: &LoadgenConfig, client_idx: usize) -> usize {
    let base = cfg.requests / cfg.clients;
    let extra = usize::from(client_idx < cfg.requests % cfg.clients);
    base + extra
}

fn do_put(
    cfg: &LoadgenConfig,
    client: &mut Client,
    field: &Field,
    tally: &mut Tally,
    names: &mut Vec<String>,
) -> Step {
    for attempt in 0..=cfg.busy_retries {
        tally.put.attempts += 1;
        let t0 = Instant::now();
        match client.put(field) {
            Ok(PutOutcome::Stored { .. }) => {
                tally.put.ok += 1;
                tally.put.ns.push(t0.elapsed().as_nanos() as u64);
                tally.put.bytes += field.size_bytes() as u64;
                names.push(field.name.clone());
                return Step::Continue;
            }
            Ok(PutOutcome::Busy) => {
                tally.put.busy += 1;
                if attempt == cfg.busy_retries {
                    break;
                }
                std::thread::sleep(backoff(attempt));
            }
            Ok(PutOutcome::ShuttingDown) => {
                tally.put.shutdown += 1;
                return Step::Stop;
            }
            Ok(PutOutcome::Failed(_)) => {
                tally.put.failed += 1;
                return Step::Continue;
            }
            Err(_) => {
                // transport loss: reconnect and retry (PUT is an upsert,
                // so at-least-once delivery is safe)
                tally.reconnects += 1;
                match connect_with_retry(cfg) {
                    Ok(c) => *client = c,
                    Err(_) => {
                        tally.put.failed += 1;
                        return Step::Stop;
                    }
                }
                if attempt == cfg.busy_retries {
                    break;
                }
            }
        }
    }
    tally.put.failed += 1;
    Step::Continue
}

fn do_get(cfg: &LoadgenConfig, client: &mut Client, name: &str, tally: &mut Tally) -> Step {
    for attempt in 0..=cfg.busy_retries {
        tally.get.attempts += 1;
        let t0 = Instant::now();
        match client.get(name) {
            Ok(GetOutcome::Field(field)) => {
                tally.get.ok += 1;
                tally.get.ns.push(t0.elapsed().as_nanos() as u64);
                tally.get.bytes += field.size_bytes() as u64;
                return Step::Continue;
            }
            Ok(GetOutcome::Busy) => {
                tally.get.busy += 1;
                if attempt == cfg.busy_retries {
                    break;
                }
                std::thread::sleep(backoff(attempt));
            }
            Ok(GetOutcome::ShuttingDown) => {
                tally.get.shutdown += 1;
                return Step::Stop;
            }
            Ok(GetOutcome::NotFound) => {
                // should be impossible (we only GET names we stored);
                // count it so the report surfaces the anomaly
                tally.get.not_found += 1;
                return Step::Continue;
            }
            Ok(GetOutcome::Quarantined) | Ok(GetOutcome::Failed(_)) => {
                tally.get.failed += 1;
                return Step::Continue;
            }
            Err(_) => {
                tally.reconnects += 1;
                match connect_with_retry(cfg) {
                    Ok(c) => *client = c,
                    Err(_) => {
                        tally.get.failed += 1;
                        return Step::Stop;
                    }
                }
                if attempt == cfg.busy_retries {
                    break;
                }
            }
        }
    }
    tally.get.failed += 1;
    Step::Continue
}

fn backoff(attempt: usize) -> Duration {
    Duration::from_millis(1u64 << attempt.min(6))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_parse_roundtrips() {
        for p in [ArrivalPattern::Steady, ArrivalPattern::Bursty, ArrivalPattern::Diurnal] {
            assert_eq!(ArrivalPattern::parse(p.name()).unwrap(), p);
        }
        assert!(ArrivalPattern::parse("nope").is_err());
    }

    #[test]
    fn report_json_carries_schema_and_percentiles() {
        let mut report = LoadReport {
            addr: "127.0.0.1:9599".into(),
            clients: 2,
            requests: 8,
            put_ratio: 0.5,
            pattern: "bursty",
            elems: 64,
            wall_seconds: 1.0,
            ..Default::default()
        };
        report.put.ok = 4;
        report.put.attempts = 5;
        report.put.busy = 1;
        report.put.ns = vec![1_000_000, 2_000_000, 3_000_000, 4_000_000];
        report.get.ok = 4;
        report.get.ns = vec![500_000; 4];
        let json = report.to_json();
        assert!(json.contains("\"schema\": \"cusz-bench-serve/v1\""), "{json}");
        assert!(json.contains("\"p99_ms\""), "{json}");
        assert!(json.contains("\"not_found\": 0"), "{json}");
        assert!(json.contains("\"throughput_rps\": 8.0000"), "{json}");
        let (p50, p95, p99) = report.put.latency_percentiles().unwrap();
        assert!(p50 <= p95 && p95 <= p99);
        assert!(report.report().contains("p50"));
    }

    #[test]
    fn pace_delay_is_zero_for_closed_loop() {
        let mut rng = Rng::new(7);
        for pattern in [ArrivalPattern::Steady, ArrivalPattern::Bursty, ArrivalPattern::Diurnal] {
            assert_eq!(pace_delay(pattern, 0.5, Duration::ZERO, &mut rng), Duration::ZERO);
        }
        // bounded above for nonzero base
        let d = pace_delay(ArrivalPattern::Diurnal, 0.25, Duration::from_millis(2), &mut rng);
        assert!(d <= Duration::from_millis(2 * 2 * 2));
    }

    #[test]
    fn per_client_split_covers_every_request() {
        let cfg = LoadgenConfig { clients: 3, requests: 10, ..Default::default() };
        let total: usize = (0..3).map(|i| per_client_requests(&cfg, i)).sum();
        assert_eq!(total, 10);
    }
}
