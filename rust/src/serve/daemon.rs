//! `cusz serve --daemon`: the long-running socket front end over the
//! batch-serving machinery — persistent TCP connections speaking the
//! [`super::wire`] frame protocol, a bounded job queue feeding a shared
//! worker pool, and a graceful drain that finishes every accepted job
//! before the process exits.
//!
//! ## Thread architecture
//!
//! ```text
//! acceptor (1)        non-blocking accept + 5ms shutdown poll; sheds
//!                     connections above `max_connections` with BUSY
//! connection (<=N)    one per live client: parse frame -> try_send job
//!                     -> await its reply channel -> write response
//! worker (W)          shared pool draining the bounded job queue:
//!                     compress+store (PUT) or load+decompress (GET)
//! ```
//!
//! ## Admission control and overload
//!
//! The job queue is a `sync_channel(queue_depth)`; connection threads
//! submit with `try_send`, so a full queue is an immediate `BUSY`
//! response — the daemon never buffers unbounded work and never blocks a
//! connection behind another client's backlog. Once a job is accepted
//! (enqueued), it is never dropped: the connection thread waits on the
//! job's reply channel, so a connection cannot close (and the drain
//! cannot finish) before every accepted job has been processed and,
//! for PUTs, committed to the store.
//!
//! ## Graceful drain
//!
//! `SIGTERM`/`SIGINT` (via [`install_signal_drain`]), a wire `SHUTDOWN`
//! frame, or [`DaemonHandle::trigger_drain`] all set one flag. The
//! acceptor stops accepting and closes the listener; connection threads
//! close as soon as their in-flight request is answered (idle ones
//! within one read-timeout); dropping the master job sender lets the
//! workers drain the remaining queue and exit; stats are finalized last.
//! Every job whose `OK` a client saw is durable in the store.
//!
//! ## Failure containment
//!
//! Worker jobs run under [`super::contain_panic`]: a panicking or
//! poisoned job becomes a per-request `SERVER_ERROR` response, never a
//! dead worker or a wedged drain. A poisoned store lock is likewise a
//! per-request error — the daemon stays up.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::container::Archive;
use crate::coordinator::{CompressStats, Coordinator, StreamHint};
use crate::obs::{self, keys};
use crate::store::Store;
use crate::util::arena;
use crate::util::govern::{MemoryGovernor, Reservation};
use crate::util::pool;

use super::wire::{self, Opcode, RawResponse, RequestHeader, Status, WireError};
use super::{contain_panic, ServiceStats};

/// Process-global drain flag, set by the signal handler installed with
/// [`install_signal_drain`]. Checked by every daemon's acceptor loop.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Install a `SIGTERM`/`SIGINT` handler that requests a graceful drain
/// (async-signal-safe: one atomic store). Called by the `cusz serve
/// --daemon` CLI path; library embedders and tests use
/// [`DaemonHandle::trigger_drain`] instead. No-op off Unix.
pub fn install_signal_drain() {
    #[cfg(unix)]
    {
        unsafe extern "C" fn on_signal(_sig: i32) {
            SIGNAL_DRAIN.store(true, Ordering::SeqCst);
        }
        // minimal in-tree libc binding: the return value (previous
        // handler) is pointer-sized and unused
        extern "C" {
            fn signal(signum: i32, handler: unsafe extern "C" fn(i32)) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }
}

/// Whether a process-level drain signal has been received.
pub fn drain_requested() -> bool {
    SIGNAL_DRAIN.load(Ordering::SeqCst)
}

/// Daemon tuning. Defaults suit tests and smoke runs; the CLI maps its
/// flags onto every field except the `fault_*` hooks, which exist only
/// for the fault-injection test battery.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Worker threads draining the job queue (0 = one per core).
    pub workers: usize,
    /// Bounded job-queue depth; a full queue sheds with `BUSY`.
    pub queue_depth: usize,
    /// Concurrent connections; excess connects are answered `BUSY` and
    /// dropped without a handler thread.
    pub max_connections: usize,
    /// Per-connection socket read timeout (bounds slow-loris writers and
    /// idle connections; also the drain-latency bound for idle conns).
    pub read_timeout: Duration,
    /// Per-connection socket write timeout (bounds unread responses).
    pub write_timeout: Duration,
    /// Wire-parser allocation bounds.
    pub limits: wire::Limits,
    /// Process-wide memory budget for admitted work, bytes. Each PUT/GET
    /// reserves an estimated working-set cost *before* its body is read
    /// (sized from the already-limit-checked frame header); a request
    /// that would push the aggregate past the budget is shed with `BUSY`
    /// — admitted work is never dropped. `None` disables byte-budget
    /// admission (the count gates — queue depth, connection cap — still
    /// apply). The CLI default is half of detected RAM
    /// ([`crate::util::govern::default_budget`]).
    pub mem_budget: Option<u64>,
    /// Test-only fault injection: a PUT under this name panics inside
    /// the worker (proves panic containment end to end).
    pub fault_panic_name: Option<String>,
    /// Test-only fault injection: every PUT sleeps this long before
    /// compressing (makes overload and drain races deterministic).
    pub fault_put_delay: Option<Duration>,
    /// Background scrubber cadence: every interval, one stored entry is
    /// CRC-verified (round-robin); a corrupt payload is pulled into
    /// `quarantine/` and its later GETs answer `QUARANTINED` while the
    /// daemon keeps serving. `None` disables the scrubber. The one-entry
    /// -per-tick pace rate-limits the extra read I/O, and the store lock
    /// is held only for that single check.
    pub scrub_interval: Option<Duration>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            workers: 0,
            queue_depth: 8,
            max_connections: 64,
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            limits: wire::Limits::default(),
            mem_budget: None,
            fault_panic_name: None,
            fault_put_delay: None,
            scrub_interval: None,
        }
    }
}

/// Aggregate daemon statistics, finalized when the drain completes. The
/// PUT side is a full [`ServiceStats`] (same per-job absorption as the
/// batch path), so `latency_percentiles`, encoder tallies, and the rest
/// of the service-level readout apply unchanged.
#[derive(Debug, Clone, Default)]
pub struct DaemonStats {
    /// Connections accepted (including ones shed at the connection cap).
    pub connections: usize,
    /// Request frames parsed across all connections.
    pub requests: usize,
    /// Compress-side aggregate (jobs, bytes, per-job latency, errors).
    pub put: ServiceStats,
    /// Successful GETs.
    pub gets: usize,
    /// GETs that failed (read, CRC, or decode error) — not-found excluded.
    pub gets_failed: usize,
    /// GETs for names not in the store.
    pub gets_not_found: usize,
    /// Restored (decompressed) bytes served by successful GETs.
    pub restored_bytes: usize,
    /// Per-GET wall nanoseconds, completion order (successful only).
    pub get_ns: Vec<u64>,
    /// Jobs/connections shed by admission control (full queue or
    /// connection cap).
    pub shed: usize,
    /// Frames rejected as malformed.
    pub bad_requests: usize,
    /// Worker threads the daemon ran with.
    pub workers: usize,
    /// Listener-open to drain-complete wall time.
    pub wall_seconds: f64,
}

impl DaemonStats {
    /// GET latency (p50, p95, p99) in milliseconds over the recorded
    /// samples. `None` until a GET completes.
    pub fn get_latency_percentiles(&self) -> Option<(f64, f64, f64)> {
        if self.get_ns.is_empty() {
            return None;
        }
        let mut v = self.get_ns.clone();
        v.sort_unstable();
        Some((
            super::percentile_ms(&v, 0.50),
            super::percentile_ms(&v, 0.95),
            super::percentile_ms(&v, 0.99),
        ))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "daemon: {} connections, {} requests, shed {}, bad {}  \
             (workers {}, wall {:.3}s)",
            self.connections,
            self.requests,
            self.shed,
            self.bad_requests,
            self.workers,
            self.wall_seconds,
        );
        s.push_str(&format!(
            "\ngets: {} ok / {} failed / {} not found  {:.2} MB restored",
            self.gets,
            self.gets_failed,
            self.gets_not_found,
            self.restored_bytes as f64 / 1e6,
        ));
        if let Some((p50, p95, p99)) = self.get_latency_percentiles() {
            s.push_str(&format!("  latency ms  p50 {p50:.3}  p95 {p95:.3}  p99 {p99:.3}"));
        }
        s.push_str(&format!("\nputs: {}", self.put.report()));
        s
    }
}

/// One accepted job. The reply channel has depth 1, so worker sends
/// never block; a connection that died mid-wait just drops the receiver
/// and the send is ignored (the job's effect — a store commit — stands).
///
/// A PUT carries the raw wire body (LE bytes, dims already validated by
/// [`wire::parse_field_dims`]) rather than a decoded `Vec<f32>`: the
/// worker streams the compressor straight over the byte region, halving
/// the job's working set. The memory [`Reservation`] made at admission
/// rides along and is released when the worker finishes the job.
enum Job {
    Put {
        name: String,
        dims: Vec<usize>,
        body: Vec<u8>,
        data_off: usize,
        reservation: Option<Reservation>,
        reply: SyncSender<RawResponse>,
    },
    Get {
        name: String,
        reservation: Option<Reservation>,
        reply: SyncSender<RawResponse>,
    },
}

/// Estimated working-set cost of a PUT, priced from the declared body
/// length alone (so admission can precede the body read): the raw body,
/// plus roughly one body's worth of band/quant buffers in the streaming
/// compressor, plus encode scratch. An estimate, not a measurement —
/// the governor bounds aggregate admission, not exact RSS.
fn put_cost(body_len: usize) -> u64 {
    (body_len as u64).saturating_mul(3)
}

/// Estimated working-set cost of a GET, priced from the store index
/// entry: the response payload (4 B/element) plus decode-side quant
/// codes and band buffers (~2 B/element), plus the compressed payload
/// itself.
fn get_cost(elems: u64, stored_len: u64) -> u64 {
    elems.saturating_mul(6).saturating_add(stored_len)
}

struct Shared {
    coord: Arc<Coordinator>,
    store: Mutex<Store>,
    cfg: DaemonConfig,
    /// Byte-budget admission governor (`mem_budget`; unbounded if None).
    governor: Arc<MemoryGovernor>,
    /// Effective worker count (`cfg.workers` with 0 resolved to cores).
    workers: usize,
    /// Per-job internal thread budget (machine threads split across the
    /// worker pool, same oversubscription discipline as the batch drain).
    job_threads: usize,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
    stats: Mutex<DaemonStats>,
    started: Instant,
}

impl Shared {
    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || drain_requested()
    }

    /// Stats under a poison-tolerant lock: a panic while holding the
    /// stats mutex must not turn every later request into an error.
    fn stats_mut(&self) -> MutexGuard<'_, DaemonStats> {
        self.stats.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Spawner for the daemon. `Daemon::spawn` binds, starts the worker pool
/// and acceptor, and returns a [`DaemonHandle`]; the daemon then runs
/// until a drain is triggered.
pub struct Daemon;

/// Handle to a running daemon: its bound address, a drain trigger, and
/// `wait`/`shutdown` to join it and collect the final [`DaemonStats`].
pub struct DaemonHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: JoinHandle<()>,
}

impl DaemonHandle {
    /// The bound listen address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful drain (idempotent, non-blocking).
    pub fn trigger_drain(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until the daemon has fully drained, then return its stats.
    pub fn wait(self) -> Result<DaemonStats> {
        self.acceptor.join().map_err(|_| anyhow!("daemon acceptor thread panicked"))?;
        let stats = self.shared.stats_mut().clone();
        Ok(stats)
    }

    /// Trigger a drain and wait for it to complete.
    pub fn shutdown(self) -> Result<DaemonStats> {
        self.trigger_drain();
        self.wait()
    }
}

impl Daemon {
    /// Bind `addr`, start `cfg.workers` job workers and the acceptor,
    /// and return immediately. The daemon owns `store` (single-writer
    /// lock semantics carry over) and shares `coord` across workers.
    pub fn spawn(
        coord: Arc<Coordinator>,
        store: Store,
        addr: impl ToSocketAddrs,
        cfg: DaemonConfig,
    ) -> Result<DaemonHandle> {
        let listener = TcpListener::bind(addr).context("binding daemon listener")?;
        let local = listener.local_addr().context("resolving daemon listen address")?;
        // non-blocking accept so the loop can poll the drain flag
        listener.set_nonblocking(true).context("setting listener non-blocking")?;

        let workers = pool::effective_threads(cfg.workers);
        let job_threads = (coord.cfg.effective_threads() / workers).max(1);
        let (job_tx, job_rx) = pool::bounded::<Job>(cfg.queue_depth.max(1));
        let job_rx = Arc::new(Mutex::new(job_rx));
        let governor = match cfg.mem_budget {
            Some(budget) => MemoryGovernor::new(budget),
            None => MemoryGovernor::unbounded(),
        };
        let shared = Arc::new(Shared {
            coord,
            store: Mutex::new(store),
            cfg,
            governor,
            workers,
            job_threads,
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            stats: Mutex::new(DaemonStats::default()),
            started: Instant::now(),
        });

        let mut worker_handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            let rx = Arc::clone(&job_rx);
            let handle = std::thread::Builder::new()
                .name(format!("daemon-worker-{w}"))
                .spawn(move || worker_loop(&shared, &rx))
                .context("spawning daemon worker")?;
            // on a partial spawn failure the already-running workers exit
            // when job_tx is dropped by the error return below
            worker_handles.push(handle);
        }

        if shared.cfg.scrub_interval.is_some() {
            let scrub_shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name("daemon-scrub".into())
                .spawn(move || scrub_loop(&scrub_shared))
                .context("spawning daemon scrubber")?;
            // joined with the workers: the scrubber exits on the same
            // drain flag the acceptor sets before joining worker_handles
            worker_handles.push(handle);
        }

        let acceptor = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("daemon-accept".into())
                .spawn(move || {
                    accept_loop(&shared, listener, job_tx, worker_handles);
                })
                .context("spawning daemon acceptor")?
        };

        Ok(DaemonHandle { addr: local, shared, acceptor })
    }
}

/// The acceptor owns the listener, every connection handle, and the
/// master job sender; its exit sequence IS the drain protocol (see the
/// module docs).
fn accept_loop(
    shared: &Arc<Shared>,
    listener: TcpListener,
    job_tx: SyncSender<Job>,
    worker_handles: Vec<JoinHandle<()>>,
) {
    let mut conn_handles: Vec<JoinHandle<()>> = Vec::new();
    while !shared.draining() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.stats_mut().connections += 1;
                obs::global().add(keys::SERVE_DAEMON_CONNECTIONS, 1);
                if shared.active_conns.load(Ordering::Acquire) >= shared.cfg.max_connections {
                    shed_connection(shared, stream, "connection limit reached");
                    continue;
                }
                shared.active_conns.fetch_add(1, Ordering::AcqRel);
                let conn_shared = Arc::clone(shared);
                let conn_tx = job_tx.clone();
                let spawned = std::thread::Builder::new().name("daemon-conn".into()).spawn(
                    move || {
                        handle_connection(&conn_shared, &conn_tx, stream);
                        conn_shared.active_conns.fetch_sub(1, Ordering::AcqRel);
                    },
                );
                match spawned {
                    Ok(h) => conn_handles.push(h),
                    Err(_) => {
                        // closure (and stream) dropped: client sees EOF;
                        // count it as shed so overload is visible
                        shared.active_conns.fetch_sub(1, Ordering::AcqRel);
                        shared.stats_mut().shed += 1;
                        obs::global().add(keys::SERVE_DAEMON_SHED, 1);
                    }
                }
                // reap finished handlers so the vec stays bounded by the
                // live-connection cap
                conn_handles.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    // Drain: close the listener first (new connects are refused), then
    // wait for every connection to finish its in-flight request.
    drop(listener);
    for h in conn_handles {
        let _ = h.join();
    }
    // All producers gone: dropping the master sender lets workers finish
    // whatever is still queued and exit.
    drop(job_tx);
    for h in worker_handles {
        let _ = h.join();
    }
    let wall = shared.started.elapsed().as_secs_f64();
    let mut stats = shared.stats_mut();
    stats.wall_seconds = wall;
    stats.workers = shared.workers;
    stats.put.wall_seconds = wall;
    stats.put.workers = shared.workers;
}

/// Answer an over-capacity connection with `BUSY` and drop it.
fn shed_connection(shared: &Arc<Shared>, mut stream: TcpStream, msg: &str) {
    shared.stats_mut().shed += 1;
    obs::global().add(keys::SERVE_DAEMON_SHED, 1);
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let _ = wire::write_response(&mut stream, Status::Busy, msg.as_bytes());
}

/// Grant a byte reservation against the daemon's governor, mirroring the
/// grant into the registry (`serve.mem.reserved` cumulative admitted
/// bytes; `serve.mem.peak` published as peak-deltas since counters are
/// monotonic). `None` means the budget would be exceeded — shed.
fn admit(shared: &Shared, bytes: u64) -> Option<Reservation> {
    let r = shared.governor.try_reserve(bytes)?;
    obs::global().add(keys::SERVE_MEM_RESERVED, r.bytes());
    let peak = shared.governor.peak_bytes();
    let peak_counter = obs::global().counter(keys::SERVE_MEM_PEAK);
    let published = peak_counter.get();
    if peak > published {
        peak_counter.add(peak - published);
    }
    Some(r)
}

/// Refuse a request on memory-budget grounds: drain its declared name
/// and body through a bounded buffer (keeping the persistent-connection
/// framing intact), record the shed, and answer `BUSY`. Returns whether
/// the connection is still usable.
fn shed_request(shared: &Shared, stream: &mut TcpStream, hdr: &RequestHeader) -> bool {
    shared.stats_mut().shed += 1;
    obs::global().add(keys::SERVE_DAEMON_SHED, 1);
    obs::global().add(keys::SERVE_MEM_SHED, 1);
    if wire::drain_request_rest(stream, hdr).is_err() {
        return false; // truncated or dead stream: no frame boundary left
    }
    wire::write_response(stream, Status::Busy, b"memory budget exceeded").is_ok()
}

/// One persistent connection: parse frames until EOF, timeout, drain, or
/// a framing violation; submit PUT/GET jobs through admission control
/// and relay their replies.
///
/// Admission is header-first: the frame header declares the body length,
/// so a PUT's byte-budget reservation is made (or refused) *before* the
/// body is buffered — an oversized burst is shed while still costing one
/// drain buffer, not a resident body per connection.
fn handle_connection(shared: &Arc<Shared>, job_tx: &SyncSender<Job>, mut stream: TcpStream) {
    // accepted sockets do not inherit the listener's non-blocking mode on
    // every platform — force blocking + timeouts explicitly
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    loop {
        if shared.draining() {
            break; // persistent connections close on drain; clients see EOF
        }
        let hdr = match wire::read_request_header(&mut stream, &shared.cfg.limits) {
            Ok(Some(hdr)) => hdr,
            Ok(None) => break, // clean close
            Err(WireError::Malformed(msg)) => {
                shared.stats_mut().bad_requests += 1;
                obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                // best effort: after a framing violation the stream
                // cannot be resynchronized, so answer and close
                let _ = wire::write_response(&mut stream, Status::BadRequest, msg.as_bytes());
                break;
            }
            Err(WireError::Io(_)) => break, // timeout / reset / slow loris
        };
        shared.stats_mut().requests += 1;
        obs::global().add(keys::SERVE_DAEMON_REQUESTS, 1);
        let ok = match hdr.opcode {
            // STATS/PING/SHUTDOWN frames were validated to carry no name
            // or body, so the header is the whole frame.
            Opcode::Ping => wire::write_response(&mut stream, Status::Ok, b"pong").is_ok(),
            Opcode::Stats => {
                let snapshot = obs::global().snapshot().to_json();
                wire::write_response(&mut stream, Status::Ok, snapshot.as_bytes()).is_ok()
            }
            Opcode::Shutdown => {
                shared.shutdown.store(true, Ordering::SeqCst);
                let _ = wire::write_response(&mut stream, Status::Ok, b"draining");
                break;
            }
            Opcode::Put => {
                // reserve from the declared body length BEFORE reading
                // the body
                let Some(reservation) = admit(shared, put_cost(hdr.body_len)) else {
                    if shed_request(shared, &mut stream, &hdr) {
                        continue;
                    }
                    break;
                };
                let (name, body) = match wire::read_request_payload(&mut stream, &hdr) {
                    Ok(p) => p,
                    Err(WireError::Malformed(msg)) => {
                        shared.stats_mut().bad_requests += 1;
                        obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                        let _ = wire::write_response(
                            &mut stream,
                            Status::BadRequest,
                            msg.as_bytes(),
                        );
                        break;
                    }
                    Err(WireError::Io(_)) => break,
                };
                let (dims, data_off) = match wire::parse_field_dims(&body) {
                    Ok(v) => v,
                    Err(msg) => {
                        shared.stats_mut().bad_requests += 1;
                        obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                        let _ = wire::write_response(
                            &mut stream,
                            Status::BadRequest,
                            msg.as_bytes(),
                        );
                        break;
                    }
                };
                let (reply_tx, reply_rx) = pool::bounded::<RawResponse>(1);
                let job = Job::Put {
                    name,
                    dims,
                    body,
                    data_off,
                    reservation: Some(reservation),
                    reply: reply_tx,
                };
                submit_job(shared, job_tx, job, reply_rx, &mut stream)
            }
            Opcode::Get => {
                let (name, _empty) = match wire::read_request_payload(&mut stream, &hdr) {
                    Ok(p) => p,
                    Err(WireError::Malformed(msg)) => {
                        shared.stats_mut().bad_requests += 1;
                        obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                        let _ = wire::write_response(
                            &mut stream,
                            Status::BadRequest,
                            msg.as_bytes(),
                        );
                        break;
                    }
                    Err(WireError::Io(_)) => break,
                };
                // size the reservation from the store index (dims and
                // stored length) before the job is queued; an unknown or
                // unreadable name reserves nothing — the worker answers
                // NOT_FOUND/QUARANTINED without meaningful memory cost
                let cost = match shared.store.lock() {
                    Ok(store) => store.find(&name).map(|e| get_cost(e.n_elements(), e.len)),
                    Err(_) => None, // poisoned: the worker answers per-request
                };
                let reservation = match cost {
                    Some(c) => match admit(shared, c) {
                        Some(r) => Some(r),
                        None => {
                            // GET has no body to drain (validated above)
                            shared.stats_mut().shed += 1;
                            obs::global().add(keys::SERVE_DAEMON_SHED, 1);
                            obs::global().add(keys::SERVE_MEM_SHED, 1);
                            let ok = wire::write_response(
                                &mut stream,
                                Status::Busy,
                                b"memory budget exceeded",
                            )
                            .is_ok();
                            if ok {
                                continue;
                            }
                            break;
                        }
                    },
                    None => None,
                };
                let (reply_tx, reply_rx) = pool::bounded::<RawResponse>(1);
                let job = Job::Get { name, reservation, reply: reply_tx };
                submit_job(shared, job_tx, job, reply_rx, &mut stream)
            }
        };
        if !ok {
            break; // response write failed: connection is gone
        }
    }
}

/// Admission control: `try_send` into the bounded queue — full means an
/// immediate `BUSY`, accepted means we block on the reply channel (the
/// no-accepted-job-is-ever-dropped invariant). Returns whether the
/// connection is still usable.
fn submit_job(
    shared: &Arc<Shared>,
    job_tx: &SyncSender<Job>,
    job: Job,
    reply_rx: Receiver<RawResponse>,
    stream: &mut TcpStream,
) -> bool {
    match job_tx.try_send(job) {
        Ok(()) => {
            obs::global().add(keys::SERVE_DAEMON_QUEUE_ENQUEUED, 1);
            match reply_rx.recv() {
                Ok(resp) => wire::write_response(stream, resp.status, &resp.body).is_ok(),
                Err(_) => {
                    // worker pool died mid-job (should be unreachable —
                    // jobs are panic-contained); report, keep daemon up
                    obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                    let _ = wire::write_response(
                        stream,
                        Status::ServerError,
                        b"worker dropped the job reply",
                    );
                    false
                }
            }
        }
        Err(TrySendError::Full(_)) => {
            shared.stats_mut().shed += 1;
            obs::global().add(keys::SERVE_DAEMON_SHED, 1);
            wire::write_response(stream, Status::Busy, b"job queue full").is_ok()
        }
        Err(TrySendError::Disconnected(_)) => {
            let _ = wire::write_response(stream, Status::ShuttingDown, b"daemon draining");
            false
        }
    }
}

/// Shared worker loop: drain the job queue until every sender is gone.
fn worker_loop(shared: &Arc<Shared>, rx: &Arc<Mutex<Receiver<Job>>>) {
    loop {
        // hold the queue lock only for the dequeue, never for the work
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break, // queue lock poisoned: no safe dequeue left
        };
        let Ok(job) = job else {
            break; // all senders dropped: drain complete
        };
        obs::global().add(keys::SERVE_DAEMON_QUEUE_DEQUEUED, 1);
        match job {
            Job::Put { name, dims, body, data_off, reservation, reply } => {
                let span = obs::span(keys::SERVE_DAEMON_PUT)
                    .with_bytes((body.len() - data_off) as u64)
                    .with_histogram(obs::global().histogram(keys::HIST_DAEMON_PUT_NS));
                let (resp, cstats) = process_put(shared, &name, &dims, &body, data_off);
                // release the job's memory in admission order: body
                // first, then the budget reservation it was priced under
                drop(body);
                drop(reservation);
                let ns = span.finish().as_nanos() as u64;
                {
                    let mut stats = shared.stats_mut();
                    match &cstats {
                        Some(cs) => {
                            stats.put.absorb(&name, cs);
                            stats.put.job_ns.push(ns);
                        }
                        None => {
                            stats.put.failed += 1;
                            stats.put.errors.push((name.clone(), resp.text()));
                        }
                    }
                }
                if resp.status != Status::Ok {
                    obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                }
                // stats first, then the ack: a client that saw OK can
                // trust both the store commit and the accounting
                let _ = reply.send(resp);
            }
            Job::Get { name, reservation, reply } => {
                let mut span = obs::span(keys::SERVE_DAEMON_GET)
                    .with_histogram(obs::global().histogram(keys::HIST_DAEMON_GET_NS));
                let (resp, restored) = process_get(shared, &name);
                drop(reservation);
                span.add_bytes(restored as u64);
                let ns = span.finish().as_nanos() as u64;
                {
                    let mut stats = shared.stats_mut();
                    match resp.status {
                        Status::Ok => {
                            stats.gets += 1;
                            stats.restored_bytes += restored;
                            stats.get_ns.push(ns);
                        }
                        Status::NotFound => stats.gets_not_found += 1,
                        _ => stats.gets_failed += 1,
                    }
                }
                if resp.status != Status::Ok && resp.status != Status::NotFound {
                    obs::global().add(keys::SERVE_DAEMON_ERRORS, 1);
                }
                let _ = reply.send(resp);
            }
        }
        // after every job, fall the thread-local scratch pools back to
        // the retention watermark so one large job doesn't pin its
        // working set in an idle worker
        arena::trim_to_watermark(arena::DEFAULT_TRIM_WATERMARK);
    }
}

/// Background incremental scrubber: every `scrub_interval`, CRC-verify
/// one stored entry (round-robin over the index) and quarantine it on a
/// checked-read failure. Sleeps in short chunks so a drain is honored
/// within ~5ms regardless of the configured cadence.
fn scrub_loop(shared: &Arc<Shared>) {
    let interval = match shared.cfg.scrub_interval {
        Some(i) => i,
        None => return,
    };
    let mut cursor: usize = 0;
    while !shared.draining() {
        let mut slept = Duration::ZERO;
        while slept < interval && !shared.draining() {
            let chunk = Duration::from_millis(5).min(interval - slept);
            std::thread::sleep(chunk);
            slept += chunk;
        }
        if shared.draining() {
            break;
        }
        let Ok(mut store) = shared.store.lock() else {
            break; // store lock poisoned: request workers answer per-call
        };
        let entries = store.list();
        if entries.is_empty() {
            continue;
        }
        cursor %= entries.len();
        let name = entries[cursor].name.clone();
        cursor += 1;
        match store.get_bytes_checked(&name) {
            Ok(bytes) => {
                obs::global().add(keys::STORE_SCRUB_CHECKED, 1);
                obs::global().add(keys::STORE_SCRUB_BYTES, bytes.len() as u64);
            }
            Err(e) => {
                obs::global().add(keys::STORE_SCRUB_CHECKED, 1);
                obs::global().add(keys::STORE_SCRUB_CORRUPT, 1);
                let reason = format!("scrubber: {e:#}");
                match store.quarantine(&name, &reason) {
                    Ok(()) => {
                        obs::global().add(keys::STORE_SCRUB_QUARANTINED, 1);
                        eprintln!("scrub: quarantined '{name}': {e:#}");
                    }
                    Err(qe) => eprintln!("scrub: '{name}' corrupt but not quarantined: {qe:#}"),
                }
            }
        }
    }
}

/// PUT: stream-compress the raw wire body (panic-contained, outside the
/// store lock), then upsert the serialized archive into the store. The
/// compressor pulls LE bytes one slab band at a time, so the job's
/// working set is the body plus one band — never body plus a decoded
/// `Vec<f32>`. A one-pass [`StreamHint`] scan reproduces exactly the
/// range/finiteness decision of the in-memory path, so the stored
/// archive bytes are identical to what `compress_encoded` would emit.
/// Every failure mode — injected panic, compression error, poisoned
/// store lock, write error — is a per-request `SERVER_ERROR`.
fn process_put(
    shared: &Shared,
    name: &str,
    dims: &[usize],
    body: &[u8],
    data_off: usize,
) -> (RawResponse, Option<CompressStats>) {
    let compressed = contain_panic("daemon put", || {
        if shared.cfg.fault_panic_name.as_deref() == Some(name) {
            panic!("injected worker fault for '{name}'");
        }
        if let Some(delay) = shared.cfg.fault_put_delay {
            std::thread::sleep(delay);
        }
        let data = &body[data_off..];
        let hint = StreamHint::scan_le_bytes(data);
        shared.coord.compress_stream(name, dims, &mut io::Cursor::new(data), Some(hint))
    });
    let compressed = match compressed {
        Ok(c) => c,
        Err(e) => return (RawResponse::error(Status::ServerError, format!("{e:#}")), None),
    };
    let entry = match shared.store.lock() {
        Ok(mut store) => store.put_bytes(name, &compressed.bytes),
        Err(_) => {
            return (
                RawResponse::error(Status::ServerError, "store lock poisoned"),
                None,
            )
        }
    };
    match entry {
        Ok(entry) => {
            let ack = wire::encode_put_ack(entry.len, compressed.stats.original_bytes as u64);
            (RawResponse::ok(ack.to_vec()), Some(compressed.stats))
        }
        Err(e) => (RawResponse::error(Status::ServerError, format!("{e:#}")), None),
    }
}

/// GET: checked store read under the lock (CRC + header digest), then
/// decode + streaming decompress outside it (panic-contained). The
/// response body is assembled as the dims header plus f32 LE data
/// appended band-by-band by the fused slab pass — the compressed bytes
/// are dropped right after the archive parse and no `Field` is ever
/// materialized. Returns the wire field payload and the restored byte
/// count.
fn process_get(shared: &Shared, name: &str) -> (RawResponse, usize) {
    let bytes = match shared.store.lock() {
        Ok(store) => {
            if store.find(name).is_none() {
                // quarantined fields are out of the live index but not
                // forgotten: answer with the dedicated integrity status,
                // not NOT_FOUND (the client did store this name)
                if store.is_quarantined(name) {
                    obs::global().add(keys::SERVE_DAEMON_GET_QUARANTINED, 1);
                    return (
                        RawResponse::error(
                            Status::Quarantined,
                            format!("'{name}' is quarantined (corrupt payload; re-PUT to clear)"),
                        ),
                        0,
                    );
                }
                return (
                    RawResponse::error(Status::NotFound, format!("'{name}' not in store")),
                    0,
                );
            }
            store.get_bytes_checked(name)
        }
        Err(_) => return (RawResponse::error(Status::ServerError, "store lock poisoned"), 0),
    };
    let bytes = match bytes {
        Ok(b) => b,
        Err(e) => return (RawResponse::error(Status::ServerError, format!("{e:#}")), 0),
    };
    let job_threads = shared.job_threads;
    let coord = Arc::clone(&shared.coord);
    let result = contain_panic("daemon get", move || {
        let archive = Archive::from_bytes_with_threads(&bytes, job_threads)?;
        drop(bytes); // archive owns its sections; free the raw payload
        let mut payload = wire::encode_field_payload_header(&archive.header.dims)?;
        let stats = coord.decompress_stream_into(&archive, job_threads, &mut payload)?;
        Ok((payload, stats.original_bytes))
    });
    match result {
        Ok((payload, restored)) => (RawResponse::ok(payload), restored),
        Err(e) => (RawResponse::error(Status::ServerError, format!("{e:#}")), 0),
    }
}
