//! Wire protocol for the `cusz serve --daemon` socket front end: a
//! little-endian length-prefixed binary frame format (spec'd in the
//! README "Serving" section) plus the [`Client`] used by `cusz loadgen`
//! and the serving test battery.
//!
//! Design constraints, in order:
//!
//! 1. **Hostile-input safety.** Every declared length is validated
//!    against [`Limits`] *before* any allocation, all arithmetic on
//!    attacker-controlled sizes is checked, and a framing violation is a
//!    clean [`WireError::Malformed`] — never a panic, never an OOM. The
//!    proptests in `tests/proptests.rs` fuzz truncation, garbage, and
//!    oversized declared lengths against exactly these entry points.
//! 2. **Timeout-friendly streaming.** Parsers work over `impl Read` so
//!    the daemon's per-connection socket timeouts bound a slow-loris
//!    writer: a stalled partial frame surfaces as [`WireError::Io`] and
//!    the connection is dropped.
//! 3. **No dependencies.** std only; the frame layout is simple enough
//!    to desk-verify against the README spec byte by byte.
//!
//! ## Frame layout
//!
//! Request (client → daemon), 12-byte header then two variable parts:
//!
//! ```text
//! [0..2)  magic  b"cZ"
//! [2]     version (1)
//! [3]     opcode  (1=PUT 2=GET 3=STATS 4=PING 5=SHUTDOWN)
//! [4..6)  name_len  u16 LE
//! [6..8)  reserved (must be 0)
//! [8..12) body_len  u32 LE
//! then: name_len bytes of UTF-8 name, body_len bytes of body
//! ```
//!
//! Response (daemon → client), 8-byte header then the body:
//!
//! ```text
//! [0..2)  magic  b"cZ"
//! [2]     version (1)
//! [3]     status  (0=OK 1=BUSY 2=NOT_FOUND 3=BAD_REQUEST 4=SERVER_ERROR
//!                  5=SHUTTING_DOWN 6=QUARANTINED)
//! [4..8)  body_len  u32 LE
//! then: body_len bytes (OK: opcode-specific payload; errors: UTF-8 text)
//! ```
//!
//! Field payload (PUT request body, GET OK response body):
//!
//! ```text
//! [0]          ndims  u8 (1..=4)
//! [1..1+4n)    dims   ndims x u32 LE (each >= 1)
//! [..]         data   product(dims) x f32 LE
//! ```

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::field::Field;

pub const MAGIC: [u8; 2] = *b"cZ";
pub const VERSION: u8 = 1;
pub const REQ_HEADER_LEN: usize = 12;
pub const RESP_HEADER_LEN: usize = 8;

/// Parser allocation bounds, enforced on every declared length *before*
/// the corresponding buffer is allocated. The daemon CLI exposes
/// `--max-body-mb`; tests shrink these to fuzz the rejection paths.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Longest accepted field name, bytes.
    pub max_name_bytes: usize,
    /// Largest accepted request/response body, bytes.
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_name_bytes: 1024, max_body_bytes: 64 << 20 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    Put = 1,
    Get = 2,
    Stats = 3,
    Ping = 4,
    Shutdown = 5,
}

impl Opcode {
    pub fn from_u8(v: u8) -> Option<Opcode> {
        match v {
            1 => Some(Opcode::Put),
            2 => Some(Opcode::Get),
            3 => Some(Opcode::Stats),
            4 => Some(Opcode::Ping),
            5 => Some(Opcode::Shutdown),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    Busy = 1,
    NotFound = 2,
    BadRequest = 3,
    ServerError = 4,
    ShuttingDown = 5,
    /// The named field exists but was pulled into quarantine by the
    /// scrubber or `fsck` — a per-request integrity error, distinct from
    /// both NOT_FOUND (never stored) and SERVER_ERROR (daemon fault).
    Quarantined = 6,
}

impl Status {
    pub fn from_u8(v: u8) -> Option<Status> {
        match v {
            0 => Some(Status::Ok),
            1 => Some(Status::Busy),
            2 => Some(Status::NotFound),
            3 => Some(Status::BadRequest),
            4 => Some(Status::ServerError),
            5 => Some(Status::ShuttingDown),
            6 => Some(Status::Quarantined),
            _ => None,
        }
    }
}

/// A parsed request frame. `Put` carries the decoded [`Field`] (the
/// request name becomes `Field::name`, so the wire field payload never
/// duplicates the name).
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Put { field: Field },
    Get { name: String },
    Stats,
    Ping,
    Shutdown,
}

/// A response frame before opcode-specific body interpretation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RawResponse {
    pub status: Status,
    pub body: Vec<u8>,
}

impl RawResponse {
    pub fn ok(body: Vec<u8>) -> Self {
        RawResponse { status: Status::Ok, body }
    }

    pub fn error(status: Status, msg: impl AsRef<str>) -> Self {
        RawResponse { status, body: msg.as_ref().as_bytes().to_vec() }
    }

    /// Error body as text (lossy; error bodies are always UTF-8 on the
    /// daemon side, but the client never trusts that).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Protocol-layer failure, split by recovery strategy: `Io` means the
/// transport died (timeout, reset, mid-frame EOF on the response path) —
/// drop the connection; `Malformed` means the peer violated the framing —
/// answer `BAD_REQUEST` (daemon side) and close, since resynchronizing
/// inside a corrupt byte stream is not possible.
#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> WireError {
    WireError::Malformed(msg.into())
}

/// `read_exact` with mid-frame EOF reclassified as a framing violation
/// (a peer that hangs up inside a frame sent a truncated frame; a peer
/// that times out is an I/O condition and keeps its `Io` kind).
fn read_exact_frame(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<(), WireError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
            Err(malformed(format!("truncated {what}")))
        }
        Err(e) => Err(WireError::Io(e)),
    }
}

/// A validated request header: magic/version/opcode/limits already
/// checked, name and body not yet read. The declared lengths let the
/// daemon make a byte-budget admission decision *before* buffering the
/// body — an accepted frame proceeds to [`read_request_rest`], a shed
/// one to [`drain_request_rest`] (which keeps the persistent-connection
/// framing intact).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestHeader {
    pub opcode: Opcode,
    pub name_len: usize,
    pub body_len: usize,
}

/// Read and validate one request header. `Ok(None)` is a clean close:
/// EOF exactly on a frame boundary, the normal end of a persistent
/// connection. Every declared length is checked against `limits` here,
/// before any allocation.
pub fn read_request_header(
    r: &mut impl Read,
    limits: &Limits,
) -> Result<Option<RequestHeader>, WireError> {
    let mut header = [0u8; REQ_HEADER_LEN];
    // Fill the header manually so a clean EOF before the first byte is
    // distinguishable from truncation inside the header.
    let mut got = 0usize;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(malformed(format!(
                    "truncated header ({got} of {REQ_HEADER_LEN} bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    if header[0..2] != MAGIC {
        return Err(malformed(format!("bad magic {:02x}{:02x}", header[0], header[1])));
    }
    if header[2] != VERSION {
        return Err(malformed(format!("unsupported version {}", header[2])));
    }
    let opcode = Opcode::from_u8(header[3])
        .ok_or_else(|| malformed(format!("unknown opcode {}", header[3])))?;
    let name_len = u16::from_le_bytes([header[4], header[5]]) as usize;
    let reserved = u16::from_le_bytes([header[6], header[7]]);
    if reserved != 0 {
        return Err(malformed(format!("reserved bytes must be 0, got {reserved}")));
    }
    let body_len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    // limits BEFORE allocation — an attacker-declared 4 GiB body is
    // rejected while still costing zero bytes of buffer
    if name_len > limits.max_name_bytes {
        return Err(malformed(format!(
            "name length {name_len} exceeds limit {}",
            limits.max_name_bytes
        )));
    }
    if body_len > limits.max_body_bytes {
        return Err(malformed(format!(
            "body length {body_len} exceeds limit {}",
            limits.max_body_bytes
        )));
    }
    match opcode {
        Opcode::Put | Opcode::Get => {
            if name_len == 0 {
                return Err(malformed("PUT/GET requires a non-empty name"));
            }
        }
        Opcode::Stats | Opcode::Ping | Opcode::Shutdown => {
            if name_len != 0 || body_len != 0 {
                return Err(malformed("STATS/PING/SHUTDOWN take no name or body"));
            }
        }
    }
    if opcode == Opcode::Get && body_len != 0 {
        return Err(malformed("GET takes no body"));
    }
    Ok(Some(RequestHeader { opcode, name_len, body_len }))
}

/// Read the name and raw body declared by an already-validated header.
/// The daemon's PUT path stops here: it keeps the body as LE bytes and
/// streams the compressor over them, never materializing a `Vec<f32>`.
pub fn read_request_payload(
    r: &mut impl Read,
    hdr: &RequestHeader,
) -> Result<(String, Vec<u8>), WireError> {
    let mut name_bytes = vec![0u8; hdr.name_len];
    read_exact_frame(r, &mut name_bytes, "name")?;
    let name = String::from_utf8(name_bytes)
        .map_err(|_| malformed("name is not valid UTF-8"))?;
    let mut body = vec![0u8; hdr.body_len];
    read_exact_frame(r, &mut body, "body")?;
    Ok((name, body))
}

/// Finish parsing a request whose header was already read.
pub fn read_request_rest(
    r: &mut impl Read,
    hdr: &RequestHeader,
) -> Result<Request, WireError> {
    let (name, body) = read_request_payload(r, hdr)?;
    let req = match hdr.opcode {
        Opcode::Put => {
            let field = parse_field_payload(&body, &name).map_err(malformed)?;
            Request::Put { field }
        }
        Opcode::Get => Request::Get { name },
        Opcode::Stats => Request::Stats,
        Opcode::Ping => Request::Ping,
        Opcode::Shutdown => Request::Shutdown,
    };
    Ok(req)
}

/// Chunk size for [`drain_request_rest`]: large enough to swallow a
/// shed frame in a few reads, small enough that refusing a request
/// never costs meaningful memory (that is the whole point of shedding).
const DRAIN_CHUNK_BYTES: usize = 64 * 1024;

/// Discard the name and body of a request the daemon refuses to admit,
/// through a bounded buffer. Keeps the persistent-connection framing
/// intact so a BUSY answer can be followed by further frames — the
/// alternative (dropping the connection) would punish a well-behaved
/// client for the daemon's own load shedding.
pub fn drain_request_rest(r: &mut impl Read, hdr: &RequestHeader) -> Result<(), WireError> {
    let mut remaining = hdr.name_len + hdr.body_len;
    let mut buf = vec![0u8; DRAIN_CHUNK_BYTES.min(remaining.max(1))];
    while remaining > 0 {
        let take = buf.len().min(remaining);
        read_exact_frame(r, &mut buf[..take], "shed frame remainder")?;
        remaining -= take;
    }
    Ok(())
}

/// Read one request frame. `Ok(None)` is a clean close: EOF exactly on a
/// frame boundary, the normal end of a persistent connection.
pub fn read_request(r: &mut impl Read, limits: &Limits) -> Result<Option<Request>, WireError> {
    match read_request_header(r, limits)? {
        None => Ok(None),
        Some(hdr) => read_request_rest(r, &hdr).map(Some),
    }
}

/// Assemble one request frame from parts. `Err` only when the name/body
/// cannot be represented in the header's fixed-width length fields.
pub fn encode_request_parts(opcode: Opcode, name: &str, body: &[u8]) -> Result<Vec<u8>> {
    let name_len: u16 = name
        .len()
        .try_into()
        .map_err(|_| anyhow!("name length {} exceeds u16", name.len()))?;
    let body_len: u32 = body
        .len()
        .try_into()
        .map_err(|_| anyhow!("body length {} exceeds u32", body.len()))?;
    let mut out = Vec::with_capacity(REQ_HEADER_LEN + name.len() + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(opcode as u8);
    out.extend_from_slice(&name_len.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&body_len.to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out.extend_from_slice(body);
    Ok(out)
}

/// Encode a full [`Request`] (the proptest roundtrip entry point; the
/// [`Client`] uses [`encode_request_parts`] to avoid cloning field data).
pub fn encode_request(req: &Request) -> Result<Vec<u8>> {
    match req {
        Request::Put { field } => {
            encode_request_parts(Opcode::Put, &field.name, &encode_field_payload(field)?)
        }
        Request::Get { name } => encode_request_parts(Opcode::Get, name, &[]),
        Request::Stats => encode_request_parts(Opcode::Stats, "", &[]),
        Request::Ping => encode_request_parts(Opcode::Ping, "", &[]),
        Request::Shutdown => encode_request_parts(Opcode::Shutdown, "", &[]),
    }
}

/// Write a response frame: 8-byte header + body.
pub fn write_response(w: &mut impl Write, status: Status, body: &[u8]) -> io::Result<()> {
    let body_len: u32 = body.len().try_into().map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidInput, "response body exceeds u32")
    })?;
    let mut header = [0u8; RESP_HEADER_LEN];
    header[0..2].copy_from_slice(&MAGIC);
    header[2] = VERSION;
    header[3] = status as u8;
    header[4..8].copy_from_slice(&body_len.to_le_bytes());
    w.write_all(&header)?;
    w.write_all(body)?;
    w.flush()
}

/// Read one response frame. EOF anywhere (including before the first
/// byte — the daemon owes a response to every request) is an error.
pub fn read_response(r: &mut impl Read, limits: &Limits) -> Result<RawResponse, WireError> {
    let mut header = [0u8; RESP_HEADER_LEN];
    match r.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
            // a drained daemon closes persistent connections instead of
            // answering: keep the Io kind so clients can reconnect/stop
            return Err(WireError::Io(e));
        }
        Err(e) => return Err(WireError::Io(e)),
    }
    if header[0..2] != MAGIC {
        return Err(malformed(format!("bad response magic {:02x}{:02x}", header[0], header[1])));
    }
    if header[2] != VERSION {
        return Err(malformed(format!("unsupported response version {}", header[2])));
    }
    let status = Status::from_u8(header[3])
        .ok_or_else(|| malformed(format!("unknown status {}", header[3])))?;
    let body_len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    if body_len > limits.max_body_bytes {
        return Err(malformed(format!(
            "response body length {body_len} exceeds limit {}",
            limits.max_body_bytes
        )));
    }
    let mut body = vec![0u8; body_len];
    read_exact_frame(r, &mut body, "response body")?;
    Ok(RawResponse { status, body })
}

/// Serialize a field as the wire payload: `u8 ndims, ndims x u32 dims,
/// product x f32 LE`. Errors only when a dim exceeds `u32` (the wire
/// format's addressable limit).
pub fn encode_field_payload(field: &Field) -> Result<Vec<u8>> {
    let mut out = encode_field_payload_header(&field.dims)?;
    out.reserve(4 * field.data.len());
    for &v in &field.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Parse and validate the dims prefix of a wire field payload,
/// returning `(dims, data_offset)` where `bytes[data_offset..]` is
/// exactly the f32 LE data region. All size arithmetic is checked and
/// validated against the (already limit-checked) payload length, so a
/// hostile dims vector cannot drive allocation past the body it arrived
/// in. The daemon uses this directly to stream the compressor over the
/// raw data region without decoding a `Vec<f32>` first.
pub fn parse_field_dims(bytes: &[u8]) -> Result<(Vec<usize>, usize), String> {
    if bytes.is_empty() {
        return Err("empty field payload".into());
    }
    let ndims = bytes[0] as usize;
    if !(1..=4).contains(&ndims) {
        return Err(format!("ndims must be 1..=4, got {ndims}"));
    }
    let dims_end = 1 + 4 * ndims;
    if bytes.len() < dims_end {
        return Err(format!(
            "payload too short for {ndims} dims ({} < {dims_end} bytes)",
            bytes.len()
        ));
    }
    let mut dims = Vec::with_capacity(ndims);
    for i in 0..ndims {
        let o = 1 + 4 * i;
        let d = u32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]]);
        if d == 0 {
            return Err(format!("dim {i} is zero"));
        }
        dims.push(d as usize);
    }
    let elems = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| format!("dims {dims:?} overflow"))?;
    let data_bytes = elems
        .checked_mul(4)
        .ok_or_else(|| format!("element count {elems} overflows byte length"))?;
    if bytes.len() - dims_end != data_bytes {
        return Err(format!(
            "dims {dims:?} declare {data_bytes} data bytes but payload has {}",
            bytes.len() - dims_end
        ));
    }
    Ok((dims, dims_end))
}

/// Parse a wire field payload into a [`Field`]. Returns `Err(reason)` —
/// the caller wraps it in the right status/error type for its side of
/// the protocol.
pub fn parse_field_payload(bytes: &[u8], name: &str) -> Result<Field, String> {
    let (dims, data_off) = parse_field_dims(bytes)?;
    let data: Vec<f32> = bytes[data_off..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Field::new(name, dims, data).map_err(|e| e.to_string())
}

/// Serialize just the dims prefix of a field payload (`u8 ndims, ndims x
/// u32 LE`). The daemon's streaming GET path writes this header and
/// then appends decompressed f32 LE data straight from the fused slab
/// pass, so the response body is assembled without a `Field` in memory.
pub fn encode_field_payload_header(dims: &[usize]) -> Result<Vec<u8>> {
    let ndims: u8 = dims
        .len()
        .try_into()
        .ok()
        .filter(|&n| (1..=4).contains(&n))
        .ok_or_else(|| anyhow!("field must have 1..=4 dims, got {}", dims.len()))?;
    let mut out = Vec::with_capacity(1 + 4 * dims.len());
    out.push(ndims);
    for &d in dims {
        let d: u32 = d.try_into().map_err(|_| anyhow!("dim {d} exceeds u32"))?;
        out.extend_from_slice(&d.to_le_bytes());
    }
    Ok(out)
}

/// PUT acknowledgement body: compressed (stored) and original byte
/// counts, two u64 LE.
pub fn encode_put_ack(stored_bytes: u64, original_bytes: u64) -> [u8; 16] {
    let mut out = [0u8; 16];
    out[0..8].copy_from_slice(&stored_bytes.to_le_bytes());
    out[8..16].copy_from_slice(&original_bytes.to_le_bytes());
    out
}

pub fn parse_put_ack(body: &[u8]) -> Result<(u64, u64)> {
    if body.len() != 16 {
        return Err(anyhow!("PUT ack must be 16 bytes, got {}", body.len()));
    }
    let stored = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let original = u64::from_le_bytes(body[8..16].try_into().unwrap());
    Ok((stored, original))
}

/// One PUT's result as seen by a client.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PutOutcome {
    /// Stored and durable: `(compressed_bytes, original_bytes)`.
    Stored { compressed_bytes: u64, original_bytes: u64 },
    /// Shed by admission control — retry later.
    Busy,
    /// Daemon is draining; no new work is admitted.
    ShuttingDown,
    /// The daemon rejected or failed the request (message attached).
    Failed(String),
}

/// One GET's result as seen by a client.
#[derive(Debug, Clone, PartialEq)]
pub enum GetOutcome {
    Field(Field),
    NotFound,
    Busy,
    ShuttingDown,
    /// The field exists but sits in quarantine (corrupt payload captured
    /// by the scrubber or fsck). A fresh PUT under the same name clears it.
    Quarantined,
    Failed(String),
}

/// A persistent-connection protocol client over one `TcpStream`. All
/// methods are synchronous request/response; transport errors surface as
/// `Err` (callers reconnect), protocol statuses as typed outcomes.
pub struct Client {
    stream: TcpStream,
    limits: Limits,
}

impl Client {
    pub fn connect(addr: &str, read_timeout: Duration, write_timeout: Duration) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_read_timeout(Some(read_timeout))?;
        stream.set_write_timeout(Some(write_timeout))?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, limits: Limits::default() })
    }

    pub fn with_limits(mut self, limits: Limits) -> Self {
        self.limits = limits;
        self
    }

    fn send(&mut self, opcode: Opcode, name: &str, body: &[u8]) -> Result<RawResponse> {
        let frame = encode_request_parts(opcode, name, body)?;
        self.stream.write_all(&frame).context("writing request")?;
        self.stream.flush().context("flushing request")?;
        read_response(&mut self.stream, &self.limits)
            .map_err(|e| anyhow!("reading response: {e}"))
    }

    /// Compress-and-store `field` under `field.name` (upsert).
    pub fn put(&mut self, field: &Field) -> Result<PutOutcome> {
        let body = encode_field_payload(field)?;
        let resp = self.send(Opcode::Put, &field.name, &body)?;
        Ok(match resp.status {
            Status::Ok => {
                let (compressed_bytes, original_bytes) = parse_put_ack(&resp.body)?;
                PutOutcome::Stored { compressed_bytes, original_bytes }
            }
            Status::Busy => PutOutcome::Busy,
            Status::ShuttingDown => PutOutcome::ShuttingDown,
            Status::NotFound => PutOutcome::Failed("unexpected NOT_FOUND for PUT".into()),
            // PUT never answers QUARANTINED (an upsert supersedes the
            // quarantine verdict), so fold it into the failure arm.
            Status::BadRequest | Status::ServerError | Status::Quarantined => {
                PutOutcome::Failed(resp.text())
            }
        })
    }

    /// Fetch and decompress the field stored under `name`.
    pub fn get(&mut self, name: &str) -> Result<GetOutcome> {
        let resp = self.send(Opcode::Get, name, &[])?;
        Ok(match resp.status {
            Status::Ok => {
                let field = parse_field_payload(&resp.body, name)
                    .map_err(|e| anyhow!("decoding GET response: {e}"))?;
                GetOutcome::Field(field)
            }
            Status::NotFound => GetOutcome::NotFound,
            Status::Busy => GetOutcome::Busy,
            Status::ShuttingDown => GetOutcome::ShuttingDown,
            Status::Quarantined => GetOutcome::Quarantined,
            Status::BadRequest | Status::ServerError => GetOutcome::Failed(resp.text()),
        })
    }

    pub fn ping(&mut self) -> Result<()> {
        let resp = self.send(Opcode::Ping, "", &[])?;
        match resp.status {
            Status::Ok => Ok(()),
            s => Err(anyhow!("ping answered {s:?}: {}", resp.text())),
        }
    }

    /// Fetch the daemon's live telemetry snapshot (cusz-metrics/v1 JSON).
    pub fn stats(&mut self) -> Result<String> {
        let resp = self.send(Opcode::Stats, "", &[])?;
        match resp.status {
            Status::Ok => Ok(resp.text()),
            s => Err(anyhow!("stats answered {s:?}: {}", resp.text())),
        }
    }

    /// Ask the daemon to drain and exit (same path as SIGTERM).
    pub fn shutdown_server(&mut self) -> Result<()> {
        let resp = self.send(Opcode::Shutdown, "", &[])?;
        match resp.status {
            Status::Ok | Status::ShuttingDown => Ok(()),
            s => Err(anyhow!("shutdown answered {s:?}: {}", resp.text())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn small_field() -> Field {
        Field::new("t", vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn request_roundtrips_through_cursor() {
        for req in [
            Request::Put { field: small_field() },
            Request::Get { name: "a/b".into() },
            Request::Stats,
            Request::Ping,
            Request::Shutdown,
        ] {
            let bytes = encode_request(&req).unwrap();
            let mut cur = Cursor::new(bytes);
            let back = read_request(&mut cur, &Limits::default()).unwrap().unwrap();
            assert_eq!(back, req);
            // frame boundary: a second read is a clean EOF
            assert!(read_request(&mut cur, &Limits::default()).unwrap().is_none());
        }
    }

    #[test]
    fn field_payload_roundtrips_bitwise() {
        let field = Field::new(
            "bits",
            vec![4],
            vec![0.0, -0.0, f32::MIN_POSITIVE, 1.5e30],
        )
        .unwrap();
        let payload = encode_field_payload(&field).unwrap();
        let back = parse_field_payload(&payload, "bits").unwrap();
        assert_eq!(back.dims, field.dims);
        let a: Vec<u32> = field.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn oversized_declared_lengths_rejected_before_allocation() {
        let limits = Limits { max_name_bytes: 8, max_body_bytes: 64 };
        // name_len = u16::MAX, body_len = u32::MAX: must reject from the
        // 12 header bytes alone
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC);
        frame.push(VERSION);
        frame.push(Opcode::Put as u8);
        frame.extend_from_slice(&u16::MAX.to_le_bytes());
        frame.extend_from_slice(&0u16.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_request(&mut Cursor::new(frame), &limits).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err}");
    }

    #[test]
    fn truncated_and_garbage_frames_fail_cleanly() {
        let full = encode_request(&Request::Put { field: small_field() }).unwrap();
        for cut in 1..full.len() {
            let r = read_request(&mut Cursor::new(&full[..cut]), &Limits::default());
            assert!(r.is_err(), "cut at {cut} must not parse");
        }
        let garbage = [0xde, 0xad, 0xbe, 0xef, 1, 2, 3, 4, 5, 6, 7, 8];
        assert!(read_request(&mut Cursor::new(&garbage[..]), &Limits::default()).is_err());
        // empty input is a clean close, not an error
        assert!(read_request(&mut Cursor::new(&[][..]), &Limits::default()).unwrap().is_none());
    }

    #[test]
    fn field_payload_rejects_dim_data_mismatch() {
        // dims say 2x3=6 floats, body carries 5
        let mut payload = vec![2u8];
        payload.extend_from_slice(&2u32.to_le_bytes());
        payload.extend_from_slice(&3u32.to_le_bytes());
        payload.extend_from_slice(&vec![0u8; 5 * 4]);
        assert!(parse_field_payload(&payload, "x").is_err());
        // zero dim
        let mut zero = vec![1u8];
        zero.extend_from_slice(&0u32.to_le_bytes());
        assert!(parse_field_payload(&zero, "x").is_err());
        // overflowing dim product must not allocate or wrap
        let mut huge = vec![4u8];
        for _ in 0..4 {
            huge.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(parse_field_payload(&huge, "x").is_err());
    }

    #[test]
    fn response_roundtrips_and_bounds_body() {
        let mut buf = Vec::new();
        write_response(&mut buf, Status::Busy, b"queue full").unwrap();
        let resp = read_response(&mut Cursor::new(&buf), &Limits::default()).unwrap();
        assert_eq!(resp.status, Status::Busy);
        assert_eq!(resp.text(), "queue full");
        // declared response body over the limit is rejected from the header
        let mut header = [0u8; RESP_HEADER_LEN];
        header[0..2].copy_from_slice(&MAGIC);
        header[2] = VERSION;
        header[3] = Status::Ok as u8;
        header[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let limits = Limits { max_body_bytes: 16, ..Limits::default() };
        assert!(matches!(
            read_response(&mut Cursor::new(&header), &limits),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn header_first_read_matches_one_shot_read() {
        let field = small_field();
        let bytes = encode_request(&Request::Put { field: field.clone() }).unwrap();
        let mut cur = Cursor::new(&bytes);
        let hdr = read_request_header(&mut cur, &Limits::default()).unwrap().unwrap();
        assert_eq!(hdr.opcode, Opcode::Put);
        assert_eq!(hdr.name_len, 1);
        assert_eq!(hdr.body_len, bytes.len() - REQ_HEADER_LEN - 1);
        let req = read_request_rest(&mut cur, &hdr).unwrap();
        assert_eq!(req, Request::Put { field });
    }

    #[test]
    fn drain_keeps_persistent_connection_framing() {
        // shed frame, then a PING on the same stream: draining the shed
        // frame must leave the cursor exactly on the next frame boundary
        let mut stream = encode_request(&Request::Put { field: small_field() }).unwrap();
        stream.extend_from_slice(&encode_request(&Request::Ping).unwrap());
        let mut cur = Cursor::new(&stream);
        let hdr = read_request_header(&mut cur, &Limits::default()).unwrap().unwrap();
        drain_request_rest(&mut cur, &hdr).unwrap();
        let next = read_request(&mut cur, &Limits::default()).unwrap().unwrap();
        assert_eq!(next, Request::Ping);
        assert!(read_request(&mut cur, &Limits::default()).unwrap().is_none());
    }

    #[test]
    fn drain_reports_truncated_shed_frame() {
        let full = encode_request(&Request::Put { field: small_field() }).unwrap();
        let cut = REQ_HEADER_LEN + 3; // header complete, name+body truncated
        let mut cur = Cursor::new(&full[..cut]);
        let hdr = read_request_header(&mut cur, &Limits::default()).unwrap().unwrap();
        let err = drain_request_rest(&mut cur, &hdr).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err}");
    }

    #[test]
    fn field_dims_prefix_matches_full_parse() {
        let field = small_field();
        let payload = encode_field_payload(&field).unwrap();
        let (dims, data_off) = parse_field_dims(&payload).unwrap();
        assert_eq!(dims, field.dims);
        assert_eq!(data_off, 1 + 4 * field.dims.len());
        assert_eq!(payload[..data_off], encode_field_payload_header(&field.dims).unwrap());
        // the data region decodes to the original values
        let vals: Vec<f32> = payload[data_off..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(vals, field.data);
    }

    #[test]
    fn put_ack_roundtrips() {
        let body = encode_put_ack(123, 456);
        assert_eq!(parse_put_ack(&body).unwrap(), (123, 456));
        assert!(parse_put_ack(&body[..8]).is_err());
    }
}
