//! Compression-quality and performance metrics: PSNR/RMSE (paper footnote
//! 6), error-bound verification, and compression ratio / bitrate. Stage
//! timing for the Table 7 breakdowns now lives in [`crate::obs`]
//! (`RunTimings` + the global registry); the [`timer`] module remains as
//! a deprecated shim.

pub mod psnr;
pub mod timer;

pub use psnr::{bitrate_bits, compression_ratio, max_abs_error, psnr, rmse, verify_error_bound};
#[allow(deprecated)]
pub use timer::StageTimer;
