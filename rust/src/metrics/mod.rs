//! Compression-quality and performance metrics: PSNR/RMSE (paper footnote
//! 6), error-bound verification, compression ratio / bitrate, and stage
//! timers for the Table 7 breakdowns.

pub mod psnr;
pub mod timer;

pub use psnr::{bitrate_bits, compression_ratio, max_abs_error, psnr, rmse, verify_error_bound};
pub use timer::StageTimer;
