//! Rate-distortion metrics, defined exactly as the paper's footnote 6:
//! PSNR = 20 log10((dmax - dmin) / RMSE).

/// Root mean squared error between original and reconstruction.
pub fn rmse(original: &[f32], decompressed: &[f32]) -> f64 {
    assert_eq!(original.len(), decompressed.len());
    if original.is_empty() {
        return 0.0;
    }
    let sum: f64 = original
        .iter()
        .zip(decompressed)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum();
    (sum / original.len() as f64).sqrt()
}

/// Peak signal-to-noise ratio in dB over the value range.
pub fn psnr(original: &[f32], decompressed: &[f32]) -> f64 {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in original {
        if v.is_finite() {
            lo = lo.min(v as f64);
            hi = hi.max(v as f64);
        }
    }
    let range = hi - lo;
    let e = rmse(original, decompressed);
    if e == 0.0 {
        return f64::INFINITY;
    }
    20.0 * (range / e).log10()
}

/// Largest pointwise absolute error.
pub fn max_abs_error(original: &[f32], decompressed: &[f32]) -> f64 {
    original
        .iter()
        .zip(decompressed)
        .map(|(&a, &b)| (a as f64 - b as f64).abs())
        .fold(0.0, f64::max)
}

/// Verify the strict bound |d - d*| <= eb (+ f32 scaling slack, DESIGN.md
/// §3): returns the first violating index if any.
pub fn verify_error_bound(original: &[f32], decompressed: &[f32], eb: f32) -> Option<usize> {
    let max_abs = original.iter().fold(0f32, |a, &b| if b.is_finite() { a.max(b.abs()) } else { a });
    let tol = eb as f64 * (1.0 + 1e-6) + 4.0 * f32::EPSILON as f64 * max_abs as f64;
    original
        .iter()
        .zip(decompressed)
        .position(|(&a, &b)| {
            if !a.is_finite() {
                return false; // non-finite inputs round-trip via verbatim storage
            }
            (a as f64 - b as f64).abs() > tol
        })
}

/// original_bytes / compressed_bytes.
pub fn compression_ratio(original_bytes: usize, compressed_bytes: usize) -> f64 {
    original_bytes as f64 / compressed_bytes.max(1) as f64
}

/// Bits per value for f32 data: 32 / CR.
pub fn bitrate_bits(original_bytes: usize, compressed_bytes: usize) -> f64 {
    32.0 / compression_ratio(original_bytes, compressed_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_reconstruction_is_infinite_psnr() {
        let d = vec![1.0f32, 2.0, 3.0];
        assert!(psnr(&d, &d).is_infinite());
        assert_eq!(rmse(&d, &d), 0.0);
    }

    #[test]
    fn known_psnr_value() {
        // range 1.0, uniform error 0.01 => rmse 0.01, psnr = 40 dB
        let orig = vec![0.0f32, 1.0];
        let dec = vec![0.01f32, 0.99];
        assert!((psnr(&orig, &dec) - 40.0).abs() < 1e-5);
    }

    #[test]
    fn bound_verification_finds_violation() {
        let orig = vec![0.0f32, 0.0, 0.0];
        let ok = vec![0.0009f32, -0.0009, 0.0];
        let bad = vec![0.0f32, 0.0021, 0.0];
        assert_eq!(verify_error_bound(&orig, &ok, 1e-3), None);
        assert_eq!(verify_error_bound(&orig, &bad, 1e-3), Some(1));
    }

    #[test]
    fn ratio_and_bitrate() {
        assert_eq!(compression_ratio(4000, 400), 10.0);
        assert!((bitrate_bits(4000, 400) - 3.2).abs() < 1e-12);
    }
}
