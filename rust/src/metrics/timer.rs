//! Per-stage wall-clock accounting for the Table 7 breakdown rows.
//!
//! Deprecated shim: `StageTimer` is single-threaded (`&mut self`) and
//! records nowhere but itself. The pipeline now uses
//! [`crate::obs::RunTimings`] (same per-run API and report format) plus
//! the global [`crate::obs::Registry`] for cross-thread aggregation.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

#[deprecated(note = "use cusz::obs::RunTimings (same API) + the obs registry")]
#[derive(Debug, Default, Clone)]
pub struct StageTimer {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

#[allow(deprecated)]
impl StageTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`, accumulating.
    pub fn time<R>(&mut self, stage: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(stage, t0.elapsed());
        r
    }

    pub fn add(&mut self, stage: &str, d: Duration) {
        *self.totals.entry(stage.to_string()).or_default() += d;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.totals.get(stage).copied().unwrap_or_default()
    }

    pub fn merge(&mut self, other: &StageTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    /// (stage, total, calls, GB/s against `bytes`) rows, insertion-sorted
    /// by stage name.
    pub fn rows(&self, bytes: usize) -> Vec<(String, Duration, u64, f64)> {
        self.totals
            .iter()
            .map(|(k, &d)| {
                let gbps = if d.as_nanos() > 0 {
                    bytes as f64 / d.as_secs_f64() / 1e9
                } else {
                    f64::INFINITY
                };
                (k.clone(), d, self.counts[k], gbps)
            })
            .collect()
    }

    pub fn report(&self, bytes: usize) -> String {
        let mut s = String::new();
        for (stage, d, n, gbps) in self.rows(bytes) {
            s.push_str(&format!(
                "  {stage:<28} {:>10.3} ms  x{n:<5} {gbps:>9.3} GB/s\n",
                d.as_secs_f64() * 1e3
            ));
        }
        s
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_calls() {
        let mut t = StageTimer::new();
        t.add("quant", Duration::from_millis(10));
        t.add("quant", Duration::from_millis(5));
        t.add("huffman", Duration::from_millis(1));
        assert_eq!(t.total("quant"), Duration::from_millis(15));
        assert_eq!(t.rows(0).len(), 2);
    }

    #[test]
    fn merge_combines() {
        let mut a = StageTimer::new();
        a.add("x", Duration::from_millis(1));
        let mut b = StageTimer::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(3));
        assert_eq!(a.total("y"), Duration::from_millis(3));
    }

    #[test]
    fn gbps_computation() {
        let mut t = StageTimer::new();
        t.add("s", Duration::from_secs(1));
        let rows = t.rows(2_000_000_000);
        assert!((rows[0].3 - 2.0).abs() < 1e-9);
    }
}
