//! Encoding: per-symbol codebook lookup into fixed-length packed words
//! (paper §3.2.4 "codebook-based encoding is basically memory copy").
//!
//! The production pipeline fuses lookup+deflate (deflate.rs); these
//! materialized variants exist to reproduce Table 4's u32-vs-u64
//! memory-bandwidth experiment faithfully, where the fixed-length encoded
//! array is written out before deflating strips the zero bits.

use super::CanonicalCodebook;
use crate::util::pool::parallel_map_range;

/// Fixed-length encode into packed u32 entries (width MSBs | code LSBs).
pub fn encode_fixed_u32(symbols: &[u16], book: &CanonicalCodebook, threads: usize) -> Vec<u32> {
    assert_eq!(book.repr_bits(), 32, "codebook too wide for u32 repr");
    let chunk = symbols.len().div_ceil(threads.max(1)).max(1);
    let nchunks = symbols.len().div_ceil(chunk).max(1);
    let parts = parallel_map_range(threads, nchunks, |ci| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(symbols.len());
        symbols[lo..hi].iter().map(|&s| book.packed_u32(s)).collect::<Vec<_>>()
    });
    let mut out = Vec::with_capacity(symbols.len());
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

/// Fixed-length encode into packed u64 entries.
pub fn encode_fixed_u64(symbols: &[u16], book: &CanonicalCodebook, threads: usize) -> Vec<u64> {
    let chunk = symbols.len().div_ceil(threads.max(1)).max(1);
    let nchunks = symbols.len().div_ceil(chunk).max(1);
    let parts = parallel_map_range(threads, nchunks, |ci| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(symbols.len());
        symbols[lo..hi].iter().map(|&s| book.packed_u64(s)).collect::<Vec<_>>()
    });
    let mut out = Vec::with_capacity(symbols.len());
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

/// Total encoded bits for a symbol stream (exact deflated size).
pub fn encoded_bits(symbols: &[u16], book: &CanonicalCodebook) -> u64 {
    symbols.iter().map(|&s| book.len[s as usize] as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::tree::build_lengths;

    fn book() -> CanonicalCodebook {
        let freq: Vec<u64> = (1..=16).collect();
        CanonicalCodebook::from_lengths(&build_lengths(&freq)).unwrap()
    }

    #[test]
    fn u32_and_u64_agree_on_payload() {
        let b = book();
        let syms: Vec<u16> = (0..16).collect();
        let e32 = encode_fixed_u32(&syms, &b, 2);
        let e64 = encode_fixed_u64(&syms, &b, 2);
        for ((s, a), c) in syms.iter().zip(e32).zip(e64) {
            let (code, len) = b.lookup(*s);
            assert_eq!(a & 0x00ff_ffff, code as u32);
            assert_eq!(a >> 24, len);
            assert_eq!(c & ((1 << 56) - 1), code);
            assert_eq!(c >> 56, len as u64);
        }
    }

    #[test]
    fn encoded_bits_matches_sum_of_lengths() {
        let b = book();
        let syms = vec![0u16, 1, 15, 15, 15];
        let expect: u64 = syms.iter().map(|&s| b.len[s as usize] as u64).sum();
        assert_eq!(encoded_bits(&syms, &b), expect);
    }

    #[test]
    fn parallel_encode_matches_serial() {
        let b = book();
        let syms: Vec<u16> = (0..10_000).map(|i| (i % 16) as u16).collect();
        assert_eq!(encode_fixed_u32(&syms, &b, 1), encode_fixed_u32(&syms, &b, 8));
    }
}
