//! Customized canonical Huffman coding (paper §3.2): histogram → tree →
//! canonical codebook → encode → deflate, plus inflate for decompression.
//!
//! The four compression subprocedures map to the paper's Figure 1 bottom
//! row; the adaptive u32/u64 codeword representation is §3.2.2 / Table 4,
//! chunked deflate/inflate is §3.2.4 / Table 6.

pub mod codebook;
pub mod deflate;
pub mod encode;
pub mod histogram;
pub mod inflate;
pub mod tree;

pub use codebook::{CanonicalCodebook, ReverseCodebook};
pub use deflate::{deflate_chunks, deflate_one_gap, DeflatedStream, GapTable, GAP_SUBCHUNK};
pub use encode::{encode_fixed_u32, encode_fixed_u64};
pub use histogram::{histogram, histogram_parallel};
pub use inflate::{inflate_chunks, inflate_one_gap_into_strict};
pub use tree::build_lengths;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// End-to-end: random skewed symbols -> codebook -> deflate -> inflate.
    #[test]
    fn full_pipeline_roundtrip() {
        let mut rng = Rng::new(42);
        let dict = 1024usize;
        // Geometric-ish distribution centered at radius, like quant codes.
        let symbols: Vec<u16> = (0..100_000)
            .map(|_| {
                let spread = (rng.normal() * 8.0) as i32;
                (512 + spread).clamp(0, dict as i32 - 1) as u16
            })
            .collect();
        let hist = histogram(&symbols, dict);
        let lengths = build_lengths(&hist.iter().map(|&c| c as u64).collect::<Vec<_>>());
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let stream = deflate_chunks(&symbols, &book, 4096, 4);
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        let out = inflate_chunks(&stream, &rev, 4);
        assert_eq!(out, symbols);
        // entropy sanity: deflated size should beat raw u16 encoding
        assert!(stream.total_bits() < symbols.len() as u64 * 16);
    }
}
