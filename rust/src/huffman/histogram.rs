//! Symbol-frequency histogram (paper §3.2.1).
//!
//! The GPU version privatizes per-block shared-memory replicas and merges
//! them; the CPU analogue privatizes one replica per worker and reduces
//! (`histogram_parallel`). The production path normally consumes the
//! histogram computed on-device by the L1 Pallas kernel — these are the
//! baseline/CPU-backend versions.

use crate::util::pool::parallel_map;

/// Serial histogram.
pub fn histogram(symbols: &[u16], dict_size: usize) -> Vec<u32> {
    let mut h = vec![0u32; dict_size];
    for &s in symbols {
        h[s as usize] += 1;
    }
    h
}

/// Privatized-replica parallel histogram (Gomez-Luna-style).
pub fn histogram_parallel(symbols: &[u16], dict_size: usize, threads: usize) -> Vec<u32> {
    let threads = threads.max(1);
    if threads == 1 || symbols.len() < 1 << 16 {
        return histogram(symbols, dict_size);
    }
    let chunk = symbols.len().div_ceil(threads);
    let chunks: Vec<&[u16]> = symbols.chunks(chunk).collect();
    let partials = parallel_map(threads, &chunks, |_, part| histogram(part, dict_size));
    let mut h = vec![0u32; dict_size];
    for p in partials {
        for (a, b) in h.iter_mut().zip(p) {
            *a += b;
        }
    }
    h
}

/// Merge per-slab histograms (u32 per-slab counts into u64 field totals).
pub fn merge_into(total: &mut [u64], part: &[u32]) {
    debug_assert_eq!(total.len(), part.len());
    for (t, &p) in total.iter_mut().zip(part) {
        *t += p as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(1);
        let syms: Vec<u16> = (0..300_000).map(|_| rng.below(1024) as u16).collect();
        assert_eq!(histogram(&syms, 1024), histogram_parallel(&syms, 1024, 8));
    }

    #[test]
    fn totals_preserved() {
        let mut rng = Rng::new(2);
        let syms: Vec<u16> = (0..70_000).map(|_| rng.below(256) as u16).collect();
        let h = histogram_parallel(&syms, 256, 4);
        assert_eq!(h.iter().map(|&x| x as usize).sum::<usize>(), syms.len());
    }

    #[test]
    fn merge_accumulates() {
        let mut total = vec![0u64; 4];
        merge_into(&mut total, &[1, 2, 3, 4]);
        merge_into(&mut total, &[10, 0, 0, 1]);
        assert_eq!(total, vec![11, 2, 3, 5]);
    }
}
