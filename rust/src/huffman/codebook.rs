//! Canonical Huffman codebook + reverse codebook (paper §3.2.2-3.2.3).
//!
//! Canonization assigns codewords in (length, symbol) order so that (i)
//! decoding needs no tree, (ii) the reverse codebook is cache-friendly,
//! and (iii) the compression ratio equals the base codebook's — the three
//! properties §3.2.3 lists.
//!
//! Forward entries use the paper's fixed-length packed representation
//! (Figure 4): bitwidth in the MSBs, the codeword (bit-reversed, ready for
//! LSB-first emission) in the LSBs. `CanonicalCodebook::repr_bits` is the
//! adaptive u32/u64 selection of §3.2.2 driven by the real max bitwidth,
//! not the pessimistic 64-bit estimate.

use anyhow::{bail, Result};

/// Bits reserved for the bitwidth field in packed entries (Figure 4).
const WIDTH_FIELD: u32 = 8;

#[derive(Debug, Clone)]
pub struct CanonicalCodebook {
    /// Per-symbol codeword, bit-reversed for LSB-first writing.
    pub code: Vec<u64>,
    /// Per-symbol bit length (0 = symbol absent).
    pub len: Vec<u8>,
    pub max_len: u8,
}

impl CanonicalCodebook {
    pub fn from_lengths(lengths: &[u8]) -> Result<Self> {
        let max_len = lengths.iter().copied().max().unwrap_or(0);
        if max_len as u32 > 64 - WIDTH_FIELD {
            bail!("codeword length {max_len} exceeds representable width");
        }
        // counts per length
        let mut count = vec![0u64; max_len as usize + 1];
        for &l in lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        // first canonical code per length (MSB-first convention)
        let mut first = vec![0u64; max_len as usize + 2];
        let mut c = 0u64;
        for l in 1..=max_len as usize {
            c = (c + count[l - 1]) << 1;
            first[l] = c;
        }
        // assign in (length, symbol) order: symbols are scanned in order,
        // so per-length cursors produce the canonical assignment directly.
        let mut next = first.clone();
        let mut code = vec![0u64; lengths.len()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l == 0 {
                continue;
            }
            let cw = next[l as usize];
            next[l as usize] += 1;
            code[sym] = reverse_bits(cw, l as u32);
        }
        Ok(CanonicalCodebook { code, len: lengths.to_vec(), max_len })
    }

    /// (packed-bit codeword ready for LSB-first write, bit length).
    #[inline]
    pub fn lookup(&self, sym: u16) -> (u64, u32) {
        (self.code[sym as usize], self.len[sym as usize] as u32)
    }

    /// Adaptive representation width (Table 4): u32 when the bitwidth field
    /// plus the longest codeword fit in 32 bits, else u64.
    pub fn repr_bits(&self) -> u32 {
        if (self.max_len as u32) <= 32 - WIDTH_FIELD {
            32
        } else {
            64
        }
    }

    /// Packed fixed-length entry per Figure 4 (width in MSBs, code in LSBs).
    pub fn packed_u32(&self, sym: u16) -> u32 {
        debug_assert_eq!(self.repr_bits(), 32);
        let (c, l) = self.lookup(sym);
        ((l as u32) << (32 - WIDTH_FIELD)) | (c as u32)
    }

    pub fn packed_u64(&self, sym: u16) -> u64 {
        let (c, l) = self.lookup(sym);
        ((l as u64) << (64 - WIDTH_FIELD as u64)) | c
    }

    /// Serialized form for the archive: just the length table (the decoder
    /// re-canonizes) — smaller than shipping codewords.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.len.clone()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        Self::from_lengths(bytes)
    }
}

/// Reverse (decoding) codebook: canonical first-code tables plus a fast
/// single-level lookup table for short codes.
#[derive(Debug, Clone)]
pub struct ReverseCodebook {
    /// first canonical code per length (MSB-first value space).
    first: Vec<u64>,
    /// index into `symbols` of the first code of each length.
    offset: Vec<u32>,
    /// symbols sorted by (length, symbol).
    symbols: Vec<u16>,
    pub max_len: u8,
    /// fast table over TABLE_BITS LSB-first bits: (symbol, len) or len=0 => slow path.
    table: Vec<(u16, u8)>,
}

pub const TABLE_BITS: u32 = 12;

impl ReverseCodebook {
    pub fn from_lengths(lengths: &[u8]) -> Result<Self> {
        let max_len = lengths.iter().copied().max().unwrap_or(0);
        if max_len == 0 {
            return Ok(ReverseCodebook {
                first: vec![0; 2],
                offset: vec![0; 2],
                symbols: vec![],
                max_len: 0,
                table: vec![(0, 0); 1 << TABLE_BITS],
            });
        }
        let ml = max_len as usize;
        let mut count = vec![0u64; ml + 1];
        for &l in lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut first = vec![0u64; ml + 2];
        let mut c = 0u64;
        for l in 1..=ml {
            c = (c + count[l - 1]) << 1;
            first[l] = c;
        }
        first[ml + 1] = (c + count[ml]) << 1; // sentinel

        let mut offset = vec![0u32; ml + 2];
        for l in 1..=ml {
            offset[l + 1] = offset[l] + count[l] as u32;
        }
        let mut cursor = offset.clone();
        let mut symbols = vec![0u16; lengths.iter().filter(|&&l| l > 0).count()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l > 0 {
                symbols[cursor[l as usize] as usize] = sym as u16;
                cursor[l as usize] += 1;
            }
        }

        // Fast table: for every symbol with len <= TABLE_BITS, fill all
        // entries whose low `len` bits match its reversed codeword.
        let mut table = vec![(0u16, 0u8); 1 << TABLE_BITS];
        {
            let mut next = first.clone();
            for (sym, &l) in lengths.iter().enumerate() {
                if l == 0 {
                    continue;
                }
                let cw = next[l as usize];
                next[l as usize] += 1;
                if (l as u32) <= TABLE_BITS {
                    let rev = reverse_bits(cw, l as u32);
                    let step = 1usize << l;
                    let mut i = rev as usize;
                    while i < table.len() {
                        table[i] = (sym as u16, l);
                        i += step;
                    }
                }
            }
        }
        Ok(ReverseCodebook { first, offset, symbols, max_len, table })
    }

    /// Decode one symbol from an LSB-first bit reader.
    /// Returns (symbol, bits consumed).
    #[inline]
    pub fn decode(&self, reader: &mut crate::util::bitio::BitReader) -> Option<u16> {
        let peeked = reader.peek(TABLE_BITS);
        let (sym, l) = self.table[peeked as usize];
        if l > 0 {
            if reader.remaining() < l as u64 {
                return None;
            }
            reader.skip(l as u32);
            return Some(sym);
        }
        // Slow path: lengths > TABLE_BITS — canonical walk, MSB-first value
        // accumulated bit by bit (our stream stores reversed codewords, so
        // sequential bits arrive MSB-first).
        let mut v = 0u64;
        let mut l = 0usize;
        loop {
            v = (v << 1) | reader.read_bit()? as u64;
            l += 1;
            if l > self.max_len as usize {
                return None; // corrupt stream
            }
            if l > self.first.len().saturating_sub(2) {
                return None;
            }
            let fl = self.first[l];
            let cnt = (self.offset.get(l + 1).copied().unwrap_or(0)
                - self.offset[l]) as u64;
            if v >= fl && v < fl + cnt {
                let idx = self.offset[l] as u64 + (v - fl);
                return Some(self.symbols[idx as usize]);
            }
        }
    }
}

#[inline]
fn reverse_bits(v: u64, n: u32) -> u64 {
    if n == 0 {
        0
    } else {
        v.reverse_bits() >> (64 - n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::tree::build_lengths;
    use crate::util::bitio::{BitReader, BitWriter};
    use crate::util::prng::Rng;

    #[test]
    fn prefix_free_property() {
        let freq: Vec<u64> = (1..=64).map(|i| i * i).collect();
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        // no codeword (in MSB-first space) is a prefix of another
        let mut entries: Vec<(u64, u8)> = (0..64)
            .filter(|&s| book.len[s] > 0)
            .map(|s| (reverse_bits(book.code[s], book.len[s] as u32), book.len[s]))
            .collect();
        entries.sort();
        for i in 0..entries.len() {
            for j in i + 1..entries.len() {
                let (ci, li) = entries[i];
                let (cj, lj) = entries[j];
                if li <= lj {
                    assert_ne!(cj >> (lj - li), ci, "prefix violation");
                }
            }
        }
    }

    #[test]
    fn encode_decode_single_symbols() {
        let freq: Vec<u64> = vec![10, 20, 30, 40, 0, 5];
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        for sym in [0u16, 1, 2, 3, 5] {
            let mut w = BitWriter::new();
            let (c, l) = book.lookup(sym);
            w.write(c, l);
            let (words, bits) = w.finish();
            let mut r = BitReader::new(&words, bits);
            assert_eq!(rev.decode(&mut r), Some(sym));
        }
    }

    #[test]
    fn roundtrip_stream_random() {
        let mut rng = Rng::new(8);
        let dict = 1024;
        let freq: Vec<u64> = (0..dict)
            .map(|i| {
                let z = (i as f64 - 512.0) / 30.0;
                ((1e5 * (-z * z / 2.0).exp()) as u64).max(if i % 37 == 0 { 1 } else { 0 })
            })
            .collect();
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        let present: Vec<u16> =
            (0..dict).filter(|&i| freq[i as usize] > 0).map(|i| i as u16).collect();
        let syms: Vec<u16> =
            (0..20_000).map(|_| present[rng.below(present.len() as u64) as usize]).collect();
        let mut w = BitWriter::new();
        for &s in &syms {
            let (c, l) = book.lookup(s);
            w.write(c, l);
        }
        let (words, bits) = w.finish();
        let mut r = BitReader::new(&words, bits);
        for &s in &syms {
            assert_eq!(rev.decode(&mut r), Some(s));
        }
    }

    #[test]
    fn long_codes_use_slow_path() {
        // Fibonacci freqs make codewords longer than TABLE_BITS.
        let mut freq = vec![0u64; 32];
        let (mut a, mut b) = (1u64, 2u64);
        for f in freq.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let lengths = build_lengths(&freq);
        assert!(*lengths.iter().max().unwrap() as u32 > TABLE_BITS);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let rev = ReverseCodebook::from_lengths(&lengths).unwrap();
        let syms: Vec<u16> = (0..32u16).collect();
        let mut w = BitWriter::new();
        for &s in &syms {
            let (c, l) = book.lookup(s);
            w.write(c, l);
        }
        let (words, bits) = w.finish();
        let mut r = BitReader::new(&words, bits);
        for &s in &syms {
            assert_eq!(rev.decode(&mut r), Some(s), "symbol {s}");
        }
    }

    #[test]
    fn adaptive_repr_selection() {
        // short codes -> u32 repr
        let lengths = build_lengths(&[100, 100, 100, 100]);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        assert_eq!(book.repr_bits(), 32);
        let packed = book.packed_u32(0);
        assert_eq!(packed >> 24, book.len[0] as u32);
    }

    #[test]
    fn serde_via_lengths() {
        let freq: Vec<u64> = (1..=100).collect();
        let lengths = build_lengths(&freq);
        let book = CanonicalCodebook::from_lengths(&lengths).unwrap();
        let restored = CanonicalCodebook::from_bytes(&book.to_bytes()).unwrap();
        assert_eq!(book.code, restored.code);
        assert_eq!(book.len, restored.len);
    }
}
